//! Quickstart: load the AOT artifacts, run one ASTRA prefill across 4
//! simulated devices, and compare against the single-device baseline.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! Demonstrates the whole public API surface in ~40 lines: artifact
//! loading, cluster construction, prefill, and the latency/communication
//! report.

use anyhow::Result;
use astra::config::RunConfig;
use astra::coordinator::Cluster;
use astra::tensor::Tensor;
use astra::util::rng::Rng;

fn main() -> Result<()> {
    let config = RunConfig { bandwidth_mbps: 50.0, ..RunConfig::default() };
    // PJRT backend if the XLA runtime is available, else pure-rust native.
    let cluster = match Cluster::load("artifacts".as_ref(), config.clone(), true) {
        Ok(c) => c,
        Err(_) => Cluster::load("artifacts".as_ref(), config, false)?,
    };
    let meta = &cluster.artifact.meta;
    println!(
        "loaded AstraFormer: {} layers, d={}, T={}, {} devices, G={}, K={}",
        meta.n_layers, meta.d_model, meta.seq_len, meta.n_devices, meta.groups,
        meta.codebook_size
    );

    // synthetic "image": T patches of patch_dim features
    let mut rng = Rng::new(42);
    let mut patches = Tensor::zeros(&[meta.seq_len, meta.patch_dim]);
    rng.fill_normal(&mut patches.data);

    let out = cluster.prefill(&patches)?;
    println!("\nASTRA prefill over {} devices @ 50 Mbps:", meta.n_devices);
    println!("  virtual latency : {:.2} ms", out.report.latency_s * 1e3);
    println!("  compute / comm  : {:.2} / {:.2} ms",
        out.report.compute_s * 1e3, out.report.comm_s * 1e3);
    println!("  wire payload    : {:.1} kbit in {} messages ({} bits/token/block)",
        out.report.payload_bits / 1e3, out.report.messages, out.report.bits_per_token_block);

    let (baseline, wall) = cluster.prefill_single_device(&patches)?;
    println!("\nsingle-device baseline: {:.2} ms (host wall time)", wall * 1e3);
    println!("max |ASTRA - baseline| logit dev: {:.4} (VQ approximation error)",
        astra::tensor::max_abs_diff(&out.logits, &baseline));

    let pred = |t: &Tensor| t.data.iter().enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).unwrap().0;
    println!("predicted class: ASTRA={} baseline={}", pred(&out.logits), pred(&baseline));
    Ok(())
}
