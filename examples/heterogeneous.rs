//! Heterogeneous-devices study (paper §4.2 + Appendix D): skew the token
//! partition toward "stronger" devices and observe (a) FPAR rising with
//! imbalance, (b) output fidelity to the full-precision baseline improving
//! with FPAR — the paper's Table 9 correlation — on the live cluster.
//!
//!     cargo run --release --example heterogeneous

use anyhow::Result;
use astra::config::RunConfig;
use astra::coordinator::{Cluster, TokenPartition};
use astra::model::shape::{TransformerShape, VqSetting};
use astra::parallel::cost::{DeviceModel, FleetProfile};
use astra::parallel::plan::Planner;
use astra::parallel::strategies::{Strategy, StrategyKind};
use astra::tensor::{max_abs_diff, Tensor};
use astra::util::rng::Rng;

fn main() -> Result<()> {
    // speeds of a mixed fleet: one workstation, one laptop, two SBCs
    let fleets: Vec<(&str, Vec<f64>)> = vec![
        ("homogeneous", vec![1.0, 1.0, 1.0, 1.0]),
        ("mild skew", vec![2.0, 1.5, 1.0, 1.0]),
        ("strong skew", vec![4.0, 2.0, 1.0, 0.5]),
        ("one big", vec![13.0, 1.0, 1.0, 1.0]),
    ];

    // --- serving cost model: what the straggler-free planner would do ---
    // one modeled request (prefill + 32 batched decode steps) at 100 Mbps:
    // even split priced like the legacy engine vs the planner's argmin
    // over profile-weighted and hybrid TP/SP candidates — the same
    // decision `serve-cb --device-speeds ... --replan-every S` makes live
    let planner = Planner::new(
        TransformerShape::paper_encoder(1024),
        Strategy::new(StrategyKind::Astra { vq: VqSetting::new(16, 1024) }, 4),
        DeviceModel::paper_1660ti(),
        0.0006,
    );
    println!(
        "{:<14}{:>12}{:>12}{:>9}{:>26}",
        "fleet", "even (s)", "planned (s)", "speedup", "chosen plan"
    );
    for (name, speeds) in &fleets {
        let profile = FleetProfile::from_speeds(DeviceModel::paper_1660ti(), speeds);
        let even = planner.score_index(0, &profile, 100.0);
        let plan = planner.plan(&profile, 100.0);
        println!(
            "{:<14}{:>12.3}{:>12.3}{:>9.2}{:>26}",
            name,
            even,
            plan.modeled_latency_s,
            even / plan.modeled_latency_s,
            plan.label
        );
    }
    println!();

    println!("{:<14}{:>22}{:>10}{:>14}", "fleet", "token split", "FPAR", "logit dev");
    for (name, speeds) in fleets {
        // probe seq_len from the artifact
        let probe = Cluster::load("artifacts".as_ref(), RunConfig::default(), false)?;
        let t = probe.artifact.meta.seq_len;
        let part = TokenPartition::proportional(t, &speeds)?;
        let config = RunConfig { token_split: part.sizes.clone(), ..RunConfig::default() };
        let cluster = Cluster::load("artifacts".as_ref(), config, false)?;
        let meta = &cluster.artifact.meta;
        let mut rng = Rng::new(3);
        let mut x = Tensor::zeros(&[meta.seq_len, meta.patch_dim]);
        rng.fill_normal(&mut x.data);
        let out = cluster.prefill(&x)?;
        let (base, _) = cluster.prefill_single_device(&x)?;
        println!(
            "{:<14}{:>22}{:>10.4}{:>14.4}",
            name,
            format!("{:?}", part.sizes),
            out.report.fpar,
            max_abs_diff(&out.logits, &base)
        );
    }
    println!("\n(higher FPAR -> more attention at full precision -> outputs closer");
    println!(" to the baseline; Appendix D Table 9 reports the same correlation)");
    Ok(())
}
