//! Figure-6 reproduction at paper scale: request throughput under a 600 s
//! Markovian bandwidth trace (20-100 Mbps), batch size 1, comparing
//! single-device, SP, BP and ASTRA. Prints the per-10 s completion bars
//! the paper plots.
//!
//!     cargo run --release --example dynamic_network -- [--seed 42]

use anyhow::Result;
use astra::comm::trace::BandwidthTrace;
use astra::model::shape::{TransformerShape, VqSetting};
use astra::parallel::strategies::{Strategy, StrategyKind};
use astra::server::engine::ServeEngine;
use astra::server::Request;
use astra::sim::latency::SimParams;
use astra::util::cli::Args;
use astra::util::rng::Rng;

fn main() -> Result<()> {
    let args = Args::from_env(&[])?;
    let seed = args.usize_or("seed", 42)? as u64;
    let mut rng = Rng::new(seed);
    let trace = BandwidthTrace::markovian(&mut rng, 20.0, 100.0, 9, 1.0, 600.0);
    println!("600 s Markov bandwidth trace, mean {:.1} Mbps", trace.mean_mbps());

    let shape = TransformerShape::paper_encoder(1024);
    let params = SimParams::paper_encoder();
    let subjects = vec![
        Strategy::new(StrategyKind::SingleDevice, 1),
        Strategy::new(StrategyKind::SequenceParallel, 4),
        Strategy::new(StrategyKind::BlockParallel { n_b: 1, sp_variant: false }, 4),
        Strategy::new(StrategyKind::Astra { vq: VqSetting::new(16, 1024) }, 4),
    ];
    let mut single_rate = 0.0;
    for s in subjects {
        let reqs: Vec<Request> = (0..200_000)
            .map(|i| Request { id: i, arrival_s: 0.0, tokens: 1024 })
            .collect();
        let mut engine = ServeEngine::new(shape, s, params.clone(), trace.clone());
        let report = engine.serve_stream(reqs, 600.0);
        if matches!(s.kind, StrategyKind::SingleDevice) {
            single_rate = report.throughput;
        }
        println!("\n{} — {} resolved ({:.2} req/s, {:.2}x single)",
            s.name(), report.completed, report.throughput,
            report.throughput / single_rate.max(1e-9));
        // ascii bars, one char per 2 completions, one row per 60 s
        for (i, w) in report.windows.chunks(6).enumerate() {
            let total: usize = w.iter().sum();
            println!("  {:>4}s |{}", i * 60, "#".repeat(total / 2));
        }
    }
    Ok(())
}
