//! End-to-end serving driver (the DESIGN.md validation experiment):
//! load the trained small model from artifacts/, serve a batched synthetic
//! request stream through the full stack — dynamic batcher → coordinator →
//! VQ codec → simulated network → PJRT/native blocks → DCT head — and
//! report latency percentiles, throughput, and measured bits-per-token.
//!
//!     make artifacts && cargo run --release --example serve_cluster -- \
//!         [--requests 32] [--bandwidth 50] [--devices 4] [--native]

use std::time::Instant;

use anyhow::Result;
use astra::comm::trace::BandwidthTrace;
use astra::config::RunConfig;
use astra::coordinator::Cluster;
use astra::model::shape::{TransformerShape, VqSetting};
use astra::parallel::strategies::{Strategy, StrategyKind};
use astra::server::live::{live_arrivals, serve_live};
use astra::server::{Batcher, CbConfig, CbEngine, Request};
use astra::sim::latency::SimParams;
use astra::tensor::Tensor;
use astra::util::cli::Args;
use astra::util::rng::Rng;
use astra::util::stats::Summary;

fn main() -> Result<()> {
    let args = Args::from_env(&["native"])?;
    let n_requests = args.usize_or("requests", 24)?;
    let config = RunConfig {
        bandwidth_mbps: args.f64_or("bandwidth", 50.0)?,
        n_devices: args.usize_or("devices", 4)?,
        ..RunConfig::default()
    };
    let use_pjrt = !args.flag("native");
    let cluster = match Cluster::load("artifacts".as_ref(), config.clone(), use_pjrt) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("PJRT unavailable ({e}); using native backend");
            Cluster::load("artifacts".as_ref(), config, false)?
        }
    };
    let meta = cluster.artifact.meta.clone();

    // open-loop Poisson arrivals into the dynamic batcher (batch=1 service,
    // the paper's Fig-6 setting)
    let mut rng = Rng::new(cluster.config.seed);
    let rate = 8.0; // req/s of *virtual* time
    let mut arrivals = Vec::new();
    let mut t = 0.0;
    for id in 0..n_requests as u64 {
        t += rng.exp(rate);
        arrivals.push(Request { id, arrival_s: t, tokens: meta.seq_len });
    }
    let mut batcher = Batcher::new(1, 0.0);

    let mut vclock = 0.0f64; // virtual serving clock
    let mut latency = Summary::new();
    let mut queue_wait = Summary::new();
    let mut payload_bits = 0.0;
    let wall0 = Instant::now();
    let mut served = 0usize;
    let mut pending = arrivals.into_iter().peekable();
    while pending.peek().is_some() || !batcher.is_empty() {
        while let Some(r) = pending.peek() {
            if r.arrival_s <= vclock {
                batcher.push(pending.next().unwrap());
            } else {
                break;
            }
        }
        let batch = batcher.next_batch(vclock, true);
        if batch.is_empty() {
            if let Some(r) = pending.peek() {
                vclock = r.arrival_s;
            }
            continue;
        }
        for req in batch {
            let start = vclock.max(req.arrival_s);
            queue_wait.add(start - req.arrival_s);
            let mut x = Tensor::zeros(&[meta.seq_len, meta.patch_dim]);
            rng.fill_normal(&mut x.data);
            let out = cluster.prefill(&x)?;
            payload_bits += out.report.payload_bits;
            vclock = start + out.report.latency_s;
            latency.add(vclock - req.arrival_s);
            served += 1;
        }
    }
    let wall = wall0.elapsed().as_secs_f64();

    println!("== serve_cluster: {} requests, {} devices, {} Mbps, backend={} ==",
        served, cluster.config.n_devices, cluster.config.bandwidth_mbps,
        if use_pjrt { "PJRT" } else { "native" });
    println!("virtual latency  mean {:.2} ms  p50 {:.2}  p95 {:.2}",
        latency.mean() * 1e3, latency.p50() * 1e3, latency.p95() * 1e3);
    println!("queue wait       mean {:.2} ms", queue_wait.mean() * 1e3);
    println!("virtual throughput {:.2} req/s over {:.2} s", served as f64 / vclock, vclock);
    println!("host wall          {:.2} s ({:.2} req/s single-core)", wall, served as f64 / wall);
    println!("wire payload       {:.2} Mbit total ({} bits/token/block)",
        payload_bits / 1e6, meta.bits_per_token);

    // ---- continuous batching vs batch-1 FIFO on the cost model ----
    // Same arrival process, served by the CbEngine at this cluster's shape
    // and bandwidth: shows what slot-based admission would buy this
    // deployment (cargo run --release --example serve_cluster -- --slots 8).
    let slots = args.usize_or("slots", 8)?;
    let shape = TransformerShape {
        n_layers: meta.n_layers,
        d_model: meta.d_model,
        n_heads: meta.n_heads,
        d_ff: meta.d_ff,
        seq_len: meta.seq_len,
        elem_bytes: 4,
    };
    let strategy = Strategy::new(
        StrategyKind::Astra { vq: VqSetting::new(meta.groups, meta.codebook_size) },
        cluster.config.n_devices,
    );
    let trace = BandwidthTrace::constant(cluster.config.bandwidth_mbps, 1e9);
    let horizon = 60.0;
    let cfg = CbConfig { max_slots: slots, max_batch: slots, ..CbConfig::default() };
    println!("\n== cost-model projection: batch-1 FIFO vs continuous batching ==");
    for (mode, cfg) in [("fifo-b1", cfg.clone().batch1()), ("cont-batch", cfg)] {
        let mut engine = CbEngine::new(
            shape, strategy, SimParams::paper_encoder(), trace.clone(), cfg);
        let mut arr_rng = Rng::new(cluster.config.seed);
        let mut r = engine.serve_poisson(&mut arr_rng, rate, horizon);
        println!(
            "{mode:<12} {:>5} done {:>5} censored  p50 {:.0} ms  p99 {:.0} ms  TTFT p50 {:.0} ms",
            r.completed, r.censored,
            r.latency.p50() * 1e3, r.latency.p99() * 1e3, r.ttft.p50() * 1e3
        );
    }

    // ---- live continuous batching on a synthetic tiny decoder ----
    // The projection above only prices work; this executes it: real
    // DecodeSessions (variable-length prompt replay into mixed-precision
    // KV caches, greedy decode) driven by the same slot scheduler, on an
    // in-memory decoder bundle — no artifacts needed.
    let n = cluster.config.n_devices.max(1);
    let dec_shape = TransformerShape {
        n_layers: 2,
        d_model: 32,
        n_heads: 4,
        d_ff: 64,
        seq_len: 8 * n,
        elem_bytes: 4,
    };
    let dec = Cluster::synthetic_decoder(
        &dec_shape,
        64,
        VqSetting::new(4, 16),
        RunConfig { n_devices: n, ..RunConfig::default() },
        cluster.config.seed,
    )?;
    let live_cfg =
        CbConfig { max_slots: slots, max_batch: slots, decode_tokens: 8, ..CbConfig::default() };
    let mut lrng = Rng::new(cluster.config.seed);
    let arrivals = live_arrivals(&mut lrng, rate, 10.0, dec_shape.seq_len);
    let live = serve_live(
        &dec,
        live_cfg,
        SimParams::paper_encoder(),
        trace.clone(),
        arrivals,
        1e4,
    )?;
    let mut lr = live.report;
    println!("\n== live continuous batching (synthetic {n}-device decoder, T<={}) ==",
        dec_shape.seq_len);
    println!(
        "{} completed / {} censored   p50 {:.1} ms   {} real decode steps, host {:.1} ms",
        lr.completed, lr.censored, lr.latency.p50() * 1e3,
        live.live_steps, live.host_compute_s * 1e3
    );
    if let Some((id, toks)) = live.generations.iter().find(|(_, t)| !t.is_empty()) {
        let k = toks.len().min(8);
        println!("sample generation (request {id}): {:?}", &toks[..k]);
    }
    Ok(())
}
