//! Figure-1-style bandwidth sweep on the *live* cluster: the same request
//! replayed at every bandwidth, with ASTRA's measured VQ payloads against
//! a dense (SP-style full-precision exchange) what-if.
//!
//!     cargo run --release --example bandwidth_sweep -- [--native]

use anyhow::Result;
use astra::comm::message::Message;
use astra::config::RunConfig;
use astra::coordinator::Cluster;
use astra::tensor::Tensor;
use astra::util::cli::Args;
use astra::util::rng::Rng;

fn main() -> Result<()> {
    let args = Args::from_env(&["native"])?;
    let use_pjrt = !args.flag("native");
    let bandwidths = args.f64_list_or("bandwidths", &[1.0, 5.0, 10.0, 20.0, 50.0, 100.0])?;

    println!("{:<10}{:>14}{:>14}{:>14}{:>12}",
        "Mbps", "astra(ms)", "comm(ms)", "dense-eq(ms)", "speedup*");
    let mut first: Option<f64> = None;
    for bw in bandwidths {
        let config = RunConfig { bandwidth_mbps: bw, ..RunConfig::default() };
        let cluster = match Cluster::load("artifacts".as_ref(), config.clone(), use_pjrt) {
            Ok(c) => c,
            Err(_) => Cluster::load("artifacts".as_ref(), config, false)?,
        };
        let meta = &cluster.artifact.meta;
        let mut rng = Rng::new(1);
        let mut x = Tensor::zeros(&[meta.seq_len, meta.patch_dim]);
        rng.fill_normal(&mut x.data);
        let out = cluster.prefill(&x)?;
        // what-if: the same exchange carrying dense f32 embeddings
        let chunk = Tensor::zeros(&[meta.seq_len / meta.n_devices, meta.d_model]);
        let dense_msg = Message::dense(0, 0, &chunk)?;
        let dense_comm_s = meta.n_layers as f64
            * (dense_msg.wire_bytes() as f64 * 8.0 / (bw * 1e6) + cluster.config.latency_s);
        let dense_total = out.report.compute_s + dense_comm_s;
        let base = *first.get_or_insert(out.report.latency_s);
        println!(
            "{:<10}{:>14.2}{:>14.2}{:>14.2}{:>12.2}",
            bw,
            out.report.latency_s * 1e3,
            out.report.comm_s * 1e3,
            dense_total * 1e3,
            dense_total / out.report.latency_s
        );
        let _ = base;
    }
    println!("\n*speedup = dense-exchange what-if / measured ASTRA latency");
    println!("(paper Fig 1: ASTRA stays flat as bandwidth drops; dense exchange blows up)");
    Ok(())
}
