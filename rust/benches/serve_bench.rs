//! Serving benchmarks: batch-1 FIFO vs continuous batching across Poisson
//! rates and bandwidth traces, plus the acceptance evidence for the
//! continuous-batching engine (>= 2x completed-request throughput at
//! saturating load with max_slots >= 8 under a constant 100 Mbps trace).

use astra::comm::trace::BandwidthTrace;
use astra::model::shape::{TransformerShape, VqSetting};
use astra::parallel::strategies::{Strategy, StrategyKind};
use astra::server::scheduler::{CbConfig, CbEngine};
use astra::server::Request;
use astra::sim::latency::SimParams;
use astra::util::bench::{black_box, header, Bench};
use astra::util::rng::Rng;

fn engine(trace: BandwidthTrace, cfg: CbConfig) -> CbEngine {
    CbEngine::new(
        TransformerShape::paper_encoder(1024),
        Strategy::new(StrategyKind::Astra { vq: VqSetting::new(16, 1024) }, 4),
        SimParams::paper_encoder(),
        trace,
        cfg,
    )
}

fn saturating(n: usize) -> Vec<Request> {
    (0..n as u64).map(|i| Request { id: i, arrival_s: 0.0, tokens: 1024 }).collect()
}

fn main() {
    header();
    let mut b = Bench::new("serve");
    let cfg = CbConfig::default();
    let const100 = BandwidthTrace::constant(100.0, 1e9);
    let mut rng = Rng::new(7);
    let markov = BandwidthTrace::markovian(&mut rng, 20.0, 100.0, 9, 1.0, 120.0);

    for (tname, trace) in [("const100", const100.clone()), ("markov", markov)] {
        for (mode, cfg) in [("fifo1", cfg.clone().batch1()), ("cb8", cfg.clone())] {
            let trace = trace.clone();
            b.run(&format!("{mode}_{tname}_saturating_120s"), move || {
                let mut e = engine(trace.clone(), cfg.clone());
                black_box(e.serve_stream(saturating(4000), 120.0).completed)
            });
        }
        // open-loop Poisson at a rate between the two capacities
        for (mode, cfg) in [("fifo1", cfg.clone().batch1()), ("cb8", cfg.clone())] {
            let trace = trace.clone();
            b.run(&format!("{mode}_{tname}_poisson8_120s"), move || {
                let mut e = engine(trace.clone(), cfg.clone());
                let mut rng = Rng::new(42);
                black_box(e.serve_poisson(&mut rng, 8.0, 120.0).completed)
            });
        }
    }
    b.finish();

    // acceptance evidence (also asserted by the unit tests in
    // src/server/scheduler.rs, continuous_batching_doubles_throughput_vs_batch1)
    let r1 = engine(const100.clone(), cfg.clone().batch1()).serve_stream(saturating(4000), 120.0);
    let r8 = engine(const100, cfg).serve_stream(saturating(4000), 120.0);
    println!(
        "\nsaturating const-100Mbps: fifo-b1 {} vs cont-batch(8) {} completed = {:.2}x",
        r1.completed,
        r8.completed,
        r8.completed as f64 / r1.completed.max(1) as f64
    );
}
