//! Serving benchmarks: batch-1 FIFO vs continuous batching across Poisson
//! rates and bandwidth traces, plus the acceptance evidence for the
//! continuous-batching engine (>= 2x completed-request throughput at
//! saturating load with max_slots >= 8 under a constant 100 Mbps trace).
//!
//! `--json [--out BENCH_serve.json]` skips the wall-clock timing and emits
//! *modeled* metrics instead — virtual-clock p50/p95/TTFT/ITL/throughput
//! on fixed-seed traces, bit-reproducible on any machine — for the CI
//! regression gate (`astra bench-gate`).

use astra::comm::trace::BandwidthTrace;
use astra::model::shape::{TransformerShape, VqSetting};
use astra::parallel::strategies::{Strategy, StrategyKind};
use astra::server::cluster::{ClusterEngine, RouteKind};
use astra::server::policy::PolicyKind;
use astra::server::scheduler::{CbConfig, CbEngine};
use astra::server::Request;
use astra::sim::latency::SimParams;
use astra::util::bench::{black_box, header, Bench, MetricSet};
use astra::util::cli::Args;
use astra::util::rng::Rng;
use astra::workload::{ArrivalProcess, PromptLengths, WorkloadSpec};

fn engine(trace: BandwidthTrace, cfg: CbConfig) -> CbEngine {
    CbEngine::new(
        TransformerShape::paper_encoder(1024),
        Strategy::new(StrategyKind::Astra { vq: VqSetting::new(16, 1024) }, 4),
        SimParams::paper_encoder(),
        trace,
        cfg,
    )
}

fn saturating(n: usize) -> Vec<Request> {
    (0..n as u64).map(|i| Request { id: i, arrival_s: 0.0, tokens: 1024 }).collect()
}

/// Deterministic modeled metrics on fixed-seed traces (see module docs).
fn emit_json(out: &str) {
    enum Load {
        Saturating(usize),
        Poisson(f64),
    }
    let mut m = MetricSet::new("serve");
    let const100 = BandwidthTrace::constant(100.0, 1e9);
    let mut markov_rng = Rng::new(7);
    let markov = BandwidthTrace::markovian(&mut markov_rng, 20.0, 100.0, 9, 1.0, 60.0);
    let base = CbConfig::default();
    let chunked = CbConfig { prefill_chunk_tokens: 256, ..CbConfig::default() };
    // radix prefix reuse: 4 prompt streams over saturating identical-length
    // requests, so most admissions attach to shared blocks
    let prefixed = CbConfig {
        prefix_cache: true,
        prompt_groups: 4,
        kv_block_tokens: 64,
        seed: 11,
        prompt_vocab: 512,
        ..CbConfig::default()
    };
    // bandwidth-priced swap preemption: a cap around two full budgets with
    // long decode growth forces evictions, and the fast host link makes
    // the round trip beat recompute
    let swap = {
        let probe = engine(
            const100.clone(),
            CbConfig { decode_tokens: 512, ..CbConfig::default() },
        );
        CbConfig {
            decode_tokens: 512,
            kv_cap_bytes: 2 * probe.kv_projection(1024) + probe.kv_step_bytes(),
            swap_bandwidth_mbps: 1e5,
            ..CbConfig::default()
        }
    };
    // two-class mixed trace (odd ids carry a tight 1.5 s deadline, even
    // ids are effectively deadline-free): emitted twice, FIFO vs the
    // slo-class policy, so the per-class attainment/p95 keys pin both the
    // baseline behavior and the policy's win
    let two_classes = vec![1e9, 1.5];
    let classed_fifo = CbConfig { classes: two_classes.clone(), ..CbConfig::default() };
    let classed_slo = CbConfig {
        policy: PolicyKind::SloClass,
        classes: two_classes,
        ..CbConfig::default()
    };
    let cases: Vec<(&str, BandwidthTrace, CbConfig, Load)> = vec![
        ("fifo1_const100_sat", const100.clone(), base.clone().batch1(), Load::Saturating(2000)),
        ("cb8_const100_sat", const100.clone(), base.clone(), Load::Saturating(2000)),
        ("cb8_markov_sat", markov, base.clone(), Load::Saturating(2000)),
        ("cb8_const100_poisson8", const100.clone(), base, Load::Poisson(8.0)),
        ("cb8_chunk256_sat", const100.clone(), chunked.clone(), Load::Saturating(2000)),
        ("cb8_chunk256_poisson8", const100.clone(), chunked, Load::Poisson(8.0)),
        ("cb8_prefix_g4_sat", const100.clone(), prefixed, Load::Saturating(2000)),
        ("cb8_swap_d512_sat", const100.clone(), swap, Load::Saturating(200)),
        ("cb8_classes2_fifo_sat", const100.clone(), classed_fifo, Load::Saturating(200)),
        ("cb8_classes2_slo_sat", const100.clone(), classed_slo, Load::Saturating(200)),
    ];
    for (name, trace, cfg, load) in cases {
        let mut e = engine(trace, cfg);
        let mut r = match load {
            Load::Saturating(n) => e.serve_stream(saturating(n), 60.0),
            Load::Poisson(rate) => e.serve_poisson(&mut Rng::new(42), rate, 60.0),
        };
        m.push(name, "completed", r.completed as f64);
        m.push(name, "throughput", r.throughput);
        m.push(name, "p50", r.latency.p50());
        m.push(name, "p95", r.latency.p95());
        m.push(name, "ttft_p50", r.ttft.p50());
        m.push(name, "itl_p95", r.itl.p95());
        m.push(name, "prefill_chunks", r.prefill_chunks as f64);
        m.push(name, "prefix_hit_rate", r.prefix_hit_rate());
        m.push(name, "swap_bytes", r.swap_bytes as f64);
        // per-class SLO metrics (classed scenarios only): attainment
        // regresses downward in the gate, latencies upward
        for c in &mut r.classes {
            m.push(name, &format!("class{}_slo_attainment", c.class), c.slo_attainment());
            m.push(name, &format!("class{}_p95", c.class), c.latency.p95());
        }
    }
    // fleet scenarios: 4 actorized replicas under the cluster event loop,
    // grouped prompts arriving staggered (an all-at-t=0 wave would route
    // every request before any shadow digest is warm), round-robin vs
    // prefix-affinity on the same trace — the affinity win shows up as a
    // higher fleet_hit_rate at the same completion count. 5 prompt groups
    // over 4 replicas: coprime, so sequential-id round-robin genuinely
    // sprays each group instead of accidentally clustering it
    let fleet_cfg = CbConfig {
        prefix_cache: true,
        prompt_groups: 5,
        kv_block_tokens: 64,
        seed: 11,
        prompt_vocab: 512,
        ..CbConfig::default()
    };
    let staggered: Vec<Request> = (0..400u64)
        .map(|i| Request { id: i, arrival_s: i as f64 * 0.02, tokens: 1024 })
        .collect();
    let fleet_routes = [
        ("fleet4_rr_sat", RouteKind::RoundRobin),
        ("fleet4_affinity_sat", RouteKind::PrefixAffinity),
    ];
    for (name, route) in fleet_routes {
        let engines: Vec<CbEngine> =
            (0..4).map(|_| engine(const100.clone(), fleet_cfg.clone())).collect();
        let mut fleet = ClusterEngine::new(engines, route);
        let r = fleet.serve_stream(staggered.clone(), 60.0).expect("model fleet serve");
        m.push(name, "completed", r.completed() as f64);
        m.push(name, "fleet_throughput", r.fleet_throughput());
        m.push(name, "fleet_p95", r.fleet_p95());
        m.push(name, "fleet_hit_rate", r.fleet_hit_rate());
        m.push(name, "load_skew", r.load_skew());
    }
    // cancel-heavy bursty workload: Markov-modulated arrival bursts (lo
    // 1/s, hi 30/s) against 2.5 s client patience on a 3-slot engine, so
    // queued requests abandon during bursts and mid-decode sessions
    // cancel once their token stream stalls. wasted_decode_tokens and
    // p95_time_to_token both regress *upward* in the gate: a scheduler
    // change that keeps decoding for abandoned clients, or stretches
    // per-token delivery tails, fails here even if throughput holds
    let cancel_cfg = CbConfig {
        max_slots: 3,
        max_batch: 4,
        decode_tokens: 24,
        seed: 9,
        patience_s: 2.5,
        patience_spread: 1.0,
        ..CbConfig::default()
    };
    let cancel_spec = WorkloadSpec {
        seed: 9,
        horizon_s: 20.0,
        process: ArrivalProcess::MarkovBursts {
            lo_rate: 1.0,
            hi_rate: 30.0,
            states: 6,
            dwell_s: 1.0,
        },
        prompts: PromptLengths::Fixed(1024),
        tenant_weights: Vec::new(),
    };
    let name = "cb3_bursty_cancel";
    let mut e = engine(const100, cancel_cfg);
    let mut r = e.serve_stream(cancel_spec.generate(), 30.0);
    m.push(name, "completed", r.completed as f64);
    m.push(name, "throughput", r.throughput);
    m.push(name, "cancelled", r.cancelled as f64);
    m.push(name, "wasted_decode_tokens", r.wasted_decode_tokens as f64);
    m.push(name, "p95_time_to_token", r.time_to_token.p95());
    // heterogeneous 4/2/1/0.5 fleet under the Markov bandwidth trace:
    // profile-weighted pricing with the t=0 plan pinned (static) vs
    // online re-planning every 5 virtual seconds. Gate directions:
    // completed is an exact pin like every completed metric; p95 and
    // replans both regress *upward* — longer tails or plan churn fail
    // the gate even if throughput holds
    let hetero_static =
        CbConfig { device_speeds: vec![4.0, 2.0, 1.0, 0.5], ..CbConfig::default() };
    let hetero_replan = CbConfig { replan_every_s: 5.0, ..hetero_static.clone() };
    let mut hetero_rng = Rng::new(7);
    let hetero_trace = BandwidthTrace::markovian(&mut hetero_rng, 20.0, 100.0, 9, 1.0, 60.0);
    let hetero_cases =
        [("cb8_hetero_static", hetero_static), ("cb8_hetero_replan", hetero_replan)];
    for (name, cfg) in hetero_cases {
        let mut e = engine(hetero_trace.clone(), cfg);
        let mut r = e.serve_stream(saturating(2000), 60.0);
        m.push(name, "completed", r.completed as f64);
        m.push(name, "p95", r.latency.p95());
        m.push(name, "replans", r.replans as f64);
    }
    m.write(out).expect("writing bench metrics");
}

fn main() {
    // `cargo bench` forwards a libtest-style `--bench` flag to the binary
    let args = Args::from_env(&["json", "bench"]).expect("parsing bench args");
    if args.flag("json") {
        emit_json(&args.get_or("out", "BENCH_serve.json"));
        return;
    }
    header();
    let mut b = Bench::new("serve");
    let cfg = CbConfig::default();
    let const100 = BandwidthTrace::constant(100.0, 1e9);
    let mut rng = Rng::new(7);
    let markov = BandwidthTrace::markovian(&mut rng, 20.0, 100.0, 9, 1.0, 120.0);

    for (tname, trace) in [("const100", const100.clone()), ("markov", markov)] {
        for (mode, cfg) in [("fifo1", cfg.clone().batch1()), ("cb8", cfg.clone())] {
            let trace = trace.clone();
            b.run(&format!("{mode}_{tname}_saturating_120s"), move || {
                let mut e = engine(trace.clone(), cfg.clone());
                black_box(e.serve_stream(saturating(4000), 120.0).completed)
            });
        }
        // open-loop Poisson at a rate between the two capacities
        for (mode, cfg) in [("fifo1", cfg.clone().batch1()), ("cb8", cfg.clone())] {
            let trace = trace.clone();
            b.run(&format!("{mode}_{tname}_poisson8_120s"), move || {
                let mut e = engine(trace.clone(), cfg.clone());
                let mut rng = Rng::new(42);
                black_box(e.serve_poisson(&mut rng, 8.0, 120.0).completed)
            });
        }
    }
    b.finish();

    // acceptance evidence (also asserted by the unit tests in
    // src/server/scheduler.rs, continuous_batching_doubles_throughput_vs_batch1)
    let r1 = engine(const100.clone(), cfg.clone().batch1()).serve_stream(saturating(4000), 120.0);
    let r8 = engine(const100, cfg).serve_stream(saturating(4000), 120.0);
    println!(
        "\nsaturating const-100Mbps: fifo-b1 {} vs cont-batch(8) {} completed = {:.2}x",
        r1.completed,
        r8.completed,
        r8.completed as f64 / r1.completed.max(1) as f64
    );
}
