//! Live-path benchmarks: real `DecodeSession` prefill replay and decode
//! steps on a synthetic decoder, and the end-to-end live
//! continuous-batching engine vs the pure cost-model run of the same
//! trace — the overhead of driving actual tensors through the scheduler.

use astra::comm::trace::BandwidthTrace;
use astra::config::RunConfig;
use astra::coordinator::decode::DecodeSession;
use astra::coordinator::Cluster;
use astra::model::shape::VqSetting;
use astra::model::TransformerShape;
use astra::server::live::{live_arrivals, live_engine, serve_live, synth_prompt};
use astra::server::scheduler::{CbConfig, ModelBackend};
use astra::sim::latency::SimParams;
use astra::util::bench::{black_box, header, Bench};
use astra::util::rng::Rng;

fn cluster() -> Cluster {
    let shape = TransformerShape {
        n_layers: 2,
        d_model: 32,
        n_heads: 4,
        d_ff: 64,
        seq_len: 32,
        elem_bytes: 4,
    };
    let config = RunConfig { n_devices: 4, ..RunConfig::default() };
    Cluster::synthetic_decoder(&shape, 64, VqSetting::new(4, 16), config, 5).unwrap()
}

fn main() {
    header();
    let cl = cluster();
    let meta = cl.artifact.meta.clone();
    let mut b = Bench::new("live");

    // variable-length prefill replay into a fresh mixed-precision cache
    for plen in [8usize, 32] {
        let prompt = synth_prompt(1, 1, plen, meta.vocab_size);
        let cl_ref = &cl;
        b.run(&format!("session_prefill_t{plen}"), move || {
            black_box(DecodeSession::new(cl_ref, &prompt).unwrap().len)
        });
    }

    // single decode step (the unit the scheduler amortizes); the session
    // is rebuilt whenever its budget fills
    let prompt = synth_prompt(1, 2, 32, meta.vocab_size);
    let mut sess = DecodeSession::with_budget(&cl, &prompt, 32 + 2048).unwrap();
    let cl_ref = &cl;
    let prompt_ref = &prompt;
    b.run("decode_step", move || {
        if sess.len == sess.s_max {
            sess = DecodeSession::with_budget(cl_ref, prompt_ref, 32 + 2048).unwrap();
        }
        black_box(sess.step().unwrap())
    });

    // end-to-end: the same fixed trace through the cost model alone vs
    // with real sessions attached
    let cfg = CbConfig { max_slots: 4, max_batch: 4, decode_tokens: 8, ..CbConfig::default() };
    let arrivals = live_arrivals(&mut Rng::new(9), 10.0, 3.0, meta.seq_len);
    let params = SimParams::paper_encoder();
    let trace = BandwidthTrace::constant(100.0, 1e9);
    {
        let cl_ref = &cl;
        let cfg = cfg.clone();
        let arrivals = arrivals.clone();
        let params = params.clone();
        let trace = trace.clone();
        b.run("serve_model_only", move || {
            let mut e = live_engine(cl_ref, cfg.clone(), params.clone(), trace.clone());
            black_box(
                e.serve_stream_with(&mut ModelBackend, arrivals.clone(), 1e4)
                    .unwrap()
                    .completed,
            )
        });
    }
    {
        let cl_ref = &cl;
        let cfg = cfg.clone();
        let arrivals = arrivals.clone();
        b.run("serve_live_sessions", move || {
            black_box(
                serve_live(
                    cl_ref,
                    cfg.clone(),
                    params.clone(),
                    trace.clone(),
                    arrivals.clone(),
                    1e4,
                )
                .unwrap()
                .report
                .completed,
            )
        });
    }
    b.finish();

    // headline numbers: live generation really happened
    let live = serve_live(
        &cl,
        cfg,
        SimParams::paper_encoder(),
        BandwidthTrace::constant(100.0, 1e9),
        arrivals,
        1e4,
    )
    .unwrap();
    println!(
        "\nlive run: {} completed, {} real decode steps, host compute {:.1} ms, \
         virtual {:.1} ms",
        live.report.completed,
        live.live_steps,
        live.host_compute_s * 1e3,
        live.report.model_time.total() * 1e3,
    );
}
