//! Live-path benchmarks: a tokens/sec microbenchmark suite over the
//! batch-fused decode path, plus the end-to-end live continuous-batching
//! engine vs the pure cost-model run of the same trace.
//!
//! Suite sections:
//!  * fused vs serial decode at batch 1/4/8 — one `step_batch` call (one
//!    batched GEMM per layer) against per-session `step` loops, with a
//!    per-layer time breakdown;
//!  * block attach vs import — the zero-copy arena attach path against
//!    the row-copy `import_rows` path, pinned bit-identical;
//!  * per-bit-width VQ index pack/unpack — the wire format hot loop;
//!  * serve-level runs (model-only, live batched, live `--serial-decode`).
//!
//! `--json [--out BENCH_live.json]` emits the CI metric file: modeled
//! scheduling numbers and generation checksums on fixed-seed traces are
//! bit-reproducible determinism pins; the tokens/sec and µs-per-op
//! numbers are wall-clock (noisy on shared runners) and ride the gate's
//! directional tolerance instead of the exact pins.

use std::time::Instant;

use astra::comm::trace::BandwidthTrace;
use astra::config::RunConfig;
use astra::coordinator::decode::{step_batch, DecodeSession};
use astra::coordinator::Cluster;
use astra::kv::arena::{BlockRows, KvArena};
use astra::model::shape::VqSetting;
use astra::model::TransformerShape;
use astra::server::live::{live_arrivals, live_engine, serve_live, synth_prompt};
use astra::server::scheduler::{CbConfig, ModelBackend};
use astra::sim::latency::SimParams;
use astra::util::bench::{black_box, header, Bench, MetricSet};
use astra::util::cli::Args;
use astra::util::rng::Rng;
use astra::vq::{pack_indices, unpack_indices};

fn cluster() -> Cluster {
    let shape = TransformerShape {
        n_layers: 2,
        d_model: 32,
        n_heads: 4,
        d_ff: 64,
        seq_len: 32,
        elem_bytes: 4,
    };
    let config = RunConfig { n_devices: 4, ..RunConfig::default() };
    Cluster::synthetic_decoder(&shape, 64, VqSetting::new(4, 16), config, 5).unwrap()
}

/// Checksum of per-session generations, the same fold the serve-level
/// metrics use — fused and serial decode must agree on it exactly.
fn generation_checksum(sessions: &[DecodeSession]) -> u64 {
    sessions
        .iter()
        .enumerate()
        .map(|(i, s)| {
            s.generated.iter().fold((i as u64 + 1).wrapping_mul(31), |acc, &t| {
                acc.wrapping_mul(131).wrapping_add(t as u64)
            }) % 1_000_000_007
        })
        .fold(0u64, |a, b| a.wrapping_add(b))
}

fn decode_sessions<'a>(cl: &'a Cluster, bs: usize, rounds: usize) -> Vec<DecodeSession<'a>> {
    let meta = &cl.artifact.meta;
    (0..bs)
        .map(|r| {
            let prompt = synth_prompt(2, r as u64 + 1, 8, meta.vocab_size);
            DecodeSession::builder(cl, &prompt).budget(8 + rounds).build().unwrap()
        })
        .collect()
}

/// Run `rounds` decode iterations over `bs` fresh sessions, fused or
/// serial; returns (wall seconds, generation checksum).
fn decode_run(cl: &Cluster, bs: usize, rounds: usize, serial: bool) -> (f64, u64) {
    let mut sessions = decode_sessions(cl, bs, rounds);
    let t0 = Instant::now();
    if serial {
        for _ in 0..rounds {
            for s in sessions.iter_mut() {
                s.step().unwrap();
            }
        }
    } else {
        for _ in 0..rounds {
            let mut refs: Vec<&mut DecodeSession> = sessions.iter_mut().collect();
            step_batch(&mut refs).unwrap();
        }
    }
    (t0.elapsed().as_secs_f64(), generation_checksum(&sessions))
}

/// Seal the donor's prompt into arena blocks; returns the arena, the
/// exported row data (for the import path), and the block geometry.
#[allow(clippy::type_complexity)]
fn sealed_blocks(
    cl: &Cluster,
    prompt: &[usize],
    block_tokens: usize,
) -> (KvArena, Vec<(usize, usize, Vec<(Vec<f32>, Vec<f32>)>)>) {
    let meta = &cl.artifact.meta;
    let mut donor = DecodeSession::builder(cl, prompt)
        .budget(prompt.len() + 4)
        .deferred()
        .positional()
        .build()
        .unwrap();
    donor.replay_range(0, prompt.len()).unwrap();
    let mut arena = KvArena::new();
    let mut exported = Vec::new();
    let mut lo = 0;
    while lo + block_tokens <= prompt.len() {
        let hi = lo + block_tokens;
        let layers = donor.export_rows(lo, hi).unwrap();
        exported.push((lo, hi, layers.clone()));
        let rows =
            BlockRows::new(lo, hi, layers, meta.n_heads, meta.d_model / meta.n_heads).unwrap();
        arena.insert((lo / block_tokens) as u64, 1, rows);
        lo = hi;
    }
    (arena, exported)
}

fn attach_session<'a>(
    cl: &'a Cluster,
    prompt: &[usize],
    arena: &KvArena,
    n_blocks: usize,
) -> DecodeSession<'a> {
    let mut s = DecodeSession::builder(cl, prompt)
        .budget(prompt.len() + 8)
        .deferred()
        .positional()
        .build()
        .unwrap();
    for b in 0..n_blocks {
        s.attach_block(arena.attach(b as u64).unwrap()).unwrap();
    }
    s
}

fn import_session<'a>(
    cl: &'a Cluster,
    prompt: &[usize],
    exported: &[(usize, usize, Vec<(Vec<f32>, Vec<f32>)>)],
) -> DecodeSession<'a> {
    let mut s = DecodeSession::builder(cl, prompt)
        .budget(prompt.len() + 8)
        .deferred()
        .positional()
        .build()
        .unwrap();
    for (lo, hi, layers) in exported {
        s.import_rows(*lo, *hi, layers).unwrap();
    }
    s
}

/// Deterministic pins + wall-clock suite metrics on fixed traces.
fn emit_json(out: &str) {
    let cl = cluster();
    let meta = cl.artifact.meta.clone();
    let params = SimParams::paper_encoder();
    let trace = BandwidthTrace::constant(100.0, 1e9);
    let arrivals = live_arrivals(&mut Rng::new(9), 10.0, 3.0, meta.seq_len);
    let base = CbConfig { max_slots: 4, max_batch: 4, decode_tokens: 8, ..CbConfig::default() };
    let chunked = CbConfig { prefill_chunk_tokens: 10, ..base.clone() };
    let mut m = MetricSet::new("live");
    for (name, cfg) in [("model_trace", &base), ("model_trace_chunk10", &chunked)] {
        let mut e = live_engine(&cl, cfg.clone(), params.clone(), trace.clone());
        let mut r = e
            .serve_stream_with(&mut ModelBackend, arrivals.clone(), 1e4)
            .expect("model backend run");
        m.push(name, "completed", r.completed as f64);
        m.push(name, "events", r.events.len() as f64);
        m.push(name, "model_total_s", r.model_time.total());
        m.push(name, "ttft_p50", r.ttft.p50());
        m.push(name, "prefill_chunks", r.prefill_chunks as f64);
    }
    for (name, cfg) in [("live_generations", &base), ("live_generations_chunk10", &chunked)] {
        let live =
            serve_live(&cl, cfg.clone(), params.clone(), trace.clone(), arrivals.clone(), 1e4)
                .expect("live run");
        // checksum of the real greedy generations: any drift in the
        // numerics (incl. incremental chunk replay) moves this integer
        let checksum: u64 = live
            .generations
            .iter()
            .map(|(id, toks)| {
                toks.iter().fold(id.wrapping_mul(31), |acc, &t| {
                    acc.wrapping_mul(131).wrapping_add(t as u64)
                }) % 1_000_000_007
            })
            .fold(0u64, |a, b| a.wrapping_add(b));
        m.push(name, "generation_checksum", checksum as f64);
        m.push(name, "live_steps", live.live_steps as f64);
        m.push(name, "completed", live.report.completed as f64);
    }

    // the serve loop under --serial-decode must reproduce the batched
    // generations exactly — the delta is an exact determinism pin at 0
    {
        let serial_cfg = CbConfig { serial_decode: true, ..base.clone() };
        let batched =
            serve_live(&cl, base.clone(), params.clone(), trace.clone(), arrivals.clone(), 1e4)
                .expect("batched live run");
        let serial =
            serve_live(&cl, serial_cfg, params.clone(), trace.clone(), arrivals.clone(), 1e4)
                .expect("serial live run");
        let delta = batched
            .generations
            .iter()
            .zip(serial.generations.iter())
            .filter(|(a, b)| a != b)
            .count()
            + batched.generations.len().abs_diff(serial.generations.len());
        m.push("fused_vs_serial", "serve_checksum_delta", delta as f64);
    }

    // fused vs serial tokens/sec at batch 1/4/8 (wall-clock: gated by the
    // directional tolerance, not the exact pins), with the per-layer and
    // per-iteration breakdowns of the fused path
    let rounds = 64;
    for bs in [1usize, 4, 8] {
        let scen = format!("decode_b{bs}");
        let (fused_s, fused_ck) = decode_run(&cl, bs, rounds, false);
        let (serial_s, serial_ck) = decode_run(&cl, bs, rounds, true);
        m.push(&scen, "tokens_per_s_fused", (bs * rounds) as f64 / fused_s);
        m.push(&scen, "tokens_per_s_serial", (bs * rounds) as f64 / serial_s);
        m.push(&scen, "fused_iter_us", fused_s / rounds as f64 * 1e6);
        m.push(&scen, "fused_per_layer_us", fused_s / (rounds * meta.n_layers) as f64 * 1e6);
        // bit-identity between the two execution paths, exact-pinned
        m.push(&scen, "checksum_delta", fused_ck.abs_diff(serial_ck) as f64);
    }

    // block attach (zero-copy arena ref) vs import (row copy): µs per
    // admission-side prefix restore, plus the bit-identity pin
    {
        let prompt = synth_prompt(3, 7, 12, meta.vocab_size);
        let (arena, exported) = sealed_blocks(&cl, &prompt, 4);
        let iters = 64;
        let t0 = Instant::now();
        for _ in 0..iters {
            black_box(attach_session(&cl, &prompt, &arena, exported.len()).len);
        }
        let attach_s = t0.elapsed().as_secs_f64();
        let t0 = Instant::now();
        for _ in 0..iters {
            black_box(import_session(&cl, &prompt, &exported).len);
        }
        let import_s = t0.elapsed().as_secs_f64();
        m.push("block", "attach_path_us", attach_s / iters as f64 * 1e6);
        m.push("block", "import_path_us", import_s / iters as f64 * 1e6);
        let mut a = attach_session(&cl, &prompt, &arena, exported.len());
        let mut i = import_session(&cl, &prompt, &exported);
        let mut delta = 0u64;
        for _ in 0..3 {
            if a.step().unwrap() != i.step().unwrap() {
                delta += 1;
            }
        }
        if a.export_rows(0, a.len).unwrap() != i.export_rows(0, i.len).unwrap() {
            delta += 1;
        }
        m.push("block", "attach_vs_import_checksum_delta", delta as f64);
    }

    // per-bit-width VQ index pack/unpack — the wire-format hot loop
    for bits in [4usize, 8, 16] {
        let count = 4096;
        let mask = if bits >= 32 { u32::MAX } else { (1u32 << bits) - 1 };
        let indices: Vec<u32> = (0..count as u32).map(|i| i.wrapping_mul(2654435761) & mask).collect();
        let iters = 128;
        let t0 = Instant::now();
        for _ in 0..iters {
            black_box(pack_indices(&indices, bits).unwrap().len());
        }
        let pack_s = t0.elapsed().as_secs_f64();
        let packed = pack_indices(&indices, bits).unwrap();
        let t0 = Instant::now();
        for _ in 0..iters {
            black_box(unpack_indices(&packed, count, bits).unwrap().len());
        }
        let unpack_s = t0.elapsed().as_secs_f64();
        let scen = format!("pack_bits{bits}");
        m.push(&scen, "pack_us", pack_s / iters as f64 * 1e6);
        m.push(&scen, "unpack_us", unpack_s / iters as f64 * 1e6);
    }

    m.write(out).expect("writing bench metrics");
}

fn main() {
    // `cargo bench` forwards a libtest-style `--bench` flag to the binary
    let args = Args::from_env(&["json", "bench"]).expect("parsing bench args");
    if args.flag("json") {
        emit_json(&args.get_or("out", "BENCH_live.json"));
        return;
    }
    header();
    let cl = cluster();
    let meta = cl.artifact.meta.clone();
    let mut b = Bench::new("live");

    // variable-length prefill replay into a fresh mixed-precision cache
    for plen in [8usize, 32] {
        let prompt = synth_prompt(1, 1, plen, meta.vocab_size);
        let cl_ref = &cl;
        b.run(&format!("session_prefill_t{plen}"), move || {
            black_box(DecodeSession::new(cl_ref, &prompt).unwrap().len)
        });
    }

    // single decode step (the unit the scheduler amortizes); the session
    // is rebuilt whenever its budget fills
    let prompt = synth_prompt(1, 2, 32, meta.vocab_size);
    let budget = 32 + 2048;
    let mut sess = DecodeSession::builder(&cl, &prompt).budget(budget).build().unwrap();
    let cl_ref = &cl;
    let prompt_ref = &prompt;
    b.run("decode_step", move || {
        if sess.len == sess.s_max {
            sess = DecodeSession::builder(cl_ref, prompt_ref).budget(budget).build().unwrap();
        }
        black_box(sess.step().unwrap())
    });

    // fused batch decode vs the serial loop over the same slots: the
    // tokens/sec headline (one batched GEMM per layer vs b small ones)
    for bs in [1usize, 4, 8] {
        let cl_ref = &cl;
        let mut sessions = decode_sessions(cl_ref, bs, 2048);
        b.run(&format!("decode_fused_b{bs}"), move || {
            if sessions.iter().any(|s| s.len == s.s_max) {
                sessions = decode_sessions(cl_ref, bs, 2048);
            }
            let mut refs: Vec<&mut DecodeSession> = sessions.iter_mut().collect();
            black_box(step_batch(&mut refs).unwrap().len())
        });
        let mut sessions = decode_sessions(cl_ref, bs, 2048);
        b.run(&format!("decode_serial_b{bs}"), move || {
            if sessions.iter().any(|s| s.len == s.s_max) {
                sessions = decode_sessions(cl_ref, bs, 2048);
            }
            let mut last = 0;
            for s in sessions.iter_mut() {
                last = s.step().unwrap();
            }
            black_box(last)
        });
    }

    // block attach (arena refcount bump) vs import (row copy)
    {
        let prompt = synth_prompt(3, 7, 12, meta.vocab_size);
        let (arena, exported) = sealed_blocks(&cl, &prompt, 4);
        let cl_ref = &cl;
        let prompt_ref = &prompt;
        let arena_ref = &arena;
        let n_blocks = exported.len();
        b.run("block_attach", move || {
            black_box(attach_session(cl_ref, prompt_ref, arena_ref, n_blocks).len)
        });
        let exported_ref = &exported;
        b.run("block_import", move || {
            black_box(import_session(cl_ref, prompt_ref, exported_ref).len)
        });
    }

    // per-bit-width pack/unpack of VQ code indices
    for bits in [4usize, 8, 16] {
        let mask = if bits >= 32 { u32::MAX } else { (1u32 << bits) - 1 };
        let indices: Vec<u32> =
            (0..4096u32).map(|i| i.wrapping_mul(2654435761) & mask).collect();
        let packed = pack_indices(&indices, bits).unwrap();
        let idx_ref = indices.clone();
        b.run(&format!("pack_bits{bits}"), move || {
            black_box(pack_indices(&idx_ref, bits).unwrap().len())
        });
        b.run(&format!("unpack_bits{bits}"), move || {
            black_box(unpack_indices(&packed, 4096, bits).unwrap().len())
        });
    }

    // end-to-end: the same fixed trace through the cost model alone, with
    // real sessions (batched), and with --serial-decode
    let cfg = CbConfig { max_slots: 4, max_batch: 4, decode_tokens: 8, ..CbConfig::default() };
    let arrivals = live_arrivals(&mut Rng::new(9), 10.0, 3.0, meta.seq_len);
    let params = SimParams::paper_encoder();
    let trace = BandwidthTrace::constant(100.0, 1e9);
    {
        let cl_ref = &cl;
        let cfg = cfg.clone();
        let arrivals = arrivals.clone();
        let params = params.clone();
        let trace = trace.clone();
        b.run("serve_model_only", move || {
            let mut e = live_engine(cl_ref, cfg.clone(), params.clone(), trace.clone());
            black_box(
                e.serve_stream_with(&mut ModelBackend, arrivals.clone(), 1e4)
                    .unwrap()
                    .completed,
            )
        });
    }
    for (name, serial) in [("serve_live_sessions", false), ("serve_live_serial", true)] {
        let cl_ref = &cl;
        let cfg = CbConfig { serial_decode: serial, ..cfg.clone() };
        let arrivals = arrivals.clone();
        let params = params.clone();
        let trace = trace.clone();
        b.run(name, move || {
            black_box(
                serve_live(
                    cl_ref,
                    cfg.clone(),
                    params.clone(),
                    trace.clone(),
                    arrivals.clone(),
                    1e4,
                )
                .unwrap()
                .report
                .completed,
            )
        });
    }
    b.finish();

    // headline numbers: live generation really happened, and the fused
    // path beats the serial loop at batch >= 4
    let live = serve_live(
        &cl,
        cfg,
        SimParams::paper_encoder(),
        BandwidthTrace::constant(100.0, 1e9),
        arrivals,
        1e4,
    )
    .unwrap();
    println!(
        "\nlive run: {} completed, {} real decode steps, host compute {:.1} ms, \
         virtual {:.1} ms",
        live.report.completed,
        live.live_steps,
        live.host_compute_s * 1e3,
        live.report.model_time.total() * 1e3,
    );
    for bs in [4usize, 8] {
        let (fused_s, fused_ck) = decode_run(&cl, bs, 64, false);
        let (serial_s, serial_ck) = decode_run(&cl, bs, 64, true);
        assert_eq!(fused_ck, serial_ck, "fused and serial decode diverged at b={bs}");
        println!(
            "decode b={bs}: fused {:.0} tok/s vs serial {:.0} tok/s ({:.2}x), bit-identical",
            bs as f64 * 64.0 / fused_s,
            bs as f64 * 64.0 / serial_s,
            serial_s / fused_s,
        );
    }
}
