//! Live-path benchmarks: real `DecodeSession` prefill replay and decode
//! steps on a synthetic decoder, and the end-to-end live
//! continuous-batching engine vs the pure cost-model run of the same
//! trace — the overhead of driving actual tensors through the scheduler.
//!
//! `--json [--out BENCH_live.json]` skips the wall-clock timing and emits
//! deterministic metrics for the CI regression gate: modeled scheduling
//! numbers on the fixed trace plus a checksum of the *real* greedy
//! generations (chunked and unchunked), which pins live-numerics drift.

use astra::comm::trace::BandwidthTrace;
use astra::config::RunConfig;
use astra::coordinator::decode::DecodeSession;
use astra::coordinator::Cluster;
use astra::model::shape::VqSetting;
use astra::model::TransformerShape;
use astra::server::live::{live_arrivals, live_engine, serve_live, synth_prompt};
use astra::server::scheduler::{CbConfig, ModelBackend};
use astra::sim::latency::SimParams;
use astra::util::bench::{black_box, header, Bench, MetricSet};
use astra::util::cli::Args;
use astra::util::rng::Rng;

fn cluster() -> Cluster {
    let shape = TransformerShape {
        n_layers: 2,
        d_model: 32,
        n_heads: 4,
        d_ff: 64,
        seq_len: 32,
        elem_bytes: 4,
    };
    let config = RunConfig { n_devices: 4, ..RunConfig::default() };
    Cluster::synthetic_decoder(&shape, 64, VqSetting::new(4, 16), config, 5).unwrap()
}

/// Deterministic metrics on the fixed live trace (see module docs).
fn emit_json(out: &str) {
    let cl = cluster();
    let meta = cl.artifact.meta.clone();
    let params = SimParams::paper_encoder();
    let trace = BandwidthTrace::constant(100.0, 1e9);
    let arrivals = live_arrivals(&mut Rng::new(9), 10.0, 3.0, meta.seq_len);
    let base = CbConfig { max_slots: 4, max_batch: 4, decode_tokens: 8, ..CbConfig::default() };
    let chunked = CbConfig { prefill_chunk_tokens: 10, ..base.clone() };
    let mut m = MetricSet::new("live");
    for (name, cfg) in [("model_trace", &base), ("model_trace_chunk10", &chunked)] {
        let mut e = live_engine(&cl, cfg.clone(), params.clone(), trace.clone());
        let mut r = e
            .serve_stream_with(&mut ModelBackend, arrivals.clone(), 1e4)
            .expect("model backend run");
        m.push(name, "completed", r.completed as f64);
        m.push(name, "events", r.events.len() as f64);
        m.push(name, "model_total_s", r.model_time.total());
        m.push(name, "ttft_p50", r.ttft.p50());
        m.push(name, "prefill_chunks", r.prefill_chunks as f64);
    }
    for (name, cfg) in [("live_generations", &base), ("live_generations_chunk10", &chunked)] {
        let live =
            serve_live(&cl, cfg.clone(), params.clone(), trace.clone(), arrivals.clone(), 1e4)
                .expect("live run");
        // checksum of the real greedy generations: any drift in the
        // numerics (incl. incremental chunk replay) moves this integer
        let checksum: u64 = live
            .generations
            .iter()
            .map(|(id, toks)| {
                toks.iter().fold(id.wrapping_mul(31), |acc, &t| {
                    acc.wrapping_mul(131).wrapping_add(t as u64)
                }) % 1_000_000_007
            })
            .fold(0u64, |a, b| a.wrapping_add(b));
        m.push(name, "generation_checksum", checksum as f64);
        m.push(name, "live_steps", live.live_steps as f64);
        m.push(name, "completed", live.report.completed as f64);
    }
    m.write(out).expect("writing bench metrics");
}

fn main() {
    // `cargo bench` forwards a libtest-style `--bench` flag to the binary
    let args = Args::from_env(&["json", "bench"]).expect("parsing bench args");
    if args.flag("json") {
        emit_json(&args.get_or("out", "BENCH_live.json"));
        return;
    }
    header();
    let cl = cluster();
    let meta = cl.artifact.meta.clone();
    let mut b = Bench::new("live");

    // variable-length prefill replay into a fresh mixed-precision cache
    for plen in [8usize, 32] {
        let prompt = synth_prompt(1, 1, plen, meta.vocab_size);
        let cl_ref = &cl;
        b.run(&format!("session_prefill_t{plen}"), move || {
            black_box(DecodeSession::new(cl_ref, &prompt).unwrap().len)
        });
    }

    // single decode step (the unit the scheduler amortizes); the session
    // is rebuilt whenever its budget fills
    let prompt = synth_prompt(1, 2, 32, meta.vocab_size);
    let mut sess = DecodeSession::with_budget(&cl, &prompt, 32 + 2048).unwrap();
    let cl_ref = &cl;
    let prompt_ref = &prompt;
    b.run("decode_step", move || {
        if sess.len == sess.s_max {
            sess = DecodeSession::with_budget(cl_ref, prompt_ref, 32 + 2048).unwrap();
        }
        black_box(sess.step().unwrap())
    });

    // end-to-end: the same fixed trace through the cost model alone vs
    // with real sessions attached
    let cfg = CbConfig { max_slots: 4, max_batch: 4, decode_tokens: 8, ..CbConfig::default() };
    let arrivals = live_arrivals(&mut Rng::new(9), 10.0, 3.0, meta.seq_len);
    let params = SimParams::paper_encoder();
    let trace = BandwidthTrace::constant(100.0, 1e9);
    {
        let cl_ref = &cl;
        let cfg = cfg.clone();
        let arrivals = arrivals.clone();
        let params = params.clone();
        let trace = trace.clone();
        b.run("serve_model_only", move || {
            let mut e = live_engine(cl_ref, cfg.clone(), params.clone(), trace.clone());
            black_box(
                e.serve_stream_with(&mut ModelBackend, arrivals.clone(), 1e4)
                    .unwrap()
                    .completed,
            )
        });
    }
    {
        let cl_ref = &cl;
        let cfg = cfg.clone();
        let arrivals = arrivals.clone();
        b.run("serve_live_sessions", move || {
            black_box(
                serve_live(
                    cl_ref,
                    cfg.clone(),
                    params.clone(),
                    trace.clone(),
                    arrivals.clone(),
                    1e4,
                )
                .unwrap()
                .report
                .completed,
            )
        });
    }
    b.finish();

    // headline numbers: live generation really happened
    let live = serve_live(
        &cl,
        cfg,
        SimParams::paper_encoder(),
        BandwidthTrace::constant(100.0, 1e9),
        arrivals,
        1e4,
    )
    .unwrap();
    println!(
        "\nlive run: {} completed, {} real decode steps, host compute {:.1} ms, \
         virtual {:.1} ms",
        live.report.completed,
        live.live_steps,
        live.host_compute_s * 1e3,
        live.report.model_time.total() * 1e3,
    );
}
