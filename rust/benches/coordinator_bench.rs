//! Coordinator benchmarks on the live artifacts (native backend): prefill
//! end-to-end, per-layer block compute, partition/bias construction.
//! Requires `make artifacts`; skips the live parts otherwise.

use std::path::Path;

use astra::config::RunConfig;
use astra::coordinator::partition::{decoder_bias, encoder_bias};
use astra::coordinator::{Cluster, TokenPartition};
use astra::model::native;
use astra::tensor::Tensor;
use astra::util::bench::{black_box, header, Bench};
use astra::util::rng::Rng;

fn main() {
    header();
    let mut b = Bench::new("coordinator");
    let mut rng = Rng::new(0);

    if Path::new("artifacts/manifest.json").exists() {
        let cluster = Cluster::load("artifacts".as_ref(), RunConfig::default(), false).unwrap();
        let meta = cluster.artifact.meta.clone();
        let mut x = Tensor::zeros(&[meta.seq_len, meta.patch_dim]);
        rng.fill_normal(&mut x.data);
        b.run("prefill_native_e2e", || {
            black_box(cluster.prefill(&x).unwrap().report.latency_s)
        });
        b.run("prefill_single_device", || {
            black_box(cluster.prefill_single_device(&x).unwrap().1)
        });
    } else {
        eprintln!("(artifacts missing; skipping live prefill benches)");
    }

    // native block at paper-ish tile (one device's share of 12L/768D)
    let d = 768;
    let blk = native::BlockWeights::random(&mut rng, d, 3072);
    let mut local = Tensor::zeros(&[256, d]);
    let mut remote = Tensor::zeros(&[768, d]);
    rng.fill_normal(&mut local.data);
    rng.fill_normal(&mut remote.data);
    b.run("native_astra_block_256x768", || {
        black_box(native::astra_block(&local, &remote, None, &blk, 12).unwrap())
    });

    let part = TokenPartition::even(1024, 4).unwrap();
    b.run("decoder_bias_1024_4dev", || black_box(decoder_bias(&part, 2)));
    b.run("encoder_bias_257x1025", || black_box(encoder_bias(257, 768)));
    b.finish();
}
