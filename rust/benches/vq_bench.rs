//! VQ hot-path benchmarks: grouped encode (distance scan), decode
//! (gather), bit packing/unpacking at paper-relevant shapes.

use astra::tensor::Tensor;
use astra::util::bench::{black_box, header, Bench};
use astra::util::rng::Rng;
use astra::vq::{pack_indices, unpack_indices, Codebook};

fn main() {
    header();
    let mut b = Bench::new("vq");
    let mut rng = Rng::new(0);

    // paper setting scaled: D=768, K=1024, chunk of 256 tokens
    for (g, k, d, t) in [
        (1usize, 1024usize, 768usize, 256usize),
        (16, 1024, 768, 256),
        (32, 1024, 768, 256),
        (16, 64, 128, 16),
    ] {
        let dg = d / g;
        let mut data = vec![0.0f32; g * k * dg];
        rng.fill_normal(&mut data);
        let cb = Codebook::new(g, k, dg, data).unwrap();
        let mut x = Tensor::zeros(&[t, d]);
        rng.fill_normal(&mut x.data);
        let idx = cb.encode(&x).unwrap();

        b.run(&format!("encode_g{g}_k{k}_d{d}_t{t}"), || {
            black_box(cb.encode(&x).unwrap())
        });
        b.run(&format!("decode_g{g}_k{k}_d{d}_t{t}"), || {
            black_box(cb.decode(&idx, t).unwrap())
        });
    }

    // bit packing at 10 bits (K=1024)
    let idx: Vec<u32> = (0..256 * 16).map(|i| (i as u32 * 37) % 1024).collect();
    let packed = pack_indices(&idx, 10).unwrap();
    b.run("pack_4096x10b", || black_box(pack_indices(&idx, 10).unwrap()));
    b.run("unpack_4096x10b", || {
        black_box(unpack_indices(&packed, idx.len(), 10).unwrap())
    });
    b.finish();
}
