//! PJRT runtime benchmarks: executor dispatch overhead vs native compute —
//! the L3 §Perf accounting of where a prefill's time goes.
//! Requires `make artifacts`; exits quietly otherwise.

use std::path::Path;

use astra::runtime::{Artifact, ModelRuntime};
use astra::tensor::Tensor;
use astra::util::bench::{black_box, header, Bench};
use astra::util::rng::Rng;

fn main() {
    if !Path::new("artifacts/manifest.json").exists() {
        eprintln!("(artifacts missing; skipping runtime benches)");
        return;
    }
    header();
    let mut b = Bench::new("runtime");
    let artifact = Artifact::load("artifacts".as_ref()).unwrap();
    let meta = artifact.meta.clone();
    let runtime = match ModelRuntime::load(artifact) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("(PJRT unavailable: {e})");
            return;
        }
    };
    let mut rng = Rng::new(0);
    let n = meta.n_devices;
    let tc = meta.seq_len / n;
    let tl = tc + 1;
    let tr = meta.seq_len - tc;

    let mk = |rng: &mut Rng, r: usize, c: usize| {
        let mut t = Tensor::zeros(&[r, c]);
        rng.fill_normal(&mut t.data);
        t
    };
    let h_local = mk(&mut rng, tl, meta.d_model);
    let x_hat = mk(&mut rng, tr, meta.d_model);
    let bias = Tensor::zeros(&[tl, tl + tr]);

    let block = runtime.executor_for_layer("astra_block", 0).unwrap();
    b.run("pjrt_astra_block", || {
        black_box(block.run(&[&h_local, &x_hat, &bias]).unwrap())
    });

    let content = mk(&mut rng, tc, meta.d_model);
    let enc = runtime.executor_for_layer("vq_encode", 0).unwrap();
    b.run("pjrt_vq_encode", || black_box(enc.run(&[&content]).unwrap()));

    // native comparison at the same shape
    let art = runtime.artifact.clone();
    let nb = art.native_block(0).unwrap();
    b.run("native_astra_block_same_shape", || {
        black_box(
            astra::model::native::astra_block(&h_local, &x_hat, None, &nb, meta.n_heads).unwrap(),
        )
    });
    b.run("native_vq_encode_same_shape", || {
        black_box(art.codebooks[0].encode(&content).unwrap())
    });
    b.finish();
}
