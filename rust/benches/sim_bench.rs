//! Simulator benchmarks: full paper-figure sweeps must be interactive
//! (Fig-1 grid target < 2 s; see DESIGN.md §Perf).

use astra::comm::trace::BandwidthTrace;
use astra::model::shape::{TransformerShape, VqSetting};
use astra::parallel::strategies::{figure1_strategies, Strategy, StrategyKind};
use astra::server::engine::ServeEngine;
use astra::server::Request;
use astra::sim::engine::Engine;
use astra::sim::latency::{evaluate, evaluate_on_trace, SimParams};
use astra::util::bench::{black_box, header, Bench};
use astra::util::rng::Rng;

fn main() {
    header();
    let mut b = Bench::new("sim");
    let params = SimParams::paper_encoder();

    b.run("fig1_full_grid", || {
        let mut acc = 0.0;
        for t in [256usize, 1024, 4096] {
            let shape = TransformerShape::paper_encoder(t);
            for s in figure1_strategies(4) {
                for bw in [10.0, 20.0, 50.0, 100.0, 200.0, 500.0] {
                    acc += evaluate(&s.schedule(&shape), &params, bw).total();
                }
            }
        }
        black_box(acc)
    });

    let shape = TransformerShape::paper_encoder(1024);
    let sched = Strategy::new(StrategyKind::Astra { vq: VqSetting::new(16, 1024) }, 4)
        .schedule(&shape);
    let mut rng = Rng::new(3);
    let trace = BandwidthTrace::markovian(&mut rng, 20.0, 100.0, 9, 1.0, 600.0);
    b.run("schedule_on_trace", || {
        black_box(evaluate_on_trace(&sched, &params, &trace, 211.0))
    });

    b.run("serve_600s_closed_loop", || {
        let reqs: Vec<Request> = (0..50_000)
            .map(|i| Request { id: i, arrival_s: 0.0, tokens: 1024 })
            .collect();
        let mut engine = ServeEngine::new(
            shape,
            Strategy::new(StrategyKind::Astra { vq: VqSetting::new(16, 1024) }, 4),
            params.clone(),
            trace.clone(),
        );
        black_box(engine.serve_stream(reqs, 600.0).completed)
    });

    b.run("event_engine_100k", || {
        let mut e = Engine::new();
        for i in 0..100_000u64 {
            e.at(i as f64 * 0.001, |_| {});
        }
        e.run();
        black_box(e.processed())
    });
    b.finish();
}
