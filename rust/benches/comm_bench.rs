//! Communication substrate benchmarks: message construction, link
//! transfer-time computation, trace integration, loss modeling.

use astra::comm::link::{LinkSpec, SimLink};
use astra::comm::message::Message;
use astra::comm::trace::BandwidthTrace;
use astra::tensor::Tensor;
use astra::util::bench::{black_box, header, Bench};
use astra::util::rng::Rng;

fn main() {
    header();
    let mut b = Bench::new("comm");
    let mut rng = Rng::new(0);

    let idx: Vec<u32> = (0..256 * 16).map(|_| rng.below(1024) as u32).collect();
    b.run("vq_message_build_256tok_g16", || {
        black_box(Message::vq(0, 0, &idx, 256, 16, 10).unwrap())
    });
    let mut x = Tensor::zeros(&[256, 768]);
    rng.fill_normal(&mut x.data);
    b.run("dense_message_build_256x768", || {
        black_box(Message::dense(0, 0, &x).unwrap())
    });

    let link = SimLink::new(LinkSpec::ideal(100.0), 1);
    b.run("link_send_clean_64KiB", || black_box(link.send(0.0, 65536)));
    let lossy = SimLink::new(LinkSpec::ideal(100.0).with_loss(0.05, true), 2);
    b.run("link_send_lossy_64KiB", || black_box(lossy.send(0.0, 65536)));

    let mut trng = Rng::new(7);
    let trace = BandwidthTrace::markovian(&mut trng, 20.0, 100.0, 9, 1.0, 600.0);
    b.run("trace_transfer_100Mbit", || {
        black_box(trace.transfer_time(123.4, 100e6))
    });
    b.run("trace_markovian_600s_build", || {
        let mut r = Rng::new(9);
        black_box(BandwidthTrace::markovian(&mut r, 20.0, 100.0, 9, 1.0, 600.0))
    });
    b.finish();
}
