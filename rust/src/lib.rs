//! # ASTRA — communication-efficient multi-device Transformer inference
//!
//! Rust L3 coordinator for the three-layer reproduction of
//! *"ASTRA: Communication-Efficient Acceleration for Multi-Device
//! Transformer Inference"*:
//!
//! * [`runtime`] loads the AOT artifacts (HLO text lowered from JAX/Pallas
//!   by `python/compile/aot.py`) and executes them on a PJRT CPU client —
//!   python never runs on the request path.
//! * [`coordinator`] implements the paper's contribution: sequence-parallel
//!   orchestration with Mixed-Precision Attention exchanges (VQ codes on
//!   the wire instead of full-precision embeddings), Distributed Class
//!   Token aggregation, and the autoregressive decode loop.
//! * [`comm`] + [`sim`] are the substrate the paper ran on real laptops:
//!   a simulated network (bandwidth caps, latency, packet loss, dynamic
//!   Markovian traces) carrying *real* bit-packed payloads, plus a
//!   discrete-event latency simulator for the paper's sweeps.
//! * [`server`] serves request streams: the paper's batch-1 FIFO loop
//!   ([`server::engine`], Fig 6) and a continuous-batching engine
//!   ([`server::scheduler`]) that admits prefill batches into in-flight
//!   decode slots. Batched execution semantics live in the cost model
//!   ([`parallel::cost::Phase::for_batch`]): per-request FLOPs and wire
//!   bits scale with the batch, while kernel launches, collective sync
//!   stages, and the weight-streaming memory floor — which gates
//!   single-token decode — are paid once, so co-scheduled decode slots are
//!   nearly free. With `CbConfig::prefill_chunk_tokens` set, long prompts
//!   stop monopolizing the cluster: Sarathi-style *chunked piggybacked
//!   prefill* splits them into fixed-token-budget chunks and fuses at most
//!   one chunk batch into each decode iteration
//!   ([`parallel::strategies::Strategy::fused_iteration_schedule`] +
//!   [`parallel::cost::Schedule::piggyback`]: chunk FLOPs/bits plus one
//!   decode token per slot, launches/sync/floor once per iteration), with
//!   `chunk_tokens >= max prompt` reproducing the unchunked event stream
//!   bit for bit. Admission is gated on Appendix-G mixed-KV memory
//!   ([`kv::pool::KvPool`]): slots grow chunk by chunk during
//!   prefill and two full-precision rows per generated token, and under
//!   pressure the newest slots are evicted back to the queue for
//!   recompute. The same scheduler loop drives two backends through
//!   [`server::scheduler::DecodeBackend`]: the pure cost model, and the
//!   *live* path ([`server::live`]) executing real
//!   [`coordinator::decode::DecodeSession`]s — variable-length prompt
//!   replay into mixed-precision KV caches (incremental under chunking via
//!   [`coordinator::decode::DecodeSession::replay_range`]), greedy
//!   generations — behind `astra serve-cb --live`.
//!   `tests/live_vs_model.rs` is the differential harness pinning both
//!   backends to identical decision streams, chunked or not. Every
//!   *discretionary* decision — which eligible request is admitted next,
//!   which slot a preemption evicts, whether to evict proactively to
//!   protect an SLO — is delegated to a pluggable
//!   [`server::policy::SchedPolicy`] (`CbConfig::policy` / `--policy`)
//!   over immutable queue/slot snapshots: [`server::policy::Fifo`] (the
//!   default, reproducing the pre-policy event streams bit for bit),
//!   [`server::policy::PrefixAware`] (admissions ordered by radix-tree
//!   covered-prefix length with an aging bound so cold requests cannot
//!   starve), and [`server::policy::SloClass`] (priority classes with
//!   per-class deadlines via `CbConfig::classes` / `--classes`:
//!   highest-class-first admission, lowest-class-first victims, classes
//!   preemption-exempt inside their deadline budget, and a proactive
//!   hook trading an already-blown low-class SLO for a salvageable
//!   high-class one). Mechanism never moves: the clock, KV pool, and
//!   backends stay in the loop, so any policy preserves the
//!   live-vs-model differential by construction. Reports cover
//!   p50/p95/p99 latency, TTFT (recorded once per request from its
//!   original arrival, eviction-safe), inter-token latency, queue depth,
//!   censored requests, goodput under an SLO, per-class
//!   latency/attainment/goodput breakdowns, and KV
//!   peak/eviction/violation counters. The engine itself is *actorized*
//!   ([`server::scheduler::EngineActor`]): the per-iteration mechanism is
//!   a `step(backend, now, horizon) -> StepOutcome` state machine that
//!   reports its next wake time instead of owning the clock, and the
//!   single-replica serve loop is a trivial driver over it. On top sits
//!   [`server::cluster`]: N replicas under one deterministic cluster
//!   event loop (`--replicas N`) that owns the shared virtual clock and
//!   the global arrival queue, routes each arrival through a pluggable
//!   [`server::cluster::RoutePolicy`] (round-robin, least-loaded, or
//!   prefix-affinity scoring per-replica shadow radix digests against
//!   load skew), aggregates per-replica reports into fleet rollups
//!   (pooled p95, pooled hit rate, load skew), and can drain a replica
//!   mid-run — evicting its slots and spilling its queue to the
//!   survivors without losing a request. A 1-replica fleet reproduces
//!   the single-engine event stream bit for bit. The chaos layer
//!   ([`server::chaos`] + [`sim::fault::FaultPlan`]) drives the fleet
//!   through seeded deterministic fault schedules — unplanned replica
//!   kills (queue and host tier *lost*, in-flight requests restored from
//!   fleet-held checkpoints or replayed from the prompt), link
//!   degradation, swap-tier slowdown, arrival bursts — all as events on
//!   the virtual clock, soaked over many seeds (`astra soak`) against an
//!   invariant checklist; the empty plan is bit-identical to no plan.
//! * [`kv`] is the block-based KV memory subsystem under the scheduler:
//!   [`kv::pool::KvPool`] accounts refcounted fixed-token blocks whose
//!   bytes are Appendix-G prefix differences (telescoping to exactly the
//!   flat per-slot bytes, so every sharing-off path reproduces the
//!   pre-pool event streams bit for bit); [`kv::prefix::RadixTree`] maps
//!   block-aligned token-id prefixes to resident or recently-freed blocks
//!   so a request sharing a prompt prefix attaches (`CbEvent::PrefixHit`)
//!   and replays only the uncovered suffix (suffix-only replay is
//!   bit-identical to full replay — positional locality in
//!   [`coordinator::decode::DecodeSession`] makes K/V rows a pure
//!   function of the token-id prefix); and [`kv::swap::SwapPolicy`]
//!   prices evictions over a host link (latency + bytes/bandwidth, the
//!   same arithmetic as [`comm::link`]) and swaps a victim's cache out
//!   (`CbEvent::SwapOut`/`SwapIn`, decode progress preserved) whenever
//!   the round trip beats the modeled recompute (re-prefill + regenerate)
//!   — recompute-style preemption remains the fallback and the default.
//!   The same priced tier doubles as a *checkpoint* store
//!   (`CbConfig::checkpoint_every`): decoding slots periodically copy
//!   their occupancy over the host link, and after a replica kill the
//!   fleet restores from the latest copy instead of replaying the prompt.
//! * [`parallel`] implements the baselines — Tensor Parallelism
//!   (Megatron-LM), Sequence Parallelism (Voltage), Block Parallelism
//!   (DeTransformer, BP+AG / BP+SP) — as per-block communication/compute
//!   schedules over the same cost model.
//! * [`vq`] is the native grouped vector-quantization engine used on the
//!   hot path (encode/decode/bit-packing), mirroring the Pallas kernels.
//! * [`workload`] generates what the server is asked to serve: seeded
//!   arrival traces (homogeneous Poisson, sinusoidal diurnal curves,
//!   Markov-modulated bursts reusing the [`comm::trace`] machinery as a
//!   rate curve, weighted multi-tenant mixes onto QoS classes), plus the
//!   streaming-client model (per-request patience deadlines, heavy-tailed
//!   decode lengths) and per-token delivery accounting. See *Workload
//!   model* below.
//! * [`model`] holds shape/FLOP/memory math and a pure-rust reference
//!   transformer used to cross-check PJRT numerics.
//!
//! The crate is dependency-light by necessity (offline image): JSON, CLI
//! parsing, PRNG, and the bench harness live in [`util`].
//!
//! # Execution model: batch-fused live decode
//!
//! Live decode advances every in-flight slot through **one fused batched
//! GEMM per layer per scheduler iteration**. The backend boundary is
//! [`server::scheduler::DecodeBackend::step`], which receives a
//! [`server::scheduler::StepBatch`] naming the iteration's planned
//! prefill chunks and decoding slots; the live backend then:
//!
//! 1. **replays prefill chunks in parallel** — each chunk targets a
//!    distinct session, so the replays fan out across
//!    `std::thread::scope` threads with disjoint `&mut` borrows;
//! 2. **gathers** each decoding slot's embedded last token into one
//!    `[batch, d_model]` activation matrix
//!    ([`coordinator::decode::step_batch`]);
//! 3. runs the **per-layer batched GEMMs** — LN, Q/K/V projections, the
//!    output projection, and the FFN all operate on the whole batch in a
//!    single [`tensor::matmul`] per weight — while attention stays
//!    per-slot (each row attends over its own KV cache);
//! 4. **scatters** the new K/V rows back into each slot's cache and takes
//!    the per-row argmax through a batched LM head.
//!
//! Every kernel involved is row-independent with a fixed inner
//! accumulation order, so the fused path is **bit-identical** to stepping
//! each session alone — `DecodeSession::step` is literally the batch-1
//! case, `CbConfig::serial_decode` forces one-session-at-a-time execution
//! for benchmarking, and `tests/live_vs_model.rs` pins batched == serial
//! differentially.
//!
//! Shared prompt prefixes never copy floats: sealed block rows are
//! exported once into the refcounted [`kv::arena::KvArena`], whose
//! flattened row layout (`(head, token, d_head)`, token rows relative to
//! the block's `lo`) is exactly the
//! [`coordinator::decode::DecodeSession::export_rows`] form priced by
//! [`kv::pool::KvPool`]'s Appendix-G block bytes — an attach is an `Arc`
//! clone ([`coordinator::decode::DecodeSession::attach_block`]), reads
//! resolve through the block for rows below the attached prefix and
//! through the session's private tensor above it, and an attached block
//! outlives both its creator session and its arena entry.
//!
//! # Workload model: streaming clients and generative traces
//!
//! The serving stack is exercised by the [`workload`] subsystem rather
//! than hard-coded Poisson streams. A [`workload::WorkloadSpec`] is *pure
//! data* drawn deterministically from a seed (the same contract as
//! [`sim::fault::FaultPlan`]): it expands once into a `Vec<Request>` via
//! Lewis–Shedler thinning against a diurnal or Markov-burst rate curve,
//! and the engine only ever sees the resulting trace. The plain-Poisson
//! spec reproduces the historical generators bit for bit.
//!
//! On top of arrivals sits the *client* model, also seeded pure data:
//! each request draws a patience deadline
//! ([`workload::patience_for`], `CbConfig::patience_s`) and optionally a
//! bounded-Pareto decode length ([`workload::tail_budget`],
//! `CbConfig::length_tail_alpha`). The engine owns the state transitions:
//! when a client has waited longer than its patience since the last
//! delivered token, the request is **cancelled mid-decode**
//! ([`server::scheduler::CbEvent::Cancelled`]) — its slot, KV blocks,
//! pending radix registrations, swap-tier parking, and fleet-held
//! checkpoints are all freed immediately, and the chaos checklist extends
//! to `completed + rejected + censored + cancelled == arrivals`. Per-token
//! delivery timestamps ([`workload::TokenStream`]) feed the report's
//! time-to-token distribution and the post-hoc waste accounting
//! ([`workload::wasted_deliveries`]): tokens generated after the client
//! gave up are `wasted_decode_tokens`, the metric the cancellation path
//! exists to minimize. All knobs default off, reproducing the pre-client
//! event streams bit for bit, and the differential harness pins live ==
//! model including `Cancelled` events.
//!
//! # Heterogeneous fleets and re-planning
//!
//! Real fleets are skewed — a workstation, a laptop, two SBCs — and an
//! even token split runs every collective at the pace of the slowest
//! device. A [`parallel::cost::FleetProfile`] (per-device speed factors
//! plus a per-link bandwidth-factor matrix, `CbConfig::device_speeds` /
//! `--device-speeds 4,2,1,0.5`) feeds the heterogeneous schedule builders
//! ([`parallel::strategies::Strategy::schedule_on`] and friends), which
//! split tokens proportionally to measured speed and price each stage at
//! its own device's rate. On top sits the pure
//! [`parallel::plan::Planner`]: profile + bandwidth in, argmin
//! [`parallel::plan::Plan`] out over a fixed five-candidate list (even
//! status quo, proportional and damped re-weightings of the configured
//! strategy, and Galaxy-style hybrid TP/SP re-partitions).
//!
//! The engine re-plans *online*: every `--replan-every` seconds it folds
//! the bandwidth trace into an EWMA estimate, re-scores the candidates,
//! and swaps plans only past a hysteresis margin
//! ([`server::scheduler::CbEvent::Replan`], counted in
//! `CbReport::replans`). In-flight sessions keep the split they were
//! admitted under; a swap only affects later admissions, where the live
//! backend partitions prompts by the plan's weights
//! ([`coordinator::SessionBuilder::split_weights`]). Placement awareness
//! closes the loop at admission ([`server::policy::PlacementAware`] orders
//! the queue by modeled decode drain time) and at routing
//! ([`server::cluster::Placement`] sends work to the replica with the
//! smallest `(load + 1) / decode_speed`). Every knob defaults off, and a
//! uniform profile — or `--replan-every 0` — reproduces the legacy
//! engine's event streams bit for bit (`tests/hetero.rs`,
//! `tests/live_vs_model.rs`).

pub mod comm;
pub mod config;
pub mod coordinator;
pub mod kv;
pub mod model;
pub mod parallel;
pub mod runtime;
pub mod server;
pub mod sim;
pub mod tensor;
pub mod util;
pub mod vq;
pub mod workload;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;

/// One-stop import surface for driving the serving stack: the cluster and
/// its sessions, the batch-first backend API, and the KV arena types that
/// cross the backend boundary.
pub mod prelude {
    pub use crate::coordinator::{step_batch, Cluster, DecodeSession, SessionBuilder};
    pub use crate::kv::{BlockRef, BlockRows, KvArena, KvPool};
    pub use crate::server::{
        serve_live, AdmitBatch, AdmitEntry, CbConfig, CbEngine, CbEvent, CbReport, ChunkPlan,
        ClusterEngine, DecodeBackend, LiveBackend, LiveReport, ModelBackend, PrefixAttach,
        Request, StepBatch,
    };
    pub use crate::workload::{ArrivalProcess, PromptLengths, TokenStream, WorkloadSpec};
    pub use crate::Result;
}
