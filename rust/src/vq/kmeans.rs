//! K-means codebook initialization (Lloyd iterations, dead-centroid
//! re-seeding) — the rust twin of `compile/vq.py::kmeans_init`, used when
//! the coordinator (re)builds codebooks from harvested embeddings, e.g.
//! for bandwidth-aware re-adaptation experiments.

use anyhow::{bail, Result};

use crate::tensor::Tensor;
use crate::util::rng::Rng;

use super::codebook::Codebook;

/// Run k-means per group over x [M, D]; returns a grouped codebook.
pub fn kmeans(rng: &mut Rng, x: &Tensor, groups: usize, k: usize, iters: usize) -> Result<Codebook> {
    let (m, d) = x.dims2()?;
    if d % groups != 0 {
        bail!("D={d} not divisible by G={groups}");
    }
    if m < k {
        bail!("need at least K={k} samples, got {m}");
    }
    let dg = d / groups;
    let mut data = vec![0.0f32; groups * k * dg];

    for g in 0..groups {
        // init: k distinct random samples
        let seeds = rng.sample_indices(m, k);
        for (c, &si) in seeds.iter().enumerate() {
            let src = &x.row(si)[g * dg..(g + 1) * dg];
            data[(g * k + c) * dg..(g * k + c + 1) * dg].copy_from_slice(src);
        }
        let mut assign = vec![0usize; m];
        for _ in 0..iters {
            // assignment step
            for ti in 0..m {
                let xg = &x.row(ti)[g * dg..(g + 1) * dg];
                let mut best = f32::INFINITY;
                for c in 0..k {
                    let e = &data[(g * k + c) * dg..(g * k + c + 1) * dg];
                    let mut dist = 0.0f32;
                    for (a, b) in xg.iter().zip(e.iter()) {
                        let diff = a - b;
                        dist += diff * diff;
                    }
                    if dist < best {
                        best = dist;
                        assign[ti] = c;
                    }
                }
            }
            // update step
            let mut counts = vec![0usize; k];
            let mut sums = vec![0.0f32; k * dg];
            for ti in 0..m {
                let c = assign[ti];
                counts[c] += 1;
                let xg = &x.row(ti)[g * dg..(g + 1) * dg];
                for (s, v) in sums[c * dg..(c + 1) * dg].iter_mut().zip(xg.iter()) {
                    *s += v;
                }
            }
            for c in 0..k {
                let dst = &mut data[(g * k + c) * dg..(g * k + c + 1) * dg];
                if counts[c] == 0 {
                    // dead centroid: re-seed from a random sample
                    let si = rng.below(m);
                    dst.copy_from_slice(&x.row(si)[g * dg..(g + 1) * dg]);
                } else {
                    let inv = 1.0 / counts[c] as f32;
                    for (d_, s) in dst.iter_mut().zip(sums[c * dg..(c + 1) * dg].iter()) {
                        *d_ = s * inv;
                    }
                }
            }
        }
    }
    Codebook::new(groups, k, dg, data)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kmeans_beats_random_on_clustered_data() {
        let mut rng = Rng::new(0);
        // 4 well-separated clusters in 8-d
        let mut centers = Tensor::zeros(&[4, 8]);
        rng.fill_normal(&mut centers.data);
        for v in centers.data.iter_mut() {
            *v *= 5.0;
        }
        let mut x = Tensor::zeros(&[256, 8]);
        for i in 0..256 {
            let c = rng.below(4);
            for j in 0..8 {
                x.row_mut(i)[j] = centers.row(c)[j] + rng.normal_f32(0.0, 0.2);
            }
        }
        let km = kmeans(&mut rng, &x, 2, 4, 12).unwrap();
        let mut rand_data = vec![0.0f32; 2 * 4 * 4];
        rng.fill_normal(&mut rand_data);
        let rand_cb = Codebook::new(2, 4, 4, rand_data).unwrap();
        let d_km = km.distortion(&x).unwrap();
        let d_rand = rand_cb.distortion(&x).unwrap();
        assert!(d_km < 0.5 * d_rand, "kmeans {d_km} vs random {d_rand}");
    }

    #[test]
    fn kmeans_shape_and_errors() {
        let mut rng = Rng::new(1);
        let mut x = Tensor::zeros(&[32, 12]);
        rng.fill_normal(&mut x.data);
        let cb = kmeans(&mut rng, &x, 3, 8, 4).unwrap();
        assert_eq!((cb.groups, cb.k, cb.dg), (3, 8, 4));
        assert!(kmeans(&mut rng, &x, 5, 8, 4).is_err()); // 12 % 5 != 0
        let tiny = Tensor::zeros(&[4, 12]);
        assert!(kmeans(&mut rng, &tiny, 3, 8, 4).is_err()); // m < k
    }
}
