//! Bit-packing codec for VQ indices.
//!
//! What actually crosses the simulated network: each index is `ceil(log2 K)`
//! bits, packed little-endian into a byte stream. This makes the paper's
//! "Total Bits per Token" columns *measured* quantities (message length in
//! bits) rather than asserted formulas.

use anyhow::{bail, Result};

/// Bytes needed for `count` indices of `bits` bits each.
pub fn packed_len_bytes(count: usize, bits: usize) -> usize {
    (count * bits + 7) / 8
}

/// Pack indices (each < 2^bits) into a little-endian bitstream.
pub fn pack_indices(indices: &[u32], bits: usize) -> Result<Vec<u8>> {
    if bits == 0 || bits > 32 {
        bail!("bits must be 1..=32, got {bits}");
    }
    let limit = if bits == 32 { u64::from(u32::MAX) + 1 } else { 1u64 << bits };
    let mut out = vec![0u8; packed_len_bytes(indices.len(), bits)];
    let mut bitpos = 0usize;
    for &idx in indices {
        if u64::from(idx) >= limit {
            bail!("index {idx} does not fit in {bits} bits");
        }
        let mut v = u64::from(idx);
        let mut remaining = bits;
        while remaining > 0 {
            let byte = bitpos / 8;
            let off = bitpos % 8;
            let take = (8 - off).min(remaining);
            out[byte] |= ((v & ((1 << take) - 1)) as u8) << off;
            v >>= take;
            bitpos += take;
            remaining -= take;
        }
    }
    Ok(out)
}

/// Unpack `count` indices of `bits` bits each.
pub fn unpack_indices(bytes: &[u8], count: usize, bits: usize) -> Result<Vec<u32>> {
    if bits == 0 || bits > 32 {
        bail!("bits must be 1..=32, got {bits}");
    }
    if bytes.len() < packed_len_bytes(count, bits) {
        bail!(
            "need {} bytes for {count} x {bits}-bit indices, got {}",
            packed_len_bytes(count, bits),
            bytes.len()
        );
    }
    let mut out = Vec::with_capacity(count);
    let mut bitpos = 0usize;
    for _ in 0..count {
        let mut v = 0u64;
        let mut got = 0usize;
        while got < bits {
            let byte = bitpos / 8;
            let off = bitpos % 8;
            let take = (8 - off).min(bits - got);
            let chunk = (bytes[byte] >> off) as u64 & ((1 << take) - 1);
            v |= chunk << got;
            got += take;
            bitpos += take;
        }
        out.push(v as u32);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip_various_widths() {
        let mut rng = Rng::new(0);
        for bits in [1usize, 3, 7, 8, 10, 11, 13, 16, 24, 32] {
            let limit = if bits == 32 { u64::from(u32::MAX) } else { (1u64 << bits) - 1 };
            let idx: Vec<u32> = (0..257)
                .map(|_| (rng.next_u64() % (limit + 1)) as u32)
                .collect();
            let packed = pack_indices(&idx, bits).unwrap();
            assert_eq!(packed.len(), packed_len_bytes(idx.len(), bits));
            let back = unpack_indices(&packed, idx.len(), bits).unwrap();
            assert_eq!(back, idx, "bits={bits}");
        }
    }

    #[test]
    fn roundtrip_exhaustive_over_every_bit_width() {
        // every legal width 1..=32, counts chosen to hit byte-aligned and
        // non-byte-aligned tails (count*bits % 8 != 0), plus the empty
        // stream; packed_len_bytes must match the produced length exactly
        let mut rng = Rng::new(1234);
        for bits in 1..=32usize {
            let limit: u64 = if bits == 32 { 1u64 << 32 } else { 1u64 << bits };
            for count in [0usize, 1, 2, 3, 5, 7, 8, 9, 11, 64, 257] {
                let idx: Vec<u32> =
                    (0..count).map(|_| (rng.next_u64() % limit) as u32).collect();
                let packed = pack_indices(&idx, bits).unwrap();
                assert_eq!(
                    packed.len(),
                    packed_len_bytes(count, bits),
                    "bits={bits} count={count}"
                );
                assert_eq!(packed.len(), (count * bits).div_ceil(8));
                let back = unpack_indices(&packed, count, bits).unwrap();
                assert_eq!(back, idx, "bits={bits} count={count}");
            }
            // boundary values (0 and 2^bits - 1) survive a non-byte-aligned
            // tail: 3 indices guarantee a ragged final byte for bits % 8 != 0
            let max = (limit - 1) as u32;
            let edge = vec![0u32, max, max];
            let packed = pack_indices(&edge, bits).unwrap();
            assert_eq!(packed.len(), packed_len_bytes(3, bits), "bits={bits}");
            assert_eq!(unpack_indices(&packed, 3, bits).unwrap(), edge, "bits={bits}");
            // one past the top of the range is rejected (except u32::MAX)
            if bits < 32 {
                assert!(pack_indices(&[max + 1], bits).is_err(), "bits={bits}");
            }
        }
        // widths outside 1..=32 are rejected by both directions
        assert!(pack_indices(&[0], 0).is_err());
        assert!(pack_indices(&[0], 33).is_err());
        assert!(unpack_indices(&[0u8; 16], 1, 0).is_err());
        assert!(unpack_indices(&[0u8; 16], 1, 33).is_err());
    }

    #[test]
    fn ten_bit_paper_setting() {
        // K=1024 -> 10 bits; 12 indices -> 120 bits -> 15 bytes (Table 1 G=1
        // per-layer accounting: one token over 12 layers).
        let idx: Vec<u32> = (0..12).map(|i| (i * 83) % 1024).collect();
        let packed = pack_indices(&idx, 10).unwrap();
        assert_eq!(packed.len(), 15);
        assert_eq!(unpack_indices(&packed, 12, 10).unwrap(), idx);
    }

    #[test]
    fn overflow_rejected() {
        assert!(pack_indices(&[8], 3).is_err());
        assert!(pack_indices(&[7], 3).is_ok());
    }

    #[test]
    fn short_buffer_rejected() {
        let packed = pack_indices(&[1, 2, 3], 10).unwrap();
        assert!(unpack_indices(&packed[..2], 3, 10).is_err());
    }
}
