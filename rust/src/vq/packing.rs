//! Bit-packing codec for VQ indices.
//!
//! What actually crosses the simulated network: each index is `ceil(log2 K)`
//! bits, packed little-endian into a byte stream. This makes the paper's
//! "Total Bits per Token" columns *measured* quantities (message length in
//! bits) rather than asserted formulas.

use anyhow::{bail, Result};

/// Bytes needed for `count` indices of `bits` bits each.
pub fn packed_len_bytes(count: usize, bits: usize) -> usize {
    (count * bits + 7) / 8
}

/// Pack indices (each < 2^bits) into a little-endian bitstream.
pub fn pack_indices(indices: &[u32], bits: usize) -> Result<Vec<u8>> {
    if bits == 0 || bits > 32 {
        bail!("bits must be 1..=32, got {bits}");
    }
    let limit = if bits == 32 { u64::from(u32::MAX) + 1 } else { 1u64 << bits };
    let mut out = vec![0u8; packed_len_bytes(indices.len(), bits)];
    let mut bitpos = 0usize;
    for &idx in indices {
        if u64::from(idx) >= limit {
            bail!("index {idx} does not fit in {bits} bits");
        }
        let mut v = u64::from(idx);
        let mut remaining = bits;
        while remaining > 0 {
            let byte = bitpos / 8;
            let off = bitpos % 8;
            let take = (8 - off).min(remaining);
            out[byte] |= ((v & ((1 << take) - 1)) as u8) << off;
            v >>= take;
            bitpos += take;
            remaining -= take;
        }
    }
    Ok(out)
}

/// Unpack `count` indices of `bits` bits each.
pub fn unpack_indices(bytes: &[u8], count: usize, bits: usize) -> Result<Vec<u32>> {
    if bits == 0 || bits > 32 {
        bail!("bits must be 1..=32, got {bits}");
    }
    if bytes.len() < packed_len_bytes(count, bits) {
        bail!(
            "need {} bytes for {count} x {bits}-bit indices, got {}",
            packed_len_bytes(count, bits),
            bytes.len()
        );
    }
    let mut out = Vec::with_capacity(count);
    let mut bitpos = 0usize;
    for _ in 0..count {
        let mut v = 0u64;
        let mut got = 0usize;
        while got < bits {
            let byte = bitpos / 8;
            let off = bitpos % 8;
            let take = (8 - off).min(bits - got);
            let chunk = (bytes[byte] >> off) as u64 & ((1 << take) - 1);
            v |= chunk << got;
            got += take;
            bitpos += take;
        }
        out.push(v as u32);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip_various_widths() {
        let mut rng = Rng::new(0);
        for bits in [1usize, 3, 7, 8, 10, 11, 13, 16, 24, 32] {
            let limit = if bits == 32 { u64::from(u32::MAX) } else { (1u64 << bits) - 1 };
            let idx: Vec<u32> = (0..257)
                .map(|_| (rng.next_u64() % (limit + 1)) as u32)
                .collect();
            let packed = pack_indices(&idx, bits).unwrap();
            assert_eq!(packed.len(), packed_len_bytes(idx.len(), bits));
            let back = unpack_indices(&packed, idx.len(), bits).unwrap();
            assert_eq!(back, idx, "bits={bits}");
        }
    }

    #[test]
    fn ten_bit_paper_setting() {
        // K=1024 -> 10 bits; 12 indices -> 120 bits -> 15 bytes (Table 1 G=1
        // per-layer accounting: one token over 12 layers).
        let idx: Vec<u32> = (0..12).map(|i| (i * 83) % 1024).collect();
        let packed = pack_indices(&idx, 10).unwrap();
        assert_eq!(packed.len(), 15);
        assert_eq!(unpack_indices(&packed, 12, 10).unwrap(), idx);
    }

    #[test]
    fn overflow_rejected() {
        assert!(pack_indices(&[8], 3).is_err());
        assert!(pack_indices(&[7], 3).is_ok());
    }

    #[test]
    fn short_buffer_rejected() {
        let packed = pack_indices(&[1, 2, 3], 10).unwrap();
        assert!(unpack_indices(&packed[..2], 3, 10).is_err());
    }
}
