//! Native grouped vector quantization — the L3 hot-path twin of the Pallas
//! kernels (`python/compile/kernels/vq_kernels.py`). The coordinator uses
//! this for encode-before-send / decode-after-receive when it is cheaper
//! than a PJRT dispatch, and the bit-packing codec that puts `G·log2(K)`
//! bits per token on the (simulated) wire.

pub mod codebook;
pub mod kmeans;
pub mod packing;

pub use codebook::Codebook;
pub use packing::{pack_indices, unpack_indices, packed_len_bytes};
