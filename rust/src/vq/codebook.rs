//! Grouped codebook: nearest-neighbour encode + decode.
//!
//! Numerics match `kernels/ref.py` (full squared distance, argmin with
//! lowest-index tie-break), so indices agree bit-for-bit with the python
//! encoder given the same codebook — asserted by integration tests.

use anyhow::{bail, Result};

use crate::model::shape::ceil_log2;
use crate::tensor::Tensor;

/// One layer's grouped codebook: `[G, K, Dg]`.
#[derive(Debug, Clone)]
pub struct Codebook {
    pub groups: usize,
    pub k: usize,
    pub dg: usize,
    /// flat [G * K * Dg]
    pub data: Vec<f32>,
    /// cached per-centroid squared norms [G * K] (encode fast path)
    norms: Vec<f32>,
    /// transposed layout [G * Dg * K]: encode computes x·eᵀ as an axpy
    /// matmul over contiguous K-rows, which auto-vectorizes (§Perf: 9.4x
    /// over the scalar per-centroid scan — see EXPERIMENTS.md)
    data_t: Vec<f32>,
}

impl Codebook {
    pub fn new(groups: usize, k: usize, dg: usize, data: Vec<f32>) -> Result<Codebook> {
        if data.len() != groups * k * dg {
            bail!(
                "codebook data {} != G*K*Dg = {}*{}*{}",
                data.len(), groups, k, dg
            );
        }
        let mut cb = Codebook { groups, k, dg, data, norms: Vec::new(), data_t: Vec::new() };
        cb.refresh_norms();
        Ok(cb)
    }

    pub fn d_model(&self) -> usize {
        self.groups * self.dg
    }

    pub fn bits_per_token(&self) -> usize {
        self.groups * ceil_log2(self.k)
    }

    fn refresh_norms(&mut self) {
        self.norms = vec![0.0; self.groups * self.k];
        self.data_t = vec![0.0; self.groups * self.dg * self.k];
        for g in 0..self.groups {
            for c in 0..self.k {
                let base = (g * self.k + c) * self.dg;
                let row = &self.data[base..base + self.dg];
                self.norms[g * self.k + c] = row.iter().map(|v| v * v).sum();
                for (j, &v) in row.iter().enumerate() {
                    self.data_t[(g * self.dg + j) * self.k + c] = v;
                }
            }
        }
    }

    #[inline]
    pub fn centroid(&self, g: usize, c: usize) -> &[f32] {
        let base = (g * self.k + c) * self.dg;
        &self.data[base..base + self.dg]
    }

    /// Encode `x` [T, D] -> indices [T * G] (row-major per token).
    ///
    /// Uses the -2·x·e + ‖e‖² identity (‖x‖² constant per row) with the
    /// dot-products computed as an axpy matmul against the transposed
    /// codebook: `scores[c] = Σ_j xg[j] * data_t[j, c]` streams contiguous
    /// K-wide rows, so the inner loop vectorizes.
    pub fn encode(&self, x: &Tensor) -> Result<Vec<u32>> {
        let (t, d) = x.dims2()?;
        if d != self.d_model() {
            bail!("encode dim mismatch: x D={d}, codebook D={}", self.d_model());
        }
        let k = self.k;
        let mut out = vec![0u32; t * self.groups];
        let mut scores = vec![0.0f32; k];
        for ti in 0..t {
            let row = x.row(ti);
            for g in 0..self.groups {
                let xg = &row[g * self.dg..(g + 1) * self.dg];
                // scores = ||e||^2 - 2 * x.e
                scores.copy_from_slice(&self.norms[g * k..(g + 1) * k]);
                let gt = &self.data_t[g * self.dg * k..(g + 1) * self.dg * k];
                for (j, &xv) in xg.iter().enumerate() {
                    let coef = -2.0 * xv;
                    if coef == 0.0 {
                        continue;
                    }
                    let trow = &gt[j * k..(j + 1) * k];
                    for (s, &e) in scores.iter_mut().zip(trow.iter()) {
                        *s += coef * e;
                    }
                }
                let mut best = f32::INFINITY;
                let mut best_i = 0u32;
                for (c, &s) in scores.iter().enumerate() {
                    if s < best {
                        best = s;
                        best_i = c as u32;
                    }
                }
                out[ti * self.groups + g] = best_i;
            }
        }
        Ok(out)
    }

    /// Decode indices [T * G] -> x_hat [T, D].
    pub fn decode(&self, indices: &[u32], t: usize) -> Result<Tensor> {
        if indices.len() != t * self.groups {
            bail!("decode: {} indices for {t} tokens x {} groups", indices.len(), self.groups);
        }
        let d = self.d_model();
        let mut out = Tensor::zeros(&[t, d]);
        for ti in 0..t {
            let row = out.row_mut(ti);
            for g in 0..self.groups {
                let idx = indices[ti * self.groups + g] as usize;
                if idx >= self.k {
                    bail!("decode: index {idx} >= K={}", self.k);
                }
                row[g * self.dg..(g + 1) * self.dg].copy_from_slice(self.centroid(g, idx));
            }
        }
        Ok(out)
    }

    /// encode+decode — the deterministic X_hat used at inference.
    pub fn roundtrip(&self, x: &Tensor) -> Result<Tensor> {
        let (t, _) = x.dims2()?;
        self.decode(&self.encode(x)?, t)
    }

    /// Mean squared quantization error over rows of x.
    pub fn distortion(&self, x: &Tensor) -> Result<f32> {
        let xh = self.roundtrip(x)?;
        let n = x.numel() as f32;
        Ok(x
            .data
            .iter()
            .zip(xh.data.iter())
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f32>()
            / n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_cb(rng: &mut Rng, g: usize, k: usize, dg: usize) -> Codebook {
        let mut data = vec![0.0f32; g * k * dg];
        rng.fill_normal(&mut data);
        Codebook::new(g, k, dg, data).unwrap()
    }

    #[test]
    fn centroids_encode_to_themselves() {
        let mut rng = Rng::new(0);
        let cb = random_cb(&mut rng, 2, 8, 4);
        // build x whose rows are centroids 3 and 5
        let mut data = Vec::new();
        data.extend_from_slice(cb.centroid(0, 3));
        data.extend_from_slice(cb.centroid(1, 5));
        let x = Tensor::from_vec(&[1, 8], data).unwrap();
        let idx = cb.encode(&x).unwrap();
        assert_eq!(idx, vec![3, 5]);
        let xh = cb.decode(&idx, 1).unwrap();
        assert_eq!(xh.data, x.data);
    }

    #[test]
    fn roundtrip_idempotent() {
        let mut rng = Rng::new(1);
        let cb = random_cb(&mut rng, 4, 16, 8);
        let mut x = Tensor::zeros(&[10, 32]);
        rng.fill_normal(&mut x.data);
        let x1 = cb.roundtrip(&x).unwrap();
        let x2 = cb.roundtrip(&x1).unwrap();
        assert_eq!(x1.data, x2.data);
    }

    #[test]
    fn distortion_decreases_with_k() {
        let mut rng = Rng::new(2);
        let mut x = Tensor::zeros(&[64, 16]);
        rng.fill_normal(&mut x.data);
        // same data, nested codebooks: bigger K can only help on average
        let d_small = random_cb(&mut rng, 2, 4, 8).distortion(&x).unwrap();
        let d_big = random_cb(&mut rng, 2, 64, 8).distortion(&x).unwrap();
        assert!(d_big < d_small, "{d_big} vs {d_small}");
    }

    #[test]
    fn errors() {
        let mut rng = Rng::new(3);
        let cb = random_cb(&mut rng, 2, 4, 4);
        let x = Tensor::zeros(&[2, 16]); // wrong D
        assert!(cb.encode(&x).is_err());
        assert!(cb.decode(&[0, 1, 2], 2).is_err()); // wrong count
        assert!(cb.decode(&[9, 9, 9, 9], 2).is_err()); // idx out of range
        assert!(Codebook::new(2, 4, 4, vec![0.0; 3]).is_err());
    }
}
