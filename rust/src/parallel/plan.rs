//! Pure heterogeneous planning: profile + bandwidth in, argmin plan out.
//!
//! # Module contract
//!
//! The [`Planner`] is *pure data in, plan out*: it consumes a
//! [`FleetProfile`] and the current measured bandwidth, scores a small
//! fixed candidate list (uneven-split variants of the serving strategy
//! plus hybrid TP/SP re-partitions, Galaxy-style), and returns the
//! argmin-latency [`Plan`]. It owns **no clock** and talks to **no
//! backend** — the same inputs always produce the same plan, and the only
//! allocations are the returned label and the transient weighted profiles,
//! so it is cheap enough to run on every `--replan-every` tick.
//!
//! Candidate `0` is always the even-split plan priced exactly like today's
//! static engine (legacy schedule builders on the reference device), so
//! the chosen plan's modeled latency is never worse than the even-split
//! baseline by construction, and "planner off" and "planner picked the
//! status quo" are the same code path.

use crate::model::shape::TransformerShape;

use super::cost::{DeviceModel, FleetProfile, Schedule};
use super::strategies::{Strategy, StrategyKind};

/// How a candidate splits tokens over the fleet.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SplitMode {
    /// Legacy even split, priced on the reference device — today's engine.
    Even,
    /// Fully proportional to measured device speed.
    Proportional,
    /// Proportional to `speed^0.5` — hedges an overconfident profile.
    Damped,
}

impl SplitMode {
    pub fn name(&self) -> &'static str {
        match self {
            SplitMode::Even => "even",
            SplitMode::Proportional => "proportional",
            SplitMode::Damped => "damped",
        }
    }

    /// The profile a candidate's schedules should be built on. `Even`
    /// returns a uniform profile so the `*_on` builders delegate to the
    /// legacy (bit-identical) schedules; `Damped` compresses the weights.
    pub fn weighted(&self, profile: &FleetProfile) -> FleetProfile {
        match self {
            SplitMode::Even => {
                let base = profile
                    .devices
                    .first()
                    .copied()
                    .unwrap_or_else(DeviceModel::paper_1660ti)
                    .with_speed(1.0);
                FleetProfile::uniform(base, profile.n())
            }
            SplitMode::Proportional => profile.clone(),
            SplitMode::Damped => profile.damped(),
        }
    }

    /// Per-device weights a live session should partition its prompt by,
    /// `None` when the split is even (keep the cluster's own partition).
    pub fn split_weights(&self, profile: &FleetProfile) -> Option<Vec<f64>> {
        match self {
            SplitMode::Even => None,
            _ => Some(self.weighted(profile).weights()),
        }
    }
}

/// One planner decision: which strategy kind runs with which split, and
/// the modeled latency that won the argmin. `index` identifies the
/// candidate slot (stable across re-plans, reported in
/// `CbEvent::Replan { from, to }`).
#[derive(Debug, Clone, PartialEq)]
pub struct Plan {
    pub index: usize,
    pub label: String,
    pub kind: StrategyKind,
    pub split: SplitMode,
    pub modeled_latency_s: f64,
}

impl Plan {
    /// True when this plan prices exactly like the static engine.
    pub fn is_even_baseline(&self) -> bool {
        self.index == 0
    }
}

/// Deterministic argmin-latency planner over a fixed candidate list.
#[derive(Debug, Clone)]
pub struct Planner {
    pub shape: TransformerShape,
    /// the strategy the engine was configured with (candidate 0's kind)
    pub base: Strategy,
    /// reference device all schedules are evaluated on
    pub device: DeviceModel,
    pub stage_latency_s: f64,
    /// decode steps weighted against one prefill in the objective — decode
    /// dominates a serving steady state, so the objective is
    /// `prefill + decode_steps * batched_decode_step`
    pub decode_steps: usize,
    /// decode batch size assumed for the objective's decode term
    pub decode_batch: usize,
}

impl Planner {
    pub fn new(
        shape: TransformerShape,
        base: Strategy,
        device: DeviceModel,
        stage_latency_s: f64,
    ) -> Planner {
        Planner { shape, base, device, stage_latency_s, decode_steps: 32, decode_batch: 8 }
    }

    /// The fixed candidate list. Slot 0 is the even-split status quo;
    /// slots 1-2 re-weight the configured strategy; slots 3-4 are the
    /// Galaxy-style hybrid re-partitions onto TP / SP.
    pub fn candidates(&self) -> Vec<(StrategyKind, SplitMode)> {
        vec![
            (self.base.kind, SplitMode::Even),
            (self.base.kind, SplitMode::Proportional),
            (self.base.kind, SplitMode::Damped),
            (StrategyKind::TensorParallel, SplitMode::Proportional),
            (StrategyKind::SequenceParallel, SplitMode::Proportional),
        ]
    }

    fn objective(&self, prefill: &Schedule, decode: &Schedule, mbps: f64) -> f64 {
        prefill.latency(&self.device, mbps, self.stage_latency_s)
            + self.decode_steps as f64 * decode.latency(&self.device, mbps, self.stage_latency_s)
    }

    /// Modeled objective of candidate `index` under `profile` at `mbps` —
    /// also used by the re-plan hysteresis check to re-score an incumbent.
    pub fn score_index(&self, index: usize, profile: &FleetProfile, mbps: f64) -> f64 {
        let (kind, split) = self.candidates()[index];
        let strategy = Strategy::new(kind, self.base.n_devices);
        let ctx = self.shape.seq_len;
        let (prefill, decode) = match split {
            SplitMode::Even => (
                strategy.schedule(&self.shape),
                strategy.decode_step_schedule(&self.shape, ctx).for_batch(self.decode_batch),
            ),
            _ => {
                let weighted = split.weighted(profile);
                (
                    strategy.schedule_on(&self.shape, &weighted),
                    strategy
                        .decode_step_schedule_on(&self.shape, ctx, &weighted)
                        .for_batch(self.decode_batch),
                )
            }
        };
        self.objective(&prefill, &decode, mbps)
    }

    /// Argmin over the candidate list; ties keep the lowest index, so a
    /// uniform profile always returns the even-split status quo (slot 0).
    pub fn plan(&self, profile: &FleetProfile, mbps: f64) -> Plan {
        let mut best: Option<Plan> = None;
        for (index, (kind, split)) in self.candidates().into_iter().enumerate() {
            let latency = self.score_index(index, profile, mbps);
            if best.as_ref().is_none_or(|b| latency < b.modeled_latency_s) {
                let strategy = Strategy::new(kind, self.base.n_devices);
                best = Some(Plan {
                    index,
                    label: format!("{}/{}", strategy.name(), split.name()),
                    kind,
                    split,
                    modeled_latency_s: latency,
                });
            }
        }
        best.expect("candidate list is non-empty")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::shape::VqSetting;
    use crate::util::rng::Rng;

    fn planner() -> Planner {
        Planner::new(
            TransformerShape::paper_encoder(1024),
            Strategy::new(StrategyKind::Astra { vq: VqSetting::new(16, 1024) }, 4),
            DeviceModel::paper_1660ti(),
            0.0006,
        )
    }

    #[test]
    fn uniform_fleet_keeps_the_even_status_quo() {
        let p = planner();
        let uni = FleetProfile::uniform(DeviceModel::paper_1660ti(), 4);
        for mbps in [10.0, 50.0, 100.0, 500.0] {
            let plan = p.plan(&uni, mbps);
            assert_eq!(plan.index, 0, "uniform fleet re-planned at {mbps} Mbps: {plan:?}");
            assert!(plan.is_even_baseline());
        }
    }

    #[test]
    fn chosen_plan_never_worse_than_even_on_seeded_skewed_fleets() {
        let p = planner();
        let mut rng = Rng::new(23);
        for trial in 0..25 {
            let speeds: Vec<f64> =
                (0..4).map(|_| 0.25 + 3.75 * (rng.below(1000) as f64 / 1000.0)).collect();
            let profile = FleetProfile::from_speeds(DeviceModel::paper_1660ti(), &speeds);
            for mbps in [10.0, 100.0] {
                let plan = p.plan(&profile, mbps);
                let even = p.score_index(0, &profile, mbps);
                assert!(
                    plan.modeled_latency_s <= even + 1e-12,
                    "trial {trial} {speeds:?} at {mbps}: {} vs even {even}",
                    plan.modeled_latency_s
                );
            }
        }
    }

    #[test]
    fn strong_skew_fleet_beats_even_strictly() {
        let p = planner();
        let profile = FleetProfile::from_speeds(DeviceModel::paper_1660ti(), &[4.0, 2.0, 1.0, 0.5]);
        let plan = p.plan(&profile, 100.0);
        let even = p.score_index(0, &profile, 100.0);
        assert!(plan.index != 0, "{plan:?}");
        assert!(plan.modeled_latency_s < even, "{} vs {even}", plan.modeled_latency_s);
        // the weights handed to live sessions favor the fast device
        let w = plan.split.split_weights(&profile).expect("non-even plan carries weights");
        assert!(w[0] > w[3]);
        // determinism: same inputs, same plan
        assert_eq!(p.plan(&profile, 100.0), plan);
    }
}
