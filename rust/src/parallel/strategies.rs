//! The paper's strategies as prefill schedules.
//!
//! * `SingleDevice` — the "Original Model" baseline.
//! * `TensorParallel` (Megatron-LM): weights sharded; 2 ring all-reduces of
//!   the full activation per layer.
//! * `SequenceParallel` (Voltage): tokens sharded; 1 ring all-gather of the
//!   activation per layer; every device projects K/V for the full sequence.
//! * `BlockParallel` (DeTransformer): restructured model with `n_b` retained
//!   block boundaries; one sync per boundary. BP+AG trades extra local
//!   compute for fewer bits; BP+SP keeps compute lean but roughly doubles
//!   the exchanged volume (two all-gathers per boundary).
//! * `Astra` — tokens sharded; per layer each device VQ-encodes its local
//!   tokens, multicasts `T/N * G*log2K` bits, decodes peers' codes, and runs
//!   the Mixed-Precision Attention block. VQ encode/decode FLOPs are charged
//!   to compute.
//!
//! Cost-model caveats vs the paper's testbed measurements are documented in
//! DESIGN.md §2 (ring collectives here; the paper's numbers mix Megatron /
//! Voltage / DeTransformer implementations).

use crate::comm::collective::{allgather, allreduce, code_multicast, CommCost};
use crate::model::shape::{TransformerShape, VqSetting};

use super::cost::{FleetProfile, Phase, Schedule};

/// Extra local-compute multiplier for BP+AG (DeTransformer performs more
/// computation locally to cut communication; calibrated from Table 7).
pub const BP_AG_COMPUTE_FACTOR: f64 = 1.25;

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum StrategyKind {
    SingleDevice,
    TensorParallel,
    SequenceParallel,
    /// (n_b, sp_variant): BP+SP when `sp_variant`, else BP+AG
    BlockParallel { n_b: usize, sp_variant: bool },
    Astra { vq: VqSetting },
}

/// A strategy bound to a device count.
#[derive(Debug, Clone, Copy)]
pub struct Strategy {
    pub kind: StrategyKind,
    pub n_devices: usize,
}

impl Strategy {
    pub fn new(kind: StrategyKind, n_devices: usize) -> Strategy {
        Strategy { kind, n_devices }
    }

    pub fn name(&self) -> String {
        match self.kind {
            StrategyKind::SingleDevice => "Single".into(),
            StrategyKind::TensorParallel => "TP".into(),
            StrategyKind::SequenceParallel => "SP".into(),
            StrategyKind::BlockParallel { n_b, sp_variant } => {
                format!("BP+{}, Nb={}", if sp_variant { "SP" } else { "AG" }, n_b)
            }
            StrategyKind::Astra { vq } => format!("ASTRA, G={}", vq.groups),
        }
    }

    /// Prefill schedule for one request of `shape.seq_len` tokens.
    pub fn schedule(&self, shape: &TransformerShape) -> Schedule {
        let n = self.n_devices;
        let t = shape.seq_len;
        let l = shape.n_layers;
        let act_bits = (t * shape.d_model * shape.elem_bytes * 8) as f64;
        let mut phases = Vec::new();
        match self.kind {
            StrategyKind::SingleDevice => {
                phases.push(Phase::compute("forward", shape.total_flops(), l));
            }
            StrategyKind::TensorParallel => {
                // weights sharded 1/N; activation stays full T
                for _ in 0..l {
                    phases.push(Phase::compute(
                        "block/N",
                        shape.block_flops(t, t) / n as f64,
                        1,
                    ));
                    phases.push(Phase::comm("allreduce x2", sum2(allreduce(act_bits, n))));
                }
            }
            StrategyKind::SequenceParallel => {
                for _ in 0..l {
                    // device computes q for T/N tokens, k/v for full T
                    phases.push(Phase::compute("block seq-shard", shape.block_flops(t / n, t), 1));
                    phases.push(Phase::comm("allgather", allgather(act_bits, n)));
                }
            }
            StrategyKind::BlockParallel { n_b, sp_variant } => {
                let factor = if sp_variant { 1.0 } else { BP_AG_COMPUTE_FACTOR };
                // compute spread over n_b segments
                let per_segment = shape.total_flops() * factor / (n as f64 * n_b as f64);
                for _ in 0..n_b {
                    phases.push(Phase::compute("bp segment", per_segment, l / n_b.max(1)));
                    let sync = if sp_variant {
                        // two all-gathers per boundary
                        sum2(allgather(act_bits, n))
                    } else {
                        allgather(act_bits, n)
                    };
                    phases.push(Phase::comm("bp sync", sync));
                }
            }
            StrategyKind::Astra { vq } => {
                let code_chunk_bits = (t / n * vq.bits_per_token()) as f64;
                for _ in 0..l {
                    // VQ encode local tokens + decode (n-1) peers' codes
                    let vq_flops = shape.vq_encode_flops(t / n, vq.groups, vq.codebook_size)
                        + shape.vq_decode_flops(t - t / n, vq.groups, vq.codebook_size);
                    phases.push(Phase::compute("vq encode/decode", vq_flops, 1));
                    phases.push(Phase::comm("code exchange", code_multicast(code_chunk_bits, n)));
                    // MPA block: q over T/N local tokens, k/v over local
                    // full-precision + dequantized remote = full T columns
                    phases.push(Phase::compute("mpa block", shape.block_flops(t / n, t), 1));
                }
            }
        }
        Schedule { phases }
    }

    /// One-token decode-step schedule at KV context `ctx` (prompt plus
    /// already-generated tokens). Decode runs on the device owning the
    /// sequence tail (paper §5 / Appendix G: the tail device holds the
    /// mixed-precision cache), so for Single/SP/BP/ASTRA it is pure local
    /// compute floored by one streaming pass over the weights — the
    /// memory-bound regime that batched decode amortizes. TP keeps weights
    /// sharded and pays two one-token all-reduces per layer.
    pub fn decode_step_schedule(&self, shape: &TransformerShape, ctx: usize) -> Schedule {
        let n = self.n_devices;
        let mut phases = Vec::new();
        match self.kind {
            StrategyKind::TensorParallel => {
                phases.push(Phase::compute_mem(
                    "decode block/N",
                    shape.decode_step_flops(ctx) / n as f64,
                    shape.n_layers,
                    shape.weight_bytes() / n as f64,
                ));
                let act_bits = shape.token_bits() as f64;
                let mut comm = CommCost::ZERO;
                for _ in 0..shape.n_layers {
                    comm = comm.plus(sum2(allreduce(act_bits, n)));
                }
                phases.push(Phase::comm("decode allreduce x2", comm));
            }
            _ => {
                phases.push(Phase::compute_mem(
                    "decode step (tail device)",
                    shape.decode_step_flops(ctx),
                    shape.n_layers,
                    shape.weight_bytes(),
                ));
            }
        }
        Schedule { phases }
    }

    /// Schedule of one prefill *chunk*: `chunk` new prompt tokens advanced
    /// through every layer while attending to `ctx` total context (the
    /// prompt rows prefilled so far, including this chunk). Aggregated into
    /// one compute phase — carrying the weight-streaming floor, because a
    /// small chunk is memory-bound exactly like a decode step — plus one
    /// comm phase, so decode work can be piggybacked onto it
    /// ([`Schedule::piggyback`]) paying launches/sync/floor once.
    pub fn prefill_chunk_schedule(
        &self,
        shape: &TransformerShape,
        chunk: usize,
        ctx: usize,
    ) -> Schedule {
        let n = self.n_devices;
        let l = shape.n_layers;
        let ctx = ctx.max(chunk).max(1);
        // bottleneck device's share of the chunk (ceil: one device absorbs
        // the remainder, mirroring prompt_partition on even partitions)
        let local = chunk.div_ceil(n.max(1)).max(1);
        let act_bits = (chunk * shape.d_model * shape.elem_bytes * 8) as f64;
        let (flops, launches, comm, mem_bytes) = match self.kind {
            StrategyKind::SingleDevice => (
                l as f64 * shape.chunk_block_flops(chunk, chunk, ctx),
                l,
                CommCost::ZERO,
                shape.weight_bytes(),
            ),
            StrategyKind::TensorParallel => {
                let mut comm = CommCost::ZERO;
                for _ in 0..l {
                    comm = comm.plus(sum2(allreduce(act_bits, n)));
                }
                (
                    l as f64 * shape.chunk_block_flops(chunk, chunk, ctx) / n as f64,
                    l,
                    comm,
                    shape.weight_bytes() / n as f64,
                )
            }
            StrategyKind::SequenceParallel => {
                let mut comm = CommCost::ZERO;
                for _ in 0..l {
                    comm = comm.plus(allgather(act_bits, n));
                }
                (
                    l as f64 * shape.chunk_block_flops(local, chunk, ctx),
                    l,
                    comm,
                    shape.weight_bytes(),
                )
            }
            StrategyKind::BlockParallel { n_b, sp_variant } => {
                let factor = if sp_variant { 1.0 } else { BP_AG_COMPUTE_FACTOR };
                let mut comm = CommCost::ZERO;
                for _ in 0..n_b {
                    comm = comm.plus(if sp_variant {
                        sum2(allgather(act_bits, n))
                    } else {
                        allgather(act_bits, n)
                    });
                }
                (
                    l as f64 * shape.chunk_block_flops(chunk, chunk, ctx) * factor / n as f64,
                    l,
                    comm,
                    shape.weight_bytes() / n as f64,
                )
            }
            StrategyKind::Astra { vq } => {
                let code_chunk_bits = (local * vq.bits_per_token()) as f64;
                let remote = chunk.saturating_sub(local);
                let vq_flops = shape.vq_encode_flops(local, vq.groups, vq.codebook_size)
                    + shape.vq_decode_flops(remote, vq.groups, vq.codebook_size);
                let mut comm = CommCost::ZERO;
                for _ in 0..l {
                    comm = comm.plus(code_multicast(code_chunk_bits, n));
                }
                (
                    l as f64 * (vq_flops + shape.chunk_block_flops(local, chunk, ctx)),
                    2 * l, // vq encode/decode + mpa block per layer
                    comm,
                    shape.weight_bytes(),
                )
            }
        };
        let mut phases = vec![Phase::compute_mem("prefill chunk", flops, launches, mem_bytes)];
        if comm.bits > 0.0 || comm.stages > 0 {
            phases.push(Phase::comm("chunk exchange", comm));
        }
        Schedule { phases }
    }

    /// One fused chunk+decode iteration (Sarathi-style piggybacking):
    /// `chunk` prompt tokens advanced at context `ctx_prefill`, co-scheduled
    /// with one decode token for each of `decode_batch` in-flight slots at
    /// KV context `ctx_decode`. FLOPs and wire bits are paid for the chunk
    /// tokens plus one token per decode slot; kernel launches, collective
    /// sync stages, and the weight-streaming floor are paid once for the
    /// whole fused iteration. With `chunk == 0` this degenerates to the
    /// plain batched decode step; with `decode_batch == 0` to the bare
    /// chunk.
    pub fn fused_iteration_schedule(
        &self,
        shape: &TransformerShape,
        chunk: usize,
        ctx_prefill: usize,
        decode_batch: usize,
        ctx_decode: usize,
    ) -> Schedule {
        if chunk == 0 {
            return self.decode_step_schedule(shape, ctx_decode).for_batch(decode_batch.max(1));
        }
        let sched = self.prefill_chunk_schedule(shape, chunk, ctx_prefill);
        if decode_batch == 0 {
            return sched;
        }
        let n = self.n_devices;
        let b = decode_batch as f64;
        let (dec_flops, dec_bits) = match self.kind {
            StrategyKind::TensorParallel => (
                shape.decode_step_flops(ctx_decode) / n as f64 * b,
                sum2(allreduce(shape.token_bits() as f64, n)).bits * shape.n_layers as f64 * b,
            ),
            _ => (shape.decode_step_flops(ctx_decode) * b, 0.0),
        };
        sched.piggyback(dec_flops, dec_bits)
    }

    // ----- heterogeneity-aware variants ---------------------------------
    //
    // Each `*_on` method is the profile-weighted generalization of its
    // legacy counterpart: token splits follow `FleetProfile::split`
    // (proportional to relative device speed), per-phase cost is the max
    // over per-device completion times expressed in reference-device units
    // (`max_i F_i / w_i` — see the `FleetProfile` docs for why that is
    // exact under the existing single-device evaluators), and every
    // collective's bits are scaled by the link bottleneck factor. A
    // uniform profile (or one whose device count does not match the
    // strategy's) delegates to the legacy method verbatim — the
    // bit-identity anchor for heterogeneity-off configs.

    /// Profile-weighted prefill schedule: [`Strategy::schedule`] over a
    /// heterogeneous fleet with proportional token splits.
    pub fn schedule_on(&self, shape: &TransformerShape, profile: &FleetProfile) -> Schedule {
        if profile.is_uniform() || profile.n() != self.n_devices {
            return self.schedule(shape);
        }
        let n = self.n_devices;
        let t = shape.seq_len;
        let l = shape.n_layers;
        let act_bits = (t * shape.d_model * shape.elem_bytes * 8) as f64;
        let w = profile.weights();
        let wsum = profile.sum_weights();
        let wmax = profile.max_weight();
        let bf = profile.bottleneck_factor();
        let split = profile.split(t);
        let mut phases = Vec::new();
        match self.kind {
            StrategyKind::SingleDevice => {
                // the whole model runs on the fastest device
                phases.push(Phase::compute("forward", shape.total_flops() / wmax, l));
            }
            StrategyKind::TensorParallel => {
                // weights sharded proportionally to speed: every device
                // finishes its share simultaneously, so the fleet phase
                // time is F / sum(w) reference-units
                for _ in 0..l {
                    phases.push(Phase::compute("block/N", shape.block_flops(t, t) / wsum, 1));
                    phases.push(Phase::comm(
                        "allreduce x2",
                        scaled(sum2(allreduce(act_bits, n)), bf),
                    ));
                }
            }
            StrategyKind::SequenceParallel => {
                let gate = gated(&split.sizes, &w, |s| shape.block_flops(s, t));
                for _ in 0..l {
                    phases.push(Phase::compute("block seq-shard", gate, 1));
                    phases.push(Phase::comm("allgather", scaled(allgather(act_bits, n), bf)));
                }
            }
            StrategyKind::BlockParallel { n_b, sp_variant } => {
                let factor = if sp_variant { 1.0 } else { BP_AG_COMPUTE_FACTOR };
                let per_segment = shape.total_flops() * factor / (wsum * n_b as f64);
                for _ in 0..n_b {
                    phases.push(Phase::compute("bp segment", per_segment, l / n_b.max(1)));
                    let sync = if sp_variant {
                        sum2(allgather(act_bits, n))
                    } else {
                        allgather(act_bits, n)
                    };
                    phases.push(Phase::comm("bp sync", scaled(sync, bf)));
                }
            }
            StrategyKind::Astra { vq } => {
                // the largest local chunk gates the multicast payload
                let t_gate = split.sizes.iter().copied().max().unwrap_or(0);
                let code_chunk_bits = (t_gate * vq.bits_per_token()) as f64;
                let vq_gate = gated(&split.sizes, &w, |s| {
                    shape.vq_encode_flops(s, vq.groups, vq.codebook_size)
                        + shape.vq_decode_flops(t - s, vq.groups, vq.codebook_size)
                });
                let mpa_gate = gated(&split.sizes, &w, |s| shape.block_flops(s, t));
                for _ in 0..l {
                    phases.push(Phase::compute("vq encode/decode", vq_gate, 1));
                    phases.push(Phase::comm(
                        "code exchange",
                        scaled(code_multicast(code_chunk_bits, n), bf),
                    ));
                    phases.push(Phase::compute("mpa block", mpa_gate, 1));
                }
            }
        }
        Schedule { phases }
    }

    /// Profile-weighted decode step: TP keeps weights sharded by speed
    /// (fleet rate `sum(w)`); every other strategy places the decode owner
    /// on the *fastest* device instead of the positional tail — the
    /// placement the planner and admission policy assume.
    pub fn decode_step_schedule_on(
        &self,
        shape: &TransformerShape,
        ctx: usize,
        profile: &FleetProfile,
    ) -> Schedule {
        if profile.is_uniform() || profile.n() != self.n_devices {
            return self.decode_step_schedule(shape, ctx);
        }
        let n = self.n_devices;
        let wsum = profile.sum_weights();
        let wmax = profile.max_weight();
        let bf = profile.bottleneck_factor();
        let mut phases = Vec::new();
        match self.kind {
            StrategyKind::TensorParallel => {
                phases.push(Phase::compute_mem(
                    "decode block/N",
                    shape.decode_step_flops(ctx) / wsum,
                    shape.n_layers,
                    shape.weight_bytes() / wsum,
                ));
                let act_bits = shape.token_bits() as f64;
                let mut comm = CommCost::ZERO;
                for _ in 0..shape.n_layers {
                    comm = comm.plus(sum2(allreduce(act_bits, n)));
                }
                phases.push(Phase::comm("decode allreduce x2", scaled(comm, bf)));
            }
            _ => {
                phases.push(Phase::compute_mem(
                    "decode step (fastest device)",
                    shape.decode_step_flops(ctx) / wmax,
                    shape.n_layers,
                    shape.weight_bytes() / wmax,
                ));
            }
        }
        Schedule { phases }
    }

    /// Profile-weighted prefill chunk (see [`Strategy::prefill_chunk_schedule`]).
    /// Strategies where every device streams the full weight set (SP,
    /// ASTRA) keep a floor gated by the *slowest* device — uneven token
    /// splits cannot buy back a memory-bound chunk, which is exactly why
    /// the planner may prefer a different strategy kind on skewed fleets.
    pub fn prefill_chunk_schedule_on(
        &self,
        shape: &TransformerShape,
        chunk: usize,
        ctx: usize,
        profile: &FleetProfile,
    ) -> Schedule {
        if profile.is_uniform() || profile.n() != self.n_devices {
            return self.prefill_chunk_schedule(shape, chunk, ctx);
        }
        let n = self.n_devices;
        let l = shape.n_layers;
        let ctx = ctx.max(chunk).max(1);
        let w = profile.weights();
        let wsum = profile.sum_weights();
        let wmax = profile.max_weight();
        let wmin = profile.min_weight();
        let bf = profile.bottleneck_factor();
        let split = profile.split(chunk.max(1));
        let act_bits = (chunk * shape.d_model * shape.elem_bytes * 8) as f64;
        let (flops, launches, comm, mem_bytes) = match self.kind {
            StrategyKind::SingleDevice => (
                l as f64 * shape.chunk_block_flops(chunk, chunk, ctx) / wmax,
                l,
                CommCost::ZERO,
                shape.weight_bytes() / wmax,
            ),
            StrategyKind::TensorParallel => {
                let mut comm = CommCost::ZERO;
                for _ in 0..l {
                    comm = comm.plus(sum2(allreduce(act_bits, n)));
                }
                (
                    l as f64 * shape.chunk_block_flops(chunk, chunk, ctx) / wsum,
                    l,
                    scaled(comm, bf),
                    shape.weight_bytes() / wsum,
                )
            }
            StrategyKind::SequenceParallel => {
                let mut comm = CommCost::ZERO;
                for _ in 0..l {
                    comm = comm.plus(allgather(act_bits, n));
                }
                let gate = gated(&split.sizes, &w, |s| shape.chunk_block_flops(s, chunk, ctx));
                (l as f64 * gate, l, scaled(comm, bf), shape.weight_bytes() / wmin)
            }
            StrategyKind::BlockParallel { n_b, sp_variant } => {
                let factor = if sp_variant { 1.0 } else { BP_AG_COMPUTE_FACTOR };
                let mut comm = CommCost::ZERO;
                for _ in 0..n_b {
                    comm = comm.plus(if sp_variant {
                        sum2(allgather(act_bits, n))
                    } else {
                        allgather(act_bits, n)
                    });
                }
                (
                    l as f64 * shape.chunk_block_flops(chunk, chunk, ctx) * factor / wsum,
                    l,
                    scaled(comm, bf),
                    shape.weight_bytes() / wsum,
                )
            }
            StrategyKind::Astra { vq } => {
                let t_gate = split.sizes.iter().copied().max().unwrap_or(0);
                let code_chunk_bits = (t_gate * vq.bits_per_token()) as f64;
                let gate = gated(&split.sizes, &w, |s| {
                    shape.vq_encode_flops(s, vq.groups, vq.codebook_size)
                        + shape.vq_decode_flops(chunk.saturating_sub(s), vq.groups, vq.codebook_size)
                        + shape.chunk_block_flops(s, chunk, ctx)
                });
                let mut comm = CommCost::ZERO;
                for _ in 0..l {
                    comm = comm.plus(code_multicast(code_chunk_bits, n));
                }
                (l as f64 * gate, 2 * l, scaled(comm, bf), shape.weight_bytes() / wmin)
            }
        };
        let mut phases = vec![Phase::compute_mem("prefill chunk", flops, launches, mem_bytes)];
        if comm.bits > 0.0 || comm.stages > 0 {
            phases.push(Phase::comm("chunk exchange", comm));
        }
        Schedule { phases }
    }

    /// Profile-weighted fused chunk+decode iteration (see
    /// [`Strategy::fused_iteration_schedule`]). The piggybacked decode
    /// FLOPs ride the decode owner's device (fastest for non-TP, the
    /// speed-sharded fleet for TP), an approximation consistent with
    /// [`Strategy::decode_step_schedule_on`].
    pub fn fused_iteration_schedule_on(
        &self,
        shape: &TransformerShape,
        chunk: usize,
        ctx_prefill: usize,
        decode_batch: usize,
        ctx_decode: usize,
        profile: &FleetProfile,
    ) -> Schedule {
        if profile.is_uniform() || profile.n() != self.n_devices {
            return self.fused_iteration_schedule(shape, chunk, ctx_prefill, decode_batch, ctx_decode);
        }
        if chunk == 0 {
            return self
                .decode_step_schedule_on(shape, ctx_decode, profile)
                .for_batch(decode_batch.max(1));
        }
        let sched = self.prefill_chunk_schedule_on(shape, chunk, ctx_prefill, profile);
        if decode_batch == 0 {
            return sched;
        }
        let n = self.n_devices;
        let b = decode_batch as f64;
        let bf = profile.bottleneck_factor();
        let (dec_flops, dec_bits) = match self.kind {
            StrategyKind::TensorParallel => (
                shape.decode_step_flops(ctx_decode) / profile.sum_weights() * b,
                sum2(allreduce(shape.token_bits() as f64, n)).bits / bf
                    * shape.n_layers as f64
                    * b,
            ),
            _ => (shape.decode_step_flops(ctx_decode) / profile.max_weight() * b, 0.0),
        };
        sched.piggyback(dec_flops, dec_bits)
    }

    /// Payload bits a single transmitted token costs over the whole model
    /// (the paper's "Total Bits per Token" column).
    pub fn total_bits_per_token(&self, shape: &TransformerShape) -> usize {
        match self.kind {
            StrategyKind::SingleDevice => 0,
            StrategyKind::Astra { vq } => vq.total_bits_per_token(shape.n_layers),
            _ => shape.total_bits_per_token(),
        }
    }
}

fn sum2(c: CommCost) -> CommCost {
    c.plus(c)
}

/// Fleet phase time in reference-device units: the slowest device's
/// per-device work `f(tokens_i)` divided by its relative speed, maxed.
fn gated(sizes: &[usize], weights: &[f64], f: impl Fn(usize) -> f64) -> f64 {
    sizes.iter().zip(weights).map(|(&s, &w)| f(s) / w.max(1e-6)).fold(0.0, f64::max)
}

/// A collective over links whose slowest member runs at `factor` times the
/// trace bandwidth: same sync stages, bits inflated by `1/factor`.
fn scaled(c: CommCost, factor: f64) -> CommCost {
    CommCost { bits: c.bits / factor.max(1e-6), stages: c.stages }
}

/// The baseline set evaluated in Figure 1 / Table 4 at a given device count.
pub fn figure1_strategies(n: usize) -> Vec<Strategy> {
    vec![
        Strategy::new(StrategyKind::TensorParallel, n),
        Strategy::new(StrategyKind::SequenceParallel, n),
        Strategy::new(StrategyKind::BlockParallel { n_b: 1, sp_variant: false }, n),
        Strategy::new(StrategyKind::BlockParallel { n_b: 4, sp_variant: false }, n),
        Strategy::new(StrategyKind::BlockParallel { n_b: 1, sp_variant: true }, n),
        Strategy::new(StrategyKind::BlockParallel { n_b: 4, sp_variant: true }, n),
        Strategy::new(StrategyKind::Astra { vq: VqSetting::new(1, 1024) }, n),
        Strategy::new(StrategyKind::Astra { vq: VqSetting::new(16, 1024) }, n),
        Strategy::new(StrategyKind::Astra { vq: VqSetting::new(32, 1024) }, n),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::parallel::cost::DeviceModel;

    fn lat(s: &Strategy, shape: &TransformerShape, mbps: f64) -> f64 {
        s.schedule(shape).latency(&DeviceModel::paper_1660ti(), mbps, 0.0006)
    }

    #[test]
    fn astra_beats_baselines_at_low_bandwidth() {
        let shape = TransformerShape::paper_encoder(1024);
        let single = Strategy::new(StrategyKind::SingleDevice, 1);
        let t_single = lat(&single, &shape, 10.0);
        let astra = Strategy::new(
            StrategyKind::Astra { vq: VqSetting::new(1, 1024) }, 4);
        let t_astra = lat(&astra, &shape, 10.0);
        // paper Fig 1: ~2.6x speedup at 10 Mbps
        let speedup = t_single / t_astra;
        assert!(speedup > 1.5 && speedup < 4.0, "speedup {speedup}");
        for s in figure1_strategies(4) {
            if matches!(s.kind, StrategyKind::Astra { .. }) {
                continue;
            }
            let t_b = lat(&s, &shape, 10.0);
            assert!(t_astra < t_b, "{} {t_astra} vs {t_b}", s.name());
            // baselines slower than single device at 10 Mbps (paper Fig 1)
            assert!(t_b > t_single, "{} should lose to single-device", s.name());
        }
    }

    #[test]
    fn baselines_recover_at_high_bandwidth() {
        let shape = TransformerShape::paper_encoder(1024);
        let t_single = lat(&Strategy::new(StrategyKind::SingleDevice, 1), &shape, 500.0);
        let bp = Strategy::new(StrategyKind::BlockParallel { n_b: 1, sp_variant: false }, 4);
        assert!(lat(&bp, &shape, 500.0) < t_single, "BP+AG should win at 500 Mbps");
    }

    #[test]
    fn astra_latency_nearly_bandwidth_independent() {
        // Table 7 shape: ASTRA G=1 moves from 1.563 s to 1.540 s across
        // 10..500 Mbps — a <2% swing.
        let shape = TransformerShape::llama3_8b(1024);
        let astra = Strategy::new(StrategyKind::Astra { vq: VqSetting::new(1, 1024) }, 4);
        let dev = DeviceModel::paper_titanx_llama();
        let t10 = astra.schedule(&shape).latency(&dev, 10.0, 0.002);
        let t500 = astra.schedule(&shape).latency(&dev, 500.0, 0.002);
        assert!((t10 - t500) / t500 < 0.10, "{t10} vs {t500}");
    }

    #[test]
    fn tp_comm_exceeds_sp_comm() {
        let shape = TransformerShape::paper_encoder(1024);
        let tp = Strategy::new(StrategyKind::TensorParallel, 4).schedule(&shape);
        let sp = Strategy::new(StrategyKind::SequenceParallel, 4).schedule(&shape);
        assert!(tp.total_comm_bits() > 2.0 * sp.total_comm_bits());
    }

    #[test]
    fn bp_nb_scales_comm() {
        let shape = TransformerShape::paper_encoder(1024);
        let bp1 = Strategy::new(StrategyKind::BlockParallel { n_b: 1, sp_variant: false }, 4)
            .schedule(&shape);
        let bp4 = Strategy::new(StrategyKind::BlockParallel { n_b: 4, sp_variant: false }, 4)
            .schedule(&shape);
        assert!((bp4.total_comm_bits() / bp1.total_comm_bits() - 4.0).abs() < 1e-9);
    }

    #[test]
    fn total_bits_per_token_matches_paper() {
        let shape = TransformerShape::paper_encoder(1024);
        let astra = Strategy::new(StrategyKind::Astra { vq: VqSetting::new(1, 1024) }, 4);
        assert_eq!(astra.total_bits_per_token(&shape), 120);
        let sp = Strategy::new(StrategyKind::SequenceParallel, 4);
        assert_eq!(sp.total_bits_per_token(&shape), 294_912);
    }

    #[test]
    fn decode_step_memory_bound_and_batchable() {
        let shape = TransformerShape::paper_encoder(1024);
        let dev = DeviceModel::paper_1660ti();
        let astra = Strategy::new(StrategyKind::Astra { vq: VqSetting::new(16, 1024) }, 4);
        let step = astra.decode_step_schedule(&shape, 1024);
        let t1 = step.latency(&dev, 100.0, 0.0006);
        // batching decode steps is nearly free while under the memory floor
        let t8 = step.for_batch(8).latency(&dev, 100.0, 0.0006);
        assert!(t8 < 2.0 * t1, "{t8} vs {t1}");
        // a decode step is far cheaper than a prefill
        let prefill = astra.schedule(&shape).latency(&dev, 100.0, 0.0006);
        assert!(t1 < prefill / 5.0, "{t1} vs {prefill}");
        // TP decode pays per-layer sync latency and loses to the local path
        let tp = Strategy::new(StrategyKind::TensorParallel, 4)
            .decode_step_schedule(&shape, 1024)
            .latency(&dev, 100.0, 0.0006);
        assert!(tp > t1, "{tp} vs {t1}");
    }

    #[test]
    fn fused_iteration_degenerates_to_its_parts() {
        let shape = TransformerShape::paper_encoder(1024);
        for s in figure1_strategies(4) {
            // chunk = 0: exactly the batched decode step the scheduler
            // already prices (bit-identity anchor for the unchunked path)
            let fused = s.fused_iteration_schedule(&shape, 0, 0, 8, 1024);
            let step = s.decode_step_schedule(&shape, 1024).for_batch(8);
            assert_eq!(fused.total_compute_flops(), step.total_compute_flops(), "{}", s.name());
            assert_eq!(fused.total_comm_bits(), step.total_comm_bits(), "{}", s.name());
            // decode_batch = 0: exactly the bare chunk
            let fused = s.fused_iteration_schedule(&shape, 128, 512, 0, 0);
            let chunk = s.prefill_chunk_schedule(&shape, 128, 512);
            assert_eq!(fused.total_compute_flops(), chunk.total_compute_flops(), "{}", s.name());
            assert_eq!(fused.total_comm_bits(), chunk.total_comm_bits(), "{}", s.name());
        }
    }

    #[test]
    fn fused_iteration_cheaper_than_separate_iterations() {
        // piggybacking decode onto a chunk must beat running the chunk and
        // the decode step as two iterations (launches/sync/floor paid once)
        let shape = TransformerShape::paper_encoder(1024);
        let dev = DeviceModel::paper_1660ti();
        for s in figure1_strategies(4) {
            let fused =
                s.fused_iteration_schedule(&shape, 128, 512, 8, 1024).latency(&dev, 100.0, 0.0006);
            let split = s.prefill_chunk_schedule(&shape, 128, 512).latency(&dev, 100.0, 0.0006)
                + s.decode_step_schedule(&shape, 1024).for_batch(8).latency(&dev, 100.0, 0.0006);
            assert!(fused < split, "{}: {fused} vs {split}", s.name());
            // and the piggybacked decode is not free: fused > bare chunk
            let bare = s.prefill_chunk_schedule(&shape, 128, 512).latency(&dev, 100.0, 0.0006);
            assert!(fused > bare, "{}: {fused} vs {bare}", s.name());
        }
    }

    #[test]
    fn chunk_schedule_scales_with_chunk_and_context() {
        let shape = TransformerShape::paper_encoder(1024);
        let astra = Strategy::new(StrategyKind::Astra { vq: VqSetting::new(16, 1024) }, 4);
        let small = astra.prefill_chunk_schedule(&shape, 64, 64);
        let big = astra.prefill_chunk_schedule(&shape, 256, 256);
        assert!(big.total_compute_flops() > small.total_compute_flops());
        assert!(big.total_comm_bits() > small.total_comm_bits());
        // a later chunk of the same size pays more attention context
        let late = astra.prefill_chunk_schedule(&shape, 64, 1024);
        assert!(late.total_compute_flops() > small.total_compute_flops());
        assert_eq!(late.total_comm_bits(), small.total_comm_bits());
        // chunking the whole prompt costs at least the monopolizing prefill
        // in overheads: N chunks pay N launch sets + N floors, one pays one
        let dev = DeviceModel::paper_1660ti();
        let chunks: f64 = (0..8)
            .map(|i| {
                astra
                    .prefill_chunk_schedule(&shape, 128, (i + 1) * 128)
                    .latency(&dev, 100.0, 0.0006)
            })
            .sum();
        let whole = astra.schedule(&shape).latency(&dev, 100.0, 0.0006);
        assert!(chunks > 0.0 && whole > 0.0);
        // the two are the same order of magnitude — chunking trades a
        // bounded per-iteration overhead (launches + sync stages + memory
        // floor, once per chunk) for interleaving freedom
        assert!(chunks > whole, "{chunks} vs {whole}");
        assert!(chunks < 4.0 * whole, "{chunks} vs {whole}");
    }

    #[test]
    fn uniform_profile_reproduces_legacy_schedules_bit_for_bit() {
        let shape = TransformerShape::paper_encoder(1024);
        let dev = DeviceModel::paper_1660ti();
        let uni = FleetProfile::uniform(dev, 4);
        let mut all = figure1_strategies(4);
        all.push(Strategy::new(StrategyKind::SingleDevice, 1));
        let uni1 = FleetProfile::uniform(dev, 1);
        for s in all {
            let p = if s.n_devices == 1 { &uni1 } else { &uni };
            let (a, b) = (s.schedule_on(&shape, p), s.schedule(&shape));
            assert_eq!(a.total_compute_flops(), b.total_compute_flops(), "{}", s.name());
            assert_eq!(a.total_comm_bits(), b.total_comm_bits(), "{}", s.name());
            assert_eq!(
                a.latency(&dev, 50.0, 0.0006),
                b.latency(&dev, 50.0, 0.0006),
                "{}",
                s.name()
            );
            let (a, b) = (s.decode_step_schedule_on(&shape, 900, p), s.decode_step_schedule(&shape, 900));
            assert_eq!(a.latency(&dev, 50.0, 0.0006), b.latency(&dev, 50.0, 0.0006));
            let (a, b) = (
                s.prefill_chunk_schedule_on(&shape, 128, 512, p),
                s.prefill_chunk_schedule(&shape, 128, 512),
            );
            assert_eq!(a.latency(&dev, 50.0, 0.0006), b.latency(&dev, 50.0, 0.0006));
            let (a, b) = (
                s.fused_iteration_schedule_on(&shape, 128, 512, 8, 1024, p),
                s.fused_iteration_schedule(&shape, 128, 512, 8, 1024),
            );
            assert_eq!(a.latency(&dev, 50.0, 0.0006), b.latency(&dev, 50.0, 0.0006));
        }
    }

    #[test]
    fn proportional_split_beats_even_on_skewed_fleet() {
        // SP phase compute on a skewed fleet: proportional shares finish
        // together; an even split leaves the 0.5-speed straggler gating
        // max_i F_i / w_i. The hand-computed even gate is the comparison.
        let shape = TransformerShape::paper_encoder(1024);
        let dev = DeviceModel::paper_1660ti();
        let profile = FleetProfile::from_speeds(dev, &[4.0, 2.0, 1.0, 0.5]);
        let t = shape.seq_len;
        let sp = Strategy::new(StrategyKind::SequenceParallel, 4);
        let balanced = sp.schedule_on(&shape, &profile).total_compute_flops();
        let even_gate =
            shape.n_layers as f64 * shape.block_flops(t / 4, t) / profile.min_weight();
        assert!(balanced < even_gate, "{balanced} vs even-split gate {even_gate}");
        // same shape of win for the decode step: fastest-device placement
        // beats the reference tail device whenever max_weight > 1
        let astra = Strategy::new(StrategyKind::Astra { vq: VqSetting::new(16, 1024) }, 4);
        let het = astra.decode_step_schedule_on(&shape, 1024, &profile);
        let legacy = astra.decode_step_schedule(&shape, 1024);
        let t_het = het.latency(&dev, 100.0, 0.0006);
        let t_leg = legacy.latency(&dev, 100.0, 0.0006);
        assert!(t_het < t_leg, "{t_het} vs {t_leg}");
        // degraded links inflate comm bits but never sync stages
        let mut lossy = profile.clone();
        lossy.link_factor[1][2] = 0.5;
        let clean = sp.schedule_on(&shape, &profile);
        let slow = sp.schedule_on(&shape, &lossy);
        assert!(slow.total_comm_bits() > clean.total_comm_bits());
        assert_eq!(slow.total_compute_flops(), clean.total_compute_flops());
    }

    #[test]
    fn more_devices_less_compute() {
        let shape = TransformerShape::paper_encoder(1024);
        let dev = DeviceModel::paper_1660ti();
        let a4 = Strategy::new(StrategyKind::Astra { vq: VqSetting::new(1, 1024) }, 4);
        let a8 = Strategy::new(StrategyKind::Astra { vq: VqSetting::new(1, 1024) }, 8);
        let (c4, _) = a4.schedule(&shape).latency_breakdown(&dev, 200.0, 0.0006);
        let (c8, _) = a8.schedule(&shape).latency_breakdown(&dev, 200.0, 0.0006);
        assert!(c8 < c4);
    }
}
