//! The paper's strategies as prefill schedules.
//!
//! * `SingleDevice` — the "Original Model" baseline.
//! * `TensorParallel` (Megatron-LM): weights sharded; 2 ring all-reduces of
//!   the full activation per layer.
//! * `SequenceParallel` (Voltage): tokens sharded; 1 ring all-gather of the
//!   activation per layer; every device projects K/V for the full sequence.
//! * `BlockParallel` (DeTransformer): restructured model with `n_b` retained
//!   block boundaries; one sync per boundary. BP+AG trades extra local
//!   compute for fewer bits; BP+SP keeps compute lean but roughly doubles
//!   the exchanged volume (two all-gathers per boundary).
//! * `Astra` — tokens sharded; per layer each device VQ-encodes its local
//!   tokens, multicasts `T/N * G*log2K` bits, decodes peers' codes, and runs
//!   the Mixed-Precision Attention block. VQ encode/decode FLOPs are charged
//!   to compute.
//!
//! Cost-model caveats vs the paper's testbed measurements are documented in
//! DESIGN.md §2 (ring collectives here; the paper's numbers mix Megatron /
//! Voltage / DeTransformer implementations).

use crate::comm::collective::{allgather, allreduce, code_multicast, CommCost};
use crate::model::shape::{TransformerShape, VqSetting};

use super::cost::{Phase, Schedule};

/// Extra local-compute multiplier for BP+AG (DeTransformer performs more
/// computation locally to cut communication; calibrated from Table 7).
pub const BP_AG_COMPUTE_FACTOR: f64 = 1.25;

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum StrategyKind {
    SingleDevice,
    TensorParallel,
    SequenceParallel,
    /// (n_b, sp_variant): BP+SP when `sp_variant`, else BP+AG
    BlockParallel { n_b: usize, sp_variant: bool },
    Astra { vq: VqSetting },
}

/// A strategy bound to a device count.
#[derive(Debug, Clone, Copy)]
pub struct Strategy {
    pub kind: StrategyKind,
    pub n_devices: usize,
}

impl Strategy {
    pub fn new(kind: StrategyKind, n_devices: usize) -> Strategy {
        Strategy { kind, n_devices }
    }

    pub fn name(&self) -> String {
        match self.kind {
            StrategyKind::SingleDevice => "Single".into(),
            StrategyKind::TensorParallel => "TP".into(),
            StrategyKind::SequenceParallel => "SP".into(),
            StrategyKind::BlockParallel { n_b, sp_variant } => {
                format!("BP+{}, Nb={}", if sp_variant { "SP" } else { "AG" }, n_b)
            }
            StrategyKind::Astra { vq } => format!("ASTRA, G={}", vq.groups),
        }
    }

    /// Prefill schedule for one request of `shape.seq_len` tokens.
    pub fn schedule(&self, shape: &TransformerShape) -> Schedule {
        let n = self.n_devices;
        let t = shape.seq_len;
        let l = shape.n_layers;
        let act_bits = (t * shape.d_model * shape.elem_bytes * 8) as f64;
        let mut phases = Vec::new();
        match self.kind {
            StrategyKind::SingleDevice => {
                phases.push(Phase::compute("forward", shape.total_flops(), l));
            }
            StrategyKind::TensorParallel => {
                // weights sharded 1/N; activation stays full T
                for _ in 0..l {
                    phases.push(Phase::compute(
                        "block/N",
                        shape.block_flops(t, t) / n as f64,
                        1,
                    ));
                    phases.push(Phase::comm("allreduce x2", sum2(allreduce(act_bits, n))));
                }
            }
            StrategyKind::SequenceParallel => {
                for _ in 0..l {
                    // device computes q for T/N tokens, k/v for full T
                    phases.push(Phase::compute("block seq-shard", shape.block_flops(t / n, t), 1));
                    phases.push(Phase::comm("allgather", allgather(act_bits, n)));
                }
            }
            StrategyKind::BlockParallel { n_b, sp_variant } => {
                let factor = if sp_variant { 1.0 } else { BP_AG_COMPUTE_FACTOR };
                // compute spread over n_b segments
                let per_segment = shape.total_flops() * factor / (n as f64 * n_b as f64);
                for _ in 0..n_b {
                    phases.push(Phase::compute("bp segment", per_segment, l / n_b.max(1)));
                    let sync = if sp_variant {
                        // two all-gathers per boundary
                        sum2(allgather(act_bits, n))
                    } else {
                        allgather(act_bits, n)
                    };
                    phases.push(Phase::comm("bp sync", sync));
                }
            }
            StrategyKind::Astra { vq } => {
                let code_chunk_bits = (t / n * vq.bits_per_token()) as f64;
                for _ in 0..l {
                    // VQ encode local tokens + decode (n-1) peers' codes
                    let vq_flops = shape.vq_encode_flops(t / n, vq.groups, vq.codebook_size)
                        + shape.vq_decode_flops(t - t / n, vq.groups, vq.codebook_size);
                    phases.push(Phase::compute("vq encode/decode", vq_flops, 1));
                    phases.push(Phase::comm("code exchange", code_multicast(code_chunk_bits, n)));
                    // MPA block: q over T/N local tokens, k/v over local
                    // full-precision + dequantized remote = full T columns
                    phases.push(Phase::compute("mpa block", shape.block_flops(t / n, t), 1));
                }
            }
        }
        Schedule { phases }
    }

    /// One-token decode-step schedule at KV context `ctx` (prompt plus
    /// already-generated tokens). Decode runs on the device owning the
    /// sequence tail (paper §5 / Appendix G: the tail device holds the
    /// mixed-precision cache), so for Single/SP/BP/ASTRA it is pure local
    /// compute floored by one streaming pass over the weights — the
    /// memory-bound regime that batched decode amortizes. TP keeps weights
    /// sharded and pays two one-token all-reduces per layer.
    pub fn decode_step_schedule(&self, shape: &TransformerShape, ctx: usize) -> Schedule {
        let n = self.n_devices;
        let mut phases = Vec::new();
        match self.kind {
            StrategyKind::TensorParallel => {
                phases.push(Phase::compute_mem(
                    "decode block/N",
                    shape.decode_step_flops(ctx) / n as f64,
                    shape.n_layers,
                    shape.weight_bytes() / n as f64,
                ));
                let act_bits = shape.token_bits() as f64;
                let mut comm = CommCost::ZERO;
                for _ in 0..shape.n_layers {
                    comm = comm.plus(sum2(allreduce(act_bits, n)));
                }
                phases.push(Phase::comm("decode allreduce x2", comm));
            }
            _ => {
                phases.push(Phase::compute_mem(
                    "decode step (tail device)",
                    shape.decode_step_flops(ctx),
                    shape.n_layers,
                    shape.weight_bytes(),
                ));
            }
        }
        Schedule { phases }
    }

    /// Payload bits a single transmitted token costs over the whole model
    /// (the paper's "Total Bits per Token" column).
    pub fn total_bits_per_token(&self, shape: &TransformerShape) -> usize {
        match self.kind {
            StrategyKind::SingleDevice => 0,
            StrategyKind::Astra { vq } => vq.total_bits_per_token(shape.n_layers),
            _ => shape.total_bits_per_token(),
        }
    }
}

fn sum2(c: CommCost) -> CommCost {
    c.plus(c)
}

/// The baseline set evaluated in Figure 1 / Table 4 at a given device count.
pub fn figure1_strategies(n: usize) -> Vec<Strategy> {
    vec![
        Strategy::new(StrategyKind::TensorParallel, n),
        Strategy::new(StrategyKind::SequenceParallel, n),
        Strategy::new(StrategyKind::BlockParallel { n_b: 1, sp_variant: false }, n),
        Strategy::new(StrategyKind::BlockParallel { n_b: 4, sp_variant: false }, n),
        Strategy::new(StrategyKind::BlockParallel { n_b: 1, sp_variant: true }, n),
        Strategy::new(StrategyKind::BlockParallel { n_b: 4, sp_variant: true }, n),
        Strategy::new(StrategyKind::Astra { vq: VqSetting::new(1, 1024) }, n),
        Strategy::new(StrategyKind::Astra { vq: VqSetting::new(16, 1024) }, n),
        Strategy::new(StrategyKind::Astra { vq: VqSetting::new(32, 1024) }, n),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::parallel::cost::DeviceModel;

    fn lat(s: &Strategy, shape: &TransformerShape, mbps: f64) -> f64 {
        s.schedule(shape).latency(&DeviceModel::paper_1660ti(), mbps, 0.0006)
    }

    #[test]
    fn astra_beats_baselines_at_low_bandwidth() {
        let shape = TransformerShape::paper_encoder(1024);
        let single = Strategy::new(StrategyKind::SingleDevice, 1);
        let t_single = lat(&single, &shape, 10.0);
        let astra = Strategy::new(
            StrategyKind::Astra { vq: VqSetting::new(1, 1024) }, 4);
        let t_astra = lat(&astra, &shape, 10.0);
        // paper Fig 1: ~2.6x speedup at 10 Mbps
        let speedup = t_single / t_astra;
        assert!(speedup > 1.5 && speedup < 4.0, "speedup {speedup}");
        for s in figure1_strategies(4) {
            if matches!(s.kind, StrategyKind::Astra { .. }) {
                continue;
            }
            let t_b = lat(&s, &shape, 10.0);
            assert!(t_astra < t_b, "{} {t_astra} vs {t_b}", s.name());
            // baselines slower than single device at 10 Mbps (paper Fig 1)
            assert!(t_b > t_single, "{} should lose to single-device", s.name());
        }
    }

    #[test]
    fn baselines_recover_at_high_bandwidth() {
        let shape = TransformerShape::paper_encoder(1024);
        let t_single = lat(&Strategy::new(StrategyKind::SingleDevice, 1), &shape, 500.0);
        let bp = Strategy::new(StrategyKind::BlockParallel { n_b: 1, sp_variant: false }, 4);
        assert!(lat(&bp, &shape, 500.0) < t_single, "BP+AG should win at 500 Mbps");
    }

    #[test]
    fn astra_latency_nearly_bandwidth_independent() {
        // Table 7 shape: ASTRA G=1 moves from 1.563 s to 1.540 s across
        // 10..500 Mbps — a <2% swing.
        let shape = TransformerShape::llama3_8b(1024);
        let astra = Strategy::new(StrategyKind::Astra { vq: VqSetting::new(1, 1024) }, 4);
        let dev = DeviceModel::paper_titanx_llama();
        let t10 = astra.schedule(&shape).latency(&dev, 10.0, 0.002);
        let t500 = astra.schedule(&shape).latency(&dev, 500.0, 0.002);
        assert!((t10 - t500) / t500 < 0.10, "{t10} vs {t500}");
    }

    #[test]
    fn tp_comm_exceeds_sp_comm() {
        let shape = TransformerShape::paper_encoder(1024);
        let tp = Strategy::new(StrategyKind::TensorParallel, 4).schedule(&shape);
        let sp = Strategy::new(StrategyKind::SequenceParallel, 4).schedule(&shape);
        assert!(tp.total_comm_bits() > 2.0 * sp.total_comm_bits());
    }

    #[test]
    fn bp_nb_scales_comm() {
        let shape = TransformerShape::paper_encoder(1024);
        let bp1 = Strategy::new(StrategyKind::BlockParallel { n_b: 1, sp_variant: false }, 4)
            .schedule(&shape);
        let bp4 = Strategy::new(StrategyKind::BlockParallel { n_b: 4, sp_variant: false }, 4)
            .schedule(&shape);
        assert!((bp4.total_comm_bits() / bp1.total_comm_bits() - 4.0).abs() < 1e-9);
    }

    #[test]
    fn total_bits_per_token_matches_paper() {
        let shape = TransformerShape::paper_encoder(1024);
        let astra = Strategy::new(StrategyKind::Astra { vq: VqSetting::new(1, 1024) }, 4);
        assert_eq!(astra.total_bits_per_token(&shape), 120);
        let sp = Strategy::new(StrategyKind::SequenceParallel, 4);
        assert_eq!(sp.total_bits_per_token(&shape), 294_912);
    }

    #[test]
    fn decode_step_memory_bound_and_batchable() {
        let shape = TransformerShape::paper_encoder(1024);
        let dev = DeviceModel::paper_1660ti();
        let astra = Strategy::new(StrategyKind::Astra { vq: VqSetting::new(16, 1024) }, 4);
        let step = astra.decode_step_schedule(&shape, 1024);
        let t1 = step.latency(&dev, 100.0, 0.0006);
        // batching decode steps is nearly free while under the memory floor
        let t8 = step.for_batch(8).latency(&dev, 100.0, 0.0006);
        assert!(t8 < 2.0 * t1, "{t8} vs {t1}");
        // a decode step is far cheaper than a prefill
        let prefill = astra.schedule(&shape).latency(&dev, 100.0, 0.0006);
        assert!(t1 < prefill / 5.0, "{t1} vs {prefill}");
        // TP decode pays per-layer sync latency and loses to the local path
        let tp = Strategy::new(StrategyKind::TensorParallel, 4)
            .decode_step_schedule(&shape, 1024)
            .latency(&dev, 100.0, 0.0006);
        assert!(tp > t1, "{tp} vs {t1}");
    }

    #[test]
    fn more_devices_less_compute() {
        let shape = TransformerShape::paper_encoder(1024);
        let dev = DeviceModel::paper_1660ti();
        let a4 = Strategy::new(StrategyKind::Astra { vq: VqSetting::new(1, 1024) }, 4);
        let a8 = Strategy::new(StrategyKind::Astra { vq: VqSetting::new(1, 1024) }, 8);
        let (c4, _) = a4.schedule(&shape).latency_breakdown(&dev, 200.0, 0.0006);
        let (c8, _) = a8.schedule(&shape).latency_breakdown(&dev, 200.0, 0.0006);
        assert!(c8 < c4);
    }
}
