//! Multi-device parallelization strategies and their cost schedules.
//!
//! Each strategy (the paper's baselines plus ASTRA) describes one prefill
//! pass as a sequence of [`Phase`]s — per-device compute FLOPs interleaved
//! with collective communication. The simulator ([`crate::sim`]) turns a
//! schedule into latency under a device model + bandwidth, which is what
//! regenerates Figures 1/3/4/5 and Tables 4/7.

pub mod cost;
pub mod plan;
pub mod strategies;

pub use cost::{DeviceModel, FleetProfile, Phase, Schedule};
pub use plan::{Plan, Planner, SplitMode};
pub use strategies::{Strategy, StrategyKind};
