//! Device model + schedule types for the latency simulator.

use crate::comm::collective::CommCost;
use crate::model::TransformerShape;

/// Compute capability of one device.
///
/// `flops` is effective sustained FLOP/s on transformer blocks; presets
/// calibrate it so the single-device reference matches the paper's
/// absolute latencies (99.9 ms for the 12L/768D encoder at T=1024 on the
/// 1660Ti testbed, 4.578 s for 8-bit Llama-3-8B prefill on the Titan X).
#[derive(Debug, Clone, Copy)]
pub struct DeviceModel {
    pub flops: f64,
    /// fixed per-kernel-launch / per-layer overhead (seconds)
    pub per_layer_overhead_s: f64,
    /// relative speed multiplier (1.0 = baseline; heterogeneous clusters
    /// scale per-device)
    pub speed: f64,
    /// effective memory bandwidth (bytes/s) for streaming a phase's weight
    /// working set. Single-token decode is memory-bound: the step cannot go
    /// faster than one pass over the weights, however small the matmuls —
    /// this is the floor that batched decode amortizes.
    pub mem_bytes_per_s: f64,
}

impl DeviceModel {
    /// Calibrated so the Fig-1 encoder (12L/768D, T=1024) takes the
    /// paper's 99.9 ms single-device.
    pub fn paper_1660ti() -> DeviceModel {
        let shape = TransformerShape::paper_encoder(1024);
        let target = 0.0999;
        let overhead = 0.0002 * shape.n_layers as f64; // 0.2 ms/layer
        DeviceModel {
            flops: shape.total_flops() / (target - overhead),
            per_layer_overhead_s: 0.0002,
            speed: 1.0,
            // GTX 1660 Ti: 288 GB/s peak, ~2/3 effective on strided KV reads
            mem_bytes_per_s: 192e9,
        }
    }

    /// Calibrated so 8-bit Llama-3-8B prefill at T=1024 takes 4.578 s.
    pub fn paper_titanx_llama() -> DeviceModel {
        let shape = TransformerShape::llama3_8b(1024);
        let target = 4.578;
        let overhead = 0.002 * shape.n_layers as f64;
        DeviceModel {
            flops: shape.total_flops() / (target - overhead),
            per_layer_overhead_s: 0.002,
            speed: 1.0,
            // Titan X (Maxwell): 336 GB/s peak
            mem_bytes_per_s: 224e9,
        }
    }

    pub fn with_speed(mut self, speed: f64) -> DeviceModel {
        self.speed = speed;
        self
    }

    /// Seconds to execute `flops` of compute plus `layers` launches.
    pub fn compute_time(&self, flops: f64, layers: usize) -> f64 {
        flops / (self.flops * self.speed) + layers as f64 * self.per_layer_overhead_s
    }

    /// Seconds for a phase's compute: the matmul term floored by one
    /// streaming pass over `mem_bytes` of weights, plus launch overheads.
    pub fn phase_compute_time(&self, flops: f64, launches: usize, mem_bytes: f64) -> f64 {
        let matmul = flops / (self.flops * self.speed);
        let stream = if mem_bytes > 0.0 {
            mem_bytes / (self.mem_bytes_per_s * self.speed)
        } else {
            0.0
        };
        matmul.max(stream) + launches as f64 * self.per_layer_overhead_s
    }
}

/// One phase of a prefill schedule. Phases run sequentially; within a
/// phase, each device computes `compute_flops` (the slowest device gates)
/// and then the collective `comm` runs.
#[derive(Debug, Clone)]
pub struct Phase {
    pub label: &'static str,
    /// per-device FLOPs (max over devices for heterogeneous splits)
    pub compute_flops: f64,
    /// number of kernel launches attributed to this phase
    pub launches: usize,
    pub comm: CommCost,
    /// weight working set streamed once per execution (bytes). Zero for
    /// compute-bound phases; the full layer-weight footprint for decode
    /// steps, where it floors the phase regardless of batch size.
    pub mem_bytes: f64,
}

impl Phase {
    pub fn compute(label: &'static str, flops: f64, launches: usize) -> Phase {
        Phase { label, compute_flops: flops, launches, comm: CommCost::ZERO, mem_bytes: 0.0 }
    }

    /// Compute phase with a memory-bandwidth floor of `mem_bytes` streamed.
    pub fn compute_mem(label: &'static str, flops: f64, launches: usize, mem_bytes: f64) -> Phase {
        Phase { label, compute_flops: flops, launches, comm: CommCost::ZERO, mem_bytes }
    }

    pub fn comm(label: &'static str, comm: CommCost) -> Phase {
        Phase { label, compute_flops: 0.0, launches: 0, comm, mem_bytes: 0.0 }
    }

    /// Cost of `b` requests executing this phase together: per-request
    /// FLOPs and wire bits scale with the batch; kernel launches, collective
    /// sync stages, and the weight-streaming floor are paid once. This is
    /// the batched-execution semantics of the continuous-batching engine.
    pub fn for_batch(&self, b: usize) -> Phase {
        Phase {
            label: self.label,
            compute_flops: self.compute_flops * b as f64,
            launches: self.launches,
            comm: CommCost { bits: self.comm.bits * b as f64, stages: self.comm.stages },
            mem_bytes: self.mem_bytes,
        }
    }
}

/// A full prefill schedule plus bookkeeping for the breakdown figure.
#[derive(Debug, Clone)]
pub struct Schedule {
    pub phases: Vec<Phase>,
}

impl Schedule {
    pub fn total_comm_bits(&self) -> f64 {
        self.phases.iter().map(|p| p.comm.bits).sum()
    }

    pub fn total_compute_flops(&self) -> f64 {
        self.phases.iter().map(|p| p.compute_flops).sum()
    }

    /// The same schedule executed by a batch of `b` requests at once
    /// (see [`Phase::for_batch`] for the scaling semantics).
    pub fn for_batch(&self, b: usize) -> Schedule {
        Schedule { phases: self.phases.iter().map(|p| p.for_batch(b)).collect() }
    }

    /// Fold piggybacked work into this schedule without paying new
    /// overheads: `flops` join the first compute phase (sharing its kernel
    /// launches and weight-stream floor) and `bits` join the first phase
    /// that already syncs (sharing its stages). This is the fused-iteration
    /// semantics of chunked prefill: the decode tokens co-scheduled with a
    /// prompt chunk add FLOPs and wire bits, while launches, sync stages,
    /// and the memory floor are paid once per iteration. `bits > 0.0` with
    /// no comm phase to ride is a caller error and is ignored (single-device
    /// schedules have nothing to sync with).
    pub fn piggyback(mut self, flops: f64, bits: f64) -> Schedule {
        if let Some(p) = self.phases.iter_mut().find(|p| p.compute_flops > 0.0) {
            p.compute_flops += flops;
        } else if flops > 0.0 {
            self.phases.insert(0, Phase::compute("piggyback", flops, 0));
        }
        if bits > 0.0 {
            if let Some(p) = self.phases.iter_mut().find(|p| p.comm.stages > 0) {
                p.comm.bits += bits;
            }
        }
        self
    }

    /// Static-bandwidth latency split into (compute_s, comm_s).
    pub fn latency_breakdown(
        &self,
        device: &DeviceModel,
        bandwidth_mbps: f64,
        stage_latency_s: f64,
    ) -> (f64, f64) {
        let mut compute = 0.0;
        let mut comm = 0.0;
        for p in &self.phases {
            compute += device.phase_compute_time(p.compute_flops, p.launches, p.mem_bytes);
            comm += p.comm.seconds(bandwidth_mbps, stage_latency_s);
        }
        (compute, comm)
    }

    /// Total static-bandwidth latency in seconds.
    pub fn latency(&self, device: &DeviceModel, bandwidth_mbps: f64, stage_latency_s: f64) -> f64 {
        let (c, m) = self.latency_breakdown(device, bandwidth_mbps, stage_latency_s);
        c + m
    }
}

/// Per-device and per-link profile of a heterogeneous fleet.
///
/// `devices[i]` models device `i` (its `speed` multiplier carries the
/// heterogeneity relative to the reference device schedules are evaluated
/// on); `link_factor[i][j]` is a *relative* bandwidth multiplier for the
/// `i -> j` link, where `1.0` means "the bandwidth trace's current value".
/// The collectives in this codebase are ring/multicast schedules gated by
/// the slowest participating link, so schedule evaluation folds the matrix
/// down to its off-diagonal minimum ([`FleetProfile::bottleneck_factor`]).
///
/// Heterogeneous schedules stay evaluable on a single reference
/// [`DeviceModel`]: a phase whose per-device work is `F_i` FLOPs (and
/// `M_i` streamed bytes) on a device of relative speed `w_i` finishes the
/// fleet-wide phase after `max_i F_i / w_i` reference-FLOPs — so the
/// `*_on` schedule builders in [`super::strategies`] store that max as the
/// phase's `compute_flops`/`mem_bytes` and the existing evaluators need no
/// change (`max_i max(a_i, b_i) == max(max_i a_i, max_i b_i)`).
#[derive(Debug, Clone, PartialEq)]
pub struct FleetProfile {
    pub devices: Vec<DeviceModel>,
    /// relative per-link bandwidth multipliers (`1.0` = trace value)
    pub link_factor: Vec<Vec<f64>>,
}

impl FleetProfile {
    /// `n` copies of `dev` over ideal (trace-speed) links — the
    /// heterogeneity-off anchor: every `*_on` schedule built from a
    /// uniform profile is bit-identical to its legacy counterpart.
    pub fn uniform(dev: DeviceModel, n: usize) -> FleetProfile {
        FleetProfile::from_speeds(dev, &vec![dev.speed; n.max(1)])
    }

    /// One device per entry of `speeds`, each `base` scaled by its
    /// relative speed, over ideal links. Non-positive speeds are clamped
    /// to a tiny positive floor so weights stay usable as split ratios.
    pub fn from_speeds(base: DeviceModel, speeds: &[f64]) -> FleetProfile {
        let n = speeds.len().max(1);
        let devices: Vec<DeviceModel> = if speeds.is_empty() {
            vec![base]
        } else {
            speeds.iter().map(|&s| base.with_speed(s.max(1e-6))).collect()
        };
        FleetProfile { devices, link_factor: vec![vec![1.0; n]; n] }
    }

    pub fn n(&self) -> usize {
        self.devices.len()
    }

    /// True when every device runs at the same speed and every link at the
    /// trace's bandwidth — the profile carries no information beyond the
    /// legacy single-`DeviceModel` world, and callers delegate to the
    /// legacy schedule builders for bit-identity.
    pub fn is_uniform(&self) -> bool {
        let s0 = self.devices.first().map(|d| d.speed).unwrap_or(1.0);
        self.devices.iter().all(|d| d.speed == s0)
            && self.link_factor.iter().flatten().all(|&f| f == 1.0)
    }

    /// Relative per-device speeds, the weights for proportional splits.
    pub fn weights(&self) -> Vec<f64> {
        self.devices.iter().map(|d| d.speed).collect()
    }

    pub fn max_weight(&self) -> f64 {
        self.devices.iter().map(|d| d.speed).fold(f64::MIN, f64::max).max(1e-6)
    }

    pub fn min_weight(&self) -> f64 {
        self.devices.iter().map(|d| d.speed).fold(f64::MAX, f64::min).max(1e-6)
    }

    pub fn sum_weights(&self) -> f64 {
        self.devices.iter().map(|d| d.speed).sum::<f64>().max(1e-6)
    }

    /// Slowest off-diagonal link multiplier — the factor every collective
    /// in a ring/multicast schedule is gated by. `1.0` for fleets of one.
    pub fn bottleneck_factor(&self) -> f64 {
        let mut min = f64::MAX;
        for (i, row) in self.link_factor.iter().enumerate() {
            for (j, &f) in row.iter().enumerate() {
                if i != j && f < min {
                    min = f;
                }
            }
        }
        if min == f64::MAX {
            1.0
        } else {
            min.max(1e-6)
        }
    }

    /// Profile-weighted token split: stronger devices take more tokens
    /// (paper §4.2), remainder to the fastest devices.
    pub fn split(&self, t: usize) -> crate::coordinator::partition::TokenPartition {
        crate::coordinator::partition::TokenPartition::proportional(t, &self.weights())
            .expect("fleet weights are clamped positive")
    }

    /// The same fleet with damped weights `w^0.5` — a planner candidate
    /// between "even" and "fully proportional" that hedges against an
    /// overconfident profile.
    pub fn damped(&self) -> FleetProfile {
        let devices = self.devices.iter().map(|d| d.with_speed(d.speed.sqrt())).collect();
        FleetProfile { devices, link_factor: self.link_factor.clone() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn calibration_hits_paper_single_device() {
        let dev = DeviceModel::paper_1660ti();
        let shape = TransformerShape::paper_encoder(1024);
        let t = dev.compute_time(shape.total_flops(), shape.n_layers);
        assert!((t - 0.0999).abs() < 1e-4, "{t}");
        let dev = DeviceModel::paper_titanx_llama();
        let shape = TransformerShape::llama3_8b(1024);
        let t = dev.compute_time(shape.total_flops(), shape.n_layers);
        assert!((t - 4.578).abs() < 1e-3, "{t}");
    }

    #[test]
    fn heterogeneous_speed_scales() {
        let dev = DeviceModel::paper_1660ti();
        let slow = dev.with_speed(0.5);
        assert!((slow.compute_time(dev.flops, 0) - 2.0).abs() < 1e-9);
    }

    #[test]
    fn schedule_breakdown_adds_up() {
        let dev = DeviceModel {
            flops: 1e9,
            per_layer_overhead_s: 0.001,
            speed: 1.0,
            mem_bytes_per_s: f64::INFINITY,
        };
        let sched = Schedule {
            phases: vec![
                Phase::compute("a", 1e9, 1),
                Phase::comm("b", CommCost { bits: 10e6, stages: 1 }),
            ],
        };
        let (c, m) = sched.latency_breakdown(&dev, 10.0, 0.005);
        assert!((c - 1.001).abs() < 1e-9);
        assert!((m - 1.005).abs() < 1e-9);
        assert!((sched.latency(&dev, 10.0, 0.005) - (c + m)).abs() < 1e-12);
    }

    #[test]
    fn memory_floor_gates_small_matmuls() {
        let dev = DeviceModel {
            flops: 1e12,
            per_layer_overhead_s: 0.0,
            speed: 1.0,
            mem_bytes_per_s: 1e9,
        };
        // 1 MFLOP would take 1 µs compute, but streaming 1 MB takes 1 ms
        let t = dev.phase_compute_time(1e6, 0, 1e6);
        assert!((t - 1e-3).abs() < 1e-12, "{t}");
        // a big matmul is unaffected by the floor
        let t = dev.phase_compute_time(1e12, 0, 1e6);
        assert!((t - 1.0).abs() < 1e-9, "{t}");
    }

    #[test]
    fn piggyback_adds_work_but_no_overheads() {
        let sched = Schedule {
            phases: vec![
                Phase::compute_mem("chunk", 1e9, 4, 2e6),
                Phase::comm("exchange", CommCost { bits: 1e6, stages: 3 }),
            ],
        };
        let fused = sched.clone().piggyback(5e8, 2e5);
        assert!((fused.total_compute_flops() - 1.5e9).abs() < 1.0);
        assert!((fused.total_comm_bits() - 1.2e6).abs() < 1e-6);
        // overheads unchanged: same launches, stages, memory floor
        assert_eq!(fused.phases[0].launches, 4);
        assert_eq!(fused.phases[1].comm.stages, 3);
        assert!((fused.phases[0].mem_bytes - 2e6).abs() < 1e-9);
        // fused latency < running the two workloads as separate iterations
        let dev = DeviceModel {
            flops: 1e12,
            per_layer_overhead_s: 0.001,
            speed: 1.0,
            mem_bytes_per_s: 1e9,
        };
        let alone = Schedule {
            phases: vec![
                Phase::compute_mem("dec", 5e8, 4, 2e6),
                Phase::comm("sync", CommCost { bits: 2e5, stages: 3 }),
            ],
        };
        let t_fused = fused.latency(&dev, 10.0, 0.001);
        let t_split = sched.latency(&dev, 10.0, 0.001) + alone.latency(&dev, 10.0, 0.001);
        assert!(t_fused < t_split, "{t_fused} vs {t_split}");
        // bits with no comm phase to ride are dropped, not crashed on
        let local = Schedule { phases: vec![Phase::compute("c", 1e9, 1)] }.piggyback(1e8, 1e6);
        assert!((local.total_comm_bits() - 0.0).abs() < 1e-12);
        assert!((local.total_compute_flops() - 1.1e9).abs() < 1.0);
    }

    #[test]
    fn batching_scales_flops_and_bits_but_not_overheads() {
        let p = Phase {
            label: "x",
            compute_flops: 1e9,
            launches: 3,
            comm: CommCost { bits: 1e6, stages: 2 },
            mem_bytes: 5e6,
        };
        let b = p.for_batch(8);
        assert!((b.compute_flops - 8e9).abs() < 1e-3);
        assert!((b.comm.bits - 8e6).abs() < 1e-3);
        assert_eq!(b.launches, 3);
        assert_eq!(b.comm.stages, 2);
        assert!((b.mem_bytes - 5e6).abs() < 1e-9);
        // batch-8 latency is strictly less than 8x the batch-1 latency
        // whenever overheads/floor are non-trivial
        let dev = DeviceModel {
            flops: 1e12,
            per_layer_overhead_s: 0.001,
            speed: 1.0,
            mem_bytes_per_s: 1e9,
        };
        let sched = Schedule { phases: vec![p] };
        let t1 = sched.latency(&dev, 100.0, 0.001);
        let t8 = sched.for_batch(8).latency(&dev, 100.0, 0.001);
        assert!(t8 < 8.0 * t1, "{t8} vs {}", 8.0 * t1);
        assert!(t8 > t1, "{t8} vs {t1}");
    }

    #[test]
    fn fleet_profile_uniform_and_weights() {
        let dev = DeviceModel::paper_1660ti();
        let uni = FleetProfile::uniform(dev, 4);
        assert_eq!(uni.n(), 4);
        assert!(uni.is_uniform());
        assert!((uni.bottleneck_factor() - 1.0).abs() < 1e-12);
        let skew = FleetProfile::from_speeds(dev, &[4.0, 2.0, 1.0, 0.5]);
        assert!(!skew.is_uniform());
        assert_eq!(skew.weights(), vec![4.0, 2.0, 1.0, 0.5]);
        assert!((skew.max_weight() - 4.0).abs() < 1e-12);
        assert!((skew.min_weight() - 0.5).abs() < 1e-12);
        assert!((skew.sum_weights() - 7.5).abs() < 1e-12);
        // proportional split sums and favors the fast device
        let part = skew.split(100);
        assert_eq!(part.total(), 100);
        assert!(part.sizes[0] > part.sizes[3]);
        // damping compresses the spread but keeps the ordering
        let damped = skew.damped();
        let w = damped.weights();
        assert!(w[0] > w[3]);
        assert!(w[0] / w[3] < 4.0 / 0.5);
        // a degraded link gates the whole bottleneck factor
        let mut linky = skew.clone();
        linky.link_factor[0][3] = 0.25;
        assert!((linky.bottleneck_factor() - 0.25).abs() < 1e-12);
        assert!(!linky.is_uniform());
    }
}
