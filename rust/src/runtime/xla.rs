//! Minimal in-repo stand-in for the `xla` (xla_extension) bindings.
//!
//! The original paper image vendored a PJRT-backed `xla` crate; this build
//! container does not ship it, and the crate cannot be added offline. The
//! executor only ever reaches these types after [`PjRtClient::cpu`] succeeds,
//! so the stub keeps the exact API surface `runtime/executor.rs` compiles
//! against and fails cleanly at client creation. Everything here is plain
//! data (`Send + Sync`), which also lets `&Cluster` cross scoped-thread
//! boundaries in the live decode path.

#![allow(dead_code)]

use std::fmt;

/// Error type mirroring the binding crate's; converts into `anyhow::Error`
/// through `std::error::Error`.
#[derive(Debug)]
pub struct XlaError(String);

impl XlaError {
    fn unavailable() -> Self {
        XlaError("PJRT runtime unavailable: the xla crate is not vendored in this build".into())
    }
}

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for XlaError {}

/// Element types the executor distinguishes on output literals.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
    Pred,
}

/// Host-side literal (dense array) handle.
#[derive(Debug, Clone)]
pub struct Literal;

impl Literal {
    pub fn vec1<T: Copy>(_data: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, XlaError> {
        Err(XlaError::unavailable())
    }

    pub fn array_shape(&self) -> Result<ArrayShape, XlaError> {
        Err(XlaError::unavailable())
    }

    pub fn ty(&self) -> Result<ElementType, XlaError> {
        Err(XlaError::unavailable())
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, XlaError> {
        Err(XlaError::unavailable())
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>, XlaError> {
        Err(XlaError::unavailable())
    }
}

/// Array shape (dims only; the executor reads dims as usizes).
#[derive(Debug, Clone)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Parsed HLO module proto.
#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, XlaError> {
        Err(XlaError::unavailable())
    }
}

/// Computation wrapper handed to `PjRtClient::compile`.
#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// PJRT client handle. `cpu()` is the single gate: with the bindings
/// absent it returns an error, so no downstream stub method ever runs.
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, XlaError> {
        Err(XlaError::unavailable())
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, XlaError> {
        Err(XlaError::unavailable())
    }
}

/// Compiled executable handle.
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        Err(XlaError::unavailable())
    }
}

/// Device buffer handle.
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, XlaError> {
        Err(XlaError::unavailable())
    }
}
