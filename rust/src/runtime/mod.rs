//! PJRT runtime: load the python-AOT artifacts and execute them.
//!
//! `python/compile/aot.py` writes HLO *text* (the only interchange format
//! the image's xla_extension 0.5.1 accepts from jax ≥ 0.5 — serialized
//! protos carry 64-bit instruction ids it rejects), plus `manifest.json`,
//! `weights.bin` and `codebooks.bin`. This module parses the manifest,
//! compiles each graph on a shared [`xla::PjRtClient`], and binds weight
//! buffers once per executable so the hot path only uploads activations.

pub mod artifact;
pub mod executor;
pub(crate) mod xla;

pub use artifact::{Artifact, GraphSpec, TensorSpec};
pub use executor::{Executor, ModelRuntime};
