//! Artifact bundle parsing: manifest.json + weights.bin + codebooks.bin.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::tensor::Tensor;
use crate::util::json::Json;
use crate::vq::Codebook;

/// One graph argument/output spec from the manifest.
#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
    /// "activation" | "weight" | "codebook"
    pub kind: String,
}

/// One AOT graph (an .hlo.txt file plus its signature).
#[derive(Debug, Clone)]
pub struct GraphSpec {
    pub name: String,
    pub file: PathBuf,
    pub args: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// Model configuration carried in the manifest.
#[derive(Debug, Clone)]
pub struct ModelMeta {
    pub n_layers: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub seq_len: usize,
    pub causal: bool,
    pub use_cls: bool,
    pub vocab_size: usize,
    pub patch_dim: usize,
    pub n_classes: usize,
    pub n_devices: usize,
    pub groups: usize,
    pub codebook_size: usize,
    pub bits_per_token: usize,
}

/// A fully-parsed artifact bundle.
#[derive(Debug)]
pub struct Artifact {
    pub dir: PathBuf,
    pub graphs: BTreeMap<String, GraphSpec>,
    pub meta: ModelMeta,
    /// parameter tensors by dotted name
    pub tensors: BTreeMap<String, Tensor>,
    /// per-layer grouped codebooks
    pub codebooks: Vec<Codebook>,
}

impl Artifact {
    pub fn load(dir: &Path) -> Result<Artifact> {
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {}", manifest_path.display()))?;
        let j = Json::parse(&text).context("parsing manifest.json")?;

        // --- model meta ---
        let m = j.get("model")?;
        let a = j.get("astra")?;
        let meta = ModelMeta {
            n_layers: m.get("n_layers")?.as_usize()?,
            d_model: m.get("d_model")?.as_usize()?,
            n_heads: m.get("n_heads")?.as_usize()?,
            d_ff: m.get("d_ff")?.as_usize()?,
            seq_len: m.get("seq_len")?.as_usize()?,
            causal: m.get("causal")?.as_bool()?,
            use_cls: m.get("use_cls")?.as_bool()?,
            vocab_size: m.get("vocab_size")?.as_usize()?,
            patch_dim: m.get("patch_dim")?.as_usize()?,
            n_classes: m.get("n_classes")?.as_usize()?,
            n_devices: a.get("n_devices")?.as_usize()?,
            groups: a.get("groups")?.as_usize()?,
            codebook_size: a.get("codebook_size")?.as_usize()?,
            bits_per_token: a.get("bits_per_token")?.as_usize()?,
        };

        // --- graphs ---
        let mut graphs = BTreeMap::new();
        for g in j.get("graphs")?.as_arr()? {
            let name = g.get("name")?.as_str()?.to_string();
            let parse_specs = |key: &str, named: bool| -> Result<Vec<TensorSpec>> {
                g.get(key)?
                    .as_arr()?
                    .iter()
                    .map(|t| {
                        Ok(TensorSpec {
                            name: if named {
                                t.get("name")?.as_str()?.to_string()
                            } else {
                                String::new()
                            },
                            shape: t
                                .get("shape")?
                                .as_arr()?
                                .iter()
                                .map(|d| d.as_usize())
                                .collect::<Result<_>>()?,
                            dtype: t.get("dtype")?.as_str()?.to_string(),
                            kind: t
                                .opt("kind")
                                .map(|k| k.as_str().map(str::to_string))
                                .transpose()?
                                .unwrap_or_default(),
                        })
                    })
                    .collect()
            };
            graphs.insert(
                name.clone(),
                GraphSpec {
                    name,
                    file: dir.join(g.get("file")?.as_str()?),
                    args: parse_specs("args", true)?,
                    outputs: parse_specs("outputs", false)?,
                },
            );
        }

        // --- weights ---
        let wpath = dir.join(j.get("weights_file")?.as_str()?);
        let raw = std::fs::read(&wpath)
            .with_context(|| format!("reading {}", wpath.display()))?;
        let floats: Vec<f32> = raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        let mut tensors = BTreeMap::new();
        for t in j.get("tensors")?.as_arr()? {
            let name = t.get("name")?.as_str()?.to_string();
            let offset = t.get("offset")?.as_usize()?;
            let shape: Vec<usize> = t
                .get("shape")?
                .as_arr()?
                .iter()
                .map(|d| d.as_usize())
                .collect::<Result<_>>()?;
            let n: usize = shape.iter().product();
            if offset + n > floats.len() {
                bail!("tensor {name} overruns weights.bin");
            }
            // scalar/1-d tensors keep their manifest shape
            let shape = if shape.is_empty() { vec![1] } else { shape };
            tensors.insert(name, Tensor::from_vec(&shape, floats[offset..offset + n].to_vec())?);
        }

        // --- codebooks ---
        let cshape: Vec<usize> = j
            .get("codebooks_shape")?
            .as_arr()?
            .iter()
            .map(|d| d.as_usize())
            .collect::<Result<_>>()?;
        let (l, g, k, dg) = (cshape[0], cshape[1], cshape[2], cshape[3]);
        let cpath = dir.join(j.get("codebooks_file")?.as_str()?);
        let craw = std::fs::read(&cpath)
            .with_context(|| format!("reading {}", cpath.display()))?;
        let cfloats: Vec<f32> = craw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        if cfloats.len() != l * g * k * dg {
            bail!(
                "codebooks.bin has {} floats, expected {}",
                cfloats.len(),
                l * g * k * dg
            );
        }
        let per = g * k * dg;
        let codebooks = (0..l)
            .map(|li| Codebook::new(g, k, dg, cfloats[li * per..(li + 1) * per].to_vec()))
            .collect::<Result<Vec<_>>>()?;

        Ok(Artifact { dir: dir.to_path_buf(), graphs, meta, tensors, codebooks })
    }

    /// Build a fully in-memory decoder bundle with random weights: no
    /// files on disk, no AOT graphs (native backend only). This is what
    /// lets the live continuous-batching path run anywhere — unit tests,
    /// the CI smoke job, and `astra serve-cb --live` when no trained
    /// bundle exists. Deterministic in `seed`.
    pub fn synthetic_decoder(
        shape: &crate::model::TransformerShape,
        vocab_size: usize,
        n_devices: usize,
        vq: crate::model::shape::VqSetting,
        seed: u64,
    ) -> Result<Artifact> {
        use crate::model::shape::ceil_log2;
        let (l, d, hh) = (shape.n_layers, shape.d_model, shape.n_heads);
        let (ff, t) = (shape.d_ff, shape.seq_len);
        if d == 0 || hh == 0 || d % hh != 0 {
            bail!("d_model {d} must divide into {hh} heads");
        }
        if vq.groups == 0 || d % vq.groups != 0 {
            bail!("d_model {d} must divide into {} VQ groups", vq.groups);
        }
        if n_devices == 0 || t % n_devices != 0 {
            bail!("seq_len {t} must split evenly over {n_devices} devices");
        }
        if vocab_size < 2 {
            bail!("vocab_size must be at least 2");
        }
        let mut rng = crate::util::rng::Rng::new(seed);
        let mut tensors = BTreeMap::new();
        tensors.insert(
            "embed".to_string(),
            rand_tensor(&mut rng, &[vocab_size, d], 0.5),
        );
        tensors.insert("pos".to_string(), rand_tensor(&mut rng, &[t, d], 0.1));
        const NAMES: [&str; 16] = [
            "ln1.g", "ln1.b", "wq", "bq", "wk", "bk", "wv", "bv", "wo", "bo",
            "ln2.g", "ln2.b", "w1", "b1", "w2", "b2",
        ];
        for li in 0..l {
            let blk = crate::model::native::BlockWeights::random(&mut rng, d, ff);
            for (name, tensor) in NAMES.iter().zip(blk.as_list()) {
                tensors.insert(format!("blocks.{li}.{name}"), tensor);
            }
        }
        tensors.insert(
            "ln_f.g".to_string(),
            Tensor::from_vec(&[d], vec![1.0; d])?,
        );
        tensors.insert(
            "ln_f.b".to_string(),
            Tensor::from_vec(&[d], vec![0.0; d])?,
        );
        tensors.insert(
            "head.w".to_string(),
            rand_tensor(&mut rng, &[d, vocab_size], (d as f32).powf(-0.5)),
        );
        tensors.insert(
            "head.b".to_string(),
            Tensor::from_vec(&[vocab_size], vec![0.0; vocab_size])?,
        );
        let dg = d / vq.groups;
        let codebooks = (0..l)
            .map(|_| {
                let data = rand_tensor(&mut rng, &[vq.groups * vq.codebook_size, dg], 0.5).data;
                Codebook::new(vq.groups, vq.codebook_size, dg, data)
            })
            .collect::<Result<Vec<_>>>()?;
        let meta = ModelMeta {
            n_layers: l,
            d_model: d,
            n_heads: hh,
            d_ff: ff,
            seq_len: t,
            causal: true,
            use_cls: false,
            vocab_size,
            patch_dim: 1,
            n_classes: 0,
            n_devices,
            groups: vq.groups,
            codebook_size: vq.codebook_size,
            bits_per_token: vq.groups * ceil_log2(vq.codebook_size),
        };
        Ok(Artifact {
            dir: PathBuf::from("<synthetic>"),
            graphs: BTreeMap::new(),
            meta,
            tensors,
            codebooks,
        })
    }

    pub fn graph(&self, name: &str) -> Result<&GraphSpec> {
        self.graphs
            .get(name)
            .with_context(|| format!("graph `{name}` not in manifest"))
    }

    pub fn tensor(&self, name: &str) -> Result<&Tensor> {
        self.tensors
            .get(name)
            .with_context(|| format!("tensor `{name}` not in weights"))
    }

    /// Block weight tensors for layer `li`, in BLOCK_WEIGHT_NAMES order.
    pub fn block_weights(&self, li: usize) -> Result<Vec<&Tensor>> {
        const NAMES: [&str; 16] = [
            "ln1.g", "ln1.b", "wq", "bq", "wk", "bk", "wv", "bv", "wo", "bo",
            "ln2.g", "ln2.b", "w1", "b1", "w2", "b2",
        ];
        NAMES
            .iter()
            .map(|n| self.tensor(&format!("blocks.{li}.{n}")))
            .collect()
    }

    /// Native BlockWeights copy for layer `li` (for the rust reference path).
    pub fn native_block(&self, li: usize) -> Result<crate::model::native::BlockWeights> {
        let t = |n: &str| -> Result<Tensor> { Ok(self.tensor(&format!("blocks.{li}.{n}"))?.clone()) };
        let v = |n: &str| -> Result<Vec<f32>> { Ok(t(n)?.data) };
        Ok(crate::model::native::BlockWeights {
            ln1_g: v("ln1.g")?,
            ln1_b: v("ln1.b")?,
            wq: t("wq")?,
            bq: v("bq")?,
            wk: t("wk")?,
            bk: v("bk")?,
            wv: t("wv")?,
            bv: v("bv")?,
            wo: t("wo")?,
            bo: v("bo")?,
            ln2_g: v("ln2.g")?,
            ln2_b: v("ln2.b")?,
            w1: t("w1")?,
            b1: v("b1")?,
            w2: t("w2")?,
            b2: v("b2")?,
        })
    }
}

/// Normal-random tensor for synthetic bundles.
fn rand_tensor(rng: &mut crate::util::rng::Rng, shape: &[usize], std: f32) -> Tensor {
    let mut t = Tensor::zeros(shape);
    for v in t.data.iter_mut() {
        *v = rng.normal_f32(0.0, std);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::shape::VqSetting;
    use crate::model::TransformerShape;

    fn tiny_shape() -> TransformerShape {
        TransformerShape {
            n_layers: 2,
            d_model: 16,
            n_heads: 2,
            d_ff: 32,
            seq_len: 16,
            elem_bytes: 4,
        }
    }

    #[test]
    fn synthetic_decoder_is_complete_and_deterministic() {
        let a = Artifact::synthetic_decoder(&tiny_shape(), 32, 2, VqSetting::new(2, 8), 7).unwrap();
        assert!(a.meta.causal);
        assert_eq!(a.meta.bits_per_token, 2 * 3);
        assert_eq!(a.codebooks.len(), 2);
        // everything the native decode path reads is present
        for name in ["embed", "pos", "ln_f.g", "ln_f.b", "head.w", "head.b"] {
            assert!(a.tensor(name).is_ok(), "missing {name}");
        }
        for li in 0..2 {
            assert!(a.native_block(li).is_ok(), "incomplete block {li}");
        }
        // deterministic in the seed
        let b = Artifact::synthetic_decoder(&tiny_shape(), 32, 2, VqSetting::new(2, 8), 7).unwrap();
        assert_eq!(a.tensor("embed").unwrap().data, b.tensor("embed").unwrap().data);
        let c = Artifact::synthetic_decoder(&tiny_shape(), 32, 2, VqSetting::new(2, 8), 8).unwrap();
        assert_ne!(a.tensor("embed").unwrap().data, c.tensor("embed").unwrap().data);
    }

    #[test]
    fn synthetic_decoder_rejects_bad_shapes() {
        let vq = VqSetting::new(2, 8);
        let mut s = tiny_shape();
        s.seq_len = 15; // not divisible by 2 devices
        assert!(Artifact::synthetic_decoder(&s, 32, 2, vq, 0).is_err());
        let mut s = tiny_shape();
        s.n_heads = 3; // 16 % 3 != 0
        assert!(Artifact::synthetic_decoder(&s, 32, 2, vq, 0).is_err());
        assert!(Artifact::synthetic_decoder(&tiny_shape(), 1, 2, vq, 0).is_err());
        assert!(Artifact::synthetic_decoder(&tiny_shape(), 32, 5, vq, 0).is_err());
    }
}
