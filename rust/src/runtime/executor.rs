//! PJRT execution: compile HLO text once, bind weight literals once,
//! execute with per-call activations.
//!
//! Follows /opt/xla-example/load_hlo: `HloModuleProto::from_text_file` →
//! `XlaComputation::from_proto` → `client.compile` → `execute`. Graphs are
//! lowered with return_tuple=True, so outputs unwrap via `to_tuple`.

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::tensor::Tensor;

use super::artifact::{Artifact, GraphSpec};
// The image-vendored `xla` bindings are absent from this build; the in-repo
// stub keeps the same API and fails cleanly at `PjRtClient::cpu()`.
use super::xla;

fn tensor_to_literal(t: &Tensor) -> Result<xla::Literal> {
    let lit = xla::Literal::vec1(&t.data);
    let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
    Ok(lit.reshape(&dims)?)
}

fn literal_to_tensor(lit: &xla::Literal) -> Result<Tensor> {
    let shape = lit.array_shape()?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    match lit.ty()? {
        xla::ElementType::F32 => Tensor::from_vec(&dims, lit.to_vec::<f32>()?),
        xla::ElementType::S32 => {
            let ints = lit.to_vec::<i32>()?;
            Tensor::from_vec(&dims, ints.into_iter().map(|v| v as f32).collect())
        }
        other => bail!("unsupported output element type {other:?}"),
    }
}

fn tensor_to_i32_literal(t: &Tensor) -> Result<xla::Literal> {
    let ints: Vec<i32> = t.data.iter().map(|&v| v as i32).collect();
    let lit = xla::Literal::vec1(&ints);
    let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
    Ok(lit.reshape(&dims)?)
}

/// One compiled graph plus its pre-staged weight literals. The compiled
/// PJRT executable is shared (Arc) between per-layer variants — only the
/// bound weight literals differ.
pub struct Executor {
    pub spec: GraphSpec,
    exe: Arc<xla::PjRtLoadedExecutable>,
    /// literals for `weight`/`codebook` args, keyed by arg position
    bound: BTreeMap<usize, xla::Literal>,
}

impl Executor {
    /// Execute with activations supplied positionally (in the order the
    /// manifest lists `activation` args). Weight args use bound literals.
    pub fn run(&self, activations: &[&Tensor]) -> Result<Vec<Tensor>> {
        let mut lits: Vec<xla::Literal> = Vec::with_capacity(self.spec.args.len());
        let mut ai = 0usize;
        for (pos, arg) in self.spec.args.iter().enumerate() {
            if let Some(b) = self.bound.get(&pos) {
                lits.push(b.clone());
            } else {
                let t = activations
                    .get(ai)
                    .with_context(|| format!("missing activation for arg `{}`", arg.name))?;
                let expect: usize = arg.shape.iter().product();
                if t.numel() != expect {
                    bail!(
                        "arg `{}` expects shape {:?} ({expect}), got {:?}",
                        arg.name, arg.shape, t.shape
                    );
                }
                if arg.dtype.contains("int32") {
                    lits.push(tensor_to_i32_literal(t)?);
                } else {
                    lits.push(tensor_to_literal(t)?);
                }
                ai += 1;
            }
        }
        if ai != activations.len() {
            bail!("{} activations supplied, {} consumed", activations.len(), ai);
        }
        let result = self.exe.execute::<xla::Literal>(&lits)?;
        let tuple = result[0][0].to_literal_sync()?;
        let outs = tuple.to_tuple()?;
        outs.iter().map(literal_to_tensor).collect()
    }

    pub fn n_activation_args(&self) -> usize {
        self.spec.args.len() - self.bound.len()
    }
}

/// All compiled graphs of one artifact bundle on a shared PJRT client.
///
/// One `ModelRuntime` is shared by every simulated device (they represent
/// replicas of the same model); per-device state lives in the coordinator.
pub struct ModelRuntime {
    pub client: Arc<xla::PjRtClient>,
    pub artifact: Arc<Artifact>,
    executors: BTreeMap<String, Arc<Executor>>,
}

impl ModelRuntime {
    /// Compile every graph in the bundle. Weight/codebook args are bound to
    /// literals from weights.bin immediately (layer-0 block weights by
    /// default; use [`Self::executor_for_layer`] to rebind other layers).
    pub fn load(artifact: Artifact) -> Result<ModelRuntime> {
        let client = Arc::new(xla::PjRtClient::cpu().context("creating PJRT CPU client")?);
        let artifact = Arc::new(artifact);
        let mut executors = BTreeMap::new();
        for (name, spec) in &artifact.graphs {
            let exe = Arc::new(compile(&client, spec)?);
            let bound = bind_weights(&artifact, spec, 0)?;
            executors.insert(
                name.clone(),
                Arc::new(Executor { spec: spec.clone(), exe, bound }),
            );
        }
        Ok(ModelRuntime { client, artifact, executors })
    }

    pub fn executor(&self, name: &str) -> Result<Arc<Executor>> {
        self.executors
            .get(name)
            .cloned()
            .with_context(|| format!("no executor `{name}`"))
    }

    /// A copy of `name`'s executor with layer-`li` weights bound. The
    /// compiled PJRT executable is shared; only literals differ.
    pub fn executor_for_layer(&self, name: &str, li: usize) -> Result<Executor> {
        let base = self.executor(name)?;
        let spec = base.spec.clone();
        let bound = bind_weights(&self.artifact, &spec, li)?;
        Ok(Executor { spec, exe: base.exe.clone(), bound })
    }

    /// Build per-layer executors for a block-type graph, binding each
    /// layer's weights once (the serving hot path's working set).
    pub fn layer_bank(&self, name: &str) -> Result<Vec<Executor>> {
        (0..self.artifact.meta.n_layers)
            .map(|li| self.executor_for_layer(name, li))
            .collect()
    }
}

fn compile(client: &xla::PjRtClient, spec: &GraphSpec) -> Result<xla::PjRtLoadedExecutable> {
    let path = spec
        .file
        .to_str()
        .with_context(|| format!("non-utf8 path {:?}", spec.file))?;
    let proto = xla::HloModuleProto::from_text_file(path)
        .with_context(|| format!("parsing HLO text {}", path))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    Ok(client.compile(&comp)?)
}

/// Bind `weight` args from the tensor table (w.<name> → blocks.<li>.<name>,
/// plain names otherwise) and `codebook` args from codebooks[li].
fn bind_weights(
    artifact: &Artifact,
    spec: &GraphSpec,
    li: usize,
) -> Result<BTreeMap<usize, xla::Literal>> {
    let mut bound = BTreeMap::new();
    for (pos, arg) in spec.args.iter().enumerate() {
        match arg.kind.as_str() {
            "weight" => {
                // block graphs name weight args `w.<name>` (bound per layer);
                // embed/head graphs use the dotted tensor-table name directly.
                let t = if let Some(block_name) = arg.name.strip_prefix("w.") {
                    artifact.tensor(&format!("blocks.{li}.{block_name}"))?
                } else {
                    artifact.tensor(&arg.name)?
                };
                bound.insert(pos, tensor_to_literal(t)?);
            }
            "codebook" => {
                let cb = &artifact.codebooks[li.min(artifact.codebooks.len() - 1)];
                let t = Tensor::from_vec(&[cb.groups, cb.k, cb.dg], cb.data.clone())?;
                bound.insert(pos, tensor_to_literal(&t)?);
            }
            _ => {}
        }
    }
    Ok(bound)
}
