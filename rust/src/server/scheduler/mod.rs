//! Continuous-batching serve scheduler.
//!
//! Replaces the batch-1 FIFO loop for load testing: requests are admitted
//! into `max_slots` in-flight decode slots (vLLM/Orca-style continuous
//! batching), prefill batches are formed by the [`Batcher`]'s deadline/fill
//! logic, and each scheduler iteration either
//!
//!  * runs one *batched prefill* for newly admitted requests — compute and
//!    wire bits scale with the batch, kernel launches and collective sync
//!    stages are paid once ([`crate::parallel::cost::Phase::for_batch`]) — or
//!  * runs one *batched decode step* advancing every active slot by one
//!    token — single-token decode is memory-bound (one streaming pass over
//!    the weights), so co-scheduled slots share that floor almost for free.
//!
//! The module is split by responsibility: this file holds the
//! configuration, events, the backend trait, and the engine's cost
//! helpers; `actor.rs` is the actorized per-iteration mechanism
//! ([`EngineActor::step`] — all run state, one scheduling iteration per
//! call); `loop.rs` the trivial single-replica driver
//! ([`CbEngine::serve_stream_with`], bit-for-bit the pre-actor loop);
//! `slots.rs` the in-flight slot state; `report.rs` the outcome
//! accounting. Decision *policy* lives one level up in
//! [`crate::server::policy`]; the multi-replica cluster loop that drives
//! many actors on one clock lives in [`crate::server::cluster`].
//!
//! # Scheduling policy
//!
//! Every discretionary decision — who is admitted next, who loses a slot
//! under KV pressure, whether to preempt proactively for SLOs — is
//! delegated to a [`crate::server::policy::SchedPolicy`]
//! (`CbConfig::policy` / `--policy`). The policy sees immutable
//! queue/slot snapshots and the virtual time, and returns indices:
//! mechanism (clock, KV pool, chunking, swap pricing, backends) stays in
//! the loop, so any policy preserves the live-vs-model differential by
//! construction. The default [`crate::server::policy::Fifo`] reproduces
//! the pre-policy-layer event streams bit for bit;
//! [`crate::server::policy::PrefixAware`] reorders admissions by
//! radix-tree prefix coverage (aging-bounded);
//! [`crate::server::policy::SloClass`] schedules priority classes with
//! per-class deadlines (`CbConfig::classes` / `--classes`: admission
//! highest-class-first, victims lowest-class-first then newest, classes
//! preemption-exempt while within their deadline budget, plus a
//! proactive hook that evicts a past-deadline lower-class slot when a
//! salvageable higher-class request is waiting). With classes configured
//! the report carries per-class latency/attainment/goodput breakdowns
//! ([`ClassReport`]) whatever the policy — so `Fifo` vs `SloClass` SLO
//! attainment is directly comparable on one trace.
//!
//! # Chunked piggybacked prefill
//!
//! With `CbConfig::prefill_chunk_tokens > 0`, a prompt longer than the
//! budget no longer monopolizes the cluster for its full prefill. Its
//! admission iteration replays only the first `prefill_chunk_tokens` rows;
//! the slot then sits in [`SlotState::Prefilling`] and each subsequent
//! iteration *fuses* one chunk batch — up to the budget of prompt tokens,
//! shared FIFO across all prefilling slots — with the decode step advancing
//! the in-flight decoding slots
//! ([`crate::parallel::strategies::Strategy::fused_iteration_schedule`]:
//! FLOPs and wire bits are paid for the chunk tokens plus one token per
//! decode slot, launches/sync/memory-floor once per iteration). Every chunk
//! is recorded as a [`CbEvent::PrefillChunk`]; TTFT for a chunked request
//! fires on its first decode step after the last chunk. Prompts that fit
//! inside the budget take the classic monopolizing path (their "first
//! chunk" is the whole prompt), so `prefill_chunk_tokens >= max prompt` —
//! and `prefill_chunk_tokens == 0`, the disabled default — reproduce the
//! unchunked scheduler's event stream bit for bit; `tests/proptests.rs`
//! pins that anchor. Prefill-only workloads (`decode_tokens == 0`) have no
//! decode iterations to piggyback on and always take the classic path.
//!
//! # Backends
//!
//! The loop owns every scheduling decision and all *timing* (the cost
//! model's virtual clock); per-slot execution is delegated to a
//! [`DecodeBackend`]. [`ModelBackend`] is the pure cost-model run;
//! [`crate::server::live::LiveBackend`] drives real
//! [`crate::coordinator::decode::DecodeSession`]s — actual tensors,
//! mixed-precision KV caches, greedy decode. Because both backends share
//! this loop, their decision streams ([`CbEvent`]) must be identical on
//! the same trace; `tests/live_vs_model.rs` asserts exactly that.
//!
//! # KV-pressure admission
//!
//! With `CbConfig::kv_cap_bytes > 0`, a [`KvBudget`] gates admission on
//! Appendix-G mixed-KV memory ([`crate::model::kv_cache_bytes_astra_live`]):
//! a request is admitted only when its prefill cache fits the cap next to
//! every in-flight slot; otherwise it queues (FIFO — nothing jumps a
//! blocked head — unless a reordering policy is active). Slots grow by
//! two full-precision rows per generated token, so pressure can build
//! *during* decode; before a step would overflow the cap, slots are
//! preempted back to the queue, the victim chosen by the policy
//! (recompute-style preemption — their requests re-prefill later, and
//! their queue/TTFT waits are recorded again on re-admission). Under the
//! default policy the victim is the most recently admitted slot and the
//! oldest is never evicted; requests whose full budget can never fit are
//! rejected outright under every policy, so admission always makes
//! progress. Requests that can never fit are counted in
//! `CbReport::kv_rejected`.
//!
//! # Block pool, prefix reuse, and swap preemption
//!
//! With `CbConfig::prefix_cache`, KV accounting moves from flat per-slot
//! bytes onto the block pool ([`crate::kv`]): prompts are split into
//! `kv_block_tokens`-token blocks whose bytes are Appendix-G prefix
//! differences (telescoping to exactly the flat bytes, so sharing-off
//! reproduces the old streams bit for bit), and a radix tree over
//! token-id prefixes lets a request whose prompt shares a block-aligned
//! prefix with a resident or recently-freed cache *attach* to those
//! blocks ([`CbEvent::PrefixHit`]): admission charges only the uncovered
//! suffix, the prefill replays only the suffix (chunked through the same
//! machinery, [`CbEvent::PrefillChunk`] events starting at the covered
//! edge), and completed slots leave their blocks cached at refcount 0
//! until capacity pressure reclaims them LRU-first. Prompt token ids are
//! derived deterministically from `(seed, prompt_groups)` — the same
//! stream the live backend feeds its sessions — so both backends agree on
//! every hit.
//!
//! With `CbConfig::swap_bandwidth_mbps > 0`, each KV-pressure eviction of
//! a decoding slot is priced: moving the cache out and back over a host
//! link at that bandwidth ([`crate::kv::swap::SwapPolicy`], the
//! [`crate::comm::link`] transfer arithmetic) versus re-prefilling the
//! prompt and regenerating every token produced so far. The cheaper side
//! wins, per eviction: [`CbEvent::SwapOut`] preserves decode progress and
//! [`CbEvent::SwapIn`] restores it at readmission (transfer time charged
//! on the virtual clock); recompute ([`CbEvent::Evict`]) stays the
//! fallback and the flag-off behavior.
//!
//! `CbConfig::decode_jitter` breaks same-length lockstep: each request's
//! decode budget is sampled once, deterministically from `(seed, id)`, in
//! `decode_tokens ± jitter`, so saturating waves stop completing in the
//! same iteration and staggered completion paths get exercised.
//!
//! # The client model
//!
//! With `CbConfig::patience_s > 0` the engine serves *impatient streaming
//! clients* ([`crate::workload`]): every generated token is stamped into
//! the request's per-token delivery record
//! ([`crate::workload::TokenStream`], reported in `CbReport::streams`),
//! and a client that has seen nothing for longer than its patience
//! abandons the request — the engine cancels it ([`CbEvent::Cancelled`]),
//! freeing the slot, its pool bytes and shared-block refs, or its queue /
//! parked-swap entry immediately. Queued and swapped requests cancel on
//! any silence since their last sign of life (arrival or last delivered
//! token); an in-flight slot cancels only on an observed inter-token
//! stall after at least one delivery, so admission order can never churn
//! a borderline request through admit/cancel cycles.
//! `CbConfig::length_tail_alpha` completes the model with bounded-Pareto
//! decode budgets (EOS-driven unknown-length generations). Both knobs
//! default off, reproducing the pre-workload event streams bit for bit.
//!
//! The engine reports tail latency (p50/p95/p99), time-to-first-token,
//! queue depth over time, goodput under an SLO, both horizon- and
//! completion-based throughput with censored (unfinished) requests
//! accounted separately, KV peak/eviction counters, prefix hit-rate and
//! swap traffic, per-class breakdowns, and the full decision event stream.

mod actor;
mod report;
#[path = "loop.rs"]
mod serve_loop;
mod slots;
#[cfg(test)]
#[path = "tests.rs"]
mod tests;

pub use actor::{CheckpointRecord, EngineActor, StepOutcome};
pub use report::{CbReport, ClassReport};
pub use slots::SlotState;

use anyhow::Result;

use crate::comm::trace::BandwidthTrace;
use crate::model::{
    kv_cache_bytes_astra_live, kv_cache_bytes_astra_positional, kv_cache_bytes_full,
    TransformerShape,
};
use crate::parallel::strategies::{Strategy, StrategyKind};
use crate::sim::latency::{evaluate_on_trace, SimParams};
use crate::util::rng::Rng;

use super::batcher::Request;
use super::live::{prompt_stream_key, synth_prompt};
use super::policy::{Fifo, PlacementAware, PolicyKind, PrefixAware, SchedPolicy, SloClass};
use crate::parallel::cost::FleetProfile;
use crate::parallel::plan::{Plan, Planner};
use crate::parallel::Schedule;
use slots::Slot;

/// Continuous-batching policy knobs.
#[derive(Debug, Clone)]
pub struct CbConfig {
    /// in-flight decode slots (1 degenerates to the batch-1 FIFO baseline)
    pub max_slots: usize,
    /// prefill admission batch cap (the batcher's fill target)
    pub max_batch: usize,
    /// batcher deadline: admit a partial batch once the oldest queued
    /// request has waited this long
    pub max_wait_s: f64,
    /// tokens generated per request after prefill (0 = prefill-only)
    pub decode_tokens: usize,
    /// end-to-end latency SLO for goodput (<= 0 disables the SLO filter)
    pub slo_s: f64,
    /// completion-bar window (Fig 6 style)
    pub window_s: f64,
    /// mixed-KV memory cap for the admission gate, bytes (0 = unlimited)
    pub kv_cap_bytes: usize,
    /// Sarathi-style chunked prefill: per-iteration prompt-token budget
    /// mixed into decode iterations, shared across prefilling slots. 0
    /// disables chunking (a prompt prefills whole at its admission — the
    /// monopolizing baseline). Prompts no longer than the budget also take
    /// that classic path, so any budget >= the longest prompt reproduces
    /// the unchunked scheduler's event stream bit for bit.
    pub prefill_chunk_tokens: usize,
    /// radix-tree prefix sharing over block-aligned prompt prefixes
    /// (`--prefix-cache`). Off (the default) keeps the flat per-slot
    /// accounting and reproduces the pre-pool event streams bit for bit.
    /// Requires `decode_tokens > 0` (prefill-only slots hold no sessions
    /// to share); ignored otherwise.
    pub prefix_cache: bool,
    /// tokens per shared KV block (`--kv-block-tokens`); sharing is
    /// block-aligned, so a block size above the longest prompt makes
    /// sharing impossible and reproduces the prefix-off stream exactly
    pub kv_block_tokens: usize,
    /// host-link bandwidth for swap-style preemption, Mbps
    /// (`--swap-bandwidth-mbps`). 0 (default) disables swapping: every
    /// KV-pressure eviction recomputes, as before. With a cap and a
    /// bandwidth set, each eviction swaps iff the round-trip transfer
    /// beats the modeled recompute.
    pub swap_bandwidth_mbps: f64,
    /// one-way host-link latency per swap transfer, seconds
    pub swap_latency_s: f64,
    /// ± tokens of seeded per-request decode-budget jitter
    /// (`--decode-jitter`); 0 keeps every budget at `decode_tokens`
    pub decode_jitter: usize,
    /// prompt-content classes for the synthetic workload
    /// (`--prompt-groups`): ids map to `id % prompt_groups`, so requests
    /// in one group share leading token ids (the prefix-cache workload).
    /// 0 (default) gives every request its own stream — the historical
    /// behavior.
    pub prompt_groups: usize,
    /// seed for prompt-content derivation and decode jitter; live runs
    /// pin this to the cluster seed so both backends see one workload
    pub seed: u64,
    /// vocabulary for model-only prompt derivation; live runs pin this to
    /// the artifact's vocab
    pub prompt_vocab: usize,
    /// which [`SchedPolicy`] makes the admission-order / victim /
    /// proactive-preemption decisions (`--policy`). The default
    /// [`PolicyKind::Fifo`] reproduces the pre-policy event streams bit
    /// for bit.
    pub policy: PolicyKind,
    /// per-class latency deadlines, seconds (`--classes d0,d1,...`).
    /// Empty (default) disables class accounting. Request ids map onto
    /// classes round-robin (`id % classes.len()`), identically on both
    /// backends; **a higher class index is a higher priority**, and
    /// `classes[k] <= 0` means class `k` has no deadline. Setting
    /// classes alone only adds per-class report breakdowns — scheduling
    /// changes only under [`PolicyKind::SloClass`].
    pub classes: Vec<f64>,
    /// seconds of sojourn per aging step for the reordering policies
    /// (`--age-bound`): one KV block of effective coverage under
    /// [`PrefixAware`], one class level under [`SloClass`] — the bound
    /// that keeps reordering starvation-free. <= 0 disables aging.
    pub age_bound_s: f64,
    /// victims the [`SloClass`] proactive hook may preempt per iteration
    /// (`--slo-preempt-budget`). The default 1 preserves the
    /// one-victim-per-iteration streams bit for bit; higher budgets pair
    /// up to that many blown lower-class slots with salvageable
    /// higher-class queued requests in one pass, draining deep two-class
    /// queues faster. Ignored by policies without the hook.
    pub slo_preempt_budget: usize,
    /// proactive checkpointing for fault recovery (`--checkpoint-every`):
    /// every K decode steps a decoding slot's full occupancy is copied to
    /// the host tier over the swap link ([`CbEvent::Checkpoint`], transfer
    /// time charged on the virtual clock), so an unplanned replica kill
    /// can restore the slot on a survivor ([`CbEvent::Restore`]) instead
    /// of replaying its whole prompt. 0 (default) disables checkpointing;
    /// requires `swap_bandwidth_mbps > 0` (the checkpoint tier *is* the
    /// priced swap tier) and decode to be on.
    pub checkpoint_every: usize,
    /// `--serial-decode`: the live backend's escape hatch — execute decode
    /// steps and prefill-chunk replays one slot at a time (the pre-fusion
    /// path) instead of the fused batched kernel + scoped-thread replay.
    /// Purely an execution-backend knob: scheduling never reads it, so the
    /// event stream is identical either way and the flag exists to *prove*
    /// that (and to anchor the tokens/sec microbenchmarks).
    pub serial_decode: bool,
    /// `--copy-engine`: model a copy engine that overlaps SwapOut and
    /// checkpoint transfers behind the decode step instead of serializing
    /// them into the evicting iteration — the iteration finishes at
    /// `max(compute, transfer)` rather than `compute + transfer`. The
    /// transfers are still fully priced in `model_time.comm_s`; only the
    /// clock stops charging them when compute already covers them. Off
    /// (default) preserves historical event streams bit for bit.
    pub copy_engine: bool,
    /// client patience between observed events, seconds (`--patience`):
    /// a request whose client has seen nothing (no arrival-ack token, no
    /// next token) for longer than its patience is abandoned and the
    /// engine cancels it ([`CbEvent::Cancelled`]) — queued and swapped
    /// requests cancel on any silence since their last sign of life;
    /// in-flight slots cancel only on an observed *inter-token* stall
    /// after at least one delivery (pre-first-token abandonment is the
    /// queue's job, so a borderline admission cannot churn). <= 0
    /// (default) disables the client model entirely — no sweep runs, no
    /// streams change.
    pub patience_s: f64,
    /// multiplicative spread of per-client patience (`--patience-spread`):
    /// each request's patience is drawn log-uniformly over
    /// `[patience_s/(1+spread), patience_s*(1+spread)]` from `(seed, id)`
    /// ([`crate::workload::patience_for`]). 0 (default) gives every
    /// client exactly `patience_s`.
    pub patience_spread: f64,
    /// tail index of the bounded-Pareto decode-length distribution
    /// (`--length-tail`): models EOS/stop-sequence-driven unknown-length
    /// decodes — budgets are drawn on `[1, decode_tokens]` from
    /// `(seed, id)` ([`crate::workload::tail_budget`]), most short, a
    /// heavy tail at the maximum; smaller alpha = heavier tail. <= 0
    /// (default) keeps the `decode_tokens ± decode_jitter` behavior.
    pub length_tail_alpha: f64,
    /// per-iteration *cost* budget for the proactive SLO hook, seconds
    /// (`--slo-preempt-cost`): each proactive eviction is priced like an
    /// ordinary preemption (the swap round-trip when the victim would
    /// swap, the modeled recompute otherwise) and the hook stops
    /// evicting once the iteration's accumulated price would exceed this
    /// budget — so one cheap victim is preferred over one enormous one.
    /// <= 0 (default) keeps the flat `slo_preempt_budget` count
    /// unpriced, bit-identical to the historical streams.
    pub slo_preempt_cost_s: f64,
    /// relative per-device speed profile (`--device-speeds 4,2,1,0.5`):
    /// non-empty with at least two distinct values builds a
    /// [`crate::parallel::FleetProfile`] and turns on heterogeneous
    /// pricing — profile-weighted token splits, fastest-device decode
    /// placement, and the planner's candidate search
    /// ([`crate::parallel::Planner`]). Empty (default) or all-equal keeps
    /// the legacy single-reference-device pricing and reproduces
    /// historical event streams bit for bit.
    pub device_speeds: Vec<f64>,
    /// re-plan tick period, virtual seconds (`--replan-every`): every S
    /// seconds the actor re-runs the planner on its EWMA bandwidth
    /// estimate and swaps the active plan when the predicted win beats
    /// the hysteresis ([`CbEvent::Replan`]). 0 (default) pins the plan
    /// chosen at t=0 for the whole run — and with a uniform (or absent)
    /// profile that is the even-split status quo, bit-identical to the
    /// static streams.
    pub replan_every_s: f64,
    /// minimum predicted relative win before a re-plan tick swaps plans
    /// (default 0.05: the challenger must model >= 5% faster than the
    /// incumbent re-scored at current bandwidth) — the guard against
    /// plan thrash on noisy traces
    pub replan_hysteresis: f64,
}

impl Default for CbConfig {
    fn default() -> CbConfig {
        CbConfig {
            max_slots: 8,
            max_batch: 8,
            max_wait_s: 0.02,
            decode_tokens: 64,
            slo_s: 0.0,
            window_s: 10.0,
            kv_cap_bytes: 0,
            prefill_chunk_tokens: 0,
            prefix_cache: false,
            kv_block_tokens: 16,
            swap_bandwidth_mbps: 0.0,
            swap_latency_s: 0.0005,
            decode_jitter: 0,
            prompt_groups: 0,
            seed: 0,
            prompt_vocab: 64,
            policy: PolicyKind::Fifo,
            classes: Vec::new(),
            age_bound_s: 0.5,
            slo_preempt_budget: 1,
            checkpoint_every: 0,
            serial_decode: false,
            copy_engine: false,
            patience_s: 0.0,
            patience_spread: 0.0,
            length_tail_alpha: 0.0,
            slo_preempt_cost_s: 0.0,
            device_speeds: Vec::new(),
            replan_every_s: 0.0,
            replan_hysteresis: 0.05,
        }
    }
}

impl CbConfig {
    /// The batch-1 FIFO baseline (the paper's Fig-6 setting) with the same
    /// workload shape — for apples-to-apples comparisons.
    pub fn batch1(self) -> CbConfig {
        CbConfig { max_slots: 1, max_batch: 1, ..self }
    }

    /// The priority class request `id` belongs to: round-robin over the
    /// configured classes, 0 when none are set. Derived from the id alone
    /// so the cost-model and live backends always agree.
    pub fn class_of(&self, id: u64) -> usize {
        if self.classes.is_empty() {
            0
        } else {
            (id % self.classes.len() as u64) as usize
        }
    }

    /// Class `class`'s latency deadline (<= 0 or unconfigured: none).
    pub fn class_deadline(&self, class: usize) -> f64 {
        self.classes.get(class).copied().unwrap_or(0.0)
    }

    /// Build the configured [`SchedPolicy`]. [`PolicyKind::Placement`]
    /// gets a neutral decode speed here; [`CbEngine::make_policy`] is the
    /// profile-aware constructor the actor actually uses.
    pub fn make_policy(&self) -> Box<dyn SchedPolicy> {
        match self.policy {
            PolicyKind::Fifo => Box::new(Fifo),
            PolicyKind::PrefixAware => Box::new(PrefixAware {
                block_tokens: self.kv_block_tokens.max(1),
                age_bound_s: self.age_bound_s,
            }),
            PolicyKind::SloClass => Box::new(SloClass {
                age_bound_s: self.age_bound_s,
                preempt_budget: self.slo_preempt_budget.max(1),
            }),
            PolicyKind::Placement => {
                Box::new(PlacementAware { decode_speed: 1.0, age_bound_s: self.age_bound_s })
            }
        }
    }
}

/// One scheduling decision. The stream of events is the scheduler's
/// complete decision record; the live-vs-model differential harness
/// (`tests/live_vs_model.rs`) asserts two backends produce identical
/// streams on the same fixed-seed trace.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CbEvent {
    /// batched prefill admitted these request ids into slots (policy
    /// admission order; queue order under the default FIFO policy)
    Admit { ids: Vec<u64> },
    /// one batched decode step advanced these in-flight slots by a token
    Decode { ids: Vec<u64> },
    /// request finished (decode budget exhausted, or prefill-only done)
    Complete { id: u64 },
    /// slot evicted back to the queue — KV pressure or an SLO preemption
    /// — and will re-prefill
    Evict { id: u64 },
    /// request whose full KV budget can never fit the cap; dropped
    Reject { id: u64 },
    /// a prefill chunk advanced slot `id`'s prompt rows `[lo, hi)` through
    /// the model, fused into the surrounding iteration. Emitted only for
    /// prompts longer than the chunk budget; per admission episode the
    /// chunk events of a slot tile `[covered, prompt_len)` contiguously in
    /// order (`covered == 0` without a prefix hit).
    PrefillChunk { id: u64, lo: usize, hi: usize },
    /// request `id`'s prompt attached to shared KV blocks covering its
    /// first `tokens` positions (block-aligned): only the suffix replays,
    /// only the suffix footprint is charged
    PrefixHit { id: u64, tokens: usize },
    /// preemption moved slot `id`'s cache to the host tier instead of
    /// dropping it — the bandwidth-priced transfer beat recompute; decode
    /// progress is preserved for [`CbEvent::SwapIn`]
    SwapOut { id: u64 },
    /// a previously swapped request re-entered a slot by transferring its
    /// cache back (charged at the host-link bandwidth), resuming decode
    /// where it left off
    SwapIn { id: u64 },
    /// an unplanned replica kill lost this in-flight or queued request;
    /// the cluster loop re-routes it to a survivor (replay from prompt,
    /// or [`CbEvent::Restore`] when a checkpoint copy exists)
    Killed { id: u64 },
    /// proactive checkpoint: slot `id`'s full occupancy was copied to the
    /// host tier over the swap link (`CbConfig::checkpoint_every`),
    /// priced into the iteration like a swap-out
    Checkpoint { id: u64 },
    /// a killed request re-entered a slot on a survivor by transferring
    /// its latest checkpoint copy back from the fleet host tier —
    /// decode progress up to the checkpoint is preserved, like
    /// [`CbEvent::SwapIn`] but sourced from a dead replica's checkpoint
    Restore { id: u64 },
    /// the request's client abandoned it (`CbConfig::patience_s`): the
    /// engine freed its slot and KV blocks — or removed it from the
    /// queue / dropped its parked swap state — immediately, with no
    /// requeue. A cancelled request is terminal: never completed, never
    /// censored, never re-admitted.
    Cancelled { id: u64 },
    /// a `--replan-every` tick swapped the active heterogeneous plan:
    /// planner candidate slot `from` -> `to`
    /// ([`crate::parallel::plan::Planner::candidates`]). Admissions after
    /// this event price and partition their prompts under the new plan;
    /// in-flight slots finish on the plan they were admitted under — the
    /// re-partition happens at the next admission boundary, so there is
    /// no correctness cliff.
    Replan { from: usize, to: usize },
}

/// LEGACY flat admission gate over Appendix-G mixed-KV memory — the
/// pre-block-pool accounting, kept for API compatibility and as the
/// reference semantics the pool must reduce to: the serving engine now
/// tracks bytes through [`crate::kv::pool::KvPool`], whose
/// private-plus-block classes telescope to exactly these counters
/// whenever prefix sharing is off. `cap_bytes == 0` disables the gate
/// (every request fits).
#[derive(Debug, Clone, Default)]
pub struct KvBudget {
    pub cap_bytes: usize,
    pub used_bytes: usize,
    pub peak_bytes: usize,
}

impl KvBudget {
    pub fn new(cap_bytes: usize) -> KvBudget {
        KvBudget { cap_bytes, used_bytes: 0, peak_bytes: 0 }
    }

    /// Would `bytes` more fit under the cap?
    pub fn fits(&self, bytes: usize) -> bool {
        self.cap_bytes == 0 || self.used_bytes + bytes <= self.cap_bytes
    }

    pub fn acquire(&mut self, bytes: usize) {
        self.used_bytes += bytes;
        self.peak_bytes = self.peak_bytes.max(self.used_bytes);
    }

    pub fn release(&mut self, bytes: usize) {
        self.used_bytes = self.used_bytes.saturating_sub(bytes);
    }
}

/// Shared-prefix attachment delivered with an admission: the request's
/// first `tokens` prompt positions are covered by the listed ready blocks
/// (root-to-leaf, contiguous, block-aligned). Empty when the prompt shares
/// nothing — or prefix caching is off.
#[derive(Debug, Clone, Default)]
pub struct PrefixAttach {
    pub tokens: usize,
    pub blocks: Vec<u64>,
}

/// One admitted request: everything its execution backend needs, in one
/// struct instead of the four parallel slices the old `admit` took.
#[derive(Debug, Clone)]
pub struct AdmitEntry {
    pub req: Request,
    /// this request's (possibly jittered) decode-token budget
    pub budget: usize,
    /// priority class ([`CbConfig::class_of`]) — advisory for execution
    /// (the loop already made every class-driven decision), plumbed so
    /// real backends can tag sessions for QoS accounting or placement
    pub class: usize,
    /// shared-prefix coverage delivered with the admission
    pub prefix: PrefixAttach,
}

/// A typed admission batch: the per-request [`AdmitEntry`] rows plus the
/// batch-wide prefill-token limit (`usize::MAX` when chunking is off, so
/// whole uncovered suffixes replay at admission).
#[derive(Debug, Clone)]
pub struct AdmitBatch {
    pub entries: Vec<AdmitEntry>,
    pub prefill_limit: usize,
    /// per-device split weights the admitted sessions should partition
    /// their prompts by (the active heterogeneous plan's weighted
    /// profile); `None` keeps the cluster's even partition — the
    /// static/legacy behavior and the value whenever no plan, an
    /// even-baseline plan, or no profile is active
    pub split_weights: Option<Vec<f64>>,
}

/// One prefill chunk fused into an iteration: replay prompt rows
/// `[lo, hi)` of slot `id`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ChunkPlan {
    pub id: u64,
    pub lo: usize,
    pub hi: usize,
}

/// The real batch boundary of one fused iteration: every prefill chunk the
/// scheduler piggybacked plus every slot taking a decode token. A backend
/// executes the whole plan as one unit — the live path replays chunks on
/// scoped threads and advances all decode slots through one fused batched
/// GEMM per layer ([`crate::coordinator::decode::step_batch`]).
#[derive(Debug, Clone, Default)]
pub struct StepBatch {
    /// prefill chunks fused into this iteration (disjoint slots)
    pub chunks: Vec<ChunkPlan>,
    /// slots advancing one decode token (disjoint from `chunks`' slots:
    /// a chunked slot never decodes in the same iteration)
    pub decode_ids: Vec<u64>,
}

impl StepBatch {
    pub fn is_empty(&self) -> bool {
        self.chunks.is_empty() && self.decode_ids.is_empty()
    }
}

/// Execution backend driven by the scheduler loop. All methods mirror a
/// decision the loop already recorded as a [`CbEvent`]; a backend performs
/// the corresponding real work (or nothing, for the cost model). The
/// block/swap methods default to no-ops so cost-model backends stay
/// trivial.
pub trait DecodeBackend {
    /// A batch was admitted: start real work (live: open a `DecodeSession`
    /// per request, sized prompt + its decode budget, attach the shared
    /// blocks listed in its [`AdmitEntry::prefix`], and replay the first
    /// `min(uncovered suffix, prefill_limit)` prompt rows). The remainder
    /// of a longer suffix arrives through [`Self::step`] chunk plans.
    /// Swapped-in requests are NOT part of the batch; they arrive through
    /// [`Self::swap_in`].
    fn admit(&mut self, batch: &AdmitBatch) -> Result<()>;
    /// Execute one fused iteration: replay every planned prefill chunk and
    /// advance every listed slot by one decode token.
    fn step(&mut self, batch: &StepBatch) -> Result<()>;
    /// The request finished; release its state and collect output.
    fn complete(&mut self, id: u64) -> Result<()>;
    /// The slot was evicted back to the queue; drop its state (it will be
    /// rebuilt from scratch on re-admission).
    fn evict(&mut self, id: u64) -> Result<()>;
    /// Slot `session`'s prompt rows `[lo, hi)` are complete and now back a
    /// shared block: copy them into the block store so later attachments
    /// survive the creator (live copies real K/V rows; `bytes` is the
    /// block's accounting size).
    fn register_block(
        &mut self,
        _session: u64,
        _block: u64,
        _lo: usize,
        _hi: usize,
        _bytes: usize,
    ) -> Result<()> {
        Ok(())
    }
    /// A cached block was reclaimed for capacity; drop its stored rows.
    fn drop_block(&mut self, _block: u64) -> Result<()> {
        Ok(())
    }
    /// Preemption chose swap over recompute: move the slot's state to the
    /// host tier, preserving decode progress.
    fn swap_out(&mut self, _id: u64) -> Result<()> {
        Ok(())
    }
    /// A swapped request re-entered a slot: restore its state from the
    /// host tier.
    fn swap_in(&mut self, _id: u64) -> Result<()> {
        Ok(())
    }
    /// The replica holding request `id`'s host-tier state is being
    /// drained from the fleet: drop the parked state (the request is
    /// still queued and will rebuild from scratch on a survivor).
    fn drop_swapped(&mut self, _id: u64) -> Result<()> {
        Ok(())
    }
    /// A killed request restores onto this backend from a fleet-level
    /// checkpoint copy: rebuild the slot's state as of `generated` decode
    /// steps past its `tokens`-token prompt (live: deterministically
    /// replay prompt + `generated` greedy steps — greedy decode makes the
    /// rebuilt cache bit-identical to the checkpointed one; the model
    /// already priced the restore as one host-link transfer).
    fn restore(
        &mut self,
        _id: u64,
        _tokens: usize,
        _generated: usize,
        _budget: usize,
        _class: usize,
    ) -> Result<()> {
        Ok(())
    }
    /// The request's client abandoned it mid-decode
    /// ([`CbEvent::Cancelled`]): drop the slot's state for good — the
    /// request will never be re-admitted, so nothing needs preserving.
    /// Defaults to [`Self::evict`] (the teardown is identical; only the
    /// scheduler-side bookkeeping differs), which is also why the loop
    /// calls this only for requests currently holding a slot — parked
    /// swap state is dropped through [`Self::drop_swapped`].
    fn cancel(&mut self, id: u64) -> Result<()> {
        self.evict(id)
    }
    /// Actual bytes currently held by in-flight slots plus the shared
    /// block store (0 if untracked); the loop counts a `kv_violations`
    /// whenever this exceeds the cap.
    fn kv_bytes_in_flight(&self) -> usize;
}

/// Cost-model-only backend: the event stream *is* the run.
pub struct ModelBackend;

impl DecodeBackend for ModelBackend {
    fn admit(&mut self, _batch: &AdmitBatch) -> Result<()> {
        Ok(())
    }
    fn step(&mut self, _batch: &StepBatch) -> Result<()> {
        Ok(())
    }
    fn complete(&mut self, _id: u64) -> Result<()> {
        Ok(())
    }
    fn evict(&mut self, _id: u64) -> Result<()> {
        Ok(())
    }
    fn kv_bytes_in_flight(&self) -> usize {
        0
    }
}

/// Continuous-batching serving engine over the cost-model clock: the
/// immutable half of a run (cost model + config). Cloneable so each
/// fleet replica's [`EngineActor`] can own its copy.
#[derive(Debug, Clone)]
pub struct CbEngine {
    pub shape: TransformerShape,
    pub strategy: Strategy,
    pub params: SimParams,
    pub trace: BandwidthTrace,
    pub cfg: CbConfig,
    /// heterogeneous fleet profile derived from `cfg.device_speeds`:
    /// `None` when the flag is unset or every speed is equal — in which
    /// case every pricing path below delegates to the legacy
    /// reference-device schedules bit for bit
    pub profile: Option<FleetProfile>,
}

impl CbEngine {
    pub fn new(
        shape: TransformerShape,
        strategy: Strategy,
        params: SimParams,
        trace: BandwidthTrace,
        cfg: CbConfig,
    ) -> CbEngine {
        let speeds = &cfg.device_speeds;
        let profile = if speeds.is_empty() || speeds.iter().all(|&s| s == speeds[0]) {
            None
        } else {
            Some(FleetProfile::from_speeds(params.device, speeds))
        };
        CbEngine { shape, strategy, params, trace, cfg, profile }
    }

    /// The pure planner this engine's actor re-runs on each
    /// `--replan-every` tick: the objective weighs one prefill against
    /// this config's decode budget of batched decode steps.
    pub fn planner(&self) -> Planner {
        let mut p = Planner::new(
            self.shape,
            self.strategy,
            self.params.device,
            self.params.stage_latency_s,
        );
        p.decode_steps = self.cfg.decode_tokens.max(1);
        p.decode_batch = self.cfg.max_slots.max(1);
        p
    }

    /// Build the configured [`SchedPolicy`], profile-aware: the
    /// placement policy learns the fleet's decode speed (its fastest
    /// device) so admission ordering can price decode work in real
    /// seconds. Every other kind delegates to [`CbConfig::make_policy`].
    pub fn make_policy(&self) -> Box<dyn SchedPolicy> {
        match self.cfg.policy {
            PolicyKind::Placement => Box::new(PlacementAware {
                decode_speed: self.profile.as_ref().map_or(1.0, |p| p.max_weight()),
                age_bound_s: self.cfg.age_bound_s,
            }),
            _ => self.cfg.make_policy(),
        }
    }

    /// The strategy + weighted profile an active non-baseline plan prices
    /// with; `None` whenever legacy pricing applies (no profile, no plan,
    /// or the even-split baseline plan) — the bit-identity anchor.
    fn plan_pricing(&self, plan: Option<&Plan>) -> Option<(Strategy, FleetProfile)> {
        let profile = self.profile.as_ref()?;
        let plan = plan?;
        if plan.is_even_baseline() {
            return None;
        }
        Some((Strategy::new(plan.kind, self.strategy.n_devices), plan.split.weighted(profile)))
    }

    /// Plan-aware batched-prefill pricing ([`Strategy::schedule`]).
    pub(crate) fn sched_prefill(&self, pshape: &TransformerShape, plan: Option<&Plan>) -> Schedule {
        match self.plan_pricing(plan) {
            Some((s, p)) => s.schedule_on(pshape, &p),
            None => self.strategy.schedule(pshape),
        }
    }

    /// Plan-aware decode-step pricing ([`Strategy::decode_step_schedule`]).
    pub(crate) fn sched_decode(&self, ctx: usize, plan: Option<&Plan>) -> Schedule {
        match self.plan_pricing(plan) {
            Some((s, p)) => s.decode_step_schedule_on(&self.shape, ctx, &p),
            None => self.strategy.decode_step_schedule(&self.shape, ctx),
        }
    }

    /// Plan-aware prefill-chunk pricing
    /// ([`Strategy::prefill_chunk_schedule`]).
    pub(crate) fn sched_chunk(&self, chunk: usize, ctx: usize, plan: Option<&Plan>) -> Schedule {
        match self.plan_pricing(plan) {
            Some((s, p)) => s.prefill_chunk_schedule_on(&self.shape, chunk, ctx, &p),
            None => self.strategy.prefill_chunk_schedule(&self.shape, chunk, ctx),
        }
    }

    /// Plan-aware fused chunk+decode pricing
    /// ([`Strategy::fused_iteration_schedule`]).
    pub(crate) fn sched_fused(
        &self,
        chunk: usize,
        ctx_prefill: usize,
        decode_batch: usize,
        ctx_decode: usize,
        plan: Option<&Plan>,
    ) -> Schedule {
        match self.plan_pricing(plan) {
            Some((s, p)) => s.fused_iteration_schedule_on(
                &self.shape,
                chunk,
                ctx_prefill,
                decode_batch,
                ctx_decode,
                &p,
            ),
            None => self.strategy.fused_iteration_schedule(
                &self.shape,
                chunk,
                ctx_prefill,
                decode_batch,
                ctx_decode,
            ),
        }
    }

    /// Modeled mixed-KV bytes a slot holds after `generated` decode tokens
    /// on a `prompt_tokens` prompt. ASTRA strategies hold the Appendix-G
    /// mixed cache; everything else holds full precision.
    pub fn kv_slot_bytes(&self, prompt_tokens: usize, generated: usize) -> usize {
        match self.strategy.kind {
            StrategyKind::Astra { vq } => kv_cache_bytes_astra_live(
                &self.shape,
                prompt_tokens,
                generated,
                self.shape.elem_bytes,
                self.strategy.n_devices,
                vq.groups,
                vq.codebook_size,
            ),
            _ => kv_cache_bytes_full(
                &self.shape,
                prompt_tokens + generated,
                self.shape.elem_bytes,
            ),
        }
    }

    /// Bytes a slot will hold once its decode budget is exhausted — the
    /// admission gate's per-request ceiling (requests above the cap are
    /// rejected outright: they could never complete).
    pub fn kv_projection(&self, prompt_tokens: usize) -> usize {
        self.kv_slot_bytes(prompt_tokens, self.cfg.decode_tokens)
    }

    /// Per-token cache growth during decode (full-precision K+V rows).
    pub fn kv_step_bytes(&self) -> usize {
        self.kv_slot_bytes(1, 1) - self.kv_slot_bytes(1, 0)
    }

    /// [`Self::kv_slot_bytes`] under positional locality — the accounting
    /// the block pool prices blocks with (prefix differences of this are
    /// identical for every prompt sharing the positions).
    pub fn kv_slot_bytes_positional(&self, prompt_tokens: usize, generated: usize) -> usize {
        match self.strategy.kind {
            StrategyKind::Astra { vq } => kv_cache_bytes_astra_positional(
                &self.shape,
                prompt_tokens,
                generated,
                self.shape.elem_bytes,
                self.strategy.n_devices,
                vq.groups,
                vq.codebook_size,
            ),
            _ => kv_cache_bytes_full(
                &self.shape,
                prompt_tokens + generated,
                self.shape.elem_bytes,
            ),
        }
    }

    /// Bytes of the first `replayed` prompt rows under the accounting
    /// active for this run (positional with the prefix cache, classic
    /// without — where the two coincide for every flag-off decision).
    /// Prefill-only workloads ignore the prefix cache entirely, including
    /// its accounting.
    fn slot_prompt_bytes(&self, replayed: usize) -> usize {
        if self.cfg.prefix_cache && self.cfg.decode_tokens > 0 {
            self.kv_slot_bytes_positional(replayed, 0)
        } else {
            self.kv_slot_bytes(replayed, 0)
        }
    }

    /// Accounting size of KV block `[lo, hi)` — the Appendix-G prefix
    /// difference, so a slot's blocks plus its private remainder
    /// telescope to exactly its flat footprint.
    fn block_bytes_range(&self, lo: usize, hi: usize) -> usize {
        self.slot_prompt_bytes(hi) - self.slot_prompt_bytes(lo)
    }

    /// The decode budget request `id` will receive: `decode_tokens`; a
    /// bounded-Pareto draw on `[1, decode_tokens]` when
    /// `length_tail_alpha > 0` (the EOS/unknown-length client model,
    /// [`crate::workload::tail_budget`]); or a deterministic sample in
    /// `decode_tokens ± decode_jitter`. All draws come from `(seed, id)`
    /// — the same everywhere the request is priced, admitted, or
    /// re-admitted, on either backend.
    pub fn decode_budget(&self, id: u64) -> usize {
        let d = self.cfg.decode_tokens;
        if d == 0 {
            return 0;
        }
        if self.cfg.length_tail_alpha > 0.0 {
            return crate::workload::tail_budget(self.cfg.seed, id, d, self.cfg.length_tail_alpha);
        }
        if self.cfg.decode_jitter == 0 {
            return d;
        }
        let j = self.cfg.decode_jitter.min(d - 1);
        let mut rng = Rng::new(
            self.cfg.seed ^ id.wrapping_mul(0x9e37_79b9_7f4a_7c15) ^ 0xa076_1d64_78bd_642f,
        );
        d - j + rng.below(2 * j + 1)
    }

    /// The patience of request `id`'s client — how long a silence
    /// (arrival with no first token, or a stalled token stream) it
    /// tolerates before abandoning ([`crate::workload::patience_for`];
    /// infinite when the client model is off).
    pub fn patience_for(&self, id: u64) -> f64 {
        crate::workload::patience_for(
            self.cfg.seed,
            id,
            self.cfg.patience_s,
            self.cfg.patience_spread,
        )
    }

    /// Bytes request `id` will hold once `budget` decode tokens are
    /// generated — the admission gate's per-request ceiling under the
    /// active accounting.
    pub fn projection_for(&self, prompt_tokens: usize, budget: usize) -> usize {
        self.slot_prompt_bytes(prompt_tokens) + budget * self.kv_step_bytes()
    }

    /// The admission gate's oversize rule, the ONE definition shared by
    /// the head reject pass, the preempt-candidate filter, and the
    /// admission fits walk: a request whose full projected footprint
    /// exceeds `cap` can never be served (`cap == 0` disables the gate).
    /// Callers exempt swapped-out requests themselves — those already
    /// fit once and return at a known preserved size.
    pub(crate) fn never_fits(&self, id: u64, tokens: usize, cap: usize) -> bool {
        cap > 0 && self.projection_for(tokens, self.decode_budget(id)) > cap
    }

    /// Deterministic prompt token ids for request `id` — the SAME stream
    /// the live backend feeds its sessions (`synth_prompt` over the
    /// grouped key), so both backends agree on every radix-tree match.
    pub fn prompt_for(&self, id: u64, tokens: usize) -> Vec<usize> {
        synth_prompt(
            self.cfg.seed,
            prompt_stream_key(self.cfg.prompt_groups, id),
            tokens,
            self.cfg.prompt_vocab.max(2),
        )
    }

    /// Modeled cost of recovering an evicted slot by recompute: re-prefill
    /// the prompt, then regenerate every token produced so far — the
    /// alternative the swap policy prices transfers against.
    fn recompute_cost_s(&self, tokens: usize, generated: usize, now: f64) -> f64 {
        let mut pshape = self.shape;
        pshape.seq_len = tokens.max(1);
        let prefill =
            evaluate_on_trace(&self.strategy.schedule(&pshape), &self.params, &self.trace, now)
                .total();
        if generated == 0 {
            return prefill;
        }
        let step = evaluate_on_trace(
            &self.strategy.decode_step_schedule(&self.shape, tokens + generated),
            &self.params,
            &self.trace,
            now,
        )
        .total();
        prefill + generated as f64 * step
    }

    /// Plan one iteration's chunk batch: `(slot index, tokens)` pairs in
    /// admission order (FIFO across prefilling slots, sharing the
    /// per-iteration token budget), plus the modeled KV growth the whole
    /// iteration causes — planned chunk rows for prefilling slots and one
    /// decode token's full-precision rows per decoding slot. With chunking
    /// disabled there are no prefilling slots, so the plan is empty and the
    /// growth reduces to the old `slots * kv_step_bytes()` check.
    fn plan_chunks(&self, slots: &[Slot], chunk_budget: usize) -> (Vec<(usize, usize)>, usize) {
        let mut order: Vec<usize> = (0..slots.len())
            .filter(|&i| matches!(slots[i].state, SlotState::Prefilling { .. }))
            .collect();
        // FIFO by current-episode admission order (the unique sequence
        // number; equals the old (admitted_at, id) order except across
        // readmissions, where queue order is the stable choice)
        order.sort_by_key(|&i| slots[i].admit_seq);
        let mut plan = Vec::new();
        let mut left = chunk_budget;
        let mut growth = 0usize;
        for i in order {
            if left == 0 {
                break;
            }
            if let SlotState::Prefilling { next_token, total } = slots[i].state {
                let take = (total - next_token).min(left);
                left -= take;
                growth += self.slot_prompt_bytes(next_token + take)
                    - self.slot_prompt_bytes(next_token);
                plan.push((i, take));
            }
        }
        let decoding = slots.iter().filter(|s| s.state == SlotState::Decoding).count();
        growth += decoding * self.kv_step_bytes();
        (plan, growth)
    }

    /// Serve an open-loop Poisson stream at `rate` req/s for `horizon_s`.
    pub fn serve_poisson(&mut self, rng: &mut Rng, rate: f64, horizon_s: f64) -> CbReport {
        let arrivals =
            super::batcher::poisson_arrivals(rng, rate, horizon_s, self.shape.seq_len);
        self.serve_stream(arrivals, horizon_s)
    }

    /// Serve a fixed arrival list under continuous batching on the cost
    /// model alone.
    pub fn serve_stream(&mut self, arrivals: Vec<Request>, horizon_s: f64) -> CbReport {
        self.serve_stream_with(&mut ModelBackend, arrivals, horizon_s)
            .expect("the cost-model backend is infallible")
    }
}
