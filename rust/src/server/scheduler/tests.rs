//! Scheduler unit + acceptance tests: continuous-batching throughput,
//! KV-gate behavior, chunked prefill, prefix sharing, swap preemption,
//! and the scheduling-policy layer (FIFO bit-for-bit anchors, SLO-class
//! attainment, prefix-aware ordering).

use std::collections::BTreeMap;

use super::*;
use crate::model::shape::VqSetting;
use crate::parallel::cost::DeviceModel;
use crate::parallel::strategies::StrategyKind;
use crate::server::engine::ServeEngine;
use crate::server::policy::PolicyKind;

fn astra_engine(cfg: CbConfig) -> CbEngine {
    CbEngine::new(
        TransformerShape::paper_encoder(1024),
        Strategy::new(StrategyKind::Astra { vq: VqSetting::new(16, 1024) }, 4),
        SimParams::paper_encoder(),
        BandwidthTrace::constant(100.0, 1e9),
        cfg,
    )
}

fn saturating(n: usize) -> Vec<Request> {
    (0..n as u64).map(|i| Request { id: i, arrival_s: 0.0, tokens: 1024 }).collect()
}

#[test]
fn continuous_batching_doubles_throughput_vs_batch1() {
    // the acceptance bar: max_slots >= 8 yields >= 2x completed
    // requests vs batch-1 FIFO at saturating load, 100 Mbps constant
    let cfg = CbConfig { max_slots: 8, max_batch: 8, decode_tokens: 64, ..CbConfig::default() };
    let mut fifo = astra_engine(cfg.clone().batch1());
    let mut cb = astra_engine(cfg.clone());
    let r_fifo = fifo.serve_stream(saturating(4000), 120.0);
    let r_cb = cb.serve_stream(saturating(4000), 120.0);
    assert!(
        r_cb.completed as f64 >= 2.0 * r_fifo.completed as f64,
        "cb {} vs fifo {}",
        r_cb.completed,
        r_fifo.completed
    );
    assert!(r_fifo.completed > 0);
    // same bar under an open-loop Poisson stream far above capacity
    let mut fifo = astra_engine(cfg.clone().batch1());
    let mut cb = astra_engine(cfg);
    let p_fifo = fifo.serve_poisson(&mut Rng::new(5), 50.0, 120.0);
    let p_cb = cb.serve_poisson(&mut Rng::new(5), 50.0, 120.0);
    assert!(
        p_cb.completed as f64 >= 2.0 * p_fifo.completed as f64,
        "poisson: cb {} vs fifo {}",
        p_cb.completed,
        p_fifo.completed
    );
}

#[test]
fn report_exposes_tail_latency_and_ttft() {
    let mut cb = astra_engine(CbConfig::default());
    let mut rng = Rng::new(3);
    let mut r = cb.serve_poisson(&mut rng, 4.0, 60.0);
    assert!(r.completed > 0, "{r:?}");
    let (p50, p95, p99) = (r.latency.p50(), r.latency.p95(), r.latency.p99());
    assert!(p50 > 0.0 && p50 <= p95 && p95 <= p99, "{p50} {p95} {p99}");
    // TTFT is recorded for every admitted-and-prefilled request and is
    // below the full latency (decode comes after the first token)
    assert!(!r.ttft.is_empty());
    assert!(r.ttft.mean() < r.latency.mean());
    assert!((6..=7).contains(&r.windows.len()), "{}", r.windows.len());
    // the virtual accounting sums every evaluated prefill/decode step
    assert!(r.model_time.total() > 0.0);
    assert!(r.model_time.compute_s > 0.0);
    // no classes configured: no per-class rows, no SLO preemptions
    assert!(r.classes.is_empty());
    assert_eq!(r.slo_preemptions, 0);
}

#[test]
fn every_request_is_completed_or_censored() {
    let total = 500;
    let mut cb = astra_engine(CbConfig::default());
    let r = cb.serve_stream(saturating(total), 20.0);
    assert_eq!(r.completed + r.censored, total);
    assert!(r.censored > 0, "20 s should not drain 500 saturating requests");
    assert_eq!(r.censored_wait.len(), r.censored);
    assert!(r.mean_queue_depth() > 0.0);
    // with the KV gate off nothing is rejected or evicted
    assert_eq!(r.kv_rejected, 0);
    assert_eq!(r.kv_evictions, 0);
    assert_eq!(r.kv_violations, 0);
}

#[test]
fn goodput_counts_only_within_slo() {
    let mut all = astra_engine(CbConfig { slo_s: 0.0, ..CbConfig::default() });
    let mut tight = astra_engine(CbConfig { slo_s: 1.0, ..CbConfig::default() });
    let r_all = all.serve_stream(saturating(2000), 60.0);
    let r_tight = tight.serve_stream(saturating(2000), 60.0);
    // identical dynamics, different SLO accounting
    assert_eq!(r_all.completed, r_tight.completed);
    assert!((r_all.goodput - r_all.throughput).abs() < 1e-12);
    // under saturation queue waits explode, so a 1 s SLO filters most
    assert!(r_tight.goodput < r_all.goodput);
}

#[test]
fn prefill_only_batch1_matches_fifo_engine() {
    // decode_tokens=0 + slots=1 + batch=1 must reproduce the classic
    // batch-1 FIFO engine's completion count on the same stream
    let shape = TransformerShape::paper_encoder(1024);
    let strat = Strategy::new(StrategyKind::Astra { vq: VqSetting::new(16, 1024) }, 4);
    let params = SimParams::paper_encoder();
    let trace = BandwidthTrace::constant(100.0, 1e9);
    let mut rng = Rng::new(9);
    let mut arrivals = Vec::new();
    let mut t = 0.0;
    for id in 0..300u64 {
        t += rng.exp(6.0);
        arrivals.push(Request { id, arrival_s: t, tokens: 1024 });
    }
    let cfg = CbConfig {
        max_slots: 1,
        max_batch: 1,
        max_wait_s: 0.0,
        decode_tokens: 0,
        ..CbConfig::default()
    };
    let mut cb = CbEngine::new(shape, strat, params.clone(), trace.clone(), cfg);
    let r_cb = cb.serve_stream(arrivals.clone(), 120.0);
    let mut fifo = ServeEngine::new(shape, strat, params, trace);
    let r_fifo = fifo.serve_stream(arrivals, 120.0);
    let diff = (r_cb.completed as i64 - r_fifo.completed as i64).abs();
    assert!(diff <= 1, "cb {} vs fifo {}", r_cb.completed, r_fifo.completed);
}

#[test]
fn kv_gate_defers_admission_and_respects_cap() {
    // cap sized for ~2 full slots: the 8-slot engine must throttle to
    // the budget, never exceed it, and still finish everything
    let cfg = CbConfig { decode_tokens: 32, ..CbConfig::default() };
    let probe = astra_engine(cfg.clone());
    let cap = 2 * probe.kv_projection(1024) + probe.kv_step_bytes();
    let mut capped = astra_engine(CbConfig { kv_cap_bytes: cap, ..cfg.clone() });
    let mut open = astra_engine(cfg);
    let r_capped = capped.serve_stream(saturating(24), 1e4);
    let r_open = open.serve_stream(saturating(24), 1e4);
    assert_eq!(r_capped.completed + r_capped.censored + r_capped.kv_rejected, 24);
    assert_eq!(r_capped.completed, 24, "{r_capped:?}");
    assert!(r_capped.kv_peak_bytes <= cap, "{} > {cap}", r_capped.kv_peak_bytes);
    // without the gate the same workload runs 8 slots deep
    assert!(r_open.kv_peak_bytes > cap, "{} <= {cap}", r_open.kv_peak_bytes);
    // throttled admission serializes work: strictly later completion
    assert!(r_capped.latency.max() >= r_open.latency.max());
}

#[test]
fn kv_pressure_evicts_newest_and_still_completes_everyone() {
    // prompts are cheap but decode growth is not: admit optimistically,
    // then force mid-decode evictions. decode budget 512 over a short
    // 128-token prompt makes growth dominate the prefill footprint.
    let base =
        CbConfig { max_slots: 4, max_batch: 4, decode_tokens: 512, ..CbConfig::default() };
    let probe = CbEngine::new(
        TransformerShape::paper_encoder(128),
        Strategy::new(StrategyKind::Astra { vq: VqSetting::new(16, 1024) }, 4),
        SimParams::paper_encoder(),
        BandwidthTrace::constant(100.0, 1e9),
        base.clone(),
    );
    // all 4 prefill footprints fit, but nowhere near 4 full budgets
    let cap = 2 * probe.kv_projection(128);
    assert!(4 * probe.kv_slot_bytes(128, 0) <= cap);
    assert!(4 * probe.kv_projection(128) > cap);
    let mut engine = CbEngine::new(
        probe.shape,
        probe.strategy,
        probe.params.clone(),
        probe.trace.clone(),
        CbConfig { kv_cap_bytes: cap, ..base },
    );
    let arrivals: Vec<Request> =
        (0..4u64).map(|i| Request { id: i, arrival_s: 0.0, tokens: 128 }).collect();
    let r = engine.serve_stream(arrivals, 1e4);
    assert!(r.kv_evictions > 0, "pressure must trigger eviction: {r:?}");
    assert!(r.events.iter().any(|e| matches!(e, CbEvent::Evict { .. })));
    assert!(r.kv_peak_bytes <= cap, "{} > {cap}", r.kv_peak_bytes);
    // evicted requests are requeued and re-prefilled, not lost
    assert_eq!(r.completed, 4, "{r:?}");
    assert_eq!(r.kv_rejected, 0);
}

#[test]
fn oversized_requests_are_rejected_not_hung() {
    // a request whose full budget exceeds the cap outright must be
    // dropped (Reject event), letting the rest of the queue proceed
    let cfg = CbConfig { decode_tokens: 32, ..CbConfig::default() };
    let probe = astra_engine(cfg.clone());
    let cap = probe.kv_projection(1024) + probe.kv_step_bytes();
    let mut engine = astra_engine(CbConfig { kv_cap_bytes: cap, ..cfg });
    // tokens=2048 projects past the cap; tokens=1024 fits
    let arrivals = vec![
        Request { id: 1, arrival_s: 0.0, tokens: 2048 },
        Request { id: 2, arrival_s: 0.0, tokens: 1024 },
        Request { id: 3, arrival_s: 0.0, tokens: 1024 },
    ];
    let r = engine.serve_stream(arrivals, 1e4);
    assert_eq!(r.kv_rejected, 1, "{r:?}");
    assert!(r.events.contains(&CbEvent::Reject { id: 1 }));
    assert_eq!(r.completed, 2);
    assert_eq!(r.completed + r.censored + r.kv_rejected, 3);
}

#[test]
fn oversized_request_behind_the_head_is_never_admitted() {
    // a request whose *prefill footprint* fits but whose full budget
    // does not must not sneak into a slot from behind an admissible
    // head — a lone oversized slot would outgrow the cap with nothing
    // to evict. It waits, reaches the head, and is rejected there.
    let cfg = CbConfig { decode_tokens: 32, max_wait_s: 0.0, ..CbConfig::default() };
    let probe = astra_engine(cfg.clone());
    // cap sits between the 2048-token prefill footprint and its full
    // projection, and above two 512-token full projections
    let cap = probe.kv_slot_bytes(2048, 0) + 16 * probe.kv_step_bytes();
    assert!(probe.kv_slot_bytes(2048, 0) <= cap);
    assert!(probe.kv_projection(2048) > cap);
    assert!(2 * probe.kv_projection(512) < cap);
    let mut engine = astra_engine(CbConfig { kv_cap_bytes: cap, ..cfg });
    let arrivals = vec![
        Request { id: 1, arrival_s: 0.0, tokens: 512 },
        Request { id: 2, arrival_s: 0.0, tokens: 2048 },
        Request { id: 3, arrival_s: 0.0, tokens: 512 },
    ];
    let r = engine.serve_stream(arrivals, 1e4);
    // id 2 was rejected (once at the head), never admitted, and the
    // cap was never breached by an unevictable lone slot
    assert_eq!(r.kv_rejected, 1, "{r:?}");
    assert!(r.events.contains(&CbEvent::Reject { id: 2 }));
    assert!(!r
        .events
        .iter()
        .any(|e| matches!(e, CbEvent::Admit { ids } if ids.contains(&2))));
    assert_eq!(r.completed, 2);
    assert!(r.kv_peak_bytes <= cap, "{} > {cap}", r.kv_peak_bytes);
    assert_eq!(r.kv_evictions, 0);
}

#[test]
fn chunk_budget_at_or_above_prompts_reproduces_unchunked_stream() {
    // the regression anchor: a budget >= the longest prompt — and the
    // disabled default — must yield the unchunked scheduler's event
    // stream bit for bit (every prompt fits its admission chunk, so
    // the classic monopolizing path runs unchanged)
    let base = CbConfig { max_batch: 4, decode_tokens: 16, ..CbConfig::default() };
    let mut unchunked = astra_engine(base.clone());
    let ra = unchunked.serve_poisson(&mut Rng::new(11), 12.0, 40.0);
    for chunk in [1024usize, 1500, usize::MAX / 2] {
        let mut chunked =
            astra_engine(CbConfig { prefill_chunk_tokens: chunk, ..base.clone() });
        let rb = chunked.serve_poisson(&mut Rng::new(11), 12.0, 40.0);
        assert_eq!(ra.events, rb.events, "chunk={chunk}");
        assert_eq!(ra.completed, rb.completed, "chunk={chunk}");
        assert_eq!(rb.prefill_chunks, 0, "chunk={chunk}");
        assert_eq!(ra.ttft.len(), rb.ttft.len(), "chunk={chunk}");
        assert_eq!(ra.queue_wait.len(), rb.queue_wait.len(), "chunk={chunk}");
    }
}

#[test]
fn chunk_events_tile_prompts_and_interleave_with_decode() {
    let cfg = CbConfig {
        max_slots: 4,
        max_batch: 2,
        decode_tokens: 8,
        prefill_chunk_tokens: 192,
        ..CbConfig::default()
    };
    let mut cb = astra_engine(cfg);
    let r = cb.serve_stream(saturating(12), 1e4);
    assert_eq!(r.completed, 12);
    assert!(r.prefill_chunks > 0, "{r:?}");
    // per request: admission chunk [0, 192) then fused chunks tiling
    // the rest of the 1024-token prompt contiguously, in order
    let mut progress: BTreeMap<u64, usize> = Default::default();
    let mut saw_decode = false;
    let mut chunk_after_decode = false;
    for e in &r.events {
        match e {
            CbEvent::PrefillChunk { id, lo, hi } => {
                let p = progress.entry(*id).or_insert(0);
                assert_eq!(*lo, *p, "request {id}: chunk out of order");
                assert!(hi > lo, "request {id}: empty chunk");
                assert!(hi - lo <= 192, "request {id}: chunk over budget");
                *p = *hi;
                if saw_decode {
                    chunk_after_decode = true;
                }
            }
            CbEvent::Decode { .. } => saw_decode = true,
            _ => {}
        }
    }
    assert_eq!(progress.len(), 12);
    for (id, p) in &progress {
        assert_eq!(*p, 1024, "request {id}: prompt not fully chunked");
    }
    assert!(chunk_after_decode, "chunks never interleaved with decode");
    // every request still decodes its full budget after its last chunk
    let steps: usize = r
        .events
        .iter()
        .map(|e| match e {
            CbEvent::Decode { ids } => ids.len(),
            _ => 0,
        })
        .sum();
    assert_eq!(steps, 12 * 8);
}

#[test]
fn evicted_requests_report_ttft_and_queue_wait_once() {
    // regression (eviction-thrash trace): re-admission used to push a
    // second, larger TTFT sample measured to the re-prefill, and to
    // re-add a queue wait spanning in-service time. Now TTFT is
    // recorded once — original arrival to the first token ever emitted
    // — and queue wait sums only the actual queueing episodes.
    let base =
        CbConfig { max_slots: 4, max_batch: 4, decode_tokens: 512, ..CbConfig::default() };
    let probe = CbEngine::new(
        TransformerShape::paper_encoder(128),
        Strategy::new(StrategyKind::Astra { vq: VqSetting::new(16, 1024) }, 4),
        SimParams::paper_encoder(),
        BandwidthTrace::constant(100.0, 1e9),
        base.clone(),
    );
    let cap = 2 * probe.kv_projection(128);
    let mut engine = CbEngine::new(
        probe.shape,
        probe.strategy,
        probe.params.clone(),
        probe.trace.clone(),
        CbConfig { kv_cap_bytes: cap, ..base },
    );
    let arrivals: Vec<Request> =
        (0..4u64).map(|i| Request { id: i, arrival_s: 0.0, tokens: 128 }).collect();
    let r = engine.serve_stream(arrivals, 1e4);
    assert!(r.kv_evictions > 0, "thrash trace must evict: {r:?}");
    assert_eq!(r.completed, 4);
    // one TTFT and one queue-wait sample per request, no duplicates
    assert_eq!(r.ttft.len(), 4, "{r:?}");
    assert_eq!(r.queue_wait.len(), 4);
    // first-token latency can never exceed the full latency
    assert!(r.ttft.max() <= r.latency.max() + 1e-12);
    // all four arrived at 0 and were admitted immediately, so queue
    // wait is exactly the post-eviction requeue time: zero for the
    // never-evicted oldest, positive but below wall latency for the
    // evicted (in-service time no longer counts as waiting)
    assert!(r.queue_wait.min() < 1e-12, "someone was never evicted: {r:?}");
    assert!(r.queue_wait.max() > 0.0);
    assert!(r.queue_wait.max() < r.latency.max());
}

#[test]
fn chunked_prefill_cuts_decode_stalls_at_throughput_parity() {
    // the PR-3 tentpole acceptance bar, long prompts (T=1024) + short
    // decode: mixing bounded prefill chunks into decode iterations must
    // cut the p95 inter-token stall of in-flight decode slots while
    // completed throughput stays within 5%. Launch/sync overheads use a
    // graph-captured-runtime calibration (per-chunk overheads at the
    // paper 1660Ti's 0.2 ms/launch would swamp the fusion win).
    let device =
        DeviceModel { per_layer_overhead_s: 1e-5, ..DeviceModel::paper_1660ti() };
    let params = SimParams { device, stage_latency_s: 5e-5 };
    let base = CbConfig {
        max_slots: 8,
        // small admission batches so completions stagger and there are
        // always in-flight decoders for a prefill to stall
        max_batch: 2,
        decode_tokens: 32,
        ..CbConfig::default()
    };
    let mk = |cfg: CbConfig| {
        CbEngine::new(
            TransformerShape::paper_encoder(1024),
            Strategy::new(StrategyKind::Astra { vq: VqSetting::new(16, 1024) }, 4),
            params.clone(),
            BandwidthTrace::constant(100.0, 1e9),
            cfg,
        )
    };
    let chunked_cfg = CbConfig { prefill_chunk_tokens: 512, ..base.clone() };

    // ITL contrast under heavy open-loop load (~0.8x capacity: slots
    // stay busy and admissions constantly interleave with decode)
    let mut r_mono = mk(base.clone()).serve_poisson(&mut Rng::new(17), 16.0, 30.0);
    let mut r_chunk = mk(chunked_cfg.clone()).serve_poisson(&mut Rng::new(17), 16.0, 30.0);
    assert!(r_chunk.prefill_chunks > 0);
    assert_eq!(r_mono.prefill_chunks, 0);
    assert!(r_mono.itl.len() > 1000, "{}", r_mono.itl.len());
    assert!(r_chunk.itl.len() > 1000, "{}", r_chunk.itl.len());
    let (p_mono, p_chunk) = (r_mono.itl.p95(), r_chunk.itl.p95());
    assert!(p_chunk < 0.9 * p_mono, "chunked p95 ITL {p_chunk} vs monopolizing {p_mono}");
    assert!(
        r_chunk.completed as f64 >= 0.95 * r_mono.completed as f64,
        "chunked {} vs monopolizing {}",
        r_chunk.completed,
        r_mono.completed
    );

    // completed-throughput parity at full saturation
    let s_mono = mk(base).serve_stream(saturating(4000), 30.0);
    let s_chunk = mk(chunked_cfg).serve_stream(saturating(4000), 30.0);
    assert!(s_mono.completed > 50, "{}", s_mono.completed);
    assert!(
        s_chunk.completed as f64 >= 0.95 * s_mono.completed as f64,
        "chunked {} vs monopolizing {}",
        s_chunk.completed,
        s_mono.completed
    );
}

#[test]
fn eviction_victims_follow_current_episode_admission_order() {
    // the spec the admit_seq fix enforces, checked over the whole
    // eviction-thrash event stream: every preemption victim is the most
    // recently (re)admitted slot still in flight — replaying the event
    // stream with an admission-ordered shadow list must always evict
    // its tail element, never the oldest
    let base =
        CbConfig { max_slots: 4, max_batch: 4, decode_tokens: 512, ..CbConfig::default() };
    let probe = CbEngine::new(
        TransformerShape::paper_encoder(128),
        Strategy::new(StrategyKind::Astra { vq: VqSetting::new(16, 1024) }, 4),
        SimParams::paper_encoder(),
        BandwidthTrace::constant(100.0, 1e9),
        base.clone(),
    );
    let cap = 2 * probe.kv_projection(128);
    let mut engine = CbEngine::new(
        probe.shape,
        probe.strategy,
        probe.params.clone(),
        probe.trace.clone(),
        CbConfig { kv_cap_bytes: cap, ..base },
    );
    let arrivals: Vec<Request> =
        (0..4u64).map(|i| Request { id: i, arrival_s: 0.0, tokens: 128 }).collect();
    let r = engine.serve_stream(arrivals, 1e4);
    assert!(r.kv_evictions > 0, "thrash trace must evict: {r:?}");
    assert_eq!(r.completed, 4);
    let mut in_flight: Vec<u64> = Vec::new(); // admission order, oldest first
    for e in &r.events {
        match e {
            CbEvent::Admit { ids } => in_flight.extend(ids.iter().copied()),
            CbEvent::Evict { id } | CbEvent::SwapOut { id } => {
                assert!(in_flight.len() > 1, "a lone slot must never be evicted");
                assert_eq!(
                    in_flight.last(),
                    Some(id),
                    "victim {id} is not the most recently admitted of {in_flight:?}"
                );
                in_flight.pop();
            }
            CbEvent::Complete { id } => in_flight.retain(|x| x != id),
            _ => {}
        }
    }
}

#[test]
fn prefix_cache_with_oversized_blocks_reproduces_baseline_stream() {
    // sharing anchor: a block size above every prompt makes attachment
    // impossible, and full-length prompts make positional accounting
    // coincide with the classic bytes — so --prefix-cache with such
    // blocks must reproduce the prefix-off event stream bit for bit,
    // capped or not
    let base = CbConfig { max_batch: 4, decode_tokens: 16, ..CbConfig::default() };
    let probe = astra_engine(base.clone());
    let cap = 2 * probe.kv_projection(1024) + probe.kv_step_bytes();
    for kv_cap_bytes in [0usize, cap] {
        let off = CbConfig { kv_cap_bytes, ..base.clone() };
        let on = CbConfig {
            prefix_cache: true,
            kv_block_tokens: 2048,
            prompt_groups: 1,
            seed: 9,
            ..off.clone()
        };
        let ra = astra_engine(off).serve_poisson(&mut Rng::new(13), 12.0, 40.0);
        let rb = astra_engine(on).serve_poisson(&mut Rng::new(13), 12.0, 40.0);
        assert_eq!(ra.events, rb.events, "cap={kv_cap_bytes}");
        assert_eq!(ra.completed, rb.completed, "cap={kv_cap_bytes}");
        assert_eq!(rb.prefix_hits, 0, "cap={kv_cap_bytes}");
        assert_eq!(ra.kv_peak_bytes, rb.kv_peak_bytes, "cap={kv_cap_bytes}");
    }
}

#[test]
fn prefix_cache_attaches_shared_prompts_and_charges_suffix_only() {
    // one prompt group: every request shares the whole (block-aligned)
    // prompt. After the first creator replays, later admissions attach
    // to resident or recently-freed blocks — PrefixHit events, high
    // token hit rate, and a lower byte peak than the unshared run
    let base = CbConfig {
        max_slots: 8,
        max_batch: 4,
        decode_tokens: 8,
        ..CbConfig::default()
    };
    let shared = CbConfig {
        prefix_cache: true,
        kv_block_tokens: 64,
        prompt_groups: 1,
        seed: 5,
        ..base.clone()
    };
    let r_plain = astra_engine(base).serve_stream(saturating(24), 1e4);
    let mut cb = astra_engine(shared);
    let r = cb.serve_stream(saturating(24), 1e4);
    assert_eq!(r.completed, 24, "{r:?}");
    assert!(r.prefix_hits > 0, "{r:?}");
    assert!(r.events.iter().any(|e| matches!(e, CbEvent::PrefixHit { .. })));
    // block-aligned coverage, counted against admitted prompt tokens
    assert_eq!(r.prefix_hit_tokens % 64, 0);
    assert_eq!(r.admitted_prompt_tokens, 24 * 1024);
    assert!(r.prefix_hit_rate() > 0.5, "hit rate {}", r.prefix_hit_rate());
    assert!(r.recompute_flops_saved > 0.0);
    // identical prompts shared once: resident peak far below unshared
    assert!(
        r.kv_peak_bytes < r_plain.kv_peak_bytes,
        "{} !< {}",
        r.kv_peak_bytes,
        r_plain.kv_peak_bytes
    );
    // a fully covered admission replays nothing and still completes:
    // its slot decodes the full budget (steps counted per id)
    let steps: usize = r
        .events
        .iter()
        .map(|e| match e {
            CbEvent::Decode { ids } => ids.len(),
            _ => 0,
        })
        .sum();
    assert_eq!(steps, 24 * 8);
}

#[test]
fn negligible_swap_bandwidth_reproduces_recompute_stream() {
    // the swap decision prices the transfer; at ~0 bandwidth it can
    // never beat recompute, so the stream must equal the swap-off run
    // bit for bit and no Swap events may appear
    let base =
        CbConfig { max_slots: 4, max_batch: 4, decode_tokens: 512, ..CbConfig::default() };
    let probe = CbEngine::new(
        TransformerShape::paper_encoder(128),
        Strategy::new(StrategyKind::Astra { vq: VqSetting::new(16, 1024) }, 4),
        SimParams::paper_encoder(),
        BandwidthTrace::constant(100.0, 1e9),
        base.clone(),
    );
    let cap = 2 * probe.kv_projection(128);
    let mk = |swap_mbps: f64| {
        CbEngine::new(
            probe.shape,
            probe.strategy,
            probe.params.clone(),
            probe.trace.clone(),
            CbConfig {
                kv_cap_bytes: cap,
                swap_bandwidth_mbps: swap_mbps,
                ..base.clone()
            },
        )
    };
    let arrivals: Vec<Request> =
        (0..4u64).map(|i| Request { id: i, arrival_s: 0.0, tokens: 128 }).collect();
    let r_off = mk(0.0).serve_stream(arrivals.clone(), 1e4);
    let r_slow = mk(1e-6).serve_stream(arrivals, 1e4);
    assert!(r_off.kv_evictions > 0);
    assert_eq!(r_off.events, r_slow.events);
    assert_eq!(r_slow.swap_outs, 0);
    assert_eq!(r_slow.swap_bytes, 0);
    assert!(!r_slow.events.iter().any(|e| matches!(e, CbEvent::SwapOut { .. })));
}

#[test]
fn fast_host_link_swaps_and_preserves_decode_progress() {
    // with a fast host link the round trip beats re-prefill +
    // regeneration, so pressure victims swap: SwapOut/SwapIn events,
    // byte traffic, and — the point of swapping — total decode steps
    // equal the exact budget (recompute restarts waste steps)
    let base =
        CbConfig { max_slots: 4, max_batch: 4, decode_tokens: 512, ..CbConfig::default() };
    let probe = CbEngine::new(
        TransformerShape::paper_encoder(128),
        Strategy::new(StrategyKind::Astra { vq: VqSetting::new(16, 1024) }, 4),
        SimParams::paper_encoder(),
        BandwidthTrace::constant(100.0, 1e9),
        base.clone(),
    );
    let cap = 2 * probe.kv_projection(128);
    let mk = |swap_mbps: f64| {
        CbEngine::new(
            probe.shape,
            probe.strategy,
            probe.params.clone(),
            probe.trace.clone(),
            CbConfig {
                kv_cap_bytes: cap,
                swap_bandwidth_mbps: swap_mbps,
                ..base.clone()
            },
        )
    };
    let arrivals: Vec<Request> =
        (0..4u64).map(|i| Request { id: i, arrival_s: 0.0, tokens: 128 }).collect();
    let steps_of = |r: &CbReport| -> usize {
        r.events
            .iter()
            .map(|e| match e {
                CbEvent::Decode { ids } => ids.len(),
                _ => 0,
            })
            .sum()
    };
    let r_swap = mk(1e6).serve_stream(arrivals.clone(), 1e5);
    let r_recompute = mk(0.0).serve_stream(arrivals, 1e5);
    assert_eq!(r_swap.completed, 4, "{r_swap:?}");
    assert!(r_swap.swap_outs > 0, "{r_swap:?}");
    assert_eq!(r_swap.swap_outs, r_swap.swap_ins, "everything swapped back in");
    assert!(r_swap.swap_bytes > 0);
    assert!(r_swap.events.iter().any(|e| matches!(e, CbEvent::SwapOut { .. })));
    assert!(r_swap.events.iter().any(|e| matches!(e, CbEvent::SwapIn { .. })));
    // progress preserved: exactly budget steps per request
    assert_eq!(steps_of(&r_swap), 4 * 512);
    // recompute thrash regenerates: strictly more raw decode steps
    assert!(r_recompute.kv_evictions > 0);
    assert!(steps_of(&r_recompute) > 4 * 512, "{}", steps_of(&r_recompute));
}

#[test]
fn copy_engine_without_transfers_is_bit_identical() {
    // the copy engine only overlaps swap/checkpoint transfer seconds
    // behind the decode step's clock; with nothing to overlap the
    // arithmetic is max(compute, 0) == compute, so a run without swap —
    // including recompute evictions under a cap — must be bit-identical,
    // latencies included
    let base =
        CbConfig { max_slots: 4, max_batch: 4, decode_tokens: 512, ..CbConfig::default() };
    let probe = CbEngine::new(
        TransformerShape::paper_encoder(128),
        Strategy::new(StrategyKind::Astra { vq: VqSetting::new(16, 1024) }, 4),
        SimParams::paper_encoder(),
        BandwidthTrace::constant(100.0, 1e9),
        base.clone(),
    );
    let cap = 2 * probe.kv_projection(128);
    let mk = |copy: bool| {
        CbEngine::new(
            probe.shape,
            probe.strategy,
            probe.params.clone(),
            probe.trace.clone(),
            CbConfig { kv_cap_bytes: cap, copy_engine: copy, ..base.clone() },
        )
    };
    let arrivals: Vec<Request> =
        (0..4u64).map(|i| Request { id: i, arrival_s: 0.0, tokens: 128 }).collect();
    let r_off = mk(false).serve_stream(arrivals.clone(), 1e5);
    let r_on = mk(true).serve_stream(arrivals, 1e5);
    assert!(r_off.kv_evictions > 0, "{r_off:?}");
    assert_eq!(r_off.events, r_on.events);
    assert_eq!(r_off.completed, r_on.completed);
    assert_eq!(r_off.latency.mean(), r_on.latency.mean());
    assert_eq!(r_off.model_time.comm_s, r_on.model_time.comm_s);
}

#[test]
fn copy_engine_overlaps_swap_transfers_behind_decode() {
    // burst arrivals on a constant trace: every decision is queue-order
    // driven, so the overlap moves only the clock — identical event
    // stream and swap traffic, but completions land strictly earlier
    // (max(compute, transfer) < compute + transfer whenever an iteration
    // both decodes and swaps) while the transfers stay fully priced in
    // the comm accounting
    let base =
        CbConfig { max_slots: 4, max_batch: 4, decode_tokens: 512, ..CbConfig::default() };
    let probe = CbEngine::new(
        TransformerShape::paper_encoder(128),
        Strategy::new(StrategyKind::Astra { vq: VqSetting::new(16, 1024) }, 4),
        SimParams::paper_encoder(),
        BandwidthTrace::constant(100.0, 1e9),
        base.clone(),
    );
    let cap = 2 * probe.kv_projection(128);
    let mk = |copy: bool| {
        CbEngine::new(
            probe.shape,
            probe.strategy,
            probe.params.clone(),
            probe.trace.clone(),
            CbConfig {
                kv_cap_bytes: cap,
                swap_bandwidth_mbps: 1e6,
                copy_engine: copy,
                ..base.clone()
            },
        )
    };
    let arrivals: Vec<Request> =
        (0..4u64).map(|i| Request { id: i, arrival_s: 0.0, tokens: 128 }).collect();
    let r_serial = mk(false).serve_stream(arrivals.clone(), 1e5);
    let r_copy = mk(true).serve_stream(arrivals, 1e5);
    assert_eq!(r_serial.events, r_copy.events, "overlap changed a scheduling decision");
    assert_eq!(r_copy.completed, 4, "{r_copy:?}");
    assert!(r_copy.swap_outs > 0, "{r_copy:?}");
    assert_eq!(r_copy.swap_outs, r_serial.swap_outs);
    assert_eq!(r_copy.swap_bytes, r_serial.swap_bytes);
    assert_eq!(r_copy.kv_violations, 0);
    assert_eq!(r_copy.model_time.comm_s, r_serial.model_time.comm_s);
    assert!(
        r_copy.latency.mean() < r_serial.latency.mean(),
        "overlap must shorten completions: {} vs {}",
        r_copy.latency.mean(),
        r_serial.latency.mean()
    );
}

#[test]
fn decode_jitter_staggers_completions_within_bounds() {
    let base = CbConfig {
        max_slots: 8,
        max_batch: 8,
        decode_tokens: 64,
        decode_jitter: 16,
        seed: 21,
        ..CbConfig::default()
    };
    let probe = astra_engine(base.clone());
    // budgets are deterministic in (seed, id) and stay inside ± jitter
    let mut distinct = std::collections::BTreeSet::new();
    for id in 0..64u64 {
        let b = probe.decode_budget(id);
        assert!((48..=80).contains(&b), "id {id}: budget {b}");
        assert_eq!(b, probe.decode_budget(id), "id {id}: not deterministic");
        distinct.insert(b);
    }
    assert!(distinct.len() > 4, "jitter produced only {distinct:?}");
    // a same-length wave no longer completes in lockstep: per-request
    // decode step counts differ, and completions spread over several
    // distinct iterations rather than one tail burst
    let mut cb = astra_engine(base.clone());
    let r = cb.serve_stream(saturating(8), 1e4);
    assert_eq!(r.completed, 8);
    let mut steps: BTreeMap<u64, usize> = BTreeMap::new();
    let mut completes_after_decodes: Vec<usize> = Vec::new();
    let mut decodes = 0usize;
    for e in &r.events {
        match e {
            CbEvent::Decode { ids } => {
                decodes += 1;
                for id in ids {
                    *steps.entry(*id).or_insert(0) += 1;
                }
            }
            CbEvent::Complete { id } => {
                completes_after_decodes.push(decodes);
                assert_eq!(steps[id], cb.decode_budget(*id), "request {id}");
            }
            _ => {}
        }
    }
    let spread: std::collections::BTreeSet<usize> =
        completes_after_decodes.iter().copied().collect();
    assert!(spread.len() > 1, "jittered wave still completed in lockstep");
    // the jitter-off control: every budget identical, one tail burst
    let mut plain = astra_engine(CbConfig { decode_jitter: 0, ..base });
    let rp = plain.serve_stream(saturating(8), 1e4);
    let plain_steps: usize = rp
        .events
        .iter()
        .map(|e| match e {
            CbEvent::Decode { ids } => ids.len(),
            _ => 0,
        })
        .sum();
    assert_eq!(plain_steps, 8 * 64);
}

#[test]
fn event_stream_is_a_complete_record() {
    let mut cb = astra_engine(CbConfig { decode_tokens: 4, ..CbConfig::default() });
    let r = cb.serve_stream(saturating(20), 1e4);
    assert_eq!(r.completed, 20);
    let admits: usize = r
        .events
        .iter()
        .map(|e| match e {
            CbEvent::Admit { ids } => ids.len(),
            _ => 0,
        })
        .sum();
    let completes =
        r.events.iter().filter(|e| matches!(e, CbEvent::Complete { .. })).count();
    assert_eq!(admits, 20);
    assert_eq!(completes, 20);
    // every slot advanced exactly decode_tokens times
    let steps: usize = r
        .events
        .iter()
        .map(|e| match e {
            CbEvent::Decode { ids } => ids.len(),
            _ => 0,
        })
        .sum();
    assert_eq!(steps, 20 * 4);
}

// ---- scheduling-policy layer ----

#[test]
fn class_reporting_alone_never_reschedules_under_fifo() {
    // classes configure accounting; under the default FIFO policy the
    // event stream must be bit-identical to the classless run, and the
    // per-class tallies must partition the totals
    let base = CbConfig { decode_tokens: 16, ..CbConfig::default() };
    let classed = CbConfig { classes: vec![2.0, 0.5, 8.0], ..base.clone() };
    let ra = astra_engine(base).serve_poisson(&mut Rng::new(19), 10.0, 30.0);
    let rb = astra_engine(classed).serve_poisson(&mut Rng::new(19), 10.0, 30.0);
    assert_eq!(ra.events, rb.events);
    assert_eq!(ra.completed, rb.completed);
    assert!(ra.classes.is_empty());
    assert_eq!(rb.classes.len(), 3);
    assert_eq!(rb.classes.iter().map(|c| c.completed).sum::<usize>(), rb.completed);
    assert_eq!(rb.classes.iter().map(|c| c.censored).sum::<usize>(), rb.censored);
    for c in &rb.classes {
        assert!(c.within_deadline <= c.completed);
        assert_eq!(c.latency.len(), c.completed);
        let a = c.slo_attainment();
        assert!((0.0..=1.0).contains(&a), "class {}: attainment {a}", c.class);
        assert!(c.goodput(rb.horizon_s) <= rb.throughput + 1e-12);
    }
    assert_eq!(rb.slo_preemptions, 0, "FIFO has no proactive hook");
}

#[test]
fn slo_class_lifts_high_class_attainment_at_throughput_parity() {
    // the tentpole acceptance bar, two-class saturating trace (odd ids
    // are the high class): pin the high class's deadline at its FIFO
    // median latency, then SloClass must lift high-class attainment
    // strictly while total completions stay within 5% (here: equal).
    let probe_cfg = CbConfig {
        decode_tokens: 32,
        classes: vec![0.0, 0.0], // deadline-free probe: reporting only
        ..CbConfig::default()
    };
    let mut r_probe = astra_engine(probe_cfg.clone()).serve_stream(saturating(40), 1e5);
    assert_eq!(r_probe.completed, 40);
    assert_eq!(r_probe.classes.len(), 2);
    let d_high = r_probe.classes[1].latency.p50();
    assert!(d_high > 0.0);
    // low class effectively deadline-free, high class pinned at the
    // FIFO median so FIFO attains ~half by construction
    let classes = vec![1e9, d_high];
    let mut r_fifo = astra_engine(CbConfig { classes: classes.clone(), ..probe_cfg.clone() })
        .serve_stream(saturating(40), 1e5);
    let r_slo = astra_engine(CbConfig {
        policy: PolicyKind::SloClass,
        classes,
        ..probe_cfg
    })
    .serve_stream(saturating(40), 1e5);
    // deadlines are accounting under FIFO: same stream as the probe
    assert_eq!(r_fifo.events, r_probe.events);
    // throughput parity: everything completes either way
    assert_eq!(r_fifo.completed, 40);
    assert_eq!(r_slo.completed, 40);
    assert!(
        r_slo.completed as f64 >= 0.95 * r_fifo.completed as f64
            && r_slo.completed as f64 <= 1.05 * r_fifo.completed as f64
    );
    // ...and the high class now meets its deadline strictly more often
    let a_fifo = r_fifo.classes[1].slo_attainment();
    let a_slo = r_slo.classes[1].slo_attainment();
    assert!(
        a_slo > a_fifo,
        "high-class attainment: slo-class {a_slo} !> fifo {a_fifo} (deadline {d_high})"
    );
    assert!(a_fifo >= 0.5, "p50 deadline must cover ~half the FIFO highs: {a_fifo}");
    // high-class median latency dropped too (they stopped queueing
    // behind low-class work)
    assert_eq!(r_slo.classes.len(), 2);
    let mut slo_classes = r_slo.classes;
    assert!(slo_classes[1].latency.p50() <= r_fifo.classes[1].latency.p50() + 1e-12);
}

#[test]
fn prefix_aware_admits_cache_warm_requests_first() {
    // ids 0 and 2 share a prompt stream (group 0); id 1 is cold. With
    // one slot, FIFO serves 0, 1, 2 — but the prefix-aware policy
    // admits the warm id 2 ahead of the cold id 1, while id 0's blocks
    // are resident
    let base = CbConfig {
        max_slots: 1,
        max_batch: 1,
        decode_tokens: 4,
        prefix_cache: true,
        kv_block_tokens: 64,
        prompt_groups: 2,
        seed: 3,
        age_bound_s: 1e9, // no aging inside this tiny trace
        ..CbConfig::default()
    };
    let arrivals: Vec<Request> =
        (0..3u64).map(|id| Request { id, arrival_s: 0.0, tokens: 1024 }).collect();
    let r_fifo = astra_engine(base.clone()).serve_stream(arrivals.clone(), 1e5);
    let r_aware = astra_engine(CbConfig { policy: PolicyKind::PrefixAware, ..base })
        .serve_stream(arrivals, 1e5);
    assert_eq!(r_fifo.completed, 3);
    assert_eq!(r_aware.completed, 3);
    let admits = |r: &CbReport| -> Vec<u64> {
        r.events
            .iter()
            .filter_map(|e| match e {
                CbEvent::Admit { ids } => Some(ids[0]),
                _ => None,
            })
            .collect()
    };
    assert_eq!(admits(&r_fifo), vec![0, 1, 2]);
    assert_eq!(admits(&r_aware), vec![0, 2, 1], "warm request must jump the cold head");
    assert!(r_aware.prefix_hits > 0);
    assert!(r_aware.prefix_hit_tokens >= r_fifo.prefix_hit_tokens);
}

#[test]
fn slo_preemption_trades_blown_deadline_for_salvageable_high_class() {
    // two low-class requests (tight 0.1 s deadline they will certainly
    // blow) fill both slots; a high-class request (lax deadline) then
    // arrives. The proactive hook must evict the newest past-deadline
    // low slot — exactly once — and seat the high request, which then
    // meets its deadline
    let cfg = CbConfig {
        max_slots: 2,
        max_batch: 2,
        decode_tokens: 256,
        policy: PolicyKind::SloClass,
        classes: vec![0.1, 50.0],
        ..CbConfig::default()
    };
    let arrivals = vec![
        Request { id: 0, arrival_s: 0.0, tokens: 1024 },
        Request { id: 2, arrival_s: 0.0, tokens: 1024 },
        Request { id: 1, arrival_s: 0.05, tokens: 1024 },
    ];
    let r = astra_engine(cfg).serve_stream(arrivals, 1e5);
    assert_eq!(r.completed, 3, "{r:?}");
    assert_eq!(r.slo_preemptions, 1, "{r:?}");
    // the victim is the newest low-class slot, resolved by recompute
    // (swap is off), and the high request is admitted in its place
    let evict_at = r
        .events
        .iter()
        .position(|e| matches!(e, CbEvent::Evict { id: 2 }))
        .expect("newest low-class slot must be preempted");
    let admit_high = r
        .events
        .iter()
        .position(|e| matches!(e, CbEvent::Admit { ids } if ids.contains(&1)))
        .expect("high class must be admitted");
    assert!(evict_at < admit_high, "preemption must open the slot the high request takes");
    // the preempted request is not lost, and the high class made its SLO
    assert_eq!(r.classes[1].completed, 1);
    assert_eq!(r.classes[1].within_deadline, 1);
    assert_eq!(r.classes[0].completed, 2);
}

#[test]
fn slo_preempt_budget_frees_slots_for_a_high_class_burst() {
    // four low-class requests (certainly-blown 0.1 s deadline) fill the
    // slots; a burst of two high-class requests then arrives. Budget 1
    // (the default, the historical single-victim hook) frees one slot
    // per iteration; budget 4 may pair every salvageable beneficiary
    // with a victim at once. Both serve everyone, both save the burst's
    // SLOs, and the larger budget never preempts less
    let mk = |budget: usize| {
        let cfg = CbConfig {
            max_slots: 4,
            max_batch: 4,
            decode_tokens: 256,
            policy: PolicyKind::SloClass,
            classes: vec![0.1, 50.0],
            slo_preempt_budget: budget,
            ..CbConfig::default()
        };
        let arrivals = vec![
            Request { id: 0, arrival_s: 0.0, tokens: 1024 },
            Request { id: 2, arrival_s: 0.0, tokens: 1024 },
            Request { id: 4, arrival_s: 0.0, tokens: 1024 },
            Request { id: 6, arrival_s: 0.0, tokens: 1024 },
            Request { id: 1, arrival_s: 0.05, tokens: 1024 },
            Request { id: 3, arrival_s: 0.05, tokens: 1024 },
        ];
        astra_engine(cfg).serve_stream(arrivals, 1e5)
    };
    let b1 = mk(1);
    let b4 = mk(4);
    assert_eq!(b1.completed, 6, "{b1:?}");
    assert_eq!(b4.completed, 6, "{b4:?}");
    assert!(b1.slo_preemptions > 0, "{b1:?}");
    assert!(
        b4.slo_preemptions >= b1.slo_preemptions,
        "{} < {}",
        b4.slo_preemptions,
        b1.slo_preemptions
    );
    // the burst met its deadlines under both budgets
    for r in [&b1, &b4] {
        assert_eq!(r.classes[1].completed, 2);
        assert_eq!(r.classes[1].within_deadline, 2);
        assert_eq!(r.classes[0].completed, 4);
    }
}

#[test]
fn slo_preempt_cost_gate_prices_the_proactive_hook() {
    // the cost-aware budget (`slo_preempt_cost_s`): each proactive victim
    // is priced at what the engine will actually pay to bring it back —
    // the swap round trip when swap wins, the modeled recompute otherwise
    // — and victims past the per-iteration budget stay resident. 0 is the
    // unpriced legacy hook; a budget too large to bind must reproduce it
    // bit for bit; a sub-nanosecond budget vetoes every victim without
    // losing anyone.
    let mk = |cost: f64| {
        let cfg = CbConfig {
            max_slots: 2,
            max_batch: 2,
            decode_tokens: 256,
            policy: PolicyKind::SloClass,
            classes: vec![0.1, 50.0],
            slo_preempt_cost_s: cost,
            ..CbConfig::default()
        };
        let arrivals = vec![
            Request { id: 0, arrival_s: 0.0, tokens: 1024 },
            Request { id: 2, arrival_s: 0.0, tokens: 1024 },
            Request { id: 1, arrival_s: 0.05, tokens: 1024 },
        ];
        astra_engine(cfg).serve_stream(arrivals, 1e5)
    };
    let unpriced = mk(0.0);
    let lavish = mk(1e9);
    let stingy = mk(1e-9);
    assert_eq!(unpriced.events, lavish.events, "an unbinding cost budget changed decisions");
    assert_eq!(lavish.slo_preemptions, 1, "{lavish:?}");
    assert_eq!(stingy.slo_preemptions, 0, "the stingy budget must veto the hook: {stingy:?}");
    assert_eq!(stingy.completed, 3, "a vetoed preemption still serves everyone: {stingy:?}");
    assert!(
        !stingy.events.iter().any(|e| matches!(e, CbEvent::Evict { .. })),
        "{stingy:?}"
    );
}
