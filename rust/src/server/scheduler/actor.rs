//! The actorized continuous-batching engine: all per-run mutable state
//! (queue, slots, KV pool, radix tree, host tier, accounting) owned by an
//! [`EngineActor`] whose [`step`](EngineActor::step) runs exactly one
//! scheduling iteration — admission, fused chunk+decode, preemption — at
//! a caller-supplied virtual time and reports when it next wants the
//! clock. The actor owns **mechanism**; **time and arrival routing**
//! belong to whoever drives it: the single-replica driver in `loop.rs`
//! (bit-for-bit the pre-actor `serve_stream`) or the multi-replica
//! cluster loop in [`crate::server::cluster`].
//!
//! The step contract mirrors the old loop body exactly:
//!
//! * the driver enqueues every arrival with `arrival_s <= now` *before*
//!   calling `step(now)`;
//! * `step` returns `until: Some(t)` when the iteration it ran finishes
//!   at virtual time `t` (the driver must not step again before `t`, and
//!   `t` may exceed the horizon — a straddling iteration advances the
//!   clock without emitting effects, exactly like the old loop);
//! * `until: None` means idle — empty queue and no in-flight slots — and
//!   the actor sleeps until the next enqueue.

use std::collections::{BTreeMap, BTreeSet};

use anyhow::Result;

use crate::kv::pool::KvPool;
use crate::kv::prefix::RadixTree;
use crate::kv::swap::SwapPolicy;
use crate::parallel::plan::Plan;
use crate::sim::latency::{evaluate_on_trace, evaluate_on_trace_batched, Breakdown};
use crate::util::stats::Summary;
use crate::workload::{wasted_deliveries, TokenStream};

use super::super::batcher::{Batcher, Request};
use super::super::live::{prompt_stream_key, synth_prompt};
use super::super::policy::{AdmissionCandidate, SchedPolicy, SlotView};
use super::report::CompletionTally;
use super::slots::{ReqStats, Slot, SlotState, SwapEntry};
use super::{
    AdmitBatch, AdmitEntry, CbConfig, CbEngine, CbEvent, CbReport, ChunkPlan, DecodeBackend,
    PrefixAttach, StepBatch,
};

/// Move a slot's own blocks whose rows are now replayed (`hi <=
/// replayed`) from pending to ready: the pool shifts their bytes out of
/// the slot's private share, and the backend copies the rows into the
/// shared store so attachments survive the creator.
fn flush_ready_blocks<B: DecodeBackend + ?Sized>(
    slot: &mut Slot,
    replayed: usize,
    pool: &mut KvPool,
    backend: &mut B,
) -> Result<()> {
    while let Some(&(block, lo, hi)) = slot.pending.first() {
        if hi > replayed {
            break;
        }
        let bytes = pool.mark_ready(block);
        slot.kv_bytes = slot.kv_bytes.saturating_sub(bytes);
        backend.register_block(slot.id, block, lo, hi, bytes)?;
        slot.pending.remove(0);
        slot.blocks.push(block);
    }
    Ok(())
}

/// Deterministic prompt lookup with per-stream caching: `synth_prompt`
/// over a keyed stream is prefix-stable (its first `n` draws are the same
/// whatever length is requested), so one growing buffer per stream key
/// serves every request length — the admission filter would otherwise
/// re-derive O(prompt) token ids per queued candidate on every iteration.
fn cached_prompt<'c>(
    cache: &'c mut BTreeMap<u64, Vec<usize>>,
    cfg: &CbConfig,
    id: u64,
    tokens: usize,
) -> &'c [usize] {
    let key = prompt_stream_key(cfg.prompt_groups, id);
    let entry = cache.entry(key).or_default();
    if entry.len() < tokens {
        *entry = synth_prompt(cfg.seed, key, tokens, cfg.prompt_vocab.max(2));
    }
    &entry[..tokens]
}

/// Reclaim cached (refcount-0) blocks, LRU subtree at a time, until
/// `need` more bytes fit resident under the cap (or nothing cacheable is
/// left). The backend drops its stored rows for every reclaimed block.
fn reclaim_cached<B: DecodeBackend + ?Sized>(
    pool: &mut KvPool,
    tree: &mut RadixTree,
    backend: &mut B,
    need: usize,
) -> Result<()> {
    while !pool.fits_resident(need) {
        let Some(victim) = pool.lru_cached() else { break };
        for block in tree.remove_subtree(victim) {
            pool.drop_cached(block);
            backend.drop_block(block)?;
        }
    }
    Ok(())
}

/// Snapshot the queue for the policy: one [`AdmissionCandidate`] per
/// queued request in FIFO order, with class and radix-tree prefix
/// coverage resolved exactly as the admission gate will resolve them.
/// The coverage walk is skipped (`covered_tokens == 0`) unless
/// `want_coverage` — it costs O(prompt / block) tree probes per queued
/// request, and only coverage-ordering policies read it.
fn candidate_views(
    engine: &CbEngine,
    batcher: &Batcher,
    prompt_cache: &mut BTreeMap<u64, Vec<usize>>,
    want_coverage: bool,
    tree: &RadixTree,
    pool: &KvPool,
    stats: &BTreeMap<u64, ReqStats>,
) -> Vec<AdmissionCandidate> {
    batcher
        .iter()
        .map(|r| {
            let covered = if want_coverage {
                let prompt = cached_prompt(prompt_cache, &engine.cfg, r.id, r.tokens);
                tree.covered_tokens(prompt, &|b| pool.block_ready(b))
            } else {
                0
            };
            let class = engine.cfg.class_of(r.id);
            AdmissionCandidate {
                id: r.id,
                arrival_s: r.arrival_s,
                queued_since: stats.get(&r.id).map(|s| s.queued_since).unwrap_or(r.arrival_s),
                tokens: r.tokens,
                class,
                deadline_s: engine.cfg.class_deadline(class),
                covered_tokens: covered,
                decode_budget: engine.decode_budget(r.id),
            }
        })
        .collect()
}

/// Snapshot the in-flight slots for the policy.
fn slot_views(cfg: &CbConfig, slots: &[Slot]) -> Vec<SlotView> {
    slots
        .iter()
        .map(|s| {
            let class = cfg.class_of(s.id);
            SlotView {
                id: s.id,
                arrival_s: s.arrival_s,
                class,
                deadline_s: cfg.class_deadline(class),
                admit_seq: s.admit_seq,
            }
        })
        .collect()
}

/// Preempt slot `i` back to the queue: the one victim-eviction mechanism,
/// shared by the KV-pressure loop, the policy's proactive SLO hook, and
/// replica drain. Resolves the eviction through the swap policy (transfer
/// vs recompute), releases the slot's pool bytes and block references,
/// notifies the backend, records the event, and requeues the request.
#[allow(clippy::too_many_arguments)]
fn preempt_slot<B: DecodeBackend + ?Sized>(
    engine: &CbEngine,
    i: usize,
    now: f64,
    swap_on: bool,
    swap_policy: &SwapPolicy,
    slots: &mut Vec<Slot>,
    pool: &mut KvPool,
    tree: &mut RadixTree,
    backend: &mut B,
    batcher: &mut Batcher,
    swapped: &mut BTreeMap<u64, SwapEntry>,
    stats: &mut BTreeMap<u64, ReqStats>,
    events: &mut Vec<CbEvent>,
    kv_evictions: &mut usize,
    swap_outs: &mut usize,
    swap_bytes: &mut usize,
    swap_out_s: &mut f64,
) -> Result<()> {
    let s = slots.remove(i);
    let occupancy = engine.slot_prompt_bytes(s.tokens) + s.generated * engine.kv_step_bytes();
    let swap_this = swap_on
        && s.state == SlotState::Decoding
        && swap_policy
            .swap_beats_recompute(occupancy, engine.recompute_cost_s(s.tokens, s.generated, now));
    pool.release_private(s.kv_bytes);
    for &b in &s.blocks {
        pool.unref_block(b);
    }
    // own blocks whose rows never finished replaying are dropped outright
    // (nothing backs them)
    if let Some(&(first_pending, _, _)) = s.pending.first() {
        for b in tree.remove_subtree(first_pending) {
            pool.drop_unready(b);
        }
    }
    if swap_this {
        backend.swap_out(s.id)?;
        events.push(CbEvent::SwapOut { id: s.id });
        *swap_outs += 1;
        *swap_bytes += occupancy;
        *swap_out_s += swap_policy.transfer_s(occupancy);
        swapped.insert(
            s.id,
            SwapEntry {
                tokens: s.tokens,
                generated: s.generated,
                remaining: s.remaining,
                budget: s.budget,
                bytes: occupancy,
                last_token_at: s.last_token_at,
            },
        );
    } else {
        backend.evict(s.id)?;
        events.push(CbEvent::Evict { id: s.id });
        *kv_evictions += 1;
    }
    if let Some(st) = stats.get_mut(&s.id) {
        st.queued_since = now; // queueing again
    }
    batcher.push(Request { id: s.id, arrival_s: s.arrival_s, tokens: s.tokens });
    Ok(())
}

/// What one [`EngineActor::step`] call did.
#[derive(Debug)]
pub struct StepOutcome {
    /// virtual time the iteration finishes (the actor's next wake);
    /// `None` = idle, sleep until the next enqueue. May exceed the
    /// horizon: a straddling iteration advances the clock without
    /// emitting effects.
    pub until: Option<f64>,
    /// events this step emitted, in order (also retained internally for
    /// the final [`CbReport`])
    pub events: Vec<CbEvent>,
}

/// Work spilled by a replica drain: every queued or in-flight request,
/// with the accounting that must follow it to its new replica.
pub(crate) struct DrainOutcome {
    pub(crate) spilled: Vec<(Request, ReqStats)>,
    pub(crate) events: Vec<CbEvent>,
}

/// Work lost to an unplanned replica kill: every queued or in-flight
/// request, stripped of the accounting that died with the replica (only
/// the once-only TTFT flag survives — a first token, once emitted,
/// happened) for the cluster loop to re-route.
pub(crate) struct KillOutcome {
    pub(crate) lost: Vec<(Request, ReqStats)>,
    pub(crate) events: Vec<CbEvent>,
}

/// One proactive checkpoint copy in the fleet host tier: everything a
/// survivor needs to rebuild the slot as of `generated` decode steps —
/// the analogue of a [`super::slots::SwapEntry`] that outlives its
/// replica. `bytes` is the full checkpointed occupancy (prompt rows plus
/// `generated` full-precision steps), which is what the restore transfer
/// is priced at.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CheckpointRecord {
    pub id: u64,
    pub arrival_s: f64,
    pub tokens: usize,
    pub generated: usize,
    pub remaining: usize,
    pub budget: usize,
    pub bytes: usize,
    pub last_token_at: f64,
}

/// The continuous-batching engine as an actor: a [`CbEngine`] (cost
/// model + config, immutable for the run) plus every piece of per-run
/// mutable state the old monolithic loop kept in locals.
pub struct EngineActor {
    engine: CbEngine,
    policy: Box<dyn SchedPolicy>,
    // config derived once, exactly as the old loop prologue derived it
    max_slots: usize,
    chunk_budget: usize,
    prefix_on: bool,
    block_tokens: usize,
    swap_policy: SwapPolicy,
    swap_on: bool,
    // run state
    batcher: Batcher,
    slots: Vec<Slot>,
    pool: KvPool,
    tree: RadixTree,
    prompt_cache: BTreeMap<u64, Vec<usize>>,
    swapped: BTreeMap<u64, SwapEntry>,
    /// ids in `swapped` whose entry is a fleet checkpoint copy, not a
    /// swap-out this replica performed: they seat through
    /// [`DecodeBackend::restore`] (no parked session exists here) and
    /// emit [`CbEvent::Restore`] instead of `SwapIn`
    restored: BTreeSet<u64>,
    /// fault-plan slowdown on the swap/checkpoint tier this step
    /// (1.0 = identity; set by the cluster loop per step)
    swap_slowdown: f64,
    /// checkpoint period in decode steps (0 = off), derived from
    /// `CbConfig::checkpoint_every` gated on the swap tier being priced
    ckpt_every: usize,
    /// checkpoint copies taken since the cluster loop last collected
    /// them into the fleet store
    pending_ckpts: Vec<CheckpointRecord>,
    next_seq: u64,
    events: Vec<CbEvent>,
    stats: BTreeMap<u64, ReqStats>,
    // accounting
    tally: CompletionTally,
    ttft: Summary,
    queue_wait: Summary,
    censored_wait: Summary,
    itl: Summary,
    queue_depth: Vec<(f64, usize)>,
    model_time: Breakdown,
    censored: usize,
    kv_rejected: usize,
    kv_evictions: usize,
    kv_violations: usize,
    prefill_chunks: usize,
    prefix_hits: usize,
    prefix_hit_tokens: usize,
    admitted_prompt_tokens: usize,
    recompute_flops_saved: f64,
    swap_outs: usize,
    swap_ins: usize,
    swap_bytes: usize,
    slo_preemptions: usize,
    /// per-request token delivery records (client model on only:
    /// `CbConfig::patience_s > 0`)
    streams: BTreeMap<u64, TokenStream>,
    /// requests cancelled by their impatient client
    cancelled: usize,
    replica: usize,
    /// active heterogeneous plan (None on uniform/unprofiled fleets —
    /// every pricing call then takes the legacy path bit for bit)
    plan: Option<Plan>,
    /// EWMA of the bandwidth trace sampled at each re-plan tick — the
    /// online profile estimate the planner re-scores against
    ewma_mbps: f64,
    /// next virtual time the re-planner runs (f64::INFINITY = off)
    next_replan_at: f64,
    /// plan swaps executed (reported as `CbReport::replans`)
    replans: usize,
}

impl EngineActor {
    /// A single-replica actor (replica id 0).
    pub fn new(engine: CbEngine) -> EngineActor {
        EngineActor::with_replica(engine, 0)
    }

    /// An actor tagged with a fleet replica id (stamped into its report).
    pub fn with_replica(engine: CbEngine, replica: usize) -> EngineActor {
        let policy = engine.make_policy();
        // heterogeneous fleets start on the planner's argmin for the
        // trace's opening bandwidth; re-planning thereafter is gated on
        // `--replan-every` (0 pins this initial plan for the whole run)
        let ewma_mbps = engine.trace.at(0.0);
        let plan =
            engine.profile.as_ref().map(|p| engine.planner().plan(p, ewma_mbps));
        let next_replan_at = if engine.profile.is_some() && engine.cfg.replan_every_s > 0.0 {
            engine.cfg.replan_every_s
        } else {
            f64::INFINITY
        };
        let max_slots = engine.cfg.max_slots.max(1);
        // prefill-only workloads have no decode iterations to piggyback
        // chunks on, so chunking applies only when decode happens
        let chunk_budget =
            if engine.cfg.prefill_chunk_tokens > 0 && engine.cfg.decode_tokens > 0 {
                engine.cfg.prefill_chunk_tokens
            } else {
                usize::MAX
            };
        // prefix sharing and swap both need live decode slots; prefill-only
        // workloads hold no state between events, so both are off there
        let prefix_on = engine.cfg.prefix_cache && engine.cfg.decode_tokens > 0;
        let block_tokens = engine.cfg.kv_block_tokens.max(1);
        let swap_policy =
            SwapPolicy::new(engine.cfg.swap_bandwidth_mbps, engine.cfg.swap_latency_s);
        let swap_on = swap_policy.enabled()
            && engine.cfg.kv_cap_bytes > 0
            && engine.cfg.decode_tokens > 0;
        // the checkpoint tier IS the swap tier: without a priced host
        // link there is nowhere to copy to (and prefill-only slots hold
        // no decode progress worth checkpointing)
        let ckpt_every = if engine.cfg.checkpoint_every > 0
            && swap_policy.enabled()
            && engine.cfg.decode_tokens > 0
        {
            engine.cfg.checkpoint_every
        } else {
            0
        };
        let batcher = Batcher::new(engine.cfg.max_batch.max(1), engine.cfg.max_wait_s);
        let pool = KvPool::new(engine.cfg.kv_cap_bytes);
        let tree = RadixTree::new(block_tokens);
        let tally =
            CompletionTally::new(engine.cfg.slo_s, engine.cfg.window_s, &engine.cfg.classes);
        EngineActor {
            engine,
            policy,
            max_slots,
            chunk_budget,
            prefix_on,
            block_tokens,
            swap_policy,
            swap_on,
            batcher,
            slots: Vec::new(),
            pool,
            tree,
            prompt_cache: BTreeMap::new(),
            swapped: BTreeMap::new(),
            restored: BTreeSet::new(),
            swap_slowdown: 1.0,
            ckpt_every,
            pending_ckpts: Vec::new(),
            next_seq: 0,
            events: Vec::new(),
            stats: BTreeMap::new(),
            tally,
            ttft: Summary::new(),
            queue_wait: Summary::new(),
            censored_wait: Summary::new(),
            itl: Summary::new(),
            queue_depth: Vec::new(),
            model_time: Breakdown::default(),
            censored: 0,
            kv_rejected: 0,
            kv_evictions: 0,
            kv_violations: 0,
            prefill_chunks: 0,
            prefix_hits: 0,
            prefix_hit_tokens: 0,
            admitted_prompt_tokens: 0,
            recompute_flops_saved: 0.0,
            swap_outs: 0,
            swap_ins: 0,
            swap_bytes: 0,
            slo_preemptions: 0,
            streams: BTreeMap::new(),
            cancelled: 0,
            replica,
            plan,
            ewma_mbps,
            next_replan_at,
            replans: 0,
        }
    }

    /// Hand the actor a newly arrived request. The driver must enqueue
    /// every arrival with `arrival_s <= now` before calling `step(now)`.
    pub fn enqueue(&mut self, req: Request) {
        self.batcher.push(req);
    }

    /// Adopt a request spilled from a drained replica, carrying its
    /// accumulated accounting (TTFT-recorded flag, closed queue-wait
    /// episodes, and the still-open episode's start) so the fleet never
    /// double-counts a first token or a wait.
    pub(crate) fn adopt(&mut self, req: Request, st: ReqStats) {
        self.stats.insert(req.id, st);
        self.batcher.push(req);
    }

    /// Queued requests (includes swapped-out requests awaiting
    /// re-admission — they queue like everyone else).
    pub fn queue_len(&self) -> usize {
        self.batcher.len()
    }

    /// In-flight decode slots.
    pub fn in_flight(&self) -> usize {
        self.slots.len()
    }

    /// Requests parked in the swap host tier.
    pub fn swapped_out(&self) -> usize {
        self.swapped.len()
    }

    /// The fleet replica id this actor reports under.
    pub fn replica(&self) -> usize {
        self.replica
    }

    /// This replica's fleet decode speed (fastest profiled device's
    /// weight; 1.0 on a uniform or unprofiled fleet) — what
    /// placement-aware routing prices replica load against.
    pub(crate) fn decode_speed(&self) -> f64 {
        self.engine.profile.as_ref().map_or(1.0, |p| p.max_weight())
    }

    /// Run exactly one scheduling iteration at virtual time `now`:
    /// head-of-line rejection, the proactive SLO hook, then either one
    /// admission or one fused chunk+decode iteration — the old loop body,
    /// verbatim. `horizon_s` gates TTFT recording and prefill-only
    /// completion exactly as the old loop's did.
    pub fn step<B: DecodeBackend>(
        &mut self,
        backend: &mut B,
        now: f64,
        horizon_s: f64,
    ) -> Result<StepOutcome> {
        let mark = self.events.len();
        let until = self.step_inner(backend, now, horizon_s)?;
        Ok(StepOutcome { until, events: self.events[mark..].to_vec() })
    }

    /// Online re-planning tick: at each `--replan-every` boundary crossed
    /// by `now`, fold the trace's current bandwidth into the EWMA estimate
    /// and swap plans when the planner's new argmin beats the incumbent's
    /// re-scored objective by more than the hysteresis margin. Both
    /// backends sample the same shared trace, so the live engine re-plans
    /// at identical ticks with identical inputs — the live-vs-model
    /// differential covers [`CbEvent::Replan`] like any other event.
    fn maybe_replan(&mut self, now: f64) {
        if now < self.next_replan_at {
            return;
        }
        let every = self.engine.cfg.replan_every_s;
        while self.next_replan_at <= now {
            self.next_replan_at += every;
        }
        let Some(profile) = self.engine.profile.as_ref() else { return };
        self.ewma_mbps = 0.3 * self.engine.trace.at(now) + 0.7 * self.ewma_mbps;
        let planner = self.engine.planner();
        let candidate = planner.plan(profile, self.ewma_mbps);
        let Some(cur) = self.plan.as_ref() else { return };
        if candidate.index == cur.index {
            return;
        }
        // hysteresis: a swap re-partitions every subsequent admission, so
        // the predicted win must clear a margin before we churn
        let incumbent = planner.score_index(cur.index, profile, self.ewma_mbps);
        if candidate.modeled_latency_s < incumbent * (1.0 - self.engine.cfg.replan_hysteresis) {
            self.events.push(CbEvent::Replan { from: cur.index, to: candidate.index });
            self.replans += 1;
            self.plan = Some(candidate);
        }
    }

    fn step_inner<B: DecodeBackend>(
        &mut self,
        backend: &mut B,
        now: f64,
        horizon_s: f64,
    ) -> Result<Option<f64>> {
        self.maybe_replan(now);
        // the plan in force for every pricing decision this iteration;
        // in-flight slots keep the split they were admitted under, so a
        // swap only changes work admitted from here on
        let active_plan = self.plan.clone();
        // disjoint field borrows: the body below is the pre-actor loop
        // iteration over what used to be locals
        let EngineActor {
            engine,
            policy,
            max_slots,
            chunk_budget,
            prefix_on,
            block_tokens,
            swap_policy,
            swap_on,
            batcher,
            slots,
            pool,
            tree,
            prompt_cache,
            swapped,
            restored,
            swap_slowdown,
            ckpt_every,
            pending_ckpts,
            next_seq,
            events,
            stats,
            tally,
            ttft,
            queue_wait,
            censored_wait,
            itl,
            queue_depth,
            model_time,
            censored,
            kv_evictions,
            kv_rejected,
            kv_violations,
            prefill_chunks,
            prefix_hits,
            prefix_hit_tokens,
            admitted_prompt_tokens,
            recompute_flops_saved,
            swap_outs,
            swap_ins,
            swap_bytes,
            slo_preemptions,
            streams,
            cancelled,
            ..
        } = self;
        let engine: &CbEngine = engine;
        let policy: &dyn SchedPolicy = policy.as_ref();
        let max_slots = *max_slots;
        let chunk_budget = *chunk_budget;
        let prefix_on = *prefix_on;
        let block_tokens = *block_tokens;
        // the fault plan's slowdown window scales every host-tier
        // transfer this step prices (swap out/in, checkpoint, restore);
        // factor 1.0 is the bit-exact identity
        let swap_policy = swap_policy.slowed(*swap_slowdown);
        let swap_on = *swap_on;
        let ckpt_every = *ckpt_every;

        // ---- client cancellation sweep (client model on only): a
        //      request whose client stopped listening is torn down for
        //      good — terminal, never requeued. Queued and swapped
        //      requests cancel on any silence since their last sign of
        //      life (arrival, or the last token delivered before an
        //      eviction); in-flight slots cancel only on an OBSERVED
        //      inter-token stall after at least one delivery —
        //      pre-first-token abandonment is the queue's job, so a
        //      borderline admission can never churn through
        //      admit/cancel cycles. ----
        if engine.cfg.patience_s > 0.0 {
            let gone: Vec<u64> = batcher
                .iter()
                .filter(|r| {
                    let seen =
                        streams.get(&r.id).map(|s| s.last_seen()).unwrap_or(r.arrival_s);
                    now - seen > engine.patience_for(r.id)
                })
                .map(|r| r.id)
                .collect();
            for id in gone {
                batcher.remove(id);
                // parked swap state dies with the cancellation; a fleet
                // checkpoint copy never lived on this backend, so there
                // is nothing parked to drop for restore-pending ids
                if swapped.remove(&id).is_some() && !restored.remove(&id) {
                    backend.drop_swapped(id)?;
                }
                stats.remove(&id);
                events.push(CbEvent::Cancelled { id });
                *cancelled += 1;
            }
            let mut i = 0;
            while i < slots.len() {
                let id = slots[i].id;
                let stalled = streams
                    .get(&id)
                    .map(|st| {
                        st.delivered() > 0 && now - st.last_seen() > engine.patience_for(id)
                    })
                    .unwrap_or(false);
                if !stalled {
                    i += 1;
                    continue;
                }
                // the kill-site teardown for one slot: release pool
                // bytes and block refs, drop unbacked pending blocks,
                // tell the backend — but no requeue and no swap: the
                // client is gone
                let s = slots.remove(i);
                pool.release_private(s.kv_bytes);
                for &b in &s.blocks {
                    pool.unref_block(b);
                }
                if let Some(&(first_pending, _, _)) = s.pending.first() {
                    for b in tree.remove_subtree(first_pending) {
                        pool.drop_unready(b);
                    }
                }
                backend.cancel(s.id)?;
                stats.remove(&s.id);
                events.push(CbEvent::Cancelled { id: s.id });
                *cancelled += 1;
            }
        }

        // a request whose full KV budget exceeds the cap can never be
        // served; drop it rather than head-of-line-block forever.
        // (Swapped requests already fit once and return at known size.)
        if pool.cap_bytes > 0 {
            loop {
                let oversized = match batcher.front() {
                    Some(r) => {
                        !swapped.contains_key(&r.id)
                            && engine.never_fits(r.id, r.tokens, pool.cap_bytes)
                    }
                    None => false,
                };
                if !oversized {
                    break;
                }
                let r = batcher.pop_front().unwrap();
                *kv_rejected += 1;
                events.push(CbEvent::Reject { id: r.id });
            }
        }

        // ---- proactive SLO preemption: with every slot occupied and
        //      work waiting, the policy may evict (swap-priced) slots
        //      to protect higher-priority queued requests' deadlines.
        //      Policies without the hook skip this entirely, keeping
        //      the default path bit-identical. ----
        let mut preempt_swap_s = 0.0f64;
        let mut preempt_cost_s = 0.0f64;
        if policy.preempts() && slots.len() >= max_slots && !batcher.is_empty() {
            let mut cands = candidate_views(
                engine,
                batcher,
                prompt_cache,
                prefix_on && policy.uses_coverage(),
                tree,
                pool,
                stats,
            );
            // a request that can never fit the cap is rejected at the
            // queue head, never preempted for — without this filter an
            // oversized high-class request behind the head would drive
            // an evict/re-seat cycle until its deadline lapsed.
            // (Swapped-out requests already fit once and return at a
            // known size, like the reject pass treats them.)
            if pool.cap_bytes > 0 {
                cands.retain(|c| {
                    swapped.contains_key(&c.id)
                        || !engine.never_fits(c.id, c.tokens, pool.cap_bytes)
                });
            }
            if !cands.is_empty() {
                let mut decisions = policy.preempt(now, &cands, &slot_views(&engine.cfg, slots));
                decisions.sort_unstable_by_key(|p| p.victim);
                decisions.dedup_by_key(|p| p.victim);
                for p in decisions.iter().rev() {
                    let vi = p.victim;
                    // a lone slot is never preempted, and stale indices
                    // (the policy saw a pre-eviction snapshot) are
                    // skipped
                    if slots.len() <= 1 || vi >= slots.len() || p.beneficiary >= cands.len() {
                        continue;
                    }
                    // mechanism-side feasibility: the eviction must
                    // actually open room for the policy's NAMED
                    // beneficiary — a fresh prefill, or a swap-in at
                    // its preserved size — or the freed slot could
                    // only be re-filled by someone else (or by the
                    // victim itself): recompute churn with no gain
                    // for the request the policy evicted for. (Why
                    // the eviction is worth it is the policy's
                    // judgment; whether it can work is the loop's.)
                    // Conservative: counts only the victim's private
                    // bytes as freed, and coverage only if the policy
                    // resolved it.
                    if pool.cap_bytes > 0 {
                        let c = &cands[p.beneficiary];
                        let need = match swapped.get(&c.id) {
                            Some(e) => e.bytes,
                            None => {
                                engine.slot_prompt_bytes(c.tokens)
                                    - engine.slot_prompt_bytes(c.covered_tokens)
                            }
                        };
                        if !pool.fits(need.saturating_sub(slots[vi].kv_bytes)) {
                            continue;
                        }
                    }
                    // cost-aware budget (`--slo-preempt-cost`): price
                    // this eviction exactly as the preemption machinery
                    // will resolve it — the swap round trip when swap
                    // wins, the modeled recompute otherwise — and skip
                    // victims once the iteration's accumulated price
                    // would exceed the budget. Off (<= 0) keeps the
                    // flat-count behavior bit for bit.
                    if engine.cfg.slo_preempt_cost_s > 0.0 {
                        let v = &slots[vi];
                        let occ = engine.slot_prompt_bytes(v.tokens)
                            + v.generated * engine.kv_step_bytes();
                        let recompute = engine.recompute_cost_s(v.tokens, v.generated, now);
                        let price = if swap_on
                            && v.state == SlotState::Decoding
                            && swap_policy.swap_beats_recompute(occ, recompute)
                        {
                            swap_policy.round_trip_s(occ)
                        } else {
                            recompute
                        };
                        if preempt_cost_s + price > engine.cfg.slo_preempt_cost_s {
                            continue;
                        }
                        preempt_cost_s += price;
                    }
                    preempt_slot(
                        engine,
                        vi,
                        now,
                        swap_on,
                        &swap_policy,
                        slots,
                        pool,
                        tree,
                        backend,
                        batcher,
                        swapped,
                        stats,
                        events,
                        kv_evictions,
                        swap_outs,
                        swap_bytes,
                        &mut preempt_swap_s,
                    )?;
                    *slo_preemptions += 1;
                }
            }
        }

        // ---- admission: batched prefill into free slots, gated on
        //      the KV pool at prefill footprint (optimistic — decode
        //      growth is handled by eviction below). A prefix hit is
        //      charged net of its covered blocks; a swapped request
        //      returns at its preserved size. Reordering policies pick
        //      the eligible order; the default is the FIFO walk. ----
        let free = max_slots.saturating_sub(slots.len());
        // an idle cluster never waits on the fill deadline
        let force = slots.is_empty();
        let batch = if free > 0 {
            // candidate snapshot for reordering policies, BEFORE the
            // stateful fits walk below mutates its accumulators
            let order: Option<Vec<usize>> = if policy.reorders() {
                let cands = candidate_views(
                    engine,
                    batcher,
                    prompt_cache,
                    prefix_on && policy.uses_coverage(),
                    tree,
                    pool,
                    stats,
                );
                Some(policy.admission_order(now, &cands))
            } else {
                None
            };
            let mut pending_bytes = 0usize;
            // cached (refcount-0) blocks this batch is about to
            // re-reference: attaching pins their bytes again, so they
            // stop being reclaimable and must be charged to the
            // admission check — once per block, however many batch
            // members share it
            let mut resurrected: BTreeSet<u64> = BTreeSet::new();
            let mut fits = |r: &Request| {
                if let Some(e) = swapped.get(&r.id) {
                    if pool.fits(pending_bytes + e.bytes) {
                        pending_bytes += e.bytes;
                        return true;
                    }
                    return false;
                }
                // a request that can never fit must not be admitted on
                // its (smaller) prefill footprint — it would grow past
                // the cap with no evictable peer. It blocks here until
                // it reaches the head, where the reject pass drops it.
                if engine.never_fits(r.id, r.tokens, pool.cap_bytes) {
                    return false;
                }
                let (hit, repin) = if prefix_on {
                    let prompt = cached_prompt(prompt_cache, &engine.cfg, r.id, r.tokens);
                    let (hit, _) = tree.lookup(prompt, &|b| pool.block_ready(b));
                    let repin: usize = hit
                        .iter()
                        .filter(|b| !resurrected.contains(*b))
                        .filter_map(|&b| pool.block(b))
                        .filter(|blk| blk.refs == 0)
                        .map(|blk| blk.bytes)
                        .sum();
                    (hit, repin)
                } else {
                    (Vec::new(), 0)
                };
                let covered = hit.len() * block_tokens;
                let need = engine.slot_prompt_bytes(r.tokens) - engine.slot_prompt_bytes(covered);
                if pool.fits(pending_bytes + repin + need) {
                    pending_bytes += repin + need;
                    resurrected.extend(hit);
                    true
                } else {
                    false
                }
            };
            match order {
                Some(ord) => batcher.next_batch_ordered(now, force, free, &ord, &mut fits),
                None => batcher.next_batch_filtered(now, force, free, &mut fits),
            }
        } else {
            Vec::new()
        };
        if !batch.is_empty() {
            queue_depth.push((now, batcher.len()));
            // resolve every batch member: swapped requests return via
            // the host link; fresh requests attach to shared blocks
            // (refcounts claimed here) and create the blocks their own
            // replay will back
            struct FreshMeta {
                req: Request,
                budget: usize,
                covered: usize,
                attach: Vec<u64>,
                pending: Vec<(u64, usize, usize)>,
                /// suffix rows the admission iteration replays
                first: usize,
            }
            let mut fresh: Vec<FreshMeta> = Vec::new();
            let mut swapped_in: Vec<(Request, SwapEntry)> = Vec::new();
            // (id, is_swap, covered) in batch order, for events/stats
            let mut order: Vec<(u64, bool, usize)> = Vec::new();
            for req in &batch {
                if let Some(e) = swapped.remove(&req.id) {
                    order.push((req.id, true, 0));
                    swapped_in.push((req.clone(), e));
                    continue;
                }
                let budget = engine.decode_budget(req.id);
                let (attach, covered, pend) = if prefix_on {
                    let prompt = cached_prompt(prompt_cache, &engine.cfg, req.id, req.tokens);
                    let (hit, extendable) = tree.lookup(prompt, &|b| pool.block_ready(b));
                    for &b in &hit {
                        pool.ref_block(b);
                    }
                    let covered = hit.len() * block_tokens;
                    let pend: Vec<(u64, usize, usize)> = if extendable {
                        tree.extend(prompt, hit.len(), &mut |lo, hi| {
                            pool.create_block(lo, hi, engine.block_bytes_range(lo, hi))
                        })
                        .into_iter()
                        .enumerate()
                        .map(|(k, b)| {
                            (b, covered + k * block_tokens, covered + (k + 1) * block_tokens)
                        })
                        .collect()
                    } else {
                        Vec::new()
                    };
                    (hit, covered, pend)
                } else {
                    (Vec::new(), 0, Vec::new())
                };
                let first = (req.tokens - covered).min(chunk_budget);
                order.push((req.id, false, covered));
                fresh.push(FreshMeta {
                    req: req.clone(),
                    budget,
                    covered,
                    attach,
                    pending: pend,
                    first,
                });
            }

            events.push(CbEvent::Admit { ids: batch.iter().map(|r| r.id).collect() });
            for &(id, is_swap, covered) in &order {
                if is_swap {
                    // a fleet checkpoint copy restores; a local swap-out
                    // swaps back in — same host-link pricing, distinct
                    // decisions in the stream
                    if restored.contains(&id) {
                        events.push(CbEvent::Restore { id });
                    } else {
                        events.push(CbEvent::SwapIn { id });
                    }
                } else if covered > 0 {
                    events.push(CbEvent::PrefixHit { id, tokens: covered });
                    *prefix_hits += 1;
                    *prefix_hit_tokens += covered;
                    // modeled prefill FLOPs the attach avoided: the
                    // covered rows advanced through every layer
                    *recompute_flops_saved += engine.shape.n_layers as f64
                        * engine.shape.chunk_block_flops(covered, covered, covered);
                }
            }
            for m in &fresh {
                *admitted_prompt_tokens += m.req.tokens;
                if m.covered + m.first < m.req.tokens {
                    events.push(CbEvent::PrefillChunk {
                        id: m.req.id,
                        lo: m.covered,
                        hi: m.covered + m.first,
                    });
                    *prefill_chunks += 1;
                }
            }

            // price the iteration: a batched prefill over the fresh
            // requests' first (suffix) chunks — the classic batched
            // path, bit for bit, when nothing attached — plus the
            // swap transfers over the host link (swap-ins here, any
            // proactive swap-outs from this iteration's hook)
            let mut iter_bd = Breakdown::default();
            let priced: Vec<&FreshMeta> = fresh.iter().filter(|m| m.first > 0).collect();
            if !priced.is_empty() {
                let b = priced.len();
                let max_first = priced.iter().map(|m| m.first).max().unwrap().max(1);
                let bd = if priced.iter().all(|m| m.covered == 0) {
                    let mut pshape = engine.shape;
                    pshape.seq_len = max_first;
                    let prefill = engine.sched_prefill(&pshape, active_plan.as_ref());
                    evaluate_on_trace_batched(&prefill, &engine.params, &engine.trace, now, b)
                } else {
                    // suffix-only pricing: covered tokens are never
                    // recomputed; the chunk schedule charges the new
                    // rows attending over the covered context
                    let ctx = priced.iter().map(|m| m.covered + m.first).max().unwrap();
                    let sched = engine.sched_chunk(max_first, ctx, active_plan.as_ref());
                    evaluate_on_trace_batched(&sched, &engine.params, &engine.trace, now, b)
                };
                iter_bd.accumulate(&bd);
            }
            if !swapped_in.is_empty() {
                let bytes: usize = swapped_in.iter().map(|(_, e)| e.bytes).sum();
                iter_bd.comm_s += swap_policy.transfer_s(bytes);
            }
            // proactive swap-outs from this iteration's SLO hook ride
            // the admission clock (0 unless the policy preempted)
            iter_bd.comm_s += preempt_swap_s;
            model_time.accumulate(&iter_bd);
            let done = now + iter_bd.total();

            let admit_batch = AdmitBatch {
                entries: fresh
                    .iter()
                    .map(|m| AdmitEntry {
                        req: m.req.clone(),
                        budget: m.budget,
                        class: engine.cfg.class_of(m.req.id),
                        prefix: PrefixAttach { tokens: m.covered, blocks: m.attach.clone() },
                    })
                    .collect(),
                prefill_limit: chunk_budget,
                split_weights: active_plan
                    .as_ref()
                    .zip(engine.profile.as_ref())
                    .and_then(|(p, profile)| p.split.split_weights(profile)),
            };
            backend.admit(&admit_batch)?;

            for (req, &(_, is_swap, covered)) in batch.iter().zip(order.iter()) {
                let st = stats.entry(req.id).or_insert(ReqStats {
                    queued_since: req.arrival_s,
                    queue_wait_s: 0.0,
                    ttft_recorded: false,
                });
                st.queue_wait_s += now - st.queued_since;
                st.queued_since = now; // in service: not queueing
                // classic path: the first token's latency is known at
                // prefill end (the uncovered suffix fits the budget).
                // Chunked slots record TTFT at their first decode step
                // instead, and an evicted-then-readmitted request keeps
                // the TTFT of the first token it ever emitted rather
                // than overwriting it here.
                if !is_swap
                    && req.tokens - covered <= chunk_budget
                    && done <= horizon_s
                    && !st.ttft_recorded
                {
                    st.ttft_recorded = true;
                    ttft.add(done - req.arrival_s);
                }
            }
            if engine.cfg.decode_tokens == 0 {
                // prefill-only workload: requests complete at prefill
                // end; past the horizon they are censored, not
                // completed, so no Complete event is emitted for them
                for req in &batch {
                    let waited = stats.get(&req.id).map(|s| s.queue_wait_s).unwrap_or(0.0);
                    queue_wait.add(waited);
                    if done <= horizon_s {
                        backend.complete(req.id)?;
                        events.push(CbEvent::Complete { id: req.id });
                        tally.record(req.arrival_s, done, engine.cfg.class_of(req.id));
                    } else {
                        *censored += 1;
                        censored_wait.add(now - req.arrival_s);
                        tally.censor(engine.cfg.class_of(req.id));
                    }
                }
            } else {
                // make room (reclaim cached blocks) for everything this
                // admission acquires, then seat the slots
                let new_private: usize = fresh
                    .iter()
                    .map(|m| {
                        engine.slot_prompt_bytes(m.covered + m.first)
                            - engine.slot_prompt_bytes(m.covered)
                    })
                    .sum::<usize>()
                    + swapped_in.iter().map(|(_, e)| e.bytes).sum::<usize>();
                reclaim_cached(pool, tree, backend, new_private)?;
                // seat slots in BATCH order, so admission sequence
                // numbers agree with the Admit event's id order — the
                // victim-selection invariant ("newest = most recently
                // admitted per the event stream") must hold for mixed
                // fresh/swapped batches too
                let mut fresh_iter = fresh.into_iter();
                let mut swap_iter = swapped_in.into_iter();
                for &(_, is_swap, _) in &order {
                    *next_seq += 1;
                    if is_swap {
                        let (req, e) = swap_iter.next().expect("order/swapped lists diverged");
                        if restored.remove(&req.id) {
                            // no parked session exists on this replica:
                            // the backend rebuilds the slot from the
                            // checkpoint metadata (live: deterministic
                            // replay of prompt + generated greedy steps)
                            backend.restore(
                                req.id,
                                e.tokens,
                                e.generated,
                                e.budget,
                                engine.cfg.class_of(req.id),
                            )?;
                        } else {
                            backend.swap_in(req.id)?;
                            *swap_ins += 1;
                            *swap_bytes += e.bytes;
                        }
                        pool.acquire_private(e.bytes);
                        slots.push(Slot {
                            id: req.id,
                            arrival_s: req.arrival_s,
                            tokens: e.tokens,
                            remaining: e.remaining,
                            generated: e.generated,
                            kv_bytes: e.bytes,
                            admit_seq: *next_seq,
                            budget: e.budget,
                            blocks: Vec::new(),
                            pending: Vec::new(),
                            state: SlotState::Decoding,
                            // preserved across the host tier: the next
                            // inter-token gap includes the swap dwell
                            last_token_at: e.last_token_at,
                        });
                    } else {
                        let m = fresh_iter.next().expect("order/fresh lists diverged");
                        let replayed0 = m.covered + m.first;
                        let kv_bytes = engine.slot_prompt_bytes(replayed0)
                            - engine.slot_prompt_bytes(m.covered);
                        pool.acquire_private(kv_bytes);
                        let mut slot = Slot {
                            id: m.req.id,
                            arrival_s: m.req.arrival_s,
                            tokens: m.req.tokens,
                            remaining: m.budget,
                            generated: 0,
                            kv_bytes,
                            admit_seq: *next_seq,
                            budget: m.budget,
                            blocks: m.attach,
                            pending: m.pending,
                            state: if replayed0 < m.req.tokens {
                                SlotState::Prefilling { next_token: replayed0, total: m.req.tokens }
                            } else {
                                SlotState::Decoding
                            },
                            last_token_at: now,
                        };
                        flush_ready_blocks(&mut slot, replayed0, pool, backend)?;
                        slots.push(slot);
                    }
                }
            }
            if pool.cap_bytes > 0 && backend.kv_bytes_in_flight() > pool.cap_bytes {
                *kv_violations += 1;
            }
            return Ok(Some(done));
        }

        // ---- one fused chunk+decode iteration for all active slots ----
        if !slots.is_empty() {
            // KV pressure: this iteration grows every decoding slot by
            // one token's full-precision rows and every planned
            // prefilling slot by its chunk's mixed rows; preempt slots
            // back to the queue — the victim chosen by the policy —
            // until the growth fits the cap. A lone slot always fits
            // (over-cap requests were rejected at admission). Each
            // victim is resolved by the swap policy: move its cache
            // over the host link when the round trip beats the modeled
            // recompute, else drop it (recompute).
            let mut swap_out_s = preempt_swap_s;
            let plan = if pool.cap_bytes > 0 {
                loop {
                    let (plan, growth) = engine.plan_chunks(slots, chunk_budget);
                    if slots.len() <= 1 || pool.fits(growth) {
                        // cached blocks yield before anything new lands
                        reclaim_cached(pool, tree, backend, growth)?;
                        break plan;
                    }
                    let i = policy.victim(now, &slot_views(&engine.cfg, slots));
                    preempt_slot(
                        engine,
                        i,
                        now,
                        swap_on,
                        &swap_policy,
                        slots,
                        pool,
                        tree,
                        backend,
                        batcher,
                        swapped,
                        stats,
                        events,
                        kv_evictions,
                        swap_outs,
                        swap_bytes,
                        &mut swap_out_s,
                    )?;
                }
            } else {
                engine.plan_chunks(slots, chunk_budget).0
            };
            let decode_ids: Vec<u64> = slots
                .iter()
                .filter(|s| s.state == SlotState::Decoding)
                .map(|s| s.id)
                .collect();
            let b = decode_ids.len();
            let ctx = slots
                .iter()
                .filter(|s| s.state == SlotState::Decoding)
                .map(|s| s.tokens + s.generated)
                .max()
                .unwrap_or(0);
            let bd = if plan.is_empty() {
                // no prefilling slots: the classic batched decode step
                // (bit-identical pricing to the unchunked scheduler)
                let step = engine.sched_decode(ctx, active_plan.as_ref());
                evaluate_on_trace_batched(&step, &engine.params, &engine.trace, now, b)
            } else {
                // fuse the chunk batch with the piggybacked decode
                let chunk_tokens: usize = plan.iter().map(|&(_, take)| take).sum();
                let ctx_prefill = plan
                    .iter()
                    .map(|&(i, take)| match slots[i].state {
                        SlotState::Prefilling { next_token, .. } => next_token + take,
                        SlotState::Decoding => 0,
                    })
                    .max()
                    .unwrap_or(chunk_tokens);
                let fused =
                    engine.sched_fused(chunk_tokens, ctx_prefill, b, ctx, active_plan.as_ref());
                evaluate_on_trace(&fused, &engine.params, &engine.trace, now)
            };
            // proactive checkpoints: every `ckpt_every`-th generated
            // token of a decoding slot copies its full post-step
            // occupancy to the host tier, priced like a swap-out on this
            // iteration's clock. A slot completing this step is not
            // checkpointed — there is nothing left to restore.
            let mut ckpt_s = 0.0f64;
            if ckpt_every > 0 {
                for s in slots.iter().filter(|s| s.state == SlotState::Decoding) {
                    if (s.generated + 1) % ckpt_every == 0 && s.remaining > 1 {
                        let occ = engine.slot_prompt_bytes(s.tokens)
                            + (s.generated + 1) * engine.kv_step_bytes();
                        ckpt_s += swap_policy.transfer_s(occ);
                    }
                }
            }
            model_time.accumulate(&bd);
            // swap and checkpoint transfers ride this iteration's clock
            // (and its comm accounting) — the host link is priced, not free
            model_time.comm_s += swap_out_s + ckpt_s;
            // with the copy engine, those transfers overlap the decode
            // step: the clock charges max(compute, transfer) instead of
            // their sum (the comm accounting above still prices them)
            let done = if engine.cfg.copy_engine {
                now + bd.total().max(swap_out_s + ckpt_s)
            } else {
                now + bd.total() + swap_out_s + ckpt_s
            };
            if done > horizon_s {
                // the iteration straddles the horizon: nothing advances
                return Ok(Some(done));
            }
            let now = done;
            // one fused execution call for the whole iteration: every
            // planned prefill chunk plus every decoding slot crosses the
            // backend's real batch boundary together (chunked slots never
            // decode in their own chunk's iteration, so the sets are
            // disjoint and replay-before-decode ordering is irrelevant)
            let step_batch = StepBatch {
                chunks: plan
                    .iter()
                    .map(|&(i, take)| {
                        let next_token = match slots[i].state {
                            SlotState::Prefilling { next_token, .. } => next_token,
                            SlotState::Decoding => unreachable!("planned a decoding slot"),
                        };
                        ChunkPlan { id: slots[i].id, lo: next_token, hi: next_token + take }
                    })
                    .collect(),
                decode_ids: decode_ids.clone(),
            };
            if !step_batch.is_empty() {
                backend.step(&step_batch)?;
            }
            // chunk effects: record the planned chunks (the backend already
            // replayed them above), grow the mixed cache per chunk, release
            // finished prompts into decode (their first decode step — and
            // TTFT — comes next iteration, never fused with their own last
            // chunk)
            for &(i, take) in &plan {
                let (next_token, total) = match slots[i].state {
                    SlotState::Prefilling { next_token, total } => (next_token, total),
                    SlotState::Decoding => unreachable!("planned a decoding slot"),
                };
                events.push(CbEvent::PrefillChunk {
                    id: slots[i].id,
                    lo: next_token,
                    hi: next_token + take,
                });
                *prefill_chunks += 1;
                let delta = engine.slot_prompt_bytes(next_token + take)
                    - engine.slot_prompt_bytes(next_token);
                pool.acquire_private(delta);
                slots[i].kv_bytes += delta;
                slots[i].state = if next_token + take == total {
                    SlotState::Decoding
                } else {
                    SlotState::Prefilling { next_token: next_token + take, total }
                };
                // rows past a block boundary back the slot's own
                // blocks now: publish them to the shared store
                flush_ready_blocks(&mut slots[i], next_token + take, pool, backend)?;
            }
            if b > 0 {
                events.push(CbEvent::Decode { ids: decode_ids.clone() });
            }
            let mut i = 0;
            while i < slots.len() {
                // only the slots that decoded this iteration advance
                // (a slot whose last chunk just landed waits one turn)
                if !decode_ids.contains(&slots[i].id) {
                    i += 1;
                    continue;
                }
                slots[i].remaining -= 1;
                slots[i].generated += 1;
                if slots[i].generated == 1 {
                    // first token this request ever produced: TTFT for
                    // chunked slots (classic slots recorded theirs at
                    // prefill end; the recorded-once guard keeps
                    // re-admitted evictees at their original value)
                    if let Some(st) = stats.get_mut(&slots[i].id) {
                        if !st.ttft_recorded {
                            st.ttft_recorded = true;
                            ttft.add(now - slots[i].arrival_s);
                        }
                    }
                } else {
                    itl.add(now - slots[i].last_token_at);
                }
                slots[i].last_token_at = now;
                // client-model delivery record: one timestamp per token
                // the client has never seen. Re-generation after a
                // recompute eviction recreates tokens the client already
                // holds (greedy decode is deterministic), so deliveries
                // resume only past the high-water mark.
                if engine.cfg.patience_s > 0.0 {
                    let stream = streams
                        .entry(slots[i].id)
                        .or_insert_with(|| TokenStream::new(slots[i].arrival_s));
                    if slots[i].generated > stream.deliveries.len() {
                        stream.deliveries.push(now);
                    }
                }
                let step_bytes = engine.kv_step_bytes();
                pool.acquire_private(step_bytes);
                slots[i].kv_bytes += step_bytes;
                // checkpoint effects, matching the pricing pass above
                // exactly (post-step: generated incremented, remaining
                // decremented): record the copy for the fleet store
                if ckpt_every > 0
                    && slots[i].generated % ckpt_every == 0
                    && slots[i].remaining > 0
                {
                    events.push(CbEvent::Checkpoint { id: slots[i].id });
                    pending_ckpts.push(CheckpointRecord {
                        id: slots[i].id,
                        arrival_s: slots[i].arrival_s,
                        tokens: slots[i].tokens,
                        generated: slots[i].generated,
                        remaining: slots[i].remaining,
                        budget: slots[i].budget,
                        bytes: engine.slot_prompt_bytes(slots[i].tokens)
                            + slots[i].generated * engine.kv_step_bytes(),
                        last_token_at: now,
                    });
                }
                if slots[i].remaining == 0 {
                    let s = slots.swap_remove(i);
                    pool.release_private(s.kv_bytes);
                    // the slot's shared blocks stay resident at
                    // refcount 0 — the "recently freed" prefix a later
                    // request can attach to without any replay
                    for &b in &s.blocks {
                        pool.unref_block(b);
                    }
                    backend.complete(s.id)?;
                    events.push(CbEvent::Complete { id: s.id });
                    tally.record(s.arrival_s, now, engine.cfg.class_of(s.id));
                    queue_wait.add(stats.get(&s.id).map(|st| st.queue_wait_s).unwrap_or(0.0));
                } else {
                    i += 1;
                }
            }
            if pool.cap_bytes > 0 && backend.kv_bytes_in_flight() > pool.cap_bytes {
                *kv_violations += 1;
            }
            return Ok(Some(now));
        }

        // ---- idle: empty queue (an idle engine force-admits anything
        //      admissible, so the queue holds at most KV-blocked
        //      requests; those wait for in-flight work that doesn't
        //      exist here) and no slots — sleep until the next enqueue ----
        Ok(None)
    }

    /// Tear the replica down at virtual time `now`: every in-flight slot
    /// is evicted recompute-style (the replica's host tier goes away with
    /// it, so swap would preserve nothing), the host tier is dropped, and
    /// every queued request — evictees included — is spilled with its
    /// accounting for the cluster loop to re-route to survivors.
    pub(crate) fn drain<B: DecodeBackend>(
        &mut self,
        backend: &mut B,
        now: f64,
    ) -> Result<DrainOutcome> {
        let mark = self.events.len();
        while !self.slots.is_empty() {
            let i = self.slots.len() - 1;
            let mut unused_swap_s = 0.0;
            preempt_slot(
                &self.engine,
                i,
                now,
                false, // never swap: the host tier is going away too
                &self.swap_policy,
                &mut self.slots,
                &mut self.pool,
                &mut self.tree,
                backend,
                &mut self.batcher,
                &mut self.swapped,
                &mut self.stats,
                &mut self.events,
                &mut self.kv_evictions,
                &mut self.swap_outs,
                &mut self.swap_bytes,
                &mut unused_swap_s,
            )?;
        }
        // host-tier entries die with the replica; their requests are
        // already queued (swap keeps the request in the batcher) and will
        // rebuild from scratch on a survivor. Restore-pending ids never
        // had a parked session on this backend — their entry is fleet
        // checkpoint metadata, so there is nothing to drop.
        let parked: Vec<u64> = self.swapped.keys().copied().collect();
        for id in parked {
            if !self.restored.contains(&id) {
                backend.drop_swapped(id)?;
            }
        }
        self.swapped.clear();
        self.restored.clear();
        let mut spilled = Vec::new();
        for req in self.batcher.drain_all() {
            let st = self.stats.remove(&req.id).unwrap_or(ReqStats {
                queued_since: req.arrival_s,
                queue_wait_s: 0.0,
                ttft_recorded: false,
            });
            spilled.push((req, st));
        }
        Ok(DrainOutcome { spilled, events: self.events[mark..].to_vec() })
    }

    /// Unplanned death at virtual time `now` — the fault-plan kill, as
    /// opposed to the scheduled [`EngineActor::drain`]: nothing is
    /// preserved. In-flight slots are torn down (their pool bytes, block
    /// refs, and backend sessions released — no `Evict` event and no
    /// `kv_evictions` count: this is a fault, not a scheduling decision),
    /// the host swap tier dies with the replica, and every request the
    /// replica held is surrendered as *lost* ([`CbEvent::Killed`], one
    /// per request) with only its once-only TTFT flag carried — accrued
    /// queue-wait episodes died with the replica's accounting.
    pub(crate) fn kill<B: DecodeBackend>(
        &mut self,
        backend: &mut B,
        now: f64,
    ) -> Result<KillOutcome> {
        let mark = self.events.len();
        while let Some(s) = self.slots.pop() {
            self.pool.release_private(s.kv_bytes);
            for &b in &s.blocks {
                self.pool.unref_block(b);
            }
            // own blocks whose rows never finished replaying die unbacked
            if let Some(&(first_pending, _, _)) = s.pending.first() {
                for b in self.tree.remove_subtree(first_pending) {
                    self.pool.drop_unready(b);
                }
            }
            backend.evict(s.id)?;
            self.batcher.push(Request { id: s.id, arrival_s: s.arrival_s, tokens: s.tokens });
        }
        let parked: Vec<u64> = self.swapped.keys().copied().collect();
        for id in parked {
            if !self.restored.contains(&id) {
                backend.drop_swapped(id)?;
            }
        }
        self.swapped.clear();
        self.restored.clear();
        let mut lost = Vec::new();
        for req in self.batcher.drain_all() {
            self.events.push(CbEvent::Killed { id: req.id });
            let ttft_recorded =
                self.stats.remove(&req.id).map(|st| st.ttft_recorded).unwrap_or(false);
            lost.push((req, ReqStats { queued_since: now, queue_wait_s: 0.0, ttft_recorded }));
        }
        Ok(KillOutcome { lost, events: self.events[mark..].to_vec() })
    }

    /// Adopt a request lost by a killed replica *with* a fleet checkpoint
    /// copy: it queues like a swapped-out request at the checkpointed
    /// size and decode progress, and seats through
    /// [`DecodeBackend::restore`] / [`CbEvent::Restore`] when admitted.
    pub(crate) fn adopt_restored(&mut self, req: Request, st: ReqStats, rec: &CheckpointRecord) {
        self.swapped.insert(
            req.id,
            SwapEntry {
                tokens: rec.tokens,
                generated: rec.generated,
                remaining: rec.remaining,
                budget: rec.budget,
                bytes: rec.bytes,
                last_token_at: rec.last_token_at,
            },
        );
        self.restored.insert(req.id);
        self.stats.insert(req.id, st);
        self.batcher.push(req);
    }

    /// Surrender the checkpoint copies taken since the last collection —
    /// the cluster loop moves them into the fleet store after every step
    /// (they must survive this replica's death, so they cannot live here).
    pub(crate) fn take_checkpoints(&mut self) -> Vec<CheckpointRecord> {
        std::mem::take(&mut self.pending_ckpts)
    }

    /// Set the fault-plan slowdown factor on the swap/checkpoint tier for
    /// the next step (1.0 = no fault active).
    pub(crate) fn set_swap_slowdown(&mut self, factor: f64) {
        self.swap_slowdown = factor;
    }

    /// Structural quiescence of the KV pool: no private bytes and no
    /// referenced blocks — what must hold after a kill or drain tore every
    /// slot down (cached refcount-0 blocks may remain).
    pub(crate) fn pool_quiescent(&self) -> bool {
        self.pool.quiescent()
    }

    /// Census a request the driver never routed to any actor (it arrived
    /// inside the horizon but the run ended first) — the same accounting
    /// the old loop applied to unpulled arrivals.
    pub fn censor_unrouted(&mut self, req: &Request, horizon_s: f64) {
        if req.arrival_s >= horizon_s {
            return;
        }
        self.censored += 1;
        self.censored_wait.add(horizon_s - req.arrival_s);
        self.tally.censor(self.engine.cfg.class_of(req.id));
    }

    /// Close the run at `horizon_s`: census everything still in flight or
    /// queued, then build the report.
    pub fn finish(mut self, horizon_s: f64) -> CbReport {
        // census: everything in flight or queued at the horizon is censored
        for s in &self.slots {
            self.censored += 1;
            self.censored_wait.add((horizon_s - s.arrival_s).max(0.0));
            self.tally.censor(self.engine.cfg.class_of(s.id));
            if let Some(st) = self.stats.get(&s.id) {
                self.queue_wait.add(st.queue_wait_s);
            }
        }
        for req in self.batcher.drain_all() {
            self.censored += 1;
            self.censored_wait.add((horizon_s - req.arrival_s).max(0.0));
            self.tally.censor(self.engine.cfg.class_of(req.id));
            // an evicted request waiting for re-admission was still
            // queueing when the horizon fell: close its open episode
            if let Some(st) = self.stats.get(&req.id) {
                self.queue_wait.add(st.queue_wait_s + (horizon_s - st.queued_since).max(0.0));
            }
        }

        // post-hoc waste accounting over the delivery records: tokens
        // delivered after their client's abandon point
        // ([`crate::workload::abandon_time`] semantics), plus the pooled
        // arrival-to-each-token latency. Pure functions of the streams,
        // so a cancellation-blind run's report can be re-scored with any
        // patience by the same arithmetic.
        let mut wasted_decode_tokens = 0usize;
        let mut time_to_token = Summary::new();
        for (&id, s) in &self.streams {
            wasted_decode_tokens +=
                wasted_deliveries(s.arrival_s, &s.deliveries, self.engine.patience_for(id));
            for &d in &s.deliveries {
                time_to_token.add(d - s.arrival_s);
            }
        }

        CbReport {
            completed: self.tally.completed,
            censored: self.censored,
            kv_rejected: self.kv_rejected,
            horizon_s,
            throughput: self.tally.windows.rate_until(horizon_s),
            throughput_completion: if self.tally.last_completion > 0.0 {
                self.tally.completed as f64 / self.tally.last_completion
            } else {
                0.0
            },
            goodput: self.tally.within_slo as f64 / horizon_s,
            slo_s: self.tally.slo,
            latency: self.tally.latency,
            ttft: self.ttft,
            queue_wait: self.queue_wait,
            itl: self.itl,
            censored_wait: self.censored_wait,
            queue_depth: self.queue_depth,
            windows: self.tally.windows.bars_until(horizon_s),
            events: self.events,
            prefill_chunks: self.prefill_chunks,
            model_time: self.model_time,
            kv_peak_bytes: self.pool.peak_bytes,
            kv_cap_bytes: self.pool.cap_bytes,
            kv_evictions: self.kv_evictions,
            kv_violations: self.kv_violations,
            prefix_hits: self.prefix_hits,
            prefix_hit_tokens: self.prefix_hit_tokens,
            admitted_prompt_tokens: self.admitted_prompt_tokens,
            recompute_flops_saved: self.recompute_flops_saved,
            swap_outs: self.swap_outs,
            swap_ins: self.swap_ins,
            swap_bytes: self.swap_bytes,
            slo_preemptions: self.slo_preemptions,
            classes: self.tally.classes,
            replica: self.replica,
            cancelled: self.cancelled,
            wasted_decode_tokens,
            time_to_token,
            streams: self.streams,
            replans: self.replans,
        }
    }
}
