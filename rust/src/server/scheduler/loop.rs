//! The single-replica driver for the actorized continuous-batching
//! engine: a trivial event loop that owns the virtual clock and the
//! arrival stream, pulls arrivals due at each instant into the actor's
//! queue, and lets [`EngineActor::step`] run the per-iteration mechanism
//! (admission, fused chunk+decode, preemption, the KV pool, swap
//! pricing, policy hooks). This reproduces the pre-actor monolithic loop
//! bit for bit — the same clock jumps, the same event stream — which the
//! Fifo anchor property tests in `tests/proptests.rs` pin. The
//! multi-replica analogue of this driver lives in
//! [`crate::server::cluster`].

use anyhow::Result;

use super::super::batcher::Request;
use super::actor::EngineActor;
use super::{CbEngine, CbReport, DecodeBackend};

impl CbEngine {
    /// Serve a fixed arrival list, delegating per-slot execution to
    /// `backend` while the engine actor makes every scheduling decision
    /// on the cost model's virtual clock. `arrivals` must be sorted by
    /// arrival.
    pub fn serve_stream_with<B: DecodeBackend>(
        &mut self,
        backend: &mut B,
        arrivals: Vec<Request>,
        horizon_s: f64,
    ) -> Result<CbReport> {
        let mut actor = EngineActor::new(self.clone());
        let mut pending = arrivals.into_iter().peekable();
        let mut now = 0.0f64;
        while now < horizon_s {
            // pull arrivals into the queue
            while let Some(r) = pending.peek() {
                if r.arrival_s <= now {
                    actor.enqueue(pending.next().unwrap());
                } else {
                    break;
                }
            }
            match actor.step(backend, now, horizon_s)?.until {
                // one iteration ran; its finish time is the next step
                // (it may exceed the horizon — the loop check ends the run)
                Some(t) => now = t,
                // idle: jump to the next arrival
                None => match pending.peek().map(|r| r.arrival_s) {
                    Some(t) => now = t,
                    None => break,
                },
            }
        }
        // arrivals the run never reached are censored, like the queue
        for req in pending {
            actor.censor_unrouted(&req, horizon_s);
        }
        Ok(actor.finish(horizon_s))
    }
}
