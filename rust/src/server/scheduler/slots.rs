//! In-flight slot state for the continuous-batching scheduler: the slot
//! record itself, its chunked-prefill progress, the host-tier entry a
//! swapped-out request parks in, and the per-request accounting that must
//! survive eviction and re-admission.

/// Chunked-prefill progress of an in-flight slot.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SlotState {
    /// prompt rows `[0, next_token)` are in the cache; `[next_token,
    /// total)` still arrive as fused chunks
    Prefilling { next_token: usize, total: usize },
    /// prompt fully prefilled; each iteration decodes one token
    Decoding,
}

/// One in-flight request occupying a decode slot.
#[derive(Debug, Clone)]
pub(crate) struct Slot {
    pub(crate) id: u64,
    pub(crate) arrival_s: f64,
    /// prompt length (the request's `tokens`)
    pub(crate) tokens: usize,
    pub(crate) remaining: usize,
    pub(crate) generated: usize,
    /// modeled mixed-KV bytes this slot holds PRIVATELY — replayed prompt
    /// rows not yet backing a ready shared block, plus two full-precision
    /// rows per decode step. Without prefix caching no blocks exist and
    /// this is the slot's whole footprint, exactly the old accounting.
    pub(crate) kv_bytes: usize,
    /// monotone admission sequence number for this episode — the default
    /// policy evicts the largest, which makes "newest" stable under
    /// readmission (a readmitted slot counts as newest by its CURRENT
    /// admission, and same-batch ties resolve in batch order instead of
    /// by raw id)
    pub(crate) admit_seq: u64,
    /// per-request decode budget (== `decode_tokens` unless jittered)
    pub(crate) budget: usize,
    /// ready shared blocks this slot holds references on (attached at
    /// admission plus own blocks whose rows finished replaying)
    pub(crate) blocks: Vec<u64>,
    /// own created blocks still waiting for their rows `(block, lo, hi)`,
    /// ascending; flushed into `blocks` as replay crosses `hi`
    pub(crate) pending: Vec<(u64, usize, usize)>,
    pub(crate) state: SlotState,
    /// virtual time this slot last completed a decode step (ITL tracking)
    pub(crate) last_token_at: f64,
}

/// Progress preserved for a swapped-out request until readmission.
#[derive(Debug, Clone, Copy)]
pub(crate) struct SwapEntry {
    pub(crate) tokens: usize,
    pub(crate) generated: usize,
    pub(crate) remaining: usize,
    pub(crate) budget: usize,
    /// occupancy transferred out — charged again on the way back in, and
    /// re-acquired as private bytes at readmission
    pub(crate) bytes: usize,
    /// when the slot last emitted a token: preserved so the inter-token
    /// gap spanning the host-tier dwell (swap-out, queueing, swap-in) is
    /// counted by the ITL stall metric — swap keeps the generation stream
    /// alive, so the user-visible gap between token k and k+1 includes it
    pub(crate) last_token_at: f64,
}

/// Per-request accounting that must survive eviction and re-admission:
/// TTFT is measured once, from the original arrival to the first token the
/// request ever produced, and queue wait sums every queueing episode
/// instead of being overwritten when a request re-enters through admission.
#[derive(Debug, Clone, Copy)]
pub(crate) struct ReqStats {
    /// when the current queueing episode began (arrival, or last eviction)
    pub(crate) queued_since: f64,
    /// completed queueing episodes, summed
    pub(crate) queue_wait_s: f64,
    pub(crate) ttft_recorded: bool,
}
