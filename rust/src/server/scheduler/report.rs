//! Outcome accounting for a continuous-batching run: the report struct,
//! the shared completion tally, and per-priority-class breakdowns.

use std::collections::BTreeMap;

use crate::sim::latency::Breakdown;
use crate::util::stats::{Summary, WindowedCounter};
use crate::workload::TokenStream;

use super::CbEvent;

/// Per-priority-class outcome breakdown (populated when
/// `CbConfig::classes` is non-empty, whatever the active policy — so a
/// FIFO run and an SLO-class run report directly comparable attainment
/// on the same trace).
#[derive(Debug)]
pub struct ClassReport {
    /// class index (== position in `CbConfig::classes`; higher = higher
    /// priority)
    pub class: usize,
    /// the class latency deadline, seconds (<= 0: none)
    pub deadline_s: f64,
    pub completed: usize,
    /// admitted or queued inside the horizon but not completed by it
    pub censored: usize,
    /// completions whose end-to-end latency met the class deadline
    pub within_deadline: usize,
    /// end-to-end latency of this class's completed requests
    pub latency: Summary,
}

impl ClassReport {
    pub(crate) fn new(class: usize, deadline_s: f64) -> ClassReport {
        ClassReport {
            class,
            deadline_s,
            completed: 0,
            censored: 0,
            within_deadline: 0,
            latency: Summary::new(),
        }
    }

    /// Fraction of this class's completions that met its deadline
    /// (0 when nothing completed; 1 when the class has no deadline).
    pub fn slo_attainment(&self) -> f64 {
        if self.completed == 0 {
            0.0
        } else {
            self.within_deadline as f64 / self.completed as f64
        }
    }

    /// Within-deadline completions per second over the run horizon.
    pub fn goodput(&self, horizon_s: f64) -> f64 {
        if horizon_s > 0.0 {
            self.within_deadline as f64 / horizon_s
        } else {
            0.0
        }
    }
}

/// Completion bookkeeping shared by the prefill-only and decode paths —
/// one point of truth for what "a request finished at `done`" updates,
/// including the per-class tallies.
pub(crate) struct CompletionTally {
    pub(crate) completed: usize,
    pub(crate) within_slo: usize,
    pub(crate) last_completion: f64,
    pub(crate) slo: f64,
    pub(crate) latency: Summary,
    pub(crate) windows: WindowedCounter,
    pub(crate) classes: Vec<ClassReport>,
}

impl CompletionTally {
    pub(crate) fn new(slo: f64, window_s: f64, class_deadlines: &[f64]) -> CompletionTally {
        CompletionTally {
            completed: 0,
            within_slo: 0,
            last_completion: 0.0,
            slo,
            latency: Summary::new(),
            windows: WindowedCounter::new(window_s),
            classes: class_deadlines
                .iter()
                .enumerate()
                .map(|(k, &d)| ClassReport::new(k, d))
                .collect(),
        }
    }

    pub(crate) fn record(&mut self, arrival_s: f64, done: f64, class: usize) {
        self.completed += 1;
        let l = done - arrival_s;
        self.latency.add(l);
        self.windows.record(done);
        self.last_completion = done;
        if self.slo <= 0.0 || l <= self.slo {
            self.within_slo += 1;
        }
        if let Some(c) = self.classes.get_mut(class) {
            c.completed += 1;
            c.latency.add(l);
            if c.deadline_s <= 0.0 || l <= c.deadline_s {
                c.within_deadline += 1;
            }
        }
    }

    /// A request of `class` fell past the horizon unfinished.
    pub(crate) fn censor(&mut self, class: usize) {
        if let Some(c) = self.classes.get_mut(class) {
            c.censored += 1;
        }
    }
}

/// Outcome of a continuous-batching serve run.
#[derive(Debug)]
pub struct CbReport {
    pub completed: usize,
    /// admitted or queued inside the horizon but not completed by it
    pub censored: usize,
    /// dropped at admission: full KV budget exceeds the cap
    pub kv_rejected: usize,
    pub horizon_s: f64,
    /// completed / horizon
    pub throughput: f64,
    /// completed / time of last completion (unbiased under early-ending
    /// arrival streams)
    pub throughput_completion: f64,
    /// completions per second that met the SLO (equals `throughput` when
    /// the SLO is disabled)
    pub goodput: f64,
    pub slo_s: f64,
    /// end-to-end latency of completed requests (p50/p95/p99 via Summary)
    pub latency: Summary,
    /// time to first token, measured from the request's ORIGINAL arrival to
    /// the first token it ever produced — recorded once per request, so an
    /// eviction + re-admission cannot overwrite it. Classic (unchunked)
    /// requests fire at prefill end; chunked requests fire on the first
    /// decode step after their last chunk.
    pub ttft: Summary,
    /// queue wait per admitted request: the SUM of its queueing episodes
    /// (arrival -> first admission, plus each eviction -> re-admission) —
    /// in-service time never counts as waiting
    pub queue_wait: Summary,
    /// inter-token latency: gaps between consecutive decode-step
    /// completions of the same slot within one residency — the in-flight
    /// decode stall metric chunked prefill improves (a monopolizing prefill
    /// shows up here as one giant gap for every in-flight slot)
    pub itl: Summary,
    /// queue wait accrued by censored requests up to the horizon
    pub censored_wait: Summary,
    /// (time, queued requests) samples taken at admission decisions
    pub queue_depth: Vec<(f64, usize)>,
    /// completion bars covering the whole horizon
    pub windows: Vec<usize>,
    /// the scheduler's full decision stream (admissions, prefill chunks,
    /// decode steps, completions, evictions, rejections) in order
    pub events: Vec<CbEvent>,
    /// prefill-chunk events emitted (0 when chunking is off or every
    /// prompt fit its admission chunk)
    pub prefill_chunks: usize,
    /// summed virtual cost of every evaluated prefill + decode step
    pub model_time: Breakdown,
    /// high-water mark of modeled in-flight KV bytes
    pub kv_peak_bytes: usize,
    /// the configured cap (0 = unlimited)
    pub kv_cap_bytes: usize,
    /// preemptions (KV pressure or SLO) resolved by recompute (slots
    /// requeued mid-decode and rebuilt from scratch)
    pub kv_evictions: usize,
    /// iterations where the backend's *actual* in-flight bytes exceeded
    /// the cap — must be zero; asserted by the live tests
    pub kv_violations: usize,
    /// admissions that attached to >= 1 shared block
    pub prefix_hits: usize,
    /// prompt tokens served from shared blocks instead of replay
    pub prefix_hit_tokens: usize,
    /// prompt tokens across all (re)admissions — the hit-rate denominator
    pub admitted_prompt_tokens: usize,
    /// modeled prefill FLOPs the covered tokens did not recompute
    pub recompute_flops_saved: f64,
    /// preemptions resolved by swapping to the host tier
    pub swap_outs: usize,
    /// swapped requests restored into slots
    pub swap_ins: usize,
    /// bytes moved over the host link, out plus in
    pub swap_bytes: usize,
    /// proactive SLO preemptions fired by the policy's per-iteration hook
    /// (each also counted in `kv_evictions` or `swap_outs` by how it was
    /// resolved); 0 under policies without the hook
    pub slo_preemptions: usize,
    /// per-priority-class breakdowns (empty when `CbConfig::classes` is)
    pub classes: Vec<ClassReport>,
    /// fleet replica id this report belongs to (0 for single-replica
    /// runs — the historical engine is replica 0 of a fleet of one)
    pub replica: usize,
    /// requests abandoned by their client and cancelled by the engine
    /// (`CbConfig::patience_s`); terminal — disjoint from completed,
    /// censored, and rejected
    pub cancelled: usize,
    /// tokens delivered after their client had already abandoned the
    /// stream ([`crate::workload::wasted_deliveries`] summed over all
    /// streams) — decode work burned for nobody; 0 with the client
    /// model off
    pub wasted_decode_tokens: usize,
    /// latency from a request's arrival to EACH delivered token, pooled
    /// over all requests — time-to-token, the streaming generalization
    /// of TTFT (empty with the client model off)
    pub time_to_token: Summary,
    /// per-request token delivery records, keyed by request id
    /// (populated only with the client model on — `patience_s > 0`)
    pub streams: BTreeMap<u64, TokenStream>,
    /// plan swaps executed by the online re-planner (`--replan-every`);
    /// 0 with re-planning off or on a uniform fleet
    pub replans: usize,
}

impl CbReport {
    /// Mean of the queue-depth samples (0 when nothing was ever queued).
    pub fn mean_queue_depth(&self) -> f64 {
        if self.queue_depth.is_empty() {
            return 0.0;
        }
        self.queue_depth.iter().map(|&(_, d)| d as f64).sum::<f64>()
            / self.queue_depth.len() as f64
    }

    /// Fraction of admitted prompt tokens served from shared KV blocks
    /// (0 when prefix caching is off or nothing was admitted).
    pub fn prefix_hit_rate(&self) -> f64 {
        if self.admitted_prompt_tokens == 0 {
            0.0
        } else {
            self.prefix_hit_tokens as f64 / self.admitted_prompt_tokens as f64
        }
    }
}
