//! Implementations of the `astra` CLI subcommands.

use std::path::Path;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::comm::trace::BandwidthTrace;
use crate::config::{shape_preset, vq_preset, RunConfig};
use crate::coordinator::Cluster;
use crate::model::shape::VqSetting;
use crate::parallel::strategies::{Strategy, StrategyKind};
use crate::server::batcher::Request;
use crate::server::cluster::{parse_route, ClusterEngine, ClusterReport, RouteKind};
use crate::server::live::{live_engine, LiveBackend};
use crate::server::policy::{parse_policy, PolicyKind};
use crate::server::scheduler::{CbConfig, CbEngine, CbEvent, CbReport};
use crate::sim::fault::FaultPlan;
use crate::sim::latency::{evaluate, SimParams};
use crate::tensor::Tensor;
use crate::util::cli::Args;
use crate::util::rng::Rng;
use crate::workload::{ArrivalProcess, PromptLengths, WorkloadSpec};

fn run_config(args: &Args) -> Result<RunConfig> {
    let mut c = match args.get("config") {
        Some(p) => RunConfig::from_file(Path::new(p))?,
        None => RunConfig::default(),
    };
    if let Some(d) = args.get("artifacts") {
        c.artifacts_dir = d.to_string();
    }
    c.n_devices = args.usize_or("devices", c.n_devices)?;
    c.bandwidth_mbps = args.f64_or("bandwidth", c.bandwidth_mbps)?;
    c.loss_rate = args.f64_or("loss", c.loss_rate)?;
    c.seed = args.usize_or("seed", c.seed as usize)? as u64;
    if let Some(split) = args.get("token-split") {
        c.token_split = split
            .split(',')
            .map(|s| s.trim().parse().context("bad --token-split"))
            .collect::<Result<_>>()?;
    }
    Ok(c)
}

fn synthetic_input(cluster: &Cluster, rng: &mut Rng) -> Result<Tensor> {
    let meta = &cluster.artifact.meta;
    if meta.causal {
        let ids: Vec<f32> = (0..meta.seq_len).map(|_| rng.below(meta.vocab_size) as f32).collect();
        Tensor::from_vec(&[meta.seq_len, 1], ids)
    } else {
        let mut x = Tensor::zeros(&[meta.seq_len, meta.patch_dim]);
        rng.fill_normal(&mut x.data);
        Ok(x)
    }
}

/// `astra run` — one prefill through the live cluster.
pub fn run_once(args: &Args) -> Result<()> {
    let config = run_config(args)?;
    let use_pjrt = !args.flag("native") && !args.flag("no-pjrt");
    let dir = config.artifacts_dir.clone();
    println!("loading artifacts from {dir} (pjrt={use_pjrt})...");
    let cluster = Cluster::load(Path::new(&dir), config, use_pjrt)?;
    let mut rng = Rng::new(cluster.config.seed);
    let x = synthetic_input(&cluster, &mut rng)?;

    let out = cluster.prefill(&x)?;
    let r = &out.report;
    println!("\n== ASTRA prefill ({} devices, {} Mbps) ==",
        cluster.config.n_devices, cluster.config.bandwidth_mbps);
    println!("virtual latency     {:>10.3} ms", r.latency_s * 1e3);
    println!("  compute           {:>10.3} ms", r.compute_s * 1e3);
    println!("  communication     {:>10.3} ms", r.comm_s * 1e3);
    println!("payload on wire     {:>10.1} kbit ({} messages)", r.payload_bits / 1e3, r.messages);
    println!("bits/token/block    {:>10.1}", r.bits_per_token_block);
    println!("FPAR                {:>10.4}", r.fpar);
    let k = out.logits.data.len().min(8);
    println!("logits[..{k}]       {:?}", &out.logits.data[..k]);

    let (base_logits, base_t) = cluster.prefill_single_device(&x)?;
    println!("\n== single-device baseline ==");
    println!("wall latency        {:>10.3} ms", base_t * 1e3);
    let diff = crate::tensor::max_abs_diff(&out.logits, &base_logits);
    println!("|ASTRA - baseline|  {:>10.4} max over logits (VQ approximation error)", diff);
    Ok(())
}

/// `astra serve` — synthetic request stream over the live cluster.
pub fn serve(args: &Args) -> Result<()> {
    let config = run_config(args)?;
    let n_requests = args.usize_or("requests", 16)?;
    let rate = args.f64_or("arrival-rate", 4.0)?;
    let use_pjrt = !args.flag("native") && !args.flag("no-pjrt");
    let dir = config.artifacts_dir.clone();
    let cluster = Cluster::load(Path::new(&dir), config, use_pjrt)?;
    let mut rng = Rng::new(cluster.config.seed);

    let mut lat = crate::util::stats::Summary::new();
    let mut vlat = crate::util::stats::Summary::new();
    let mut bits_total = 0.0;
    let t0 = Instant::now();
    let _ = rate; // open-loop pacing is virtual; requests run back-to-back
    for i in 0..n_requests {
        let x = synthetic_input(&cluster, &mut rng)?;
        let w0 = Instant::now();
        let out = cluster.prefill(&x)?;
        lat.add(w0.elapsed().as_secs_f64());
        vlat.add(out.report.latency_s);
        bits_total += out.report.payload_bits;
        if i == 0 {
            println!(
                "first request: virtual {:.2} ms, {} msgs, {:.0} bits/token/block",
                out.report.latency_s * 1e3,
                out.report.messages,
                out.report.bits_per_token_block
            );
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    println!("\n== serve report ({n_requests} requests, {} devices, {} Mbps) ==",
        cluster.config.n_devices, cluster.config.bandwidth_mbps);
    println!("virtual latency   mean {:.2} ms  p50 {:.2} ms  p95 {:.2} ms",
        vlat.mean() * 1e3, vlat.p50() * 1e3, vlat.p95() * 1e3);
    println!("host wall/request mean {:.2} ms (single-core execution of all {} devices)",
        lat.mean() * 1e3, cluster.config.n_devices);
    println!("virtual throughput {:.2} req/s", 1.0 / vlat.mean());
    println!("host throughput    {:.2} req/s", n_requests as f64 / wall);
    println!("total payload      {:.1} Mbit", bits_total / 1e6);
    Ok(())
}

/// Parse the scheduling-policy flags shared by the model and live
/// serve-cb paths: `--policy fifo|prefix-aware|slo-class|placement`,
/// `--classes d0,d1,...` (per-class deadlines, seconds; higher class
/// index = higher priority; ids map round-robin), `--age-bound S`
/// (reordering aging step). Setting `--classes` without `--policy`
/// implies `slo-class`.
///
/// The heterogeneous-fleet flags ride the same paths:
/// `--device-speeds w0,w1,...` (relative per-device speed; unset or all
/// equal = the legacy uniform fleet, bit for bit) and `--replan-every S`
/// (online re-planning period, seconds; 0 = the initial profile-weighted
/// plan is pinned for the whole run).
fn policy_from_args(args: &Args) -> Result<(PolicyKind, Vec<f64>, f64)> {
    let classes = args.f64_list_or("classes", &[])?;
    let policy = match args.get("policy") {
        Some(p) => parse_policy(p)?,
        None if !classes.is_empty() => PolicyKind::SloClass,
        None => PolicyKind::Fifo,
    };
    Ok((policy, classes, args.f64_or("age-bound", 0.5)?))
}

/// Parse the client-model knobs shared by the serve-cb paths into an
/// existing config: `--patience S` (mean client patience before a stalled
/// request is abandoned; 0 = infinitely patient clients, the exact legacy
/// code path), `--patience-spread F` (log-uniform per-request spread
/// around the mean), `--length-tail A` (bounded-Pareto decode-length tail
/// exponent; 0 = every request wants the full budget), and
/// `--slo-preempt-cost S` (per-iteration budget, in modeled seconds, for
/// pricing proactive SLO evictions through the swap policy; 0 = unpriced).
fn client_model_from_args(args: &Args, cfg: &mut CbConfig) -> Result<()> {
    cfg.patience_s = args.f64_or("patience", 0.0)?;
    cfg.patience_spread = args.f64_or("patience-spread", 0.0)?;
    cfg.length_tail_alpha = args.f64_or("length-tail", 0.0)?;
    cfg.slo_preempt_cost_s = args.f64_or("slo-preempt-cost", 0.0)?;
    Ok(())
}

/// Parse the generative-trace flags into a [`WorkloadSpec`], or `None`
/// for the classic fixed-rate configuration (served by the legacy
/// generators, bit for bit): `--arrivals poisson|diurnal|bursty` picks
/// the process (`--rate` is the base/lo rate, `--peak-rate` the ceiling,
/// default 3x the base), `--period S` the diurnal period (default: the
/// horizon), `--burst-states K` / `--dwell S` the Markov burst chain, and
/// `--tenants w0,w1,...` layers a weighted multi-tenant mix onto the ids
/// (tenant k lands in QoS class k under `--classes`).
fn workload_from_args(
    args: &Args,
    seed: u64,
    rate: f64,
    horizon_s: f64,
    prompts: PromptLengths,
) -> Result<Option<WorkloadSpec>> {
    let kind = args.get_or("arrivals", "poisson");
    let tenants = args.f64_list_or("tenants", &[])?;
    if kind == "poisson" && tenants.is_empty() {
        return Ok(None);
    }
    let peak = args.f64_or("peak-rate", 3.0 * rate)?;
    let process = match kind.as_str() {
        "poisson" => ArrivalProcess::Poisson { rate },
        "diurnal" => ArrivalProcess::Diurnal {
            base_rate: rate,
            peak_rate: peak,
            period_s: args.f64_or("period", horizon_s)?,
        },
        "bursty" => ArrivalProcess::MarkovBursts {
            lo_rate: rate,
            hi_rate: peak,
            states: args.usize_or("burst-states", 5)?,
            dwell_s: args.f64_or("dwell", 2.0)?,
        },
        other => anyhow::bail!("unknown --arrivals `{other}` (poisson|diurnal|bursty)"),
    };
    Ok(Some(WorkloadSpec { seed, horizon_s, process, prompts, tenant_weights: tenants }))
}

/// Client-model report row (printed only when the run produced client
/// outcomes — cancellations, wasted tokens, or delivery timestamps).
fn print_client_rows(r: &mut CbReport) {
    if r.cancelled == 0 && r.wasted_decode_tokens == 0 && r.time_to_token.is_empty() {
        return;
    }
    let (p50, p95) = if r.time_to_token.is_empty() {
        (0.0, 0.0)
    } else {
        (r.time_to_token.p50(), r.time_to_token.p95())
    };
    println!(
        "clients   cancelled {:>5}  wasted decode tokens {:>6}  \
         time-to-token p50 {:>7.1} ms  p95 {:>7.1} ms",
        r.cancelled,
        r.wasted_decode_tokens,
        p50 * 1e3,
        p95 * 1e3
    );
}

/// Parse `--route-policy` (fleet request routing; default round-robin).
fn route_from_args(args: &Args) -> Result<RouteKind> {
    let name = args.get_or("route-policy", "round-robin");
    parse_route(&name).with_context(|| {
        format!(
            "unknown --route-policy `{name}` \
             (round-robin|least-loaded|prefix-affinity|placement)"
        )
    })
}

/// Per-class report rows (printed only when classes are configured).
fn print_class_rows(r: &mut CbReport) {
    let horizon = r.horizon_s;
    for c in &mut r.classes {
        println!(
            "class {}  (deadline {:>6.2} s): completed {:>5}  censored {:>5}  \
             attainment {:>5.1}%  p95 {:>8.1} ms  goodput {:.2}/s",
            c.class,
            c.deadline_s,
            c.completed,
            c.censored,
            c.slo_attainment() * 100.0,
            c.latency.p95() * 1e3,
            c.goodput(horizon),
        );
    }
}

/// Parse `--strategy` (+ `--nb`, `--vq`) into a [`StrategyKind`].
fn strategy_kind_from_args(args: &Args) -> Result<StrategyKind> {
    Ok(match args.get_or("strategy", "astra").as_str() {
        "single" => StrategyKind::SingleDevice,
        "tp" => StrategyKind::TensorParallel,
        "sp" => StrategyKind::SequenceParallel,
        "bp-ag" => StrategyKind::BlockParallel {
            n_b: args.usize_or("nb", 1)?,
            sp_variant: false,
        },
        "bp-sp" => StrategyKind::BlockParallel {
            n_b: args.usize_or("nb", 1)?,
            sp_variant: true,
        },
        "astra" => StrategyKind::Astra {
            vq: match args.get("vq") {
                Some(v) => vq_preset(v)?,
                None => VqSetting::new(16, 1024),
            },
        },
        other => anyhow::bail!("unknown strategy `{other}`"),
    })
}

/// `astra serve-cb` — continuous-batching load test on the cost model,
/// with the batch-1 FIFO baseline run on the same arrival stream.
/// With `--live`, drives real `DecodeSession`s instead (see
/// [`serve_cb_live`]).
pub fn serve_cb(args: &Args) -> Result<()> {
    if args.flag("live") {
        return serve_cb_live(args);
    }
    let model = args.get_or("model", "vit-base");
    let tokens = args.usize_or("tokens", 1024)?;
    let n = args.usize_or("devices", 4)?;
    let bw = args.f64_or("bandwidth", 100.0)?;
    let rate = args.f64_or("rate", 8.0)?;
    let horizon = args.f64_or("horizon", 120.0)?;
    let seed = args.usize_or("seed", 42)? as u64;
    let shape = shape_preset(&model, tokens)?;
    let params = if model == "llama3-8b" {
        SimParams::paper_llama()
    } else {
        SimParams::paper_encoder()
    };
    let strategy = Strategy::new(strategy_kind_from_args(args)?, n);
    let trace = match args.get_or("trace", "constant").as_str() {
        "constant" => BandwidthTrace::constant(bw, 1e9),
        // markov trace honours --bandwidth as its ceiling, dipping to 20%
        // of it (the paper's 20-100 Mbps shape at the default 100)
        "markov" => {
            let mut rng = Rng::new(seed);
            BandwidthTrace::markovian(&mut rng, 0.2 * bw, bw, 9, 1.0, horizon)
        }
        other => anyhow::bail!("unknown trace `{other}` (constant|markov)"),
    };
    let (policy, classes, age_bound_s) = policy_from_args(args)?;
    let mut cfg = CbConfig {
        max_slots: args.usize_or("slots", 8)?,
        max_batch: args.usize_or("max-batch", 8)?,
        max_wait_s: args.f64_or("max-wait", 0.02)?,
        decode_tokens: args.usize_or("decode-tokens", 64)?,
        slo_s: args.f64_or("slo", 2.0)?,
        window_s: 10.0,
        kv_cap_bytes: args.usize_or("kv-cap", 0)?,
        prefill_chunk_tokens: args.usize_or("chunk-tokens", 0)?,
        prefix_cache: args.flag("prefix-cache"),
        kv_block_tokens: args.usize_or("kv-block-tokens", 16)?,
        swap_bandwidth_mbps: args.f64_or("swap-bandwidth-mbps", 0.0)?,
        decode_jitter: args.usize_or("decode-jitter", 0)?,
        prompt_groups: args.usize_or("prompt-groups", 0)?,
        checkpoint_every: args.usize_or("checkpoint-every", 0)?,
        serial_decode: args.flag("serial-decode"),
        copy_engine: args.flag("copy-engine"),
        seed,
        prompt_vocab: 256,
        policy,
        classes,
        age_bound_s,
        slo_preempt_budget: args.usize_or("slo-preempt-budget", 1)?,
        device_speeds: args.f64_list_or("device-speeds", &[])?,
        replan_every_s: args.f64_or("replan-every", 0.0)?,
        ..CbConfig::default()
    };
    client_model_from_args(args, &mut cfg)?;
    let workload =
        workload_from_args(args, seed, rate, horizon, PromptLengths::Fixed(shape.seq_len))?;
    let replicas = args.usize_or("replicas", 1)?;
    if replicas > 1 {
        let proto = CbEngine::new(shape, strategy, params, trace, cfg);
        return serve_cb_fleet(args, proto, rate, horizon, seed, replicas, workload);
    }

    println!(
        "== serve-cb: {} on {model} T={tokens} N={n}, {} trace, {} arrivals, \
         rate {rate}/s, {horizon} s ==",
        strategy.name(),
        args.get_or("trace", "constant"),
        args.get_or("arrivals", "poisson"),
    );
    let mut rows = Vec::new();
    for (mode, cfg) in [("fifo-b1", cfg.clone().batch1()), ("cont-batch", cfg)] {
        let mut engine =
            CbEngine::new(shape, strategy, params.clone(), trace.clone(), cfg.clone());
        let mut r = match &workload {
            Some(spec) => engine.serve_stream(spec.generate(), horizon),
            None => engine.serve_poisson(&mut Rng::new(seed), rate, horizon),
        };
        println!(
            "-- {mode} (slots={}, batch<={}, {} decode tokens, SLO {:.1} s, policy {:?}{}) --",
            cfg.max_slots,
            cfg.max_batch,
            cfg.decode_tokens,
            cfg.slo_s,
            cfg.policy,
            if cfg.prefill_chunk_tokens > 0 {
                format!(", chunked prefill @{} tokens", cfg.prefill_chunk_tokens)
            } else {
                String::new()
            },
        );
        println!(
            "completed {:>6}   censored {:>6}   throughput {:.2}/s (horizon) {:.2}/s (completion)",
            r.completed, r.censored, r.throughput, r.throughput_completion
        );
        println!(
            "latency   p50 {:>8.1} ms  p95 {:>8.1} ms  p99 {:>8.1} ms",
            r.latency.p50() * 1e3, r.latency.p95() * 1e3, r.latency.p99() * 1e3
        );
        println!(
            "TTFT      p50 {:>8.1} ms  p95 {:>8.1} ms   queue depth mean {:.1}",
            r.ttft.p50() * 1e3, r.ttft.p95() * 1e3, r.mean_queue_depth()
        );
        if !r.itl.is_empty() {
            println!(
                "ITL       p50 {:>8.1} ms  p95 {:>8.1} ms   prefill chunks {}",
                r.itl.p50() * 1e3,
                r.itl.p95() * 1e3,
                r.prefill_chunks
            );
        }
        if cfg.prefix_cache {
            println!(
                "prefix    {} hits, {:.1}% of admitted prompt tokens shared, \
                 ~{:.1} GFLOP recompute saved",
                r.prefix_hits,
                r.prefix_hit_rate() * 100.0,
                r.recompute_flops_saved / 1e9
            );
        }
        if cfg.swap_bandwidth_mbps > 0.0 && cfg.kv_cap_bytes > 0 {
            println!(
                "swap      {} out / {} in, {:.1} KiB over the host link \
                 ({} recompute evictions)",
                r.swap_outs,
                r.swap_ins,
                r.swap_bytes as f64 / 1024.0,
                r.kv_evictions
            );
        }
        println!("goodput   {:.2}/s within SLO", r.goodput);
        if r.slo_preemptions > 0 {
            println!("SLO preemptions {}", r.slo_preemptions);
        }
        if r.replans > 0 {
            println!("re-plans  {} plan swaps (--replan-every)", r.replans);
        }
        print_client_rows(&mut r);
        print_class_rows(&mut r);
        // model-path smoke invariants (`--assert-invariants`, mirroring
        // the live checklist): every serve mode completes work and the
        // modeled KV accounting never exceeds its cap
        if args.flag("assert-invariants") {
            anyhow::ensure!(r.completed > 0, "model smoke ({mode}): nothing completed");
            anyhow::ensure!(
                r.kv_violations == 0,
                "model smoke ({mode}): {} KV violations",
                r.kv_violations
            );
        }
        rows.push((mode, r.completed));
    }
    if let [(_, fifo), (_, cb)] = rows[..] {
        if fifo > 0 {
            println!("\ncontinuous batching completed {:.2}x the batch-1 FIFO total",
                cb as f64 / fifo as f64);
        }
    }
    if args.flag("assert-invariants") {
        println!("model smoke invariants hold: completions in every mode, zero KV violations");
    }
    Ok(())
}

/// `astra serve-cb --live` — the live continuous-batching path: real
/// `coordinator::DecodeSession`s (actual tensors, mixed-precision KV
/// caches, greedy decode) driven through the slot scheduler. Loads a
/// decoder bundle from `--artifacts` when one exists; otherwise builds a
/// synthetic tiny decoder in memory so the path runs anywhere (the CI
/// smoke job relies on this). Exits non-zero if the run violates the KV
/// cap or completes requests without real generations — the smoke
/// invariants.
pub fn serve_cb_live(args: &Args) -> Result<()> {
    let config = run_config(args)?;
    let dir = config.artifacts_dir.clone();
    let cluster = match Cluster::load(Path::new(&dir), config.clone(), false) {
        Ok(c) if c.artifact.meta.causal => {
            println!("loaded decoder artifacts from {dir}");
            c
        }
        _ => {
            println!("(no decoder artifacts at {dir}; using a synthetic tiny decoder)");
            let n = config.n_devices.max(1);
            let shape = crate::model::TransformerShape {
                n_layers: 2,
                d_model: 32,
                n_heads: 4,
                d_ff: 64,
                seq_len: 8 * n,
                elem_bytes: 4,
            };
            let seed = config.seed;
            Cluster::synthetic_decoder(&shape, 64, VqSetting::new(4, 16), config, seed)?
        }
    };
    let meta = cluster.artifact.meta.clone();
    let rate = args.f64_or("rate", 8.0)?;
    let horizon = args.f64_or("horizon", 30.0)?;
    let (policy, classes, age_bound_s) = policy_from_args(args)?;
    let mut cfg = CbConfig {
        max_slots: args.usize_or("slots", 4)?,
        max_batch: args.usize_or("max-batch", 4)?,
        max_wait_s: args.f64_or("max-wait", 0.02)?,
        decode_tokens: args.usize_or("decode-tokens", 8)?,
        slo_s: args.f64_or("slo", 0.0)?,
        window_s: 10.0,
        kv_cap_bytes: args.usize_or("kv-cap", 0)?,
        prefill_chunk_tokens: args.usize_or("chunk-tokens", 0)?,
        prefix_cache: args.flag("prefix-cache"),
        kv_block_tokens: args.usize_or("kv-block-tokens", 4)?,
        swap_bandwidth_mbps: args.f64_or("swap-bandwidth-mbps", 0.0)?,
        decode_jitter: args.usize_or("decode-jitter", 0)?,
        prompt_groups: args.usize_or("prompt-groups", 0)?,
        checkpoint_every: args.usize_or("checkpoint-every", 0)?,
        serial_decode: args.flag("serial-decode"),
        copy_engine: args.flag("copy-engine"),
        policy,
        classes,
        age_bound_s,
        slo_preempt_budget: args.usize_or("slo-preempt-budget", 1)?,
        device_speeds: args.f64_list_or("device-speeds", &[])?,
        replan_every_s: args.f64_or("replan-every", 0.0)?,
        // seed + prompt_vocab are pinned to the cluster by `live_engine`
        ..CbConfig::default()
    };
    client_model_from_args(args, &mut cfg)?;
    let workload = workload_from_args(
        args,
        cluster.config.seed,
        rate,
        horizon,
        PromptLengths::UniformHalf(meta.seq_len),
    )?;
    let arrivals = match &workload {
        Some(spec) => spec.generate(),
        None => crate::server::live::live_arrivals(
            &mut Rng::new(cluster.config.seed),
            rate,
            horizon,
            meta.seq_len,
        ),
    };
    let replicas = args.usize_or("replicas", 1)?;
    if replicas > 1 {
        return serve_cb_live_fleet(args, &cluster, &cfg, arrivals, horizon, replicas);
    }
    let n_arrivals = arrivals.len();
    let params = SimParams::paper_encoder();
    let trace = BandwidthTrace::constant(cluster.config.bandwidth_mbps, 1e9);
    // the decode budget each request is owed (jitter-aware, seed-pinned) —
    // the "full generations" invariant checks against this per id
    let probe =
        crate::server::live::live_engine(&cluster, cfg.clone(), params.clone(), trace.clone());
    let wall0 = Instant::now();
    let live =
        crate::server::live::serve_live(&cluster, cfg.clone(), params, trace, arrivals, horizon)?;
    let wall = wall0.elapsed().as_secs_f64();

    let mut r = live.report;
    println!(
        "\n== serve-cb --live: {} devices, T<= {}, {} Mbps, {} slots, {} decode tokens{} ==",
        cluster.config.n_devices,
        meta.seq_len,
        cluster.config.bandwidth_mbps,
        cfg.max_slots,
        cfg.decode_tokens,
        if cfg.prefill_chunk_tokens > 0 {
            format!(", chunked prefill @{} tokens ({} chunks)",
                cfg.prefill_chunk_tokens, r.prefill_chunks)
        } else {
            String::new()
        },
    );
    println!(
        "arrivals {n_arrivals}   completed {}   censored {}   rejected {}",
        r.completed, r.censored, r.kv_rejected
    );
    println!(
        "virtual latency p50 {:>8.1} ms  p95 {:>8.1} ms   TTFT p50 {:>8.1} ms",
        r.latency.p50() * 1e3, r.latency.p95() * 1e3, r.ttft.p50() * 1e3
    );
    println!(
        "virtual cost: compute {:.1} ms + comm {:.1} ms over {} events",
        r.model_time.compute_s * 1e3, r.model_time.comm_s * 1e3, r.events.len()
    );
    println!(
        "live execution: {} real decode steps, host compute {:.1} ms, wall {:.2} s",
        live.live_steps, live.host_compute_s * 1e3, wall
    );
    if r.kv_cap_bytes > 0 {
        println!(
            "KV budget: peak {} / cap {} bytes, {} evictions, {} violations",
            r.kv_peak_bytes, r.kv_cap_bytes, r.kv_evictions, r.kv_violations
        );
    }
    if cfg.prefix_cache {
        println!(
            "prefix cache: {} hits, {} prompt tokens shared = {:.1}% of admitted \
             ({} block tokens, {} groups)",
            r.prefix_hits,
            r.prefix_hit_tokens,
            r.prefix_hit_rate() * 100.0,
            cfg.kv_block_tokens,
            cfg.prompt_groups
        );
    }
    if cfg.swap_bandwidth_mbps > 0.0 && cfg.kv_cap_bytes > 0 {
        println!(
            "swap preemption: {} out / {} in, {} bytes over the {} Mbps host link, \
             {} recompute evictions",
            r.swap_outs, r.swap_ins, r.swap_bytes, cfg.swap_bandwidth_mbps, r.kv_evictions
        );
    }
    if cfg.policy != PolicyKind::Fifo || !cfg.classes.is_empty() {
        println!("scheduling policy {:?}: {} SLO preemptions", cfg.policy, r.slo_preemptions);
        print_class_rows(&mut r);
    }
    if !cfg.device_speeds.is_empty() {
        println!(
            "heterogeneous fleet {:?}: {} re-plans (--replan-every {})",
            cfg.device_speeds, r.replans, cfg.replan_every_s
        );
    }
    print_client_rows(&mut r);
    if let Some((id, toks)) = live.generations.iter().find(|(_, t)| !t.is_empty()) {
        let k = toks.len().min(8);
        println!("sample generation (request {id}): {:?}", &toks[..k]);
    }

    // smoke invariants: the live path must really generate, within the
    // cap, with sane first-token accounting. Each is evaluated
    // independently so a failing run names exactly what broke
    // (`--assert-invariants` prints the checklist even on success).
    let partial = live
        .generations
        .iter()
        .filter(|(id, t)| t.len() != probe.decode_budget(*id))
        .count();
    let admitted: std::collections::BTreeSet<u64> = r
        .events
        .iter()
        .flat_map(|e| match e {
            CbEvent::Admit { ids } => ids.clone(),
            _ => Vec::new(),
        })
        .collect();
    let invariants: Vec<(&str, bool, String)> = vec![
        (
            "completed > 0",
            r.completed > 0,
            format!("{} of {n_arrivals} arrivals completed inside the horizon", r.completed),
        ),
        (
            "full generations",
            cfg.decode_tokens == 0 || partial == 0,
            format!(
                "{partial} of {} completed requests lack their {}-token generation",
                live.generations.len(),
                cfg.decode_tokens
            ),
        ),
        (
            "zero kv_violations",
            r.kv_violations == 0,
            format!(
                "live session bytes exceeded the KV cap in {} iterations",
                r.kv_violations
            ),
        ),
        (
            "zero TTFT anomalies",
            !r.ttft.is_empty()
                && r.ttft.min() >= 0.0
                && r.ttft.max().is_finite()
                && r.ttft.len() <= admitted.len(),
            format!(
                "{} TTFT samples over {} distinct admitted requests (min {:.4}, max {:.4}): \
                 every sample must be finite, non-negative, and recorded at most once",
                r.ttft.len(),
                admitted.len(),
                r.ttft.min(),
                r.ttft.max()
            ),
        ),
    ];
    let failed: Vec<&str> = invariants.iter().filter(|t| !t.1).map(|t| t.0).collect();
    if args.flag("assert-invariants") || !failed.is_empty() {
        println!("\nsmoke invariants:");
        for (name, ok, detail) in &invariants {
            println!("  [{}] {name}: {detail}", if *ok { "ok" } else { "FAIL" });
        }
    }
    anyhow::ensure!(failed.is_empty(), "smoke invariants violated: {}", failed.join(", "));
    println!("smoke invariants hold: full generations, zero KV violations, sane TTFT");
    Ok(())
}

/// `astra serve-cb --replicas N` on the cost model: N clones of the
/// configured engine under the deterministic cluster event loop, with
/// `--route-policy` deciding which replica each arrival joins and
/// `--drain-at S` optionally removing replica 0 mid-run.
fn serve_cb_fleet(
    args: &Args,
    proto: CbEngine,
    rate: f64,
    horizon: f64,
    seed: u64,
    replicas: usize,
    workload: Option<WorkloadSpec>,
) -> Result<()> {
    let route = route_from_args(args)?;
    let seq_len = proto.shape.seq_len;
    let engines: Vec<CbEngine> = (0..replicas).map(|_| proto.clone()).collect();
    let mut fleet = ClusterEngine::new(engines, route);
    if args.get("drain-at").is_some() {
        fleet = fleet.with_drain(0, args.f64_or("drain-at", 0.0)?);
    }
    if let Some(fs) = args.get("fault-seed") {
        let fs: u64 = fs.parse().context("bad --fault-seed")?;
        fleet = fleet.with_faults(FaultPlan::seeded(fs, replicas, horizon));
    }
    let arrivals = match &workload {
        Some(spec) => spec.generate(),
        None => crate::server::batcher::poisson_arrivals(
            &mut Rng::new(seed),
            rate,
            horizon,
            seq_len,
        ),
    };
    let n_arrivals = arrivals.len();
    let mut report = fleet.serve_stream(arrivals, horizon)?;

    println!(
        "== serve-cb fleet: {replicas} replicas, {} routing, rate {rate}/s, {horizon} s ==",
        route.name(),
    );
    println!("arrivals {n_arrivals}");
    print_fleet_report(&mut report);
    if args.flag("assert-invariants") {
        assert_fleet_invariants(n_arrivals, &report)?;
    }
    Ok(())
}

/// `astra serve-cb --live --replicas N`: N engine replicas each driving
/// its own real [`LiveBackend`] (all sharing the loaded cluster's
/// weights) under the cluster event loop and `--route-policy`.
fn serve_cb_live_fleet(
    args: &Args,
    cluster: &Cluster,
    cfg: &CbConfig,
    arrivals: Vec<Request>,
    horizon: f64,
    replicas: usize,
) -> Result<()> {
    let route = route_from_args(args)?;
    let params = SimParams::paper_encoder();
    let trace = BandwidthTrace::constant(cluster.config.bandwidth_mbps, 1e9);
    let engines: Vec<CbEngine> = (0..replicas)
        .map(|_| live_engine(cluster, cfg.clone(), params.clone(), trace.clone()))
        .collect();
    // the pinned config (seed + prompt_vocab from the cluster), so every
    // backend derives the same prompt streams as the schedulers
    let pinned = engines[0].cfg.clone();
    let mut backends: Vec<LiveBackend> =
        (0..replicas).map(|_| LiveBackend::for_config(cluster, &pinned)).collect();
    let mut fleet = ClusterEngine::new(engines, route);
    if args.get("drain-at").is_some() {
        fleet = fleet.with_drain(0, args.f64_or("drain-at", 0.0)?);
    }
    if let Some(fs) = args.get("fault-seed") {
        let fs: u64 = fs.parse().context("bad --fault-seed")?;
        fleet = fleet.with_faults(FaultPlan::seeded(fs, replicas, horizon));
    }
    let n_arrivals = arrivals.len();
    let wall0 = Instant::now();
    let mut report = fleet.serve_stream_with(&mut backends, arrivals, horizon)?;
    let wall = wall0.elapsed().as_secs_f64();

    println!(
        "\n== serve-cb --live fleet: {replicas} replicas x {} devices, {} routing, {horizon} s ==",
        cluster.config.n_devices,
        route.name(),
    );
    println!("arrivals {n_arrivals}   wall {wall:.2} s");
    print_fleet_report(&mut report);
    let steps: usize = backends.iter().map(|b| b.steps).sum();
    let host_s: f64 = backends.iter().map(|b| b.host_compute_s).sum();
    println!("live execution: {steps} real decode steps, host compute {:.1} ms", host_s * 1e3);
    if args.flag("assert-invariants") {
        assert_fleet_invariants(n_arrivals, &report)?;
    }
    Ok(())
}

/// Per-replica rows plus the fleet rollups shared by the model and live
/// fleet paths.
fn print_fleet_report(report: &mut ClusterReport) {
    let routed = report.routed.clone();
    let drained = report.drained;
    let killed = report.killed.clone();
    for r in &mut report.replicas {
        let mark = if drained == Some(r.replica) {
            "  (drained)"
        } else if killed.contains(&r.replica) {
            "  (killed)"
        } else {
            ""
        };
        println!(
            "replica {}  routed {:>5}  completed {:>5}  censored {:>4}  p95 {:>8.1} ms  \
             hit {:>5.1}%{mark}",
            r.replica,
            routed[r.replica],
            r.completed,
            r.censored,
            r.latency.p95() * 1e3,
            r.prefix_hit_rate() * 100.0,
        );
    }
    println!(
        "fleet      completed {}  censored {}  throughput {:.2}/s  goodput {:.2}/s",
        report.completed(),
        report.censored(),
        report.fleet_throughput(),
        report.fleet_goodput()
    );
    let unrouted = if report.unrouted > 0 {
        format!("  ({} unrouted)", report.unrouted)
    } else {
        String::new()
    };
    println!(
        "fleet      p95 {:.1} ms  hit rate {:.1}%  load skew {:.2}{unrouted}",
        report.fleet_p95() * 1e3,
        report.fleet_hit_rate() * 100.0,
        report.load_skew(),
    );
    if report.cancelled() > 0 || report.wasted_decode_tokens() > 0 {
        println!(
            "clients    cancelled {}  wasted decode tokens {}",
            report.cancelled(),
            report.wasted_decode_tokens()
        );
    }
    if !report.killed.is_empty() || report.restored > 0 || report.replayed > 0 {
        println!(
            "chaos      killed {:?}  recovered {} from checkpoints, {} replayed from prompt",
            report.killed, report.restored, report.replayed
        );
    }
    if let Some(victim) = report.drain_skipped {
        println!(
            "warning: drain of replica {victim} skipped — it was the last live replica, \
             so its queue had nowhere to spill"
        );
    }
    for victim in &report.kills_skipped {
        println!(
            "warning: kill of replica {victim} skipped — already dead, out of range, \
             or the last live replica"
        );
    }
}

/// Fleet smoke invariants (`--assert-invariants`): work completed, plus
/// the chaos checklist from [`crate::server::chaos::chaos_invariants`] —
/// no request lost or double-completed even across drains and kills,
/// no double-rejects, zero KV violations fleet-wide. The checklist holds
/// for faultless runs too, so every fleet smoke job exercises it.
fn assert_fleet_invariants(n_arrivals: usize, report: &ClusterReport) -> Result<()> {
    let mut invariants: Vec<(&str, bool, String)> = vec![(
        "fleet completed > 0",
        report.completed() > 0,
        format!("{} completions across the fleet", report.completed()),
    )];
    invariants.extend(crate::server::chaos::chaos_invariants(n_arrivals, report));
    let failed: Vec<&str> = invariants.iter().filter(|t| !t.1).map(|t| t.0).collect();
    println!("\nfleet invariants:");
    for (name, ok, detail) in &invariants {
        println!("  [{}] {name}: {detail}", if *ok { "ok" } else { "FAIL" });
    }
    anyhow::ensure!(failed.is_empty(), "fleet invariants violated: {}", failed.join(", "));
    Ok(())
}

/// `astra soak` — the VOPR-style chaos soak on the cost model: for each
/// of `--seeds` consecutive fault seeds (base `--fault-seed`, default 0),
/// build a seeded [`FaultPlan`] over a `--replicas` fleet, run the same
/// Poisson workload through it, and check the full chaos invariant
/// checklist. Any violation aborts with the failing seed in the error —
/// deterministic plans make that seed a standalone repro
/// (`astra serve-cb --replicas N --fault-seed S --assert-invariants`).
pub fn soak(args: &Args) -> Result<()> {
    let seeds = args.usize_or("seeds", 100)?;
    let replicas = args.usize_or("replicas", 4)?;
    let model = args.get_or("model", "vit-base");
    let tokens = args.usize_or("tokens", 1024)?;
    let n = args.usize_or("devices", 4)?;
    let bw = args.f64_or("bandwidth", 100.0)?;
    let rate = args.f64_or("rate", 8.0)?;
    let horizon = args.f64_or("horizon", 10.0)?;
    let seed = args.usize_or("seed", 42)? as u64;
    let base = args.usize_or("fault-seed", 0)? as u64;
    let shape = shape_preset(&model, tokens)?;
    let params = if model == "llama3-8b" {
        SimParams::paper_llama()
    } else {
        SimParams::paper_encoder()
    };
    let strategy = Strategy::new(strategy_kind_from_args(args)?, n);
    let trace = BandwidthTrace::constant(bw, 1e9);
    let mut cfg = CbConfig {
        max_slots: args.usize_or("slots", 8)?,
        max_batch: args.usize_or("max-batch", 8)?,
        decode_tokens: args.usize_or("decode-tokens", 16)?,
        kv_cap_bytes: args.usize_or("kv-cap", 0)?,
        kv_block_tokens: args.usize_or("kv-block-tokens", 16)?,
        swap_bandwidth_mbps: args.f64_or("swap-bandwidth-mbps", 0.0)?,
        checkpoint_every: args.usize_or("checkpoint-every", 0)?,
        seed,
        ..CbConfig::default()
    };
    client_model_from_args(args, &mut cfg)?;
    let route = route_from_args(args)?;
    let proto = CbEngine::new(shape, strategy, params, trace, cfg);
    let seq_len = proto.shape.seq_len;
    let workload =
        workload_from_args(args, seed, rate, horizon, PromptLengths::Fixed(seq_len))?;

    println!(
        "== soak: {seeds} seeds x {replicas} replicas, rate {rate}/s, {horizon} s, \
         fault seeds {base}..{} ==",
        base + seeds as u64
    );
    let (mut kills, mut restores, mut replays, mut faultless) = (0usize, 0usize, 0usize, 0usize);
    for s in 0..seeds as u64 {
        let plan = FaultPlan::seeded(base + s, replicas, horizon);
        if plan.is_empty() {
            faultless += 1;
        }
        let engines: Vec<CbEngine> = (0..replicas).map(|_| proto.clone()).collect();
        let mut fleet = ClusterEngine::new(engines, route).with_faults(plan);
        let arrivals = match &workload {
            Some(spec) => spec.generate(),
            None => crate::server::batcher::poisson_arrivals(
                &mut Rng::new(seed),
                rate,
                horizon,
                seq_len,
            ),
        };
        let n_arrivals = arrivals.len();
        let report = fleet
            .serve_stream(arrivals, horizon)
            .with_context(|| format!("soak run failed at fault seed {}", base + s))?;
        crate::server::chaos::assert_chaos_invariants(n_arrivals, &report)
            .with_context(|| format!("soak invariants broken at fault seed {}", base + s))?;
        kills += report.killed.len();
        restores += report.restored;
        replays += report.replayed;
        if (s + 1) % 25 == 0 {
            println!(
                "  {}/{seeds} seeds clean ({kills} kills, {restores} restores, {replays} replays)",
                s + 1
            );
        }
    }
    println!(
        "soak clean: {seeds} seeds, {kills} replica kills survived, \
         {restores} checkpoint restores, {replays} prompt replays, {faultless} faultless plans"
    );
    anyhow::ensure!(
        kills > 0 || replicas < 2,
        "soak exercised no kills over {seeds} seeds — widen the seed range"
    );
    Ok(())
}

/// `astra simulate` — cost-model latency point.
pub fn simulate(args: &Args) -> Result<()> {
    let model = args.get_or("model", "vit-base");
    let tokens = args.usize_or("tokens", 1024)?;
    let n = args.usize_or("devices", 4)?;
    let bw = args.f64_or("bandwidth", 100.0)?;
    let shape = shape_preset(&model, tokens)?;
    let params = if model == "llama3-8b" {
        SimParams::paper_llama()
    } else {
        SimParams::paper_encoder()
    };
    let strat = Strategy::new(strategy_kind_from_args(args)?, n);
    let single = Strategy::new(StrategyKind::SingleDevice, 1);
    let bd = evaluate(&strat.schedule(&shape), &params, bw);
    let bd_single = evaluate(&single.schedule(&shape), &params, bw);
    println!("model={model} T={tokens} N={n} bandwidth={bw} Mbps strategy={}", strat.name());
    println!("latency   {:>10.2} ms  (compute {:.2} ms, comm {:.2} ms, comm {:.1}%)",
        bd.total() * 1e3, bd.compute_s * 1e3, bd.comm_s * 1e3, bd.comm_fraction() * 100.0);
    println!("single    {:>10.2} ms", bd_single.total() * 1e3);
    println!("speedup   {:>10.2}x", bd_single.total() / bd.total());
    Ok(())
}

/// `astra calibrate` — measure this host's effective FLOP/s on the block
/// shapes, for feeding a custom DeviceModel.
pub fn calibrate(args: &Args) -> Result<()> {
    let d = args.usize_or("dim", 256)?;
    let t = args.usize_or("tokens", 128)?;
    let mut rng = Rng::new(0);
    let blk = crate::model::native::BlockWeights::random(&mut rng, d, 4 * d);
    let mut x = Tensor::zeros(&[t, d]);
    rng.fill_normal(&mut x.data);
    let flops = crate::model::TransformerShape {
        n_layers: 1, d_model: d, n_heads: 4, d_ff: 4 * d, seq_len: t, elem_bytes: 4,
    }
    .block_flops(t, t);
    // warmup + timed loop
    for _ in 0..2 {
        crate::model::native::baseline_block(&x, None, &blk, 4)?;
    }
    let t0 = Instant::now();
    let iters = 10;
    for _ in 0..iters {
        crate::model::native::baseline_block(&x, None, &blk, 4)?;
    }
    let per = t0.elapsed().as_secs_f64() / iters as f64;
    println!("native block [{t}x{d}] : {:.3} ms/block, {:.2} GFLOP/s", per * 1e3, flops / per / 1e9);
    println!("(pass as a custom DeviceModel {{ flops }} for host-scale simulations)");
    Ok(())
}

/// `astra info` — artifact manifest summary.
pub fn info(args: &Args) -> Result<()> {
    let dir = args.get_or("artifacts", "artifacts");
    let artifact = crate::runtime::Artifact::load(Path::new(&dir))?;
    let m = &artifact.meta;
    println!("artifact bundle: {dir}");
    println!(
        "model: {} layers, d={}, heads={}, ff={}, T={}, {}",
        m.n_layers, m.d_model, m.n_heads, m.d_ff, m.seq_len,
        if m.causal { "decoder (causal)" } else { "encoder (+CLS)" }
    );
    println!(
        "astra: {} devices, G={}, K={}, {} bits/token/block",
        m.n_devices, m.groups, m.codebook_size, m.bits_per_token
    );
    println!("graphs:");
    for (name, g) in &artifact.graphs {
        let args_desc: Vec<String> = g
            .args
            .iter()
            .map(|a| format!("{}{:?}", if a.kind == "weight" { "w:" } else { "" }, a.shape))
            .collect();
        println!("  {name:<16} {}", args_desc.join(" "));
    }
    println!("tensors: {} ({} floats)", artifact.tensors.len(),
        artifact.tensors.values().map(|t| t.numel()).sum::<usize>());
    println!("codebooks: {} layers x [{}x{}x{}]", artifact.codebooks.len(),
        artifact.codebooks[0].groups, artifact.codebooks[0].k, artifact.codebooks[0].dg);
    Ok(())
}
