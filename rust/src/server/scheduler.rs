//! Continuous-batching serve scheduler.
//!
//! Replaces the batch-1 FIFO loop for load testing: requests are admitted
//! into `max_slots` in-flight decode slots (vLLM/Orca-style continuous
//! batching), prefill batches are formed by the [`Batcher`]'s deadline/fill
//! logic, and each scheduler iteration either
//!
//!  * runs one *batched prefill* for newly admitted requests — compute and
//!    wire bits scale with the batch, kernel launches and collective sync
//!    stages are paid once ([`crate::parallel::cost::Phase::for_batch`]) — or
//!  * runs one *batched decode step* advancing every active slot by one
//!    token — single-token decode is memory-bound (one streaming pass over
//!    the weights), so co-scheduled slots share that floor almost for free.
//!
//! # Chunked piggybacked prefill
//!
//! With `CbConfig::prefill_chunk_tokens > 0`, a prompt longer than the
//! budget no longer monopolizes the cluster for its full prefill. Its
//! admission iteration replays only the first `prefill_chunk_tokens` rows;
//! the slot then sits in [`SlotState::Prefilling`] and each subsequent
//! iteration *fuses* one chunk batch — up to the budget of prompt tokens,
//! shared FIFO across all prefilling slots — with the decode step advancing
//! the in-flight decoding slots
//! ([`crate::parallel::strategies::Strategy::fused_iteration_schedule`]:
//! FLOPs and wire bits are paid for the chunk tokens plus one token per
//! decode slot, launches/sync/memory-floor once per iteration). Every chunk
//! is recorded as a [`CbEvent::PrefillChunk`]; TTFT for a chunked request
//! fires on its first decode step after the last chunk. Prompts that fit
//! inside the budget take the classic monopolizing path (their "first
//! chunk" is the whole prompt), so `prefill_chunk_tokens >= max prompt` —
//! and `prefill_chunk_tokens == 0`, the disabled default — reproduce the
//! unchunked scheduler's event stream bit for bit; `tests/proptests.rs`
//! pins that anchor. Prefill-only workloads (`decode_tokens == 0`) have no
//! decode iterations to piggyback on and always take the classic path.
//!
//! # Backends
//!
//! The loop owns every scheduling decision and all *timing* (the cost
//! model's virtual clock); per-slot execution is delegated to a
//! [`DecodeBackend`]. [`ModelBackend`] is the pure cost-model run;
//! [`crate::server::live::LiveBackend`] drives real
//! [`crate::coordinator::decode::DecodeSession`]s — actual tensors,
//! mixed-precision KV caches, greedy decode. Because both backends share
//! this loop, their decision streams ([`CbEvent`]) must be identical on
//! the same trace; `tests/live_vs_model.rs` asserts exactly that.
//!
//! # KV-pressure admission
//!
//! With `CbConfig::kv_cap_bytes > 0`, a [`KvBudget`] gates admission on
//! Appendix-G mixed-KV memory ([`crate::model::kv_cache_bytes_astra_live`]):
//! a request is admitted only when its prefill cache fits the cap next to
//! every in-flight slot; otherwise it queues (FIFO — nothing jumps a
//! blocked head). Slots grow by two full-precision rows per generated
//! token, so pressure can build *during* decode; before a step would
//! overflow the cap, the newest slots are evicted back to the queue
//! (recompute-style preemption — their requests re-prefill later, and
//! their queue/TTFT waits are recorded again on re-admission). The oldest
//! slot is never evicted, and requests whose full budget can never fit are
//! rejected outright, so admission always makes progress. Requests that
//! can never fit are counted in `CbReport::kv_rejected`.
//!
//! # Block pool, prefix reuse, and swap preemption
//!
//! With `CbConfig::prefix_cache`, KV accounting moves from flat per-slot
//! bytes onto the block pool ([`crate::kv`]): prompts are split into
//! `kv_block_tokens`-token blocks whose bytes are Appendix-G prefix
//! differences (telescoping to exactly the flat bytes, so sharing-off
//! reproduces the old streams bit for bit), and a radix tree over
//! token-id prefixes lets a request whose prompt shares a block-aligned
//! prefix with a resident or recently-freed cache *attach* to those
//! blocks ([`CbEvent::PrefixHit`]): admission charges only the uncovered
//! suffix, the prefill replays only the suffix (chunked through the same
//! machinery, [`CbEvent::PrefillChunk`] events starting at the covered
//! edge), and completed slots leave their blocks cached at refcount 0
//! until capacity pressure reclaims them LRU-first. Prompt token ids are
//! derived deterministically from `(seed, prompt_groups)` — the same
//! stream the live backend feeds its sessions — so both backends agree on
//! every hit.
//!
//! With `CbConfig::swap_bandwidth_mbps > 0`, each KV-pressure eviction of
//! a decoding slot is priced: moving the cache out and back over a host
//! link at that bandwidth ([`crate::kv::swap::SwapPolicy`], the
//! [`crate::comm::link`] transfer arithmetic) versus re-prefilling the
//! prompt and regenerating every token produced so far. The cheaper side
//! wins, per eviction: [`CbEvent::SwapOut`] preserves decode progress and
//! [`CbEvent::SwapIn`] restores it at readmission (transfer time charged
//! on the virtual clock); recompute ([`CbEvent::Evict`]) stays the
//! fallback and the flag-off behavior.
//!
//! `CbConfig::decode_jitter` breaks same-length lockstep: each request's
//! decode budget is sampled once, deterministically from `(seed, id)`, in
//! `decode_tokens ± jitter`, so saturating waves stop completing in the
//! same iteration and staggered completion paths get exercised.
//!
//! The engine reports tail latency (p50/p95/p99), time-to-first-token,
//! queue depth over time, goodput under an SLO, both horizon- and
//! completion-based throughput with censored (unfinished) requests
//! accounted separately, KV peak/eviction counters, prefix hit-rate and
//! swap traffic, and the full decision event stream.

use std::collections::BTreeMap;

use anyhow::Result;

use crate::comm::trace::BandwidthTrace;
use crate::kv::pool::KvPool;
use crate::kv::prefix::RadixTree;
use crate::kv::swap::SwapPolicy;
use crate::model::{
    kv_cache_bytes_astra_live, kv_cache_bytes_astra_positional, kv_cache_bytes_full,
    TransformerShape,
};
use crate::parallel::strategies::{Strategy, StrategyKind};
use crate::sim::latency::{evaluate_on_trace, evaluate_on_trace_batched, Breakdown, SimParams};
use crate::util::rng::Rng;
use crate::util::stats::{Summary, WindowedCounter};

use super::batcher::{Batcher, Request};
use super::live::{prompt_stream_key, synth_prompt};

/// Continuous-batching policy knobs.
#[derive(Debug, Clone)]
pub struct CbConfig {
    /// in-flight decode slots (1 degenerates to the batch-1 FIFO baseline)
    pub max_slots: usize,
    /// prefill admission batch cap (the batcher's fill target)
    pub max_batch: usize,
    /// batcher deadline: admit a partial batch once the oldest queued
    /// request has waited this long
    pub max_wait_s: f64,
    /// tokens generated per request after prefill (0 = prefill-only)
    pub decode_tokens: usize,
    /// end-to-end latency SLO for goodput (<= 0 disables the SLO filter)
    pub slo_s: f64,
    /// completion-bar window (Fig 6 style)
    pub window_s: f64,
    /// mixed-KV memory cap for the admission gate, bytes (0 = unlimited)
    pub kv_cap_bytes: usize,
    /// Sarathi-style chunked prefill: per-iteration prompt-token budget
    /// mixed into decode iterations, shared across prefilling slots. 0
    /// disables chunking (a prompt prefills whole at its admission — the
    /// monopolizing baseline). Prompts no longer than the budget also take
    /// that classic path, so any budget >= the longest prompt reproduces
    /// the unchunked scheduler's event stream bit for bit.
    pub prefill_chunk_tokens: usize,
    /// radix-tree prefix sharing over block-aligned prompt prefixes
    /// (`--prefix-cache`). Off (the default) keeps the flat per-slot
    /// accounting and reproduces the pre-pool event streams bit for bit.
    /// Requires `decode_tokens > 0` (prefill-only slots hold no sessions
    /// to share); ignored otherwise.
    pub prefix_cache: bool,
    /// tokens per shared KV block (`--kv-block-tokens`); sharing is
    /// block-aligned, so a block size above the longest prompt makes
    /// sharing impossible and reproduces the prefix-off stream exactly
    pub kv_block_tokens: usize,
    /// host-link bandwidth for swap-style preemption, Mbps
    /// (`--swap-bandwidth-mbps`). 0 (default) disables swapping: every
    /// KV-pressure eviction recomputes, as before. With a cap and a
    /// bandwidth set, each eviction swaps iff the round-trip transfer
    /// beats the modeled recompute.
    pub swap_bandwidth_mbps: f64,
    /// one-way host-link latency per swap transfer, seconds
    pub swap_latency_s: f64,
    /// ± tokens of seeded per-request decode-budget jitter
    /// (`--decode-jitter`); 0 keeps every budget at `decode_tokens`
    pub decode_jitter: usize,
    /// prompt-content classes for the synthetic workload
    /// (`--prompt-groups`): ids map to `id % prompt_groups`, so requests
    /// in one group share leading token ids (the prefix-cache workload).
    /// 0 (default) gives every request its own stream — the historical
    /// behavior.
    pub prompt_groups: usize,
    /// seed for prompt-content derivation and decode jitter; live runs
    /// pin this to the cluster seed so both backends see one workload
    pub seed: u64,
    /// vocabulary for model-only prompt derivation; live runs pin this to
    /// the artifact's vocab
    pub prompt_vocab: usize,
}

impl Default for CbConfig {
    fn default() -> CbConfig {
        CbConfig {
            max_slots: 8,
            max_batch: 8,
            max_wait_s: 0.02,
            decode_tokens: 64,
            slo_s: 0.0,
            window_s: 10.0,
            kv_cap_bytes: 0,
            prefill_chunk_tokens: 0,
            prefix_cache: false,
            kv_block_tokens: 16,
            swap_bandwidth_mbps: 0.0,
            swap_latency_s: 0.0005,
            decode_jitter: 0,
            prompt_groups: 0,
            seed: 0,
            prompt_vocab: 64,
        }
    }
}

impl CbConfig {
    /// The batch-1 FIFO baseline (the paper's Fig-6 setting) with the same
    /// workload shape — for apples-to-apples comparisons.
    pub fn batch1(self) -> CbConfig {
        CbConfig { max_slots: 1, max_batch: 1, ..self }
    }
}

/// One scheduling decision. The stream of events is the scheduler's
/// complete decision record; the live-vs-model differential harness
/// (`tests/live_vs_model.rs`) asserts two backends produce identical
/// streams on the same fixed-seed trace.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CbEvent {
    /// batched prefill admitted these request ids into slots (queue order)
    Admit { ids: Vec<u64> },
    /// one batched decode step advanced these in-flight slots by a token
    Decode { ids: Vec<u64> },
    /// request finished (decode budget exhausted, or prefill-only done)
    Complete { id: u64 },
    /// slot evicted back to the queue under KV pressure (will re-prefill)
    Evict { id: u64 },
    /// request whose full KV budget can never fit the cap; dropped
    Reject { id: u64 },
    /// a prefill chunk advanced slot `id`'s prompt rows `[lo, hi)` through
    /// the model, fused into the surrounding iteration. Emitted only for
    /// prompts longer than the chunk budget; per admission episode the
    /// chunk events of a slot tile `[covered, prompt_len)` contiguously in
    /// order (`covered == 0` without a prefix hit).
    PrefillChunk { id: u64, lo: usize, hi: usize },
    /// request `id`'s prompt attached to shared KV blocks covering its
    /// first `tokens` positions (block-aligned): only the suffix replays,
    /// only the suffix footprint is charged
    PrefixHit { id: u64, tokens: usize },
    /// KV pressure moved slot `id`'s cache to the host tier instead of
    /// dropping it — the bandwidth-priced transfer beat recompute; decode
    /// progress is preserved for [`CbEvent::SwapIn`]
    SwapOut { id: u64 },
    /// a previously swapped request re-entered a slot by transferring its
    /// cache back (charged at the host-link bandwidth), resuming decode
    /// where it left off
    SwapIn { id: u64 },
}

/// LEGACY flat admission gate over Appendix-G mixed-KV memory — the
/// pre-block-pool accounting, kept for API compatibility and as the
/// reference semantics the pool must reduce to: the serving engine now
/// tracks bytes through [`crate::kv::pool::KvPool`], whose
/// private-plus-block classes telescope to exactly these counters
/// whenever prefix sharing is off. `cap_bytes == 0` disables the gate
/// (every request fits).
#[derive(Debug, Clone, Default)]
pub struct KvBudget {
    pub cap_bytes: usize,
    pub used_bytes: usize,
    pub peak_bytes: usize,
}

impl KvBudget {
    pub fn new(cap_bytes: usize) -> KvBudget {
        KvBudget { cap_bytes, used_bytes: 0, peak_bytes: 0 }
    }

    /// Would `bytes` more fit under the cap?
    pub fn fits(&self, bytes: usize) -> bool {
        self.cap_bytes == 0 || self.used_bytes + bytes <= self.cap_bytes
    }

    pub fn acquire(&mut self, bytes: usize) {
        self.used_bytes += bytes;
        self.peak_bytes = self.peak_bytes.max(self.used_bytes);
    }

    pub fn release(&mut self, bytes: usize) {
        self.used_bytes = self.used_bytes.saturating_sub(bytes);
    }
}

/// Shared-prefix attachment delivered with an admission: the request's
/// first `tokens` prompt positions are covered by the listed ready blocks
/// (root-to-leaf, contiguous, block-aligned). Empty when the prompt shares
/// nothing — or prefix caching is off.
#[derive(Debug, Clone, Default)]
pub struct PrefixAttach {
    pub tokens: usize,
    pub blocks: Vec<u64>,
}

/// Execution backend driven by the scheduler loop. All methods mirror a
/// decision the loop already recorded as a [`CbEvent`]; a backend performs
/// the corresponding real work (or nothing, for the cost model). The
/// block/swap methods default to no-ops so cost-model backends stay
/// trivial.
pub trait DecodeBackend {
    /// A batch was admitted: start real work (live: open a `DecodeSession`
    /// per request, sized prompt + its decode budget, import the shared
    /// blocks listed in `prefixes[i]`, and replay the first
    /// `min(uncovered suffix, prefill_limit)` prompt rows).
    /// `prefill_limit` is `usize::MAX` when chunking is off (whole
    /// suffixes replay here); the remainder of a longer suffix arrives
    /// through [`Self::prefill_chunk`]. `decode_budgets` and `prefixes`
    /// parallel `batch`. Swapped-in requests are NOT part of `batch`; they
    /// arrive through [`Self::swap_in`].
    fn admit(
        &mut self,
        batch: &[Request],
        decode_budgets: &[usize],
        prefill_limit: usize,
        prefixes: &[PrefixAttach],
    ) -> Result<()>;
    /// Replay prompt rows `[lo, hi)` of slot `id` into its cache — one
    /// chunk the scheduler fused into a decode iteration.
    fn prefill_chunk(&mut self, id: u64, lo: usize, hi: usize) -> Result<()>;
    /// One co-scheduled decode step advancing every listed slot by a token.
    fn step(&mut self, ids: &[u64]) -> Result<()>;
    /// The request finished; release its state and collect output.
    fn complete(&mut self, id: u64) -> Result<()>;
    /// The slot was evicted back to the queue; drop its state (it will be
    /// rebuilt from scratch on re-admission).
    fn evict(&mut self, id: u64) -> Result<()>;
    /// Slot `session`'s prompt rows `[lo, hi)` are complete and now back a
    /// shared block: copy them into the block store so later attachments
    /// survive the creator (live copies real K/V rows; `bytes` is the
    /// block's accounting size).
    fn register_block(
        &mut self,
        _session: u64,
        _block: u64,
        _lo: usize,
        _hi: usize,
        _bytes: usize,
    ) -> Result<()> {
        Ok(())
    }
    /// A cached block was reclaimed for capacity; drop its stored rows.
    fn drop_block(&mut self, _block: u64) -> Result<()> {
        Ok(())
    }
    /// KV pressure chose swap over recompute: move the slot's state to the
    /// host tier, preserving decode progress.
    fn swap_out(&mut self, _id: u64) -> Result<()> {
        Ok(())
    }
    /// A swapped request re-entered a slot: restore its state from the
    /// host tier.
    fn swap_in(&mut self, _id: u64) -> Result<()> {
        Ok(())
    }
    /// Actual bytes currently held by in-flight slots plus the shared
    /// block store (0 if untracked); the loop counts a `kv_violations`
    /// whenever this exceeds the cap.
    fn kv_bytes_in_flight(&self) -> usize;
}

/// Cost-model-only backend: the event stream *is* the run.
pub struct ModelBackend;

impl DecodeBackend for ModelBackend {
    fn admit(
        &mut self,
        _batch: &[Request],
        _decode_budgets: &[usize],
        _prefill_limit: usize,
        _prefixes: &[PrefixAttach],
    ) -> Result<()> {
        Ok(())
    }
    fn prefill_chunk(&mut self, _id: u64, _lo: usize, _hi: usize) -> Result<()> {
        Ok(())
    }
    fn step(&mut self, _ids: &[u64]) -> Result<()> {
        Ok(())
    }
    fn complete(&mut self, _id: u64) -> Result<()> {
        Ok(())
    }
    fn evict(&mut self, _id: u64) -> Result<()> {
        Ok(())
    }
    fn kv_bytes_in_flight(&self) -> usize {
        0
    }
}

/// Outcome of a continuous-batching serve run.
#[derive(Debug)]
pub struct CbReport {
    pub completed: usize,
    /// admitted or queued inside the horizon but not completed by it
    pub censored: usize,
    /// dropped at admission: full KV budget exceeds the cap
    pub kv_rejected: usize,
    pub horizon_s: f64,
    /// completed / horizon
    pub throughput: f64,
    /// completed / time of last completion (unbiased under early-ending
    /// arrival streams)
    pub throughput_completion: f64,
    /// completions per second that met the SLO (equals `throughput` when
    /// the SLO is disabled)
    pub goodput: f64,
    pub slo_s: f64,
    /// end-to-end latency of completed requests (p50/p95/p99 via Summary)
    pub latency: Summary,
    /// time to first token, measured from the request's ORIGINAL arrival to
    /// the first token it ever produced — recorded once per request, so an
    /// eviction + re-admission cannot overwrite it. Classic (unchunked)
    /// requests fire at prefill end; chunked requests fire on the first
    /// decode step after their last chunk.
    pub ttft: Summary,
    /// queue wait per admitted request: the SUM of its queueing episodes
    /// (arrival -> first admission, plus each eviction -> re-admission) —
    /// in-service time never counts as waiting
    pub queue_wait: Summary,
    /// inter-token latency: gaps between consecutive decode-step
    /// completions of the same slot within one residency — the in-flight
    /// decode stall metric chunked prefill improves (a monopolizing prefill
    /// shows up here as one giant gap for every in-flight slot)
    pub itl: Summary,
    /// queue wait accrued by censored requests up to the horizon
    pub censored_wait: Summary,
    /// (time, queued requests) samples taken at admission decisions
    pub queue_depth: Vec<(f64, usize)>,
    /// completion bars covering the whole horizon
    pub windows: Vec<usize>,
    /// the scheduler's full decision stream (admissions, prefill chunks,
    /// decode steps, completions, evictions, rejections) in order
    pub events: Vec<CbEvent>,
    /// prefill-chunk events emitted (0 when chunking is off or every
    /// prompt fit its admission chunk)
    pub prefill_chunks: usize,
    /// summed virtual cost of every evaluated prefill + decode step
    pub model_time: Breakdown,
    /// high-water mark of modeled in-flight KV bytes
    pub kv_peak_bytes: usize,
    /// the configured cap (0 = unlimited)
    pub kv_cap_bytes: usize,
    /// KV-pressure evictions resolved by recompute (slots requeued
    /// mid-decode and rebuilt from scratch)
    pub kv_evictions: usize,
    /// iterations where the backend's *actual* in-flight bytes exceeded
    /// the cap — must be zero; asserted by the live tests
    pub kv_violations: usize,
    /// admissions that attached to >= 1 shared block
    pub prefix_hits: usize,
    /// prompt tokens served from shared blocks instead of replay
    pub prefix_hit_tokens: usize,
    /// prompt tokens across all (re)admissions — the hit-rate denominator
    pub admitted_prompt_tokens: usize,
    /// modeled prefill FLOPs the covered tokens did not recompute
    pub recompute_flops_saved: f64,
    /// KV-pressure evictions resolved by swapping to the host tier
    pub swap_outs: usize,
    /// swapped requests restored into slots
    pub swap_ins: usize,
    /// bytes moved over the host link, out plus in
    pub swap_bytes: usize,
}

impl CbReport {
    /// Mean of the queue-depth samples (0 when nothing was ever queued).
    pub fn mean_queue_depth(&self) -> f64 {
        if self.queue_depth.is_empty() {
            return 0.0;
        }
        self.queue_depth.iter().map(|&(_, d)| d as f64).sum::<f64>()
            / self.queue_depth.len() as f64
    }

    /// Fraction of admitted prompt tokens served from shared KV blocks
    /// (0 when prefix caching is off or nothing was admitted).
    pub fn prefix_hit_rate(&self) -> f64 {
        if self.admitted_prompt_tokens == 0 {
            0.0
        } else {
            self.prefix_hit_tokens as f64 / self.admitted_prompt_tokens as f64
        }
    }
}

/// Completion bookkeeping shared by the prefill-only and decode paths —
/// one point of truth for what "a request finished at `done`" updates.
struct CompletionTally {
    completed: usize,
    within_slo: usize,
    last_completion: f64,
    slo: f64,
    latency: Summary,
    windows: WindowedCounter,
}

impl CompletionTally {
    fn new(slo: f64, window_s: f64) -> CompletionTally {
        CompletionTally {
            completed: 0,
            within_slo: 0,
            last_completion: 0.0,
            slo,
            latency: Summary::new(),
            windows: WindowedCounter::new(window_s),
        }
    }

    fn record(&mut self, arrival_s: f64, done: f64) {
        self.completed += 1;
        let l = done - arrival_s;
        self.latency.add(l);
        self.windows.record(done);
        self.last_completion = done;
        if self.slo <= 0.0 || l <= self.slo {
            self.within_slo += 1;
        }
    }
}

/// Chunked-prefill progress of an in-flight slot.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SlotState {
    /// prompt rows `[0, next_token)` are in the cache; `[next_token,
    /// total)` still arrive as fused chunks
    Prefilling { next_token: usize, total: usize },
    /// prompt fully prefilled; each iteration decodes one token
    Decoding,
}

/// One in-flight request occupying a decode slot.
#[derive(Debug, Clone)]
struct Slot {
    id: u64,
    arrival_s: f64,
    /// prompt length (the request's `tokens`)
    tokens: usize,
    remaining: usize,
    generated: usize,
    /// modeled mixed-KV bytes this slot holds PRIVATELY — replayed prompt
    /// rows not yet backing a ready shared block, plus two full-precision
    /// rows per decode step. Without prefix caching no blocks exist and
    /// this is the slot's whole footprint, exactly the old accounting.
    kv_bytes: usize,
    /// monotone admission sequence number for this episode — eviction
    /// picks the largest, which makes "newest" stable under readmission
    /// (a readmitted slot counts as newest by its CURRENT admission, and
    /// same-batch ties resolve in queue order instead of by raw id)
    admit_seq: u64,
    /// per-request decode budget (== `decode_tokens` unless jittered)
    budget: usize,
    /// ready shared blocks this slot holds references on (attached at
    /// admission plus own blocks whose rows finished replaying)
    blocks: Vec<u64>,
    /// own created blocks still waiting for their rows `(block, lo, hi)`,
    /// ascending; flushed into `blocks` as replay crosses `hi`
    pending: Vec<(u64, usize, usize)>,
    state: SlotState,
    /// virtual time this slot last completed a decode step (ITL tracking)
    last_token_at: f64,
}

/// Progress preserved for a swapped-out request until readmission.
#[derive(Debug, Clone, Copy)]
struct SwapEntry {
    tokens: usize,
    generated: usize,
    remaining: usize,
    budget: usize,
    /// occupancy transferred out — charged again on the way back in, and
    /// re-acquired as private bytes at readmission
    bytes: usize,
    /// when the slot last emitted a token: preserved so the inter-token
    /// gap spanning the host-tier dwell (swap-out, queueing, swap-in) is
    /// counted by the ITL stall metric — swap keeps the generation stream
    /// alive, so the user-visible gap between token k and k+1 includes it
    last_token_at: f64,
}

/// Per-request accounting that must survive eviction and re-admission:
/// TTFT is measured once, from the original arrival to the first token the
/// request ever produced, and queue wait sums every queueing episode
/// instead of being overwritten when a request re-enters through admission.
#[derive(Debug, Clone, Copy)]
struct ReqStats {
    /// when the current queueing episode began (arrival, or last eviction)
    queued_since: f64,
    /// completed queueing episodes, summed
    queue_wait_s: f64,
    ttft_recorded: bool,
}

/// Index of the newest slot — the KV-pressure eviction victim. "Newest"
/// is the largest `admit_seq` (current-episode admission order), NOT the
/// (admitted_at, id) pair used before: under readmission several slots
/// share an `admitted_at` and the id tiebreak ranked a fresh high-id
/// request "newer" than a just-readmitted low-id one, so victim selection
/// thrashed the wrong slot. The sequence number is unique and monotone, so
/// ordering is stable: the most recently (re)admitted slot is always the
/// victim, and the oldest resident slot is never chosen while another
/// exists — preemption stays livelock-free.
fn newest_slot_index(slots: &[Slot]) -> usize {
    let mut best = 0;
    for (i, s) in slots.iter().enumerate().skip(1) {
        if s.admit_seq > slots[best].admit_seq {
            best = i;
        }
    }
    best
}

/// Move a slot's own blocks whose rows are now replayed (`hi <=
/// replayed`) from pending to ready: the pool shifts their bytes out of
/// the slot's private share, and the backend copies the rows into the
/// shared store so attachments survive the creator.
fn flush_ready_blocks<B: DecodeBackend + ?Sized>(
    slot: &mut Slot,
    replayed: usize,
    pool: &mut KvPool,
    backend: &mut B,
) -> Result<()> {
    while let Some(&(block, lo, hi)) = slot.pending.first() {
        if hi > replayed {
            break;
        }
        let bytes = pool.mark_ready(block);
        slot.kv_bytes = slot.kv_bytes.saturating_sub(bytes);
        backend.register_block(slot.id, block, lo, hi, bytes)?;
        slot.pending.remove(0);
        slot.blocks.push(block);
    }
    Ok(())
}

/// Deterministic prompt lookup with per-stream caching: `synth_prompt`
/// over a keyed stream is prefix-stable (its first `n` draws are the same
/// whatever length is requested), so one growing buffer per stream key
/// serves every request length — the admission filter would otherwise
/// re-derive O(prompt) token ids per queued candidate on every iteration.
fn cached_prompt<'c>(
    cache: &'c mut BTreeMap<u64, Vec<usize>>,
    cfg: &CbConfig,
    id: u64,
    tokens: usize,
) -> &'c [usize] {
    let key = prompt_stream_key(cfg.prompt_groups, id);
    let entry = cache.entry(key).or_default();
    if entry.len() < tokens {
        *entry = synth_prompt(cfg.seed, key, tokens, cfg.prompt_vocab.max(2));
    }
    &entry[..tokens]
}

/// Reclaim cached (refcount-0) blocks, LRU subtree at a time, until
/// `need` more bytes fit resident under the cap (or nothing cacheable is
/// left). The backend drops its stored rows for every reclaimed block.
fn reclaim_cached<B: DecodeBackend + ?Sized>(
    pool: &mut KvPool,
    tree: &mut RadixTree,
    backend: &mut B,
    need: usize,
) -> Result<()> {
    while !pool.fits_resident(need) {
        let Some(victim) = pool.lru_cached() else { break };
        for block in tree.remove_subtree(victim) {
            pool.drop_cached(block);
            backend.drop_block(block)?;
        }
    }
    Ok(())
}

/// Continuous-batching serving engine over the cost-model clock.
pub struct CbEngine {
    pub shape: TransformerShape,
    pub strategy: Strategy,
    pub params: SimParams,
    pub trace: BandwidthTrace,
    pub cfg: CbConfig,
}

impl CbEngine {
    pub fn new(
        shape: TransformerShape,
        strategy: Strategy,
        params: SimParams,
        trace: BandwidthTrace,
        cfg: CbConfig,
    ) -> CbEngine {
        CbEngine { shape, strategy, params, trace, cfg }
    }

    /// Modeled mixed-KV bytes a slot holds after `generated` decode tokens
    /// on a `prompt_tokens` prompt. ASTRA strategies hold the Appendix-G
    /// mixed cache; everything else holds full precision.
    pub fn kv_slot_bytes(&self, prompt_tokens: usize, generated: usize) -> usize {
        match self.strategy.kind {
            StrategyKind::Astra { vq } => kv_cache_bytes_astra_live(
                &self.shape,
                prompt_tokens,
                generated,
                self.shape.elem_bytes,
                self.strategy.n_devices,
                vq.groups,
                vq.codebook_size,
            ),
            _ => kv_cache_bytes_full(
                &self.shape,
                prompt_tokens + generated,
                self.shape.elem_bytes,
            ),
        }
    }

    /// Bytes a slot will hold once its decode budget is exhausted — the
    /// admission gate's per-request ceiling (requests above the cap are
    /// rejected outright: they could never complete).
    pub fn kv_projection(&self, prompt_tokens: usize) -> usize {
        self.kv_slot_bytes(prompt_tokens, self.cfg.decode_tokens)
    }

    /// Per-token cache growth during decode (full-precision K+V rows).
    pub fn kv_step_bytes(&self) -> usize {
        self.kv_slot_bytes(1, 1) - self.kv_slot_bytes(1, 0)
    }

    /// [`Self::kv_slot_bytes`] under positional locality — the accounting
    /// the block pool prices blocks with (prefix differences of this are
    /// identical for every prompt sharing the positions).
    pub fn kv_slot_bytes_positional(&self, prompt_tokens: usize, generated: usize) -> usize {
        match self.strategy.kind {
            StrategyKind::Astra { vq } => kv_cache_bytes_astra_positional(
                &self.shape,
                prompt_tokens,
                generated,
                self.shape.elem_bytes,
                self.strategy.n_devices,
                vq.groups,
                vq.codebook_size,
            ),
            _ => kv_cache_bytes_full(
                &self.shape,
                prompt_tokens + generated,
                self.shape.elem_bytes,
            ),
        }
    }

    /// Bytes of the first `replayed` prompt rows under the accounting
    /// active for this run (positional with the prefix cache, classic
    /// without — where the two coincide for every flag-off decision).
    /// Prefill-only workloads ignore the prefix cache entirely, including
    /// its accounting.
    fn slot_prompt_bytes(&self, replayed: usize) -> usize {
        if self.cfg.prefix_cache && self.cfg.decode_tokens > 0 {
            self.kv_slot_bytes_positional(replayed, 0)
        } else {
            self.kv_slot_bytes(replayed, 0)
        }
    }

    /// Accounting size of KV block `[lo, hi)` — the Appendix-G prefix
    /// difference, so a slot's blocks plus its private remainder
    /// telescope to exactly its flat footprint.
    fn block_bytes_range(&self, lo: usize, hi: usize) -> usize {
        self.slot_prompt_bytes(hi) - self.slot_prompt_bytes(lo)
    }

    /// The decode budget request `id` will receive: `decode_tokens`, or a
    /// deterministic sample in `decode_tokens ± decode_jitter` drawn from
    /// `(seed, id)` — the same everywhere the request is priced, admitted,
    /// or re-admitted, on either backend.
    pub fn decode_budget(&self, id: u64) -> usize {
        let d = self.cfg.decode_tokens;
        if d == 0 || self.cfg.decode_jitter == 0 {
            return d;
        }
        let j = self.cfg.decode_jitter.min(d - 1);
        let mut rng = Rng::new(
            self.cfg.seed ^ id.wrapping_mul(0x9e37_79b9_7f4a_7c15) ^ 0xa076_1d64_78bd_642f,
        );
        d - j + rng.below(2 * j + 1)
    }

    /// Bytes request `id` will hold once `budget` decode tokens are
    /// generated — the admission gate's per-request ceiling under the
    /// active accounting.
    pub fn projection_for(&self, prompt_tokens: usize, budget: usize) -> usize {
        self.slot_prompt_bytes(prompt_tokens) + budget * self.kv_step_bytes()
    }

    /// Deterministic prompt token ids for request `id` — the SAME stream
    /// the live backend feeds its sessions (`synth_prompt` over the
    /// grouped key), so both backends agree on every radix-tree match.
    pub fn prompt_for(&self, id: u64, tokens: usize) -> Vec<usize> {
        synth_prompt(
            self.cfg.seed,
            prompt_stream_key(self.cfg.prompt_groups, id),
            tokens,
            self.cfg.prompt_vocab.max(2),
        )
    }

    /// Modeled cost of recovering an evicted slot by recompute: re-prefill
    /// the prompt, then regenerate every token produced so far — the
    /// alternative the swap policy prices transfers against.
    fn recompute_cost_s(&self, tokens: usize, generated: usize, now: f64) -> f64 {
        let mut pshape = self.shape;
        pshape.seq_len = tokens.max(1);
        let prefill =
            evaluate_on_trace(&self.strategy.schedule(&pshape), &self.params, &self.trace, now)
                .total();
        if generated == 0 {
            return prefill;
        }
        let step = evaluate_on_trace(
            &self.strategy.decode_step_schedule(&self.shape, tokens + generated),
            &self.params,
            &self.trace,
            now,
        )
        .total();
        prefill + generated as f64 * step
    }

    /// Plan one iteration's chunk batch: `(slot index, tokens)` pairs in
    /// admission order (FIFO across prefilling slots, sharing the
    /// per-iteration token budget), plus the modeled KV growth the whole
    /// iteration causes — planned chunk rows for prefilling slots and one
    /// decode token's full-precision rows per decoding slot. With chunking
    /// disabled there are no prefilling slots, so the plan is empty and the
    /// growth reduces to the old `slots * kv_step_bytes()` check.
    fn plan_chunks(&self, slots: &[Slot], chunk_budget: usize) -> (Vec<(usize, usize)>, usize) {
        let mut order: Vec<usize> = (0..slots.len())
            .filter(|&i| matches!(slots[i].state, SlotState::Prefilling { .. }))
            .collect();
        // FIFO by current-episode admission order (the unique sequence
        // number; equals the old (admitted_at, id) order except across
        // readmissions, where queue order is the stable choice)
        order.sort_by_key(|&i| slots[i].admit_seq);
        let mut plan = Vec::new();
        let mut left = chunk_budget;
        let mut growth = 0usize;
        for i in order {
            if left == 0 {
                break;
            }
            if let SlotState::Prefilling { next_token, total } = slots[i].state {
                let take = (total - next_token).min(left);
                left -= take;
                growth += self.slot_prompt_bytes(next_token + take)
                    - self.slot_prompt_bytes(next_token);
                plan.push((i, take));
            }
        }
        let decoding = slots.iter().filter(|s| s.state == SlotState::Decoding).count();
        growth += decoding * self.kv_step_bytes();
        (plan, growth)
    }

    /// Serve an open-loop Poisson stream at `rate` req/s for `horizon_s`.
    pub fn serve_poisson(&mut self, rng: &mut Rng, rate: f64, horizon_s: f64) -> CbReport {
        let arrivals =
            super::batcher::poisson_arrivals(rng, rate, horizon_s, self.shape.seq_len);
        self.serve_stream(arrivals, horizon_s)
    }

    /// Serve a fixed arrival list under continuous batching on the cost
    /// model alone.
    pub fn serve_stream(&mut self, arrivals: Vec<Request>, horizon_s: f64) -> CbReport {
        self.serve_stream_with(&mut ModelBackend, arrivals, horizon_s)
            .expect("the cost-model backend is infallible")
    }

    /// Serve a fixed arrival list, delegating per-slot execution to
    /// `backend` while this loop makes every scheduling decision on the
    /// cost model's virtual clock. `arrivals` must be sorted by arrival.
    pub fn serve_stream_with<B: DecodeBackend>(
        &mut self,
        backend: &mut B,
        arrivals: Vec<Request>,
        horizon_s: f64,
    ) -> Result<CbReport> {
        let max_slots = self.cfg.max_slots.max(1);
        // prefill-only workloads have no decode iterations to piggyback
        // chunks on, so chunking applies only when decode happens
        let chunk_budget = if self.cfg.prefill_chunk_tokens > 0 && self.cfg.decode_tokens > 0 {
            self.cfg.prefill_chunk_tokens
        } else {
            usize::MAX
        };
        // prefix sharing and swap both need live decode slots; prefill-only
        // workloads hold no state between events, so both are off there
        let prefix_on = self.cfg.prefix_cache && self.cfg.decode_tokens > 0;
        let block_tokens = self.cfg.kv_block_tokens.max(1);
        let swap_policy = SwapPolicy::new(self.cfg.swap_bandwidth_mbps, self.cfg.swap_latency_s);
        let swap_on =
            swap_policy.enabled() && self.cfg.kv_cap_bytes > 0 && self.cfg.decode_tokens > 0;
        let mut batcher = Batcher::new(self.cfg.max_batch.max(1), self.cfg.max_wait_s);
        let mut slots: Vec<Slot> = Vec::new();
        let mut pending = arrivals.into_iter().peekable();
        let mut pool = KvPool::new(self.cfg.kv_cap_bytes);
        let mut tree = RadixTree::new(block_tokens);
        let mut prompt_cache: BTreeMap<u64, Vec<usize>> = BTreeMap::new();
        let mut swapped: BTreeMap<u64, SwapEntry> = BTreeMap::new();
        let mut next_seq = 0u64;
        let mut events: Vec<CbEvent> = Vec::new();
        let mut stats: BTreeMap<u64, ReqStats> = BTreeMap::new();

        let mut now = 0.0f64;
        let mut tally = CompletionTally::new(self.cfg.slo_s, self.cfg.window_s);
        let mut ttft = Summary::new();
        let mut queue_wait = Summary::new();
        let mut censored_wait = Summary::new();
        let mut itl = Summary::new();
        let mut queue_depth: Vec<(f64, usize)> = Vec::new();
        let mut model_time = Breakdown::default();
        let mut censored = 0usize;
        let mut kv_rejected = 0usize;
        let mut kv_evictions = 0usize;
        let mut kv_violations = 0usize;
        let mut prefill_chunks = 0usize;
        let mut prefix_hits = 0usize;
        let mut prefix_hit_tokens = 0usize;
        let mut admitted_prompt_tokens = 0usize;
        let mut recompute_flops_saved = 0.0f64;
        let mut swap_outs = 0usize;
        let mut swap_ins = 0usize;
        let mut swap_bytes = 0usize;

        while now < horizon_s {
            // pull arrivals into the queue
            while let Some(r) = pending.peek() {
                if r.arrival_s <= now {
                    batcher.push(pending.next().unwrap());
                } else {
                    break;
                }
            }

            // a request whose full KV budget exceeds the cap can never be
            // served; drop it rather than head-of-line-block forever.
            // (Swapped requests already fit once and return at known size.)
            if pool.cap_bytes > 0 {
                loop {
                    let oversized = match batcher.front() {
                        Some(r) => {
                            !swapped.contains_key(&r.id)
                                && self.projection_for(r.tokens, self.decode_budget(r.id))
                                    > pool.cap_bytes
                        }
                        None => false,
                    };
                    if !oversized {
                        break;
                    }
                    let r = batcher.pop_front().unwrap();
                    kv_rejected += 1;
                    events.push(CbEvent::Reject { id: r.id });
                }
            }

            // ---- admission: batched prefill into free slots, gated on
            //      the KV pool at prefill footprint (optimistic — decode
            //      growth is handled by eviction below). A prefix hit is
            //      charged net of its covered blocks; a swapped request
            //      returns at its preserved size. ----
            let free = max_slots.saturating_sub(slots.len());
            // an idle cluster never waits on the fill deadline
            let force = slots.is_empty();
            let batch = if free > 0 {
                let mut pending_bytes = 0usize;
                // cached (refcount-0) blocks this batch is about to
                // re-reference: attaching pins their bytes again, so they
                // stop being reclaimable and must be charged to the
                // admission check — once per block, however many batch
                // members share it
                let mut resurrected: std::collections::BTreeSet<u64> =
                    std::collections::BTreeSet::new();
                batcher.next_batch_filtered(now, force, free, |r| {
                    if let Some(e) = swapped.get(&r.id) {
                        if pool.fits(pending_bytes + e.bytes) {
                            pending_bytes += e.bytes;
                            return true;
                        }
                        return false;
                    }
                    // a request that can never fit must not be admitted on
                    // its (smaller) prefill footprint — it would grow past
                    // the cap with no evictable peer. It blocks here until
                    // it reaches the head, where the reject pass drops it.
                    if pool.cap_bytes > 0
                        && self.projection_for(r.tokens, self.decode_budget(r.id))
                            > pool.cap_bytes
                    {
                        return false;
                    }
                    let (hit, repin) = if prefix_on {
                        let prompt = cached_prompt(&mut prompt_cache, &self.cfg, r.id, r.tokens);
                        let (hit, _) = tree.lookup(prompt, &|b| pool.block_ready(b));
                        let repin: usize = hit
                            .iter()
                            .filter(|b| !resurrected.contains(*b))
                            .filter_map(|&b| pool.block(b))
                            .filter(|blk| blk.refs == 0)
                            .map(|blk| blk.bytes)
                            .sum();
                        (hit, repin)
                    } else {
                        (Vec::new(), 0)
                    };
                    let covered = hit.len() * block_tokens;
                    let need =
                        self.slot_prompt_bytes(r.tokens) - self.slot_prompt_bytes(covered);
                    if pool.fits(pending_bytes + repin + need) {
                        pending_bytes += repin + need;
                        resurrected.extend(hit);
                        true
                    } else {
                        false
                    }
                })
            } else {
                Vec::new()
            };
            if !batch.is_empty() {
                queue_depth.push((now, batcher.len()));
                // resolve every batch member: swapped requests return via
                // the host link; fresh requests attach to shared blocks
                // (refcounts claimed here) and create the blocks their own
                // replay will back
                struct FreshMeta {
                    req: Request,
                    budget: usize,
                    covered: usize,
                    attach: Vec<u64>,
                    pending: Vec<(u64, usize, usize)>,
                    /// suffix rows the admission iteration replays
                    first: usize,
                }
                let mut fresh: Vec<FreshMeta> = Vec::new();
                let mut swapped_in: Vec<(Request, SwapEntry)> = Vec::new();
                // (id, is_swap, covered) in batch order, for events/stats
                let mut order: Vec<(u64, bool, usize)> = Vec::new();
                for req in &batch {
                    if let Some(e) = swapped.remove(&req.id) {
                        order.push((req.id, true, 0));
                        swapped_in.push((req.clone(), e));
                        continue;
                    }
                    let budget = self.decode_budget(req.id);
                    let (attach, covered, pend) = if prefix_on {
                        let prompt =
                            cached_prompt(&mut prompt_cache, &self.cfg, req.id, req.tokens);
                        let (hit, extendable) =
                            tree.lookup(prompt, &|b| pool.block_ready(b));
                        for &b in &hit {
                            pool.ref_block(b);
                        }
                        let covered = hit.len() * block_tokens;
                        let pend: Vec<(u64, usize, usize)> = if extendable {
                            tree.extend(prompt, hit.len(), &mut |lo, hi| {
                                pool.create_block(lo, hi, self.block_bytes_range(lo, hi))
                            })
                            .into_iter()
                            .enumerate()
                            .map(|(k, b)| {
                                (
                                    b,
                                    covered + k * block_tokens,
                                    covered + (k + 1) * block_tokens,
                                )
                            })
                            .collect()
                        } else {
                            Vec::new()
                        };
                        (hit, covered, pend)
                    } else {
                        (Vec::new(), 0, Vec::new())
                    };
                    let first = (req.tokens - covered).min(chunk_budget);
                    order.push((req.id, false, covered));
                    fresh.push(FreshMeta {
                        req: req.clone(),
                        budget,
                        covered,
                        attach,
                        pending: pend,
                        first,
                    });
                }

                events.push(CbEvent::Admit { ids: batch.iter().map(|r| r.id).collect() });
                for &(id, is_swap, covered) in &order {
                    if is_swap {
                        events.push(CbEvent::SwapIn { id });
                    } else if covered > 0 {
                        events.push(CbEvent::PrefixHit { id, tokens: covered });
                        prefix_hits += 1;
                        prefix_hit_tokens += covered;
                        // modeled prefill FLOPs the attach avoided: the
                        // covered rows advanced through every layer
                        recompute_flops_saved += self.shape.n_layers as f64
                            * self.shape.chunk_block_flops(covered, covered, covered);
                    }
                }
                for m in &fresh {
                    admitted_prompt_tokens += m.req.tokens;
                    if m.covered + m.first < m.req.tokens {
                        events.push(CbEvent::PrefillChunk {
                            id: m.req.id,
                            lo: m.covered,
                            hi: m.covered + m.first,
                        });
                        prefill_chunks += 1;
                    }
                }

                // price the iteration: a batched prefill over the fresh
                // requests' first (suffix) chunks — the classic batched
                // path, bit for bit, when nothing attached — plus the
                // swap-in transfers over the host link
                let mut iter_bd = Breakdown::default();
                let priced: Vec<&FreshMeta> = fresh.iter().filter(|m| m.first > 0).collect();
                if !priced.is_empty() {
                    let b = priced.len();
                    let max_first = priced.iter().map(|m| m.first).max().unwrap().max(1);
                    let bd = if priced.iter().all(|m| m.covered == 0) {
                        let mut pshape = self.shape;
                        pshape.seq_len = max_first;
                        let prefill = self.strategy.schedule(&pshape);
                        evaluate_on_trace_batched(&prefill, &self.params, &self.trace, now, b)
                    } else {
                        // suffix-only pricing: covered tokens are never
                        // recomputed; the chunk schedule charges the new
                        // rows attending over the covered context
                        let ctx = priced.iter().map(|m| m.covered + m.first).max().unwrap();
                        let sched =
                            self.strategy.prefill_chunk_schedule(&self.shape, max_first, ctx);
                        evaluate_on_trace_batched(&sched, &self.params, &self.trace, now, b)
                    };
                    iter_bd.accumulate(&bd);
                }
                if !swapped_in.is_empty() {
                    let bytes: usize = swapped_in.iter().map(|(_, e)| e.bytes).sum();
                    iter_bd.comm_s += swap_policy.transfer_s(bytes);
                }
                model_time.accumulate(&iter_bd);
                let done = now + iter_bd.total();

                let fresh_reqs: Vec<Request> = fresh.iter().map(|m| m.req.clone()).collect();
                let fresh_budgets: Vec<usize> = fresh.iter().map(|m| m.budget).collect();
                let fresh_prefixes: Vec<PrefixAttach> = fresh
                    .iter()
                    .map(|m| PrefixAttach { tokens: m.covered, blocks: m.attach.clone() })
                    .collect();
                backend.admit(&fresh_reqs, &fresh_budgets, chunk_budget, &fresh_prefixes)?;

                for (req, &(_, is_swap, covered)) in batch.iter().zip(order.iter()) {
                    let st = stats.entry(req.id).or_insert(ReqStats {
                        queued_since: req.arrival_s,
                        queue_wait_s: 0.0,
                        ttft_recorded: false,
                    });
                    st.queue_wait_s += now - st.queued_since;
                    st.queued_since = now; // in service: not queueing
                    // classic path: the first token's latency is known at
                    // prefill end (the uncovered suffix fits the budget).
                    // Chunked slots record TTFT at their first decode step
                    // instead, and an evicted-then-readmitted request keeps
                    // the TTFT of the first token it ever emitted rather
                    // than overwriting it here.
                    if !is_swap
                        && req.tokens - covered <= chunk_budget
                        && done <= horizon_s
                        && !st.ttft_recorded
                    {
                        st.ttft_recorded = true;
                        ttft.add(done - req.arrival_s);
                    }
                }
                if self.cfg.decode_tokens == 0 {
                    // prefill-only workload: requests complete at prefill
                    // end; past the horizon they are censored, not
                    // completed, so no Complete event is emitted for them
                    for req in &batch {
                        let waited = stats.get(&req.id).map(|s| s.queue_wait_s).unwrap_or(0.0);
                        queue_wait.add(waited);
                        if done <= horizon_s {
                            backend.complete(req.id)?;
                            events.push(CbEvent::Complete { id: req.id });
                            tally.record(req.arrival_s, done);
                        } else {
                            censored += 1;
                            censored_wait.add(now - req.arrival_s);
                        }
                    }
                } else {
                    // make room (reclaim cached blocks) for everything this
                    // admission acquires, then seat the slots
                    let new_private: usize = fresh
                        .iter()
                        .map(|m| {
                            self.slot_prompt_bytes(m.covered + m.first)
                                - self.slot_prompt_bytes(m.covered)
                        })
                        .sum::<usize>()
                        + swapped_in.iter().map(|(_, e)| e.bytes).sum::<usize>();
                    reclaim_cached(&mut pool, &mut tree, backend, new_private)?;
                    // seat slots in BATCH order, so admission sequence
                    // numbers agree with the Admit event's id order — the
                    // victim-selection invariant ("newest = most recently
                    // admitted per the event stream") must hold for mixed
                    // fresh/swapped batches too
                    let mut fresh_iter = fresh.into_iter();
                    let mut swap_iter = swapped_in.into_iter();
                    for &(_, is_swap, _) in &order {
                        next_seq += 1;
                        if is_swap {
                            let (req, e) =
                                swap_iter.next().expect("order/swapped lists diverged");
                            backend.swap_in(req.id)?;
                            swap_ins += 1;
                            swap_bytes += e.bytes;
                            pool.acquire_private(e.bytes);
                            slots.push(Slot {
                                id: req.id,
                                arrival_s: req.arrival_s,
                                tokens: e.tokens,
                                remaining: e.remaining,
                                generated: e.generated,
                                kv_bytes: e.bytes,
                                admit_seq: next_seq,
                                budget: e.budget,
                                blocks: Vec::new(),
                                pending: Vec::new(),
                                state: SlotState::Decoding,
                                // preserved across the host tier: the next
                                // inter-token gap includes the swap dwell
                                last_token_at: e.last_token_at,
                            });
                        } else {
                            let m = fresh_iter.next().expect("order/fresh lists diverged");
                            let replayed0 = m.covered + m.first;
                            let kv_bytes = self.slot_prompt_bytes(replayed0)
                                - self.slot_prompt_bytes(m.covered);
                            pool.acquire_private(kv_bytes);
                            let mut slot = Slot {
                                id: m.req.id,
                                arrival_s: m.req.arrival_s,
                                tokens: m.req.tokens,
                                remaining: m.budget,
                                generated: 0,
                                kv_bytes,
                                admit_seq: next_seq,
                                budget: m.budget,
                                blocks: m.attach,
                                pending: m.pending,
                                state: if replayed0 < m.req.tokens {
                                    SlotState::Prefilling {
                                        next_token: replayed0,
                                        total: m.req.tokens,
                                    }
                                } else {
                                    SlotState::Decoding
                                },
                                last_token_at: now,
                            };
                            flush_ready_blocks(&mut slot, replayed0, &mut pool, backend)?;
                            slots.push(slot);
                        }
                    }
                }
                if pool.cap_bytes > 0 && backend.kv_bytes_in_flight() > pool.cap_bytes {
                    kv_violations += 1;
                }
                now = done;
                continue;
            }

            // ---- one fused chunk+decode iteration for all active slots ----
            if !slots.is_empty() {
                // KV pressure: this iteration grows every decoding slot by
                // one token's full-precision rows and every planned
                // prefilling slot by its chunk's mixed rows; preempt newest
                // slots back to the queue until the growth fits the cap. A
                // lone slot always fits (over-cap requests were rejected at
                // admission). Each victim is resolved by the swap policy:
                // move its cache over the host link when the round trip
                // beats the modeled recompute, else drop it (recompute).
                let mut swap_out_s = 0.0f64;
                let plan = if pool.cap_bytes > 0 {
                    loop {
                        let (plan, growth) = self.plan_chunks(&slots, chunk_budget);
                        if slots.len() <= 1 || pool.fits(growth) {
                            // cached blocks yield before anything new lands
                            reclaim_cached(&mut pool, &mut tree, backend, growth)?;
                            break plan;
                        }
                        let i = newest_slot_index(&slots);
                        let s = slots.remove(i);
                        let occupancy =
                            self.slot_prompt_bytes(s.tokens) + s.generated * self.kv_step_bytes();
                        let swap_this = swap_on
                            && s.state == SlotState::Decoding
                            && swap_policy.swap_beats_recompute(
                                occupancy,
                                self.recompute_cost_s(s.tokens, s.generated, now),
                            );
                        pool.release_private(s.kv_bytes);
                        for &b in &s.blocks {
                            pool.unref_block(b);
                        }
                        // own blocks whose rows never finished replaying
                        // are dropped outright (nothing backs them)
                        if let Some(&(first_pending, _, _)) = s.pending.first() {
                            for b in tree.remove_subtree(first_pending) {
                                pool.drop_unready(b);
                            }
                        }
                        if swap_this {
                            backend.swap_out(s.id)?;
                            events.push(CbEvent::SwapOut { id: s.id });
                            swap_outs += 1;
                            swap_bytes += occupancy;
                            swap_out_s += swap_policy.transfer_s(occupancy);
                            swapped.insert(
                                s.id,
                                SwapEntry {
                                    tokens: s.tokens,
                                    generated: s.generated,
                                    remaining: s.remaining,
                                    budget: s.budget,
                                    bytes: occupancy,
                                    last_token_at: s.last_token_at,
                                },
                            );
                        } else {
                            backend.evict(s.id)?;
                            events.push(CbEvent::Evict { id: s.id });
                            kv_evictions += 1;
                        }
                        if let Some(st) = stats.get_mut(&s.id) {
                            st.queued_since = now; // queueing again
                        }
                        batcher.push(Request {
                            id: s.id,
                            arrival_s: s.arrival_s,
                            tokens: s.tokens,
                        });
                    }
                } else {
                    self.plan_chunks(&slots, chunk_budget).0
                };
                let decode_ids: Vec<u64> = slots
                    .iter()
                    .filter(|s| s.state == SlotState::Decoding)
                    .map(|s| s.id)
                    .collect();
                let b = decode_ids.len();
                let ctx = slots
                    .iter()
                    .filter(|s| s.state == SlotState::Decoding)
                    .map(|s| s.tokens + s.generated)
                    .max()
                    .unwrap_or(0);
                let bd = if plan.is_empty() {
                    // no prefilling slots: the classic batched decode step
                    // (bit-identical pricing to the unchunked scheduler)
                    let step = self.strategy.decode_step_schedule(&self.shape, ctx);
                    evaluate_on_trace_batched(&step, &self.params, &self.trace, now, b)
                } else {
                    // fuse the chunk batch with the piggybacked decode
                    let chunk_tokens: usize = plan.iter().map(|&(_, take)| take).sum();
                    let ctx_prefill = plan
                        .iter()
                        .map(|&(i, take)| match slots[i].state {
                            SlotState::Prefilling { next_token, .. } => next_token + take,
                            SlotState::Decoding => 0,
                        })
                        .max()
                        .unwrap_or(chunk_tokens);
                    let fused = self.strategy.fused_iteration_schedule(
                        &self.shape,
                        chunk_tokens,
                        ctx_prefill,
                        b,
                        ctx,
                    );
                    evaluate_on_trace(&fused, &self.params, &self.trace, now)
                };
                model_time.accumulate(&bd);
                // swap-out transfers ride this iteration's clock (and its
                // comm accounting) — the host link is priced, not free
                model_time.comm_s += swap_out_s;
                let done = now + bd.total() + swap_out_s;
                if done > horizon_s {
                    // the iteration straddles the horizon: nothing advances
                    now = done;
                    continue;
                }
                now = done;
                // chunk effects: record and replay the planned chunks, grow
                // the mixed cache per chunk, release finished prompts into
                // decode (their first decode step — and TTFT — comes next
                // iteration, never fused with their own last chunk)
                for &(i, take) in &plan {
                    let (next_token, total) = match slots[i].state {
                        SlotState::Prefilling { next_token, total } => (next_token, total),
                        SlotState::Decoding => unreachable!("planned a decoding slot"),
                    };
                    events.push(CbEvent::PrefillChunk {
                        id: slots[i].id,
                        lo: next_token,
                        hi: next_token + take,
                    });
                    prefill_chunks += 1;
                    backend.prefill_chunk(slots[i].id, next_token, next_token + take)?;
                    let delta = self.slot_prompt_bytes(next_token + take)
                        - self.slot_prompt_bytes(next_token);
                    pool.acquire_private(delta);
                    slots[i].kv_bytes += delta;
                    slots[i].state = if next_token + take == total {
                        SlotState::Decoding
                    } else {
                        SlotState::Prefilling { next_token: next_token + take, total }
                    };
                    // rows past a block boundary back the slot's own
                    // blocks now: publish them to the shared store
                    flush_ready_blocks(&mut slots[i], next_token + take, &mut pool, backend)?;
                }
                if b > 0 {
                    backend.step(&decode_ids)?;
                    events.push(CbEvent::Decode { ids: decode_ids.clone() });
                }
                let mut i = 0;
                while i < slots.len() {
                    // only the slots that decoded this iteration advance
                    // (a slot whose last chunk just landed waits one turn)
                    if !decode_ids.contains(&slots[i].id) {
                        i += 1;
                        continue;
                    }
                    slots[i].remaining -= 1;
                    slots[i].generated += 1;
                    if slots[i].generated == 1 {
                        // first token this request ever produced: TTFT for
                        // chunked slots (classic slots recorded theirs at
                        // prefill end; the recorded-once guard keeps
                        // re-admitted evictees at their original value)
                        if let Some(st) = stats.get_mut(&slots[i].id) {
                            if !st.ttft_recorded {
                                st.ttft_recorded = true;
                                ttft.add(now - slots[i].arrival_s);
                            }
                        }
                    } else {
                        itl.add(now - slots[i].last_token_at);
                    }
                    slots[i].last_token_at = now;
                    let step_bytes = self.kv_step_bytes();
                    pool.acquire_private(step_bytes);
                    slots[i].kv_bytes += step_bytes;
                    if slots[i].remaining == 0 {
                        let s = slots.swap_remove(i);
                        pool.release_private(s.kv_bytes);
                        // the slot's shared blocks stay resident at
                        // refcount 0 — the "recently freed" prefix a later
                        // request can attach to without any replay
                        for &b in &s.blocks {
                            pool.unref_block(b);
                        }
                        backend.complete(s.id)?;
                        events.push(CbEvent::Complete { id: s.id });
                        tally.record(s.arrival_s, now);
                        queue_wait
                            .add(stats.get(&s.id).map(|st| st.queue_wait_s).unwrap_or(0.0));
                    } else {
                        i += 1;
                    }
                }
                if pool.cap_bytes > 0 && backend.kv_bytes_in_flight() > pool.cap_bytes {
                    kv_violations += 1;
                }
                continue;
            }

            // ---- idle: jump to the next arrival ----
            // (an idle engine force-admits anything admissible, so the
            // queue holds at most KV-blocked requests; those wait for
            // in-flight work that doesn't exist here — meaning the queue
            // is empty whenever the KV gate is off)
            match pending.peek().map(|r| r.arrival_s) {
                Some(t) => now = t,
                None => break,
            }
        }

        // census: everything in flight or queued at the horizon is censored
        for s in &slots {
            censored += 1;
            censored_wait.add((horizon_s - s.arrival_s).max(0.0));
            if let Some(st) = stats.get(&s.id) {
                queue_wait.add(st.queue_wait_s);
            }
        }
        for req in batcher.drain_all() {
            censored += 1;
            censored_wait.add((horizon_s - req.arrival_s).max(0.0));
            // an evicted request waiting for re-admission was still
            // queueing when the horizon fell: close its open episode
            if let Some(st) = stats.get(&req.id) {
                queue_wait.add(st.queue_wait_s + (horizon_s - st.queued_since).max(0.0));
            }
        }
        for req in pending {
            if req.arrival_s < horizon_s {
                censored += 1;
                censored_wait.add(horizon_s - req.arrival_s);
            }
        }

        Ok(CbReport {
            completed: tally.completed,
            censored,
            kv_rejected,
            horizon_s,
            throughput: tally.windows.rate_until(horizon_s),
            throughput_completion: if tally.last_completion > 0.0 {
                tally.completed as f64 / tally.last_completion
            } else {
                0.0
            },
            goodput: tally.within_slo as f64 / horizon_s,
            slo_s: tally.slo,
            latency: tally.latency,
            ttft,
            queue_wait,
            itl,
            censored_wait,
            queue_depth,
            windows: tally.windows.bars_until(horizon_s),
            events,
            prefill_chunks,
            model_time,
            kv_peak_bytes: pool.peak_bytes,
            kv_cap_bytes: pool.cap_bytes,
            kv_evictions,
            kv_violations,
            prefix_hits,
            prefix_hit_tokens,
            admitted_prompt_tokens,
            recompute_flops_saved,
            swap_outs,
            swap_ins,
            swap_bytes,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::shape::VqSetting;
    use crate::parallel::cost::DeviceModel;
    use crate::parallel::strategies::StrategyKind;
    use crate::server::engine::ServeEngine;

    fn astra_engine(cfg: CbConfig) -> CbEngine {
        CbEngine::new(
            TransformerShape::paper_encoder(1024),
            Strategy::new(StrategyKind::Astra { vq: VqSetting::new(16, 1024) }, 4),
            SimParams::paper_encoder(),
            BandwidthTrace::constant(100.0, 1e9),
            cfg,
        )
    }

    fn saturating(n: usize) -> Vec<Request> {
        (0..n as u64).map(|i| Request { id: i, arrival_s: 0.0, tokens: 1024 }).collect()
    }

    #[test]
    fn continuous_batching_doubles_throughput_vs_batch1() {
        // the acceptance bar: max_slots >= 8 yields >= 2x completed
        // requests vs batch-1 FIFO at saturating load, 100 Mbps constant
        let cfg = CbConfig { max_slots: 8, max_batch: 8, decode_tokens: 64, ..CbConfig::default() };
        let mut fifo = astra_engine(cfg.clone().batch1());
        let mut cb = astra_engine(cfg.clone());
        let r_fifo = fifo.serve_stream(saturating(4000), 120.0);
        let r_cb = cb.serve_stream(saturating(4000), 120.0);
        assert!(
            r_cb.completed as f64 >= 2.0 * r_fifo.completed as f64,
            "cb {} vs fifo {}",
            r_cb.completed,
            r_fifo.completed
        );
        assert!(r_fifo.completed > 0);
        // same bar under an open-loop Poisson stream far above capacity
        let mut fifo = astra_engine(cfg.clone().batch1());
        let mut cb = astra_engine(cfg);
        let p_fifo = fifo.serve_poisson(&mut Rng::new(5), 50.0, 120.0);
        let p_cb = cb.serve_poisson(&mut Rng::new(5), 50.0, 120.0);
        assert!(
            p_cb.completed as f64 >= 2.0 * p_fifo.completed as f64,
            "poisson: cb {} vs fifo {}",
            p_cb.completed,
            p_fifo.completed
        );
    }

    #[test]
    fn report_exposes_tail_latency_and_ttft() {
        let mut cb = astra_engine(CbConfig::default());
        let mut rng = Rng::new(3);
        let mut r = cb.serve_poisson(&mut rng, 4.0, 60.0);
        assert!(r.completed > 0, "{r:?}");
        let (p50, p95, p99) = (r.latency.p50(), r.latency.p95(), r.latency.p99());
        assert!(p50 > 0.0 && p50 <= p95 && p95 <= p99, "{p50} {p95} {p99}");
        // TTFT is recorded for every admitted-and-prefilled request and is
        // below the full latency (decode comes after the first token)
        assert!(!r.ttft.is_empty());
        assert!(r.ttft.mean() < r.latency.mean());
        assert!((6..=7).contains(&r.windows.len()), "{}", r.windows.len());
        // the virtual accounting sums every evaluated prefill/decode step
        assert!(r.model_time.total() > 0.0);
        assert!(r.model_time.compute_s > 0.0);
    }

    #[test]
    fn every_request_is_completed_or_censored() {
        let total = 500;
        let mut cb = astra_engine(CbConfig::default());
        let r = cb.serve_stream(saturating(total), 20.0);
        assert_eq!(r.completed + r.censored, total);
        assert!(r.censored > 0, "20 s should not drain 500 saturating requests");
        assert_eq!(r.censored_wait.len(), r.censored);
        assert!(r.mean_queue_depth() > 0.0);
        // with the KV gate off nothing is rejected or evicted
        assert_eq!(r.kv_rejected, 0);
        assert_eq!(r.kv_evictions, 0);
        assert_eq!(r.kv_violations, 0);
    }

    #[test]
    fn goodput_counts_only_within_slo() {
        let mut all = astra_engine(CbConfig { slo_s: 0.0, ..CbConfig::default() });
        let mut tight = astra_engine(CbConfig { slo_s: 1.0, ..CbConfig::default() });
        let r_all = all.serve_stream(saturating(2000), 60.0);
        let r_tight = tight.serve_stream(saturating(2000), 60.0);
        // identical dynamics, different SLO accounting
        assert_eq!(r_all.completed, r_tight.completed);
        assert!((r_all.goodput - r_all.throughput).abs() < 1e-12);
        // under saturation queue waits explode, so a 1 s SLO filters most
        assert!(r_tight.goodput < r_all.goodput);
    }

    #[test]
    fn prefill_only_batch1_matches_fifo_engine() {
        // decode_tokens=0 + slots=1 + batch=1 must reproduce the classic
        // batch-1 FIFO engine's completion count on the same stream
        let shape = TransformerShape::paper_encoder(1024);
        let strat = Strategy::new(StrategyKind::Astra { vq: VqSetting::new(16, 1024) }, 4);
        let params = SimParams::paper_encoder();
        let trace = BandwidthTrace::constant(100.0, 1e9);
        let mut rng = Rng::new(9);
        let mut arrivals = Vec::new();
        let mut t = 0.0;
        for id in 0..300u64 {
            t += rng.exp(6.0);
            arrivals.push(Request { id, arrival_s: t, tokens: 1024 });
        }
        let cfg = CbConfig {
            max_slots: 1,
            max_batch: 1,
            max_wait_s: 0.0,
            decode_tokens: 0,
            ..CbConfig::default()
        };
        let mut cb = CbEngine::new(shape, strat, params.clone(), trace.clone(), cfg);
        let r_cb = cb.serve_stream(arrivals.clone(), 120.0);
        let mut fifo = ServeEngine::new(shape, strat, params, trace);
        let r_fifo = fifo.serve_stream(arrivals, 120.0);
        let diff = (r_cb.completed as i64 - r_fifo.completed as i64).abs();
        assert!(diff <= 1, "cb {} vs fifo {}", r_cb.completed, r_fifo.completed);
    }

    #[test]
    fn kv_gate_defers_admission_and_respects_cap() {
        // cap sized for ~2 full slots: the 8-slot engine must throttle to
        // the budget, never exceed it, and still finish everything
        let cfg = CbConfig { decode_tokens: 32, ..CbConfig::default() };
        let probe = astra_engine(cfg.clone());
        let cap = 2 * probe.kv_projection(1024) + probe.kv_step_bytes();
        let mut capped = astra_engine(CbConfig { kv_cap_bytes: cap, ..cfg.clone() });
        let mut open = astra_engine(cfg);
        let r_capped = capped.serve_stream(saturating(24), 1e4);
        let r_open = open.serve_stream(saturating(24), 1e4);
        assert_eq!(r_capped.completed + r_capped.censored + r_capped.kv_rejected, 24);
        assert_eq!(r_capped.completed, 24, "{r_capped:?}");
        assert!(r_capped.kv_peak_bytes <= cap, "{} > {cap}", r_capped.kv_peak_bytes);
        // without the gate the same workload runs 8 slots deep
        assert!(r_open.kv_peak_bytes > cap, "{} <= {cap}", r_open.kv_peak_bytes);
        // throttled admission serializes work: strictly later completion
        assert!(r_capped.latency.max() >= r_open.latency.max());
    }

    #[test]
    fn kv_pressure_evicts_newest_and_still_completes_everyone() {
        // prompts are cheap but decode growth is not: admit optimistically,
        // then force mid-decode evictions. decode budget 512 over a short
        // 128-token prompt makes growth dominate the prefill footprint.
        let base =
            CbConfig { max_slots: 4, max_batch: 4, decode_tokens: 512, ..CbConfig::default() };
        let probe = CbEngine::new(
            TransformerShape::paper_encoder(128),
            Strategy::new(StrategyKind::Astra { vq: VqSetting::new(16, 1024) }, 4),
            SimParams::paper_encoder(),
            BandwidthTrace::constant(100.0, 1e9),
            base.clone(),
        );
        // all 4 prefill footprints fit, but nowhere near 4 full budgets
        let cap = 2 * probe.kv_projection(128);
        assert!(4 * probe.kv_slot_bytes(128, 0) <= cap);
        assert!(4 * probe.kv_projection(128) > cap);
        let mut engine = CbEngine::new(
            probe.shape,
            probe.strategy,
            probe.params.clone(),
            probe.trace.clone(),
            CbConfig { kv_cap_bytes: cap, ..base },
        );
        let arrivals: Vec<Request> =
            (0..4u64).map(|i| Request { id: i, arrival_s: 0.0, tokens: 128 }).collect();
        let r = engine.serve_stream(arrivals, 1e4);
        assert!(r.kv_evictions > 0, "pressure must trigger eviction: {r:?}");
        assert!(r.events.iter().any(|e| matches!(e, CbEvent::Evict { .. })));
        assert!(r.kv_peak_bytes <= cap, "{} > {cap}", r.kv_peak_bytes);
        // evicted requests are requeued and re-prefilled, not lost
        assert_eq!(r.completed, 4, "{r:?}");
        assert_eq!(r.kv_rejected, 0);
    }

    #[test]
    fn oversized_requests_are_rejected_not_hung() {
        // a request whose full budget exceeds the cap outright must be
        // dropped (Reject event), letting the rest of the queue proceed
        let cfg = CbConfig { decode_tokens: 32, ..CbConfig::default() };
        let probe = astra_engine(cfg.clone());
        let cap = probe.kv_projection(1024) + probe.kv_step_bytes();
        let mut engine = astra_engine(CbConfig { kv_cap_bytes: cap, ..cfg });
        // tokens=2048 projects past the cap; tokens=1024 fits
        let arrivals = vec![
            Request { id: 1, arrival_s: 0.0, tokens: 2048 },
            Request { id: 2, arrival_s: 0.0, tokens: 1024 },
            Request { id: 3, arrival_s: 0.0, tokens: 1024 },
        ];
        let r = engine.serve_stream(arrivals, 1e4);
        assert_eq!(r.kv_rejected, 1, "{r:?}");
        assert!(r.events.contains(&CbEvent::Reject { id: 1 }));
        assert_eq!(r.completed, 2);
        assert_eq!(r.completed + r.censored + r.kv_rejected, 3);
    }

    #[test]
    fn oversized_request_behind_the_head_is_never_admitted() {
        // a request whose *prefill footprint* fits but whose full budget
        // does not must not sneak into a slot from behind an admissible
        // head — a lone oversized slot would outgrow the cap with nothing
        // to evict. It waits, reaches the head, and is rejected there.
        let cfg = CbConfig { decode_tokens: 32, max_wait_s: 0.0, ..CbConfig::default() };
        let probe = astra_engine(cfg.clone());
        // cap sits between the 2048-token prefill footprint and its full
        // projection, and above two 512-token full projections
        let cap = probe.kv_slot_bytes(2048, 0) + 16 * probe.kv_step_bytes();
        assert!(probe.kv_slot_bytes(2048, 0) <= cap);
        assert!(probe.kv_projection(2048) > cap);
        assert!(2 * probe.kv_projection(512) < cap);
        let mut engine = astra_engine(CbConfig { kv_cap_bytes: cap, ..cfg });
        let arrivals = vec![
            Request { id: 1, arrival_s: 0.0, tokens: 512 },
            Request { id: 2, arrival_s: 0.0, tokens: 2048 },
            Request { id: 3, arrival_s: 0.0, tokens: 512 },
        ];
        let r = engine.serve_stream(arrivals, 1e4);
        // id 2 was rejected (once at the head), never admitted, and the
        // cap was never breached by an unevictable lone slot
        assert_eq!(r.kv_rejected, 1, "{r:?}");
        assert!(r.events.contains(&CbEvent::Reject { id: 2 }));
        assert!(!r
            .events
            .iter()
            .any(|e| matches!(e, CbEvent::Admit { ids } if ids.contains(&2))));
        assert_eq!(r.completed, 2);
        assert!(r.kv_peak_bytes <= cap, "{} > {cap}", r.kv_peak_bytes);
        assert_eq!(r.kv_evictions, 0);
    }

    #[test]
    fn chunk_budget_at_or_above_prompts_reproduces_unchunked_stream() {
        // the regression anchor: a budget >= the longest prompt — and the
        // disabled default — must yield the unchunked scheduler's event
        // stream bit for bit (every prompt fits its admission chunk, so
        // the classic monopolizing path runs unchanged)
        let base = CbConfig { max_batch: 4, decode_tokens: 16, ..CbConfig::default() };
        let mut unchunked = astra_engine(base.clone());
        let ra = unchunked.serve_poisson(&mut Rng::new(11), 12.0, 40.0);
        for chunk in [1024usize, 1500, usize::MAX / 2] {
            let mut chunked =
                astra_engine(CbConfig { prefill_chunk_tokens: chunk, ..base.clone() });
            let rb = chunked.serve_poisson(&mut Rng::new(11), 12.0, 40.0);
            assert_eq!(ra.events, rb.events, "chunk={chunk}");
            assert_eq!(ra.completed, rb.completed, "chunk={chunk}");
            assert_eq!(rb.prefill_chunks, 0, "chunk={chunk}");
            assert_eq!(ra.ttft.len(), rb.ttft.len(), "chunk={chunk}");
            assert_eq!(ra.queue_wait.len(), rb.queue_wait.len(), "chunk={chunk}");
        }
    }

    #[test]
    fn chunk_events_tile_prompts_and_interleave_with_decode() {
        let cfg = CbConfig {
            max_slots: 4,
            max_batch: 2,
            decode_tokens: 8,
            prefill_chunk_tokens: 192,
            ..CbConfig::default()
        };
        let mut cb = astra_engine(cfg);
        let r = cb.serve_stream(saturating(12), 1e4);
        assert_eq!(r.completed, 12);
        assert!(r.prefill_chunks > 0, "{r:?}");
        // per request: admission chunk [0, 192) then fused chunks tiling
        // the rest of the 1024-token prompt contiguously, in order
        let mut progress: std::collections::BTreeMap<u64, usize> = Default::default();
        let mut saw_decode = false;
        let mut chunk_after_decode = false;
        for e in &r.events {
            match e {
                CbEvent::PrefillChunk { id, lo, hi } => {
                    let p = progress.entry(*id).or_insert(0);
                    assert_eq!(*lo, *p, "request {id}: chunk out of order");
                    assert!(hi > lo, "request {id}: empty chunk");
                    assert!(hi - lo <= 192, "request {id}: chunk over budget");
                    *p = *hi;
                    if saw_decode {
                        chunk_after_decode = true;
                    }
                }
                CbEvent::Decode { .. } => saw_decode = true,
                _ => {}
            }
        }
        assert_eq!(progress.len(), 12);
        for (id, p) in &progress {
            assert_eq!(*p, 1024, "request {id}: prompt not fully chunked");
        }
        assert!(chunk_after_decode, "chunks never interleaved with decode");
        // every request still decodes its full budget after its last chunk
        let steps: usize = r
            .events
            .iter()
            .map(|e| match e {
                CbEvent::Decode { ids } => ids.len(),
                _ => 0,
            })
            .sum();
        assert_eq!(steps, 12 * 8);
    }

    #[test]
    fn evicted_requests_report_ttft_and_queue_wait_once() {
        // regression (eviction-thrash trace): re-admission used to push a
        // second, larger TTFT sample measured to the re-prefill, and to
        // re-add a queue wait spanning in-service time. Now TTFT is
        // recorded once — original arrival to the first token ever emitted
        // — and queue wait sums only the actual queueing episodes.
        let base =
            CbConfig { max_slots: 4, max_batch: 4, decode_tokens: 512, ..CbConfig::default() };
        let probe = CbEngine::new(
            TransformerShape::paper_encoder(128),
            Strategy::new(StrategyKind::Astra { vq: VqSetting::new(16, 1024) }, 4),
            SimParams::paper_encoder(),
            BandwidthTrace::constant(100.0, 1e9),
            base.clone(),
        );
        let cap = 2 * probe.kv_projection(128);
        let mut engine = CbEngine::new(
            probe.shape,
            probe.strategy,
            probe.params.clone(),
            probe.trace.clone(),
            CbConfig { kv_cap_bytes: cap, ..base },
        );
        let arrivals: Vec<Request> =
            (0..4u64).map(|i| Request { id: i, arrival_s: 0.0, tokens: 128 }).collect();
        let r = engine.serve_stream(arrivals, 1e4);
        assert!(r.kv_evictions > 0, "thrash trace must evict: {r:?}");
        assert_eq!(r.completed, 4);
        // one TTFT and one queue-wait sample per request, no duplicates
        assert_eq!(r.ttft.len(), 4, "{r:?}");
        assert_eq!(r.queue_wait.len(), 4);
        // first-token latency can never exceed the full latency
        assert!(r.ttft.max() <= r.latency.max() + 1e-12);
        // all four arrived at 0 and were admitted immediately, so queue
        // wait is exactly the post-eviction requeue time: zero for the
        // never-evicted oldest, positive but below wall latency for the
        // evicted (in-service time no longer counts as waiting)
        assert!(r.queue_wait.min() < 1e-12, "someone was never evicted: {r:?}");
        assert!(r.queue_wait.max() > 0.0);
        assert!(r.queue_wait.max() < r.latency.max());
    }

    #[test]
    fn chunked_prefill_cuts_decode_stalls_at_throughput_parity() {
        // the tentpole acceptance bar, long prompts (T=1024) + short
        // decode: mixing bounded prefill chunks into decode iterations must
        // cut the p95 inter-token stall of in-flight decode slots while
        // completed throughput stays within 5%. Launch/sync overheads use a
        // graph-captured-runtime calibration (per-chunk overheads at the
        // paper 1660Ti's 0.2 ms/launch would swamp the fusion win).
        let device =
            DeviceModel { per_layer_overhead_s: 1e-5, ..DeviceModel::paper_1660ti() };
        let params = SimParams { device, stage_latency_s: 5e-5 };
        let base = CbConfig {
            max_slots: 8,
            // small admission batches so completions stagger and there are
            // always in-flight decoders for a prefill to stall
            max_batch: 2,
            decode_tokens: 32,
            ..CbConfig::default()
        };
        let mk = |cfg: CbConfig| {
            CbEngine::new(
                TransformerShape::paper_encoder(1024),
                Strategy::new(StrategyKind::Astra { vq: VqSetting::new(16, 1024) }, 4),
                params.clone(),
                BandwidthTrace::constant(100.0, 1e9),
                cfg,
            )
        };
        let chunked_cfg = CbConfig { prefill_chunk_tokens: 512, ..base.clone() };

        // ITL contrast under heavy open-loop load (~0.8x capacity: slots
        // stay busy and admissions constantly interleave with decode)
        let mut r_mono = mk(base.clone()).serve_poisson(&mut Rng::new(17), 16.0, 30.0);
        let mut r_chunk = mk(chunked_cfg.clone()).serve_poisson(&mut Rng::new(17), 16.0, 30.0);
        assert!(r_chunk.prefill_chunks > 0);
        assert_eq!(r_mono.prefill_chunks, 0);
        assert!(r_mono.itl.len() > 1000, "{}", r_mono.itl.len());
        assert!(r_chunk.itl.len() > 1000, "{}", r_chunk.itl.len());
        let (p_mono, p_chunk) = (r_mono.itl.p95(), r_chunk.itl.p95());
        assert!(p_chunk < 0.9 * p_mono, "chunked p95 ITL {p_chunk} vs monopolizing {p_mono}");
        assert!(
            r_chunk.completed as f64 >= 0.95 * r_mono.completed as f64,
            "chunked {} vs monopolizing {}",
            r_chunk.completed,
            r_mono.completed
        );

        // completed-throughput parity at full saturation
        let s_mono = mk(base).serve_stream(saturating(4000), 30.0);
        let s_chunk = mk(chunked_cfg).serve_stream(saturating(4000), 30.0);
        assert!(s_mono.completed > 50, "{}", s_mono.completed);
        assert!(
            s_chunk.completed as f64 >= 0.95 * s_mono.completed as f64,
            "chunked {} vs monopolizing {}",
            s_chunk.completed,
            s_mono.completed
        );
    }

    fn mk_slot(id: u64, admit_seq: u64) -> Slot {
        Slot {
            id,
            arrival_s: 0.0,
            tokens: 8,
            remaining: 1,
            generated: 0,
            kv_bytes: 0,
            admit_seq,
            budget: 1,
            blocks: Vec::new(),
            pending: Vec::new(),
            state: SlotState::Decoding,
            last_token_at: 0.0,
        }
    }

    #[test]
    fn newest_slot_is_latest_admission_not_largest_id() {
        // regression (eviction victim selection): after an eviction wave
        // requeues [3, 2] and both readmit in one batch, id 3 holds the
        // earlier admission sequence. The victim must be id 2 — the most
        // recently readmitted slot — where the old (admitted_at, id)
        // tiebreak picked id 3 because the batch shared one timestamp.
        let slots = vec![mk_slot(0, 0), mk_slot(1, 1), mk_slot(3, 4), mk_slot(2, 5)];
        assert_eq!(newest_slot_index(&slots), 3, "index of id 2 (seq 5)");
        // unique sequences: order of insertion never matters
        let slots = vec![mk_slot(2, 5), mk_slot(3, 4), mk_slot(0, 0)];
        assert_eq!(newest_slot_index(&slots), 0);
    }

    #[test]
    fn eviction_victims_follow_current_episode_admission_order() {
        // the spec the admit_seq fix enforces, checked over the whole
        // eviction-thrash event stream: every preemption victim is the most
        // recently (re)admitted slot still in flight — replaying the event
        // stream with an admission-ordered shadow list must always evict
        // its tail element, never the oldest
        let base =
            CbConfig { max_slots: 4, max_batch: 4, decode_tokens: 512, ..CbConfig::default() };
        let probe = CbEngine::new(
            TransformerShape::paper_encoder(128),
            Strategy::new(StrategyKind::Astra { vq: VqSetting::new(16, 1024) }, 4),
            SimParams::paper_encoder(),
            BandwidthTrace::constant(100.0, 1e9),
            base.clone(),
        );
        let cap = 2 * probe.kv_projection(128);
        let mut engine = CbEngine::new(
            probe.shape,
            probe.strategy,
            probe.params.clone(),
            probe.trace.clone(),
            CbConfig { kv_cap_bytes: cap, ..base },
        );
        let arrivals: Vec<Request> =
            (0..4u64).map(|i| Request { id: i, arrival_s: 0.0, tokens: 128 }).collect();
        let r = engine.serve_stream(arrivals, 1e4);
        assert!(r.kv_evictions > 0, "thrash trace must evict: {r:?}");
        assert_eq!(r.completed, 4);
        let mut in_flight: Vec<u64> = Vec::new(); // admission order, oldest first
        for e in &r.events {
            match e {
                CbEvent::Admit { ids } => in_flight.extend(ids.iter().copied()),
                CbEvent::Evict { id } | CbEvent::SwapOut { id } => {
                    assert!(in_flight.len() > 1, "a lone slot must never be evicted");
                    assert_eq!(
                        in_flight.last(),
                        Some(id),
                        "victim {id} is not the most recently admitted of {in_flight:?}"
                    );
                    in_flight.pop();
                }
                CbEvent::Complete { id } => in_flight.retain(|x| x != id),
                _ => {}
            }
        }
    }

    #[test]
    fn prefix_cache_with_oversized_blocks_reproduces_baseline_stream() {
        // sharing anchor: a block size above every prompt makes attachment
        // impossible, and full-length prompts make positional accounting
        // coincide with the classic bytes — so --prefix-cache with such
        // blocks must reproduce the prefix-off event stream bit for bit,
        // capped or not
        let base = CbConfig { max_batch: 4, decode_tokens: 16, ..CbConfig::default() };
        let probe = astra_engine(base.clone());
        let cap = 2 * probe.kv_projection(1024) + probe.kv_step_bytes();
        for kv_cap_bytes in [0usize, cap] {
            let off = CbConfig { kv_cap_bytes, ..base.clone() };
            let on = CbConfig {
                prefix_cache: true,
                kv_block_tokens: 2048,
                prompt_groups: 1,
                seed: 9,
                ..off.clone()
            };
            let ra = astra_engine(off).serve_poisson(&mut Rng::new(13), 12.0, 40.0);
            let rb = astra_engine(on).serve_poisson(&mut Rng::new(13), 12.0, 40.0);
            assert_eq!(ra.events, rb.events, "cap={kv_cap_bytes}");
            assert_eq!(ra.completed, rb.completed, "cap={kv_cap_bytes}");
            assert_eq!(rb.prefix_hits, 0, "cap={kv_cap_bytes}");
            assert_eq!(ra.kv_peak_bytes, rb.kv_peak_bytes, "cap={kv_cap_bytes}");
        }
    }

    #[test]
    fn prefix_cache_attaches_shared_prompts_and_charges_suffix_only() {
        // one prompt group: every request shares the whole (block-aligned)
        // prompt. After the first creator replays, later admissions attach
        // to resident or recently-freed blocks — PrefixHit events, high
        // token hit rate, and a lower byte peak than the unshared run
        let base = CbConfig {
            max_slots: 8,
            max_batch: 4,
            decode_tokens: 8,
            ..CbConfig::default()
        };
        let shared = CbConfig {
            prefix_cache: true,
            kv_block_tokens: 64,
            prompt_groups: 1,
            seed: 5,
            ..base.clone()
        };
        let r_plain = astra_engine(base).serve_stream(saturating(24), 1e4);
        let mut cb = astra_engine(shared);
        let r = cb.serve_stream(saturating(24), 1e4);
        assert_eq!(r.completed, 24, "{r:?}");
        assert!(r.prefix_hits > 0, "{r:?}");
        assert!(r.events.iter().any(|e| matches!(e, CbEvent::PrefixHit { .. })));
        // block-aligned coverage, counted against admitted prompt tokens
        assert_eq!(r.prefix_hit_tokens % 64, 0);
        assert_eq!(r.admitted_prompt_tokens, 24 * 1024);
        assert!(r.prefix_hit_rate() > 0.5, "hit rate {}", r.prefix_hit_rate());
        assert!(r.recompute_flops_saved > 0.0);
        // identical prompts shared once: resident peak far below unshared
        assert!(
            r.kv_peak_bytes < r_plain.kv_peak_bytes,
            "{} !< {}",
            r.kv_peak_bytes,
            r_plain.kv_peak_bytes
        );
        // a fully covered admission replays nothing and still completes:
        // its slot decodes the full budget (steps counted per id)
        let steps: usize = r
            .events
            .iter()
            .map(|e| match e {
                CbEvent::Decode { ids } => ids.len(),
                _ => 0,
            })
            .sum();
        assert_eq!(steps, 24 * 8);
    }

    #[test]
    fn negligible_swap_bandwidth_reproduces_recompute_stream() {
        // the swap decision prices the transfer; at ~0 bandwidth it can
        // never beat recompute, so the stream must equal the swap-off run
        // bit for bit and no Swap events may appear
        let base =
            CbConfig { max_slots: 4, max_batch: 4, decode_tokens: 512, ..CbConfig::default() };
        let probe = CbEngine::new(
            TransformerShape::paper_encoder(128),
            Strategy::new(StrategyKind::Astra { vq: VqSetting::new(16, 1024) }, 4),
            SimParams::paper_encoder(),
            BandwidthTrace::constant(100.0, 1e9),
            base.clone(),
        );
        let cap = 2 * probe.kv_projection(128);
        let mk = |swap_mbps: f64| {
            CbEngine::new(
                probe.shape,
                probe.strategy,
                probe.params.clone(),
                probe.trace.clone(),
                CbConfig {
                    kv_cap_bytes: cap,
                    swap_bandwidth_mbps: swap_mbps,
                    ..base.clone()
                },
            )
        };
        let arrivals: Vec<Request> =
            (0..4u64).map(|i| Request { id: i, arrival_s: 0.0, tokens: 128 }).collect();
        let r_off = mk(0.0).serve_stream(arrivals.clone(), 1e4);
        let r_slow = mk(1e-6).serve_stream(arrivals, 1e4);
        assert!(r_off.kv_evictions > 0);
        assert_eq!(r_off.events, r_slow.events);
        assert_eq!(r_slow.swap_outs, 0);
        assert_eq!(r_slow.swap_bytes, 0);
        assert!(!r_slow.events.iter().any(|e| matches!(e, CbEvent::SwapOut { .. })));
    }

    #[test]
    fn fast_host_link_swaps_and_preserves_decode_progress() {
        // with a fast host link the round trip beats re-prefill +
        // regeneration, so pressure victims swap: SwapOut/SwapIn events,
        // byte traffic, and — the point of swapping — total decode steps
        // equal the exact budget (recompute restarts waste steps)
        let base =
            CbConfig { max_slots: 4, max_batch: 4, decode_tokens: 512, ..CbConfig::default() };
        let probe = CbEngine::new(
            TransformerShape::paper_encoder(128),
            Strategy::new(StrategyKind::Astra { vq: VqSetting::new(16, 1024) }, 4),
            SimParams::paper_encoder(),
            BandwidthTrace::constant(100.0, 1e9),
            base.clone(),
        );
        let cap = 2 * probe.kv_projection(128);
        let mk = |swap_mbps: f64| {
            CbEngine::new(
                probe.shape,
                probe.strategy,
                probe.params.clone(),
                probe.trace.clone(),
                CbConfig {
                    kv_cap_bytes: cap,
                    swap_bandwidth_mbps: swap_mbps,
                    ..base.clone()
                },
            )
        };
        let arrivals: Vec<Request> =
            (0..4u64).map(|i| Request { id: i, arrival_s: 0.0, tokens: 128 }).collect();
        let steps_of = |r: &CbReport| -> usize {
            r.events
                .iter()
                .map(|e| match e {
                    CbEvent::Decode { ids } => ids.len(),
                    _ => 0,
                })
                .sum()
        };
        let r_swap = mk(1e6).serve_stream(arrivals.clone(), 1e5);
        let r_recompute = mk(0.0).serve_stream(arrivals, 1e5);
        assert_eq!(r_swap.completed, 4, "{r_swap:?}");
        assert!(r_swap.swap_outs > 0, "{r_swap:?}");
        assert_eq!(r_swap.swap_outs, r_swap.swap_ins, "everything swapped back in");
        assert!(r_swap.swap_bytes > 0);
        assert!(r_swap.events.iter().any(|e| matches!(e, CbEvent::SwapOut { .. })));
        assert!(r_swap.events.iter().any(|e| matches!(e, CbEvent::SwapIn { .. })));
        // progress preserved: exactly budget steps per request
        assert_eq!(steps_of(&r_swap), 4 * 512);
        // recompute thrash regenerates: strictly more raw decode steps
        assert!(r_recompute.kv_evictions > 0);
        assert!(steps_of(&r_recompute) > 4 * 512, "{}", steps_of(&r_recompute));
    }

    #[test]
    fn decode_jitter_staggers_completions_within_bounds() {
        let base = CbConfig {
            max_slots: 8,
            max_batch: 8,
            decode_tokens: 64,
            decode_jitter: 16,
            seed: 21,
            ..CbConfig::default()
        };
        let probe = astra_engine(base.clone());
        // budgets are deterministic in (seed, id) and stay inside ± jitter
        let mut distinct = std::collections::BTreeSet::new();
        for id in 0..64u64 {
            let b = probe.decode_budget(id);
            assert!((48..=80).contains(&b), "id {id}: budget {b}");
            assert_eq!(b, probe.decode_budget(id), "id {id}: not deterministic");
            distinct.insert(b);
        }
        assert!(distinct.len() > 4, "jitter produced only {distinct:?}");
        // a same-length wave no longer completes in lockstep: per-request
        // decode step counts differ, and completions spread over several
        // distinct iterations rather than one tail burst
        let mut cb = astra_engine(base.clone());
        let r = cb.serve_stream(saturating(8), 1e4);
        assert_eq!(r.completed, 8);
        let mut steps: BTreeMap<u64, usize> = BTreeMap::new();
        let mut completes_after_decodes: Vec<usize> = Vec::new();
        let mut decodes = 0usize;
        for e in &r.events {
            match e {
                CbEvent::Decode { ids } => {
                    decodes += 1;
                    for id in ids {
                        *steps.entry(*id).or_insert(0) += 1;
                    }
                }
                CbEvent::Complete { id } => {
                    completes_after_decodes.push(decodes);
                    assert_eq!(steps[id], cb.decode_budget(*id), "request {id}");
                }
                _ => {}
            }
        }
        let spread: std::collections::BTreeSet<usize> =
            completes_after_decodes.iter().copied().collect();
        assert!(spread.len() > 1, "jittered wave still completed in lockstep");
        // the jitter-off control: every budget identical, one tail burst
        let mut plain = astra_engine(CbConfig { decode_jitter: 0, ..base });
        let rp = plain.serve_stream(saturating(8), 1e4);
        let plain_steps: usize = rp
            .events
            .iter()
            .map(|e| match e {
                CbEvent::Decode { ids } => ids.len(),
                _ => 0,
            })
            .sum();
        assert_eq!(plain_steps, 8 * 64);
    }

    #[test]
    fn event_stream_is_a_complete_record() {
        let mut cb = astra_engine(CbConfig { decode_tokens: 4, ..CbConfig::default() });
        let r = cb.serve_stream(saturating(20), 1e4);
        assert_eq!(r.completed, 20);
        let admits: usize = r
            .events
            .iter()
            .map(|e| match e {
                CbEvent::Admit { ids } => ids.len(),
                _ => 0,
            })
            .sum();
        let completes =
            r.events.iter().filter(|e| matches!(e, CbEvent::Complete { .. })).count();
        assert_eq!(admits, 20);
        assert_eq!(completes, 20);
        // every slot advanced exactly decode_tokens times
        let steps: usize = r
            .events
            .iter()
            .map(|e| match e {
                CbEvent::Decode { ids } => ids.len(),
                _ => 0,
            })
            .sum();
        assert_eq!(steps, 20 * 4);
    }
}
