//! Continuous-batching serve scheduler over the cost model.
//!
//! Replaces the batch-1 FIFO loop for load testing: requests are admitted
//! into `max_slots` in-flight decode slots (vLLM/Orca-style continuous
//! batching), prefill batches are formed by the [`Batcher`]'s deadline/fill
//! logic, and each scheduler iteration either
//!
//!  * runs one *batched prefill* for newly admitted requests — compute and
//!    wire bits scale with the batch, kernel launches and collective sync
//!    stages are paid once ([`crate::parallel::cost::Phase::for_batch`]) — or
//!  * runs one *batched decode step* advancing every active slot by one
//!    token — single-token decode is memory-bound (one streaming pass over
//!    the weights), so co-scheduled slots share that floor almost for free.
//!
//! The engine reports tail latency (p50/p95/p99), time-to-first-token,
//! queue depth over time, goodput under an SLO, and both horizon- and
//! completion-based throughput, with censored (unfinished) requests
//! accounted separately.

use crate::comm::trace::BandwidthTrace;
use crate::model::TransformerShape;
use crate::parallel::strategies::Strategy;
use crate::sim::latency::{evaluate_on_trace_batched, SimParams};
use crate::util::rng::Rng;
use crate::util::stats::{Summary, WindowedCounter};

use super::batcher::{Batcher, Request};

/// Continuous-batching policy knobs.
#[derive(Debug, Clone)]
pub struct CbConfig {
    /// in-flight decode slots (1 degenerates to the batch-1 FIFO baseline)
    pub max_slots: usize,
    /// prefill admission batch cap (the batcher's fill target)
    pub max_batch: usize,
    /// batcher deadline: admit a partial batch once the oldest queued
    /// request has waited this long
    pub max_wait_s: f64,
    /// tokens generated per request after prefill (0 = prefill-only)
    pub decode_tokens: usize,
    /// end-to-end latency SLO for goodput (<= 0 disables the SLO filter)
    pub slo_s: f64,
    /// completion-bar window (Fig 6 style)
    pub window_s: f64,
}

impl Default for CbConfig {
    fn default() -> CbConfig {
        CbConfig {
            max_slots: 8,
            max_batch: 8,
            max_wait_s: 0.02,
            decode_tokens: 64,
            slo_s: 0.0,
            window_s: 10.0,
        }
    }
}

impl CbConfig {
    /// The batch-1 FIFO baseline (the paper's Fig-6 setting) with the same
    /// workload shape — for apples-to-apples comparisons.
    pub fn batch1(self) -> CbConfig {
        CbConfig { max_slots: 1, max_batch: 1, ..self }
    }
}

/// Outcome of a continuous-batching serve run.
#[derive(Debug)]
pub struct CbReport {
    pub completed: usize,
    /// admitted or queued inside the horizon but not completed by it
    pub censored: usize,
    pub horizon_s: f64,
    /// completed / horizon
    pub throughput: f64,
    /// completed / time of last completion (unbiased under early-ending
    /// arrival streams)
    pub throughput_completion: f64,
    /// completions per second that met the SLO (equals `throughput` when
    /// the SLO is disabled)
    pub goodput: f64,
    pub slo_s: f64,
    /// end-to-end latency of completed requests (p50/p95/p99 via Summary)
    pub latency: Summary,
    /// time to first token (prefill end - arrival) of admitted requests
    /// whose prefill finished inside the horizon
    pub ttft: Summary,
    /// queue wait (admission - arrival) of admitted requests
    pub queue_wait: Summary,
    /// queue wait accrued by censored requests up to the horizon
    pub censored_wait: Summary,
    /// (time, queued requests) samples taken at admission decisions
    pub queue_depth: Vec<(f64, usize)>,
    /// completion bars covering the whole horizon
    pub windows: Vec<usize>,
}

impl CbReport {
    /// Mean of the queue-depth samples (0 when nothing was ever queued).
    pub fn mean_queue_depth(&self) -> f64 {
        if self.queue_depth.is_empty() {
            return 0.0;
        }
        self.queue_depth.iter().map(|&(_, d)| d as f64).sum::<f64>()
            / self.queue_depth.len() as f64
    }
}

/// One in-flight request occupying a decode slot.
#[derive(Debug, Clone, Copy)]
struct Slot {
    arrival_s: f64,
    remaining: usize,
    generated: usize,
}

/// Continuous-batching cost-model serving engine.
pub struct CbEngine {
    pub shape: TransformerShape,
    pub strategy: Strategy,
    pub params: SimParams,
    pub trace: BandwidthTrace,
    pub cfg: CbConfig,
}

impl CbEngine {
    pub fn new(
        shape: TransformerShape,
        strategy: Strategy,
        params: SimParams,
        trace: BandwidthTrace,
        cfg: CbConfig,
    ) -> CbEngine {
        CbEngine { shape, strategy, params, trace, cfg }
    }

    /// Serve an open-loop Poisson stream at `rate` req/s for `horizon_s`.
    pub fn serve_poisson(&mut self, rng: &mut Rng, rate: f64, horizon_s: f64) -> CbReport {
        let arrivals =
            super::batcher::poisson_arrivals(rng, rate, horizon_s, self.shape.seq_len);
        self.serve_stream(arrivals, horizon_s)
    }

    /// Serve a fixed arrival list under continuous batching.
    pub fn serve_stream(&mut self, arrivals: Vec<Request>, horizon_s: f64) -> CbReport {
        let prefill = self.strategy.schedule(&self.shape);
        let max_slots = self.cfg.max_slots.max(1);
        let mut batcher = Batcher::new(self.cfg.max_batch.max(1), self.cfg.max_wait_s);
        let mut slots: Vec<Slot> = Vec::new();
        let mut pending = arrivals.into_iter().peekable();

        let mut now = 0.0f64;
        let mut latency = Summary::new();
        let mut ttft = Summary::new();
        let mut queue_wait = Summary::new();
        let mut censored_wait = Summary::new();
        let mut queue_depth: Vec<(f64, usize)> = Vec::new();
        let mut windows = WindowedCounter::new(self.cfg.window_s);
        let mut completed = 0usize;
        let mut within_slo = 0usize;
        let mut censored = 0usize;
        let mut last_completion = 0.0f64;

        let slo = self.cfg.slo_s;
        let mut complete =
            |arrival_s: f64, done: f64, latency: &mut Summary, windows: &mut WindowedCounter| {
                completed += 1;
                let l = done - arrival_s;
                latency.add(l);
                windows.record(done);
                last_completion = done;
                if slo <= 0.0 || l <= slo {
                    within_slo += 1;
                }
            };

        while now < horizon_s {
            // pull arrivals into the queue
            while let Some(r) = pending.peek() {
                if r.arrival_s <= now {
                    batcher.push(pending.next().unwrap());
                } else {
                    break;
                }
            }

            // ---- admission: batched prefill into free slots ----
            let free = max_slots.saturating_sub(slots.len());
            // an idle cluster never waits on the fill deadline
            let force = slots.is_empty();
            let batch =
                if free > 0 { batcher.next_batch_capped(now, force, free) } else { Vec::new() };
            if !batch.is_empty() {
                queue_depth.push((now, batcher.len()));
                let b = batch.len();
                let bd = evaluate_on_trace_batched(&prefill, &self.params, &self.trace, now, b);
                let done = now + bd.total();
                for req in &batch {
                    queue_wait.add(now - req.arrival_s);
                    if done <= horizon_s {
                        ttft.add(done - req.arrival_s);
                    }
                }
                if self.cfg.decode_tokens == 0 {
                    // prefill-only workload: requests complete at prefill end
                    for req in &batch {
                        if done <= horizon_s {
                            complete(req.arrival_s, done, &mut latency, &mut windows);
                        } else {
                            censored += 1;
                            censored_wait.add(now - req.arrival_s);
                        }
                    }
                } else {
                    for req in &batch {
                        slots.push(Slot {
                            arrival_s: req.arrival_s,
                            remaining: self.cfg.decode_tokens,
                            generated: 0,
                        });
                    }
                }
                now = done;
                continue;
            }

            // ---- one batched decode step for all active slots ----
            if !slots.is_empty() {
                let b = slots.len();
                let ctx = self.shape.seq_len
                    + slots.iter().map(|s| s.generated).max().unwrap_or(0);
                let step = self.strategy.decode_step_schedule(&self.shape, ctx);
                let bd = evaluate_on_trace_batched(&step, &self.params, &self.trace, now, b);
                let done = now + bd.total();
                if done > horizon_s {
                    // the step straddles the horizon: nobody finishes in time
                    now = done;
                    continue;
                }
                now = done;
                let mut i = 0;
                while i < slots.len() {
                    slots[i].remaining -= 1;
                    slots[i].generated += 1;
                    if slots[i].remaining == 0 {
                        let s = slots.swap_remove(i);
                        complete(s.arrival_s, now, &mut latency, &mut windows);
                    } else {
                        i += 1;
                    }
                }
                continue;
            }

            // ---- idle: jump to the next arrival ----
            // (an idle engine force-admits, so the queue is empty here)
            match pending.peek().map(|r| r.arrival_s) {
                Some(t) => now = t,
                None => break,
            }
        }
        drop(complete);

        // census: everything in flight or queued at the horizon is censored
        for s in &slots {
            censored += 1;
            censored_wait.add((horizon_s - s.arrival_s).max(0.0));
        }
        for req in batcher.drain_all() {
            censored += 1;
            censored_wait.add((horizon_s - req.arrival_s).max(0.0));
        }
        for req in pending {
            if req.arrival_s < horizon_s {
                censored += 1;
                censored_wait.add(horizon_s - req.arrival_s);
            }
        }

        CbReport {
            completed,
            censored,
            horizon_s,
            throughput: windows.rate_until(horizon_s),
            throughput_completion: if last_completion > 0.0 {
                completed as f64 / last_completion
            } else {
                0.0
            },
            goodput: within_slo as f64 / horizon_s,
            slo_s: slo,
            latency,
            ttft,
            queue_wait,
            censored_wait,
            queue_depth,
            windows: windows.bars_until(horizon_s),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::shape::VqSetting;
    use crate::parallel::strategies::StrategyKind;
    use crate::server::engine::ServeEngine;

    fn astra_engine(cfg: CbConfig) -> CbEngine {
        CbEngine::new(
            TransformerShape::paper_encoder(1024),
            Strategy::new(StrategyKind::Astra { vq: VqSetting::new(16, 1024) }, 4),
            SimParams::paper_encoder(),
            BandwidthTrace::constant(100.0, 1e9),
            cfg,
        )
    }

    fn saturating(n: usize) -> Vec<Request> {
        (0..n as u64).map(|i| Request { id: i, arrival_s: 0.0, tokens: 1024 }).collect()
    }

    #[test]
    fn continuous_batching_doubles_throughput_vs_batch1() {
        // the acceptance bar: max_slots >= 8 yields >= 2x completed
        // requests vs batch-1 FIFO at saturating load, 100 Mbps constant
        let cfg = CbConfig { max_slots: 8, max_batch: 8, decode_tokens: 64, ..CbConfig::default() };
        let mut fifo = astra_engine(cfg.clone().batch1());
        let mut cb = astra_engine(cfg.clone());
        let r_fifo = fifo.serve_stream(saturating(4000), 120.0);
        let r_cb = cb.serve_stream(saturating(4000), 120.0);
        assert!(
            r_cb.completed as f64 >= 2.0 * r_fifo.completed as f64,
            "cb {} vs fifo {}",
            r_cb.completed,
            r_fifo.completed
        );
        assert!(r_fifo.completed > 0);
        // same bar under an open-loop Poisson stream far above capacity
        let mut fifo = astra_engine(cfg.clone().batch1());
        let mut cb = astra_engine(cfg);
        let p_fifo = fifo.serve_poisson(&mut Rng::new(5), 50.0, 120.0);
        let p_cb = cb.serve_poisson(&mut Rng::new(5), 50.0, 120.0);
        assert!(
            p_cb.completed as f64 >= 2.0 * p_fifo.completed as f64,
            "poisson: cb {} vs fifo {}",
            p_cb.completed,
            p_fifo.completed
        );
    }

    #[test]
    fn report_exposes_tail_latency_and_ttft() {
        let mut cb = astra_engine(CbConfig::default());
        let mut rng = Rng::new(3);
        let mut r = cb.serve_poisson(&mut rng, 4.0, 60.0);
        assert!(r.completed > 0, "{r:?}");
        let (p50, p95, p99) = (r.latency.p50(), r.latency.p95(), r.latency.p99());
        assert!(p50 > 0.0 && p50 <= p95 && p95 <= p99, "{p50} {p95} {p99}");
        // TTFT is recorded for every admitted-and-prefilled request and is
        // below the full latency (decode comes after the first token)
        assert!(!r.ttft.is_empty());
        assert!(r.ttft.mean() < r.latency.mean());
        assert!((6..=7).contains(&r.windows.len()), "{}", r.windows.len());
    }

    #[test]
    fn every_request_is_completed_or_censored() {
        let total = 500;
        let mut cb = astra_engine(CbConfig::default());
        let r = cb.serve_stream(saturating(total), 20.0);
        assert_eq!(r.completed + r.censored, total);
        assert!(r.censored > 0, "20 s should not drain 500 saturating requests");
        assert_eq!(r.censored_wait.len(), r.censored);
        assert!(r.mean_queue_depth() > 0.0);
    }

    #[test]
    fn goodput_counts_only_within_slo() {
        let mut all = astra_engine(CbConfig { slo_s: 0.0, ..CbConfig::default() });
        let mut tight = astra_engine(CbConfig { slo_s: 1.0, ..CbConfig::default() });
        let r_all = all.serve_stream(saturating(2000), 60.0);
        let r_tight = tight.serve_stream(saturating(2000), 60.0);
        // identical dynamics, different SLO accounting
        assert_eq!(r_all.completed, r_tight.completed);
        assert!((r_all.goodput - r_all.throughput).abs() < 1e-12);
        // under saturation queue waits explode, so a 1 s SLO filters most
        assert!(r_tight.goodput < r_all.goodput);
    }

    #[test]
    fn prefill_only_batch1_matches_fifo_engine() {
        // decode_tokens=0 + slots=1 + batch=1 must reproduce the classic
        // batch-1 FIFO engine's completion count on the same stream
        let shape = TransformerShape::paper_encoder(1024);
        let strat = Strategy::new(StrategyKind::Astra { vq: VqSetting::new(16, 1024) }, 4);
        let params = SimParams::paper_encoder();
        let trace = BandwidthTrace::constant(100.0, 1e9);
        let mut rng = Rng::new(9);
        let mut arrivals = Vec::new();
        let mut t = 0.0;
        for id in 0..300u64 {
            t += rng.exp(6.0);
            arrivals.push(Request { id, arrival_s: t, tokens: 1024 });
        }
        let cfg = CbConfig {
            max_slots: 1,
            max_batch: 1,
            max_wait_s: 0.0,
            decode_tokens: 0,
            ..CbConfig::default()
        };
        let mut cb = CbEngine::new(shape, strat, params.clone(), trace.clone(), cfg);
        let r_cb = cb.serve_stream(arrivals.clone(), 120.0);
        let mut fifo = ServeEngine::new(shape, strat, params, trace);
        let r_fifo = fifo.serve_stream(arrivals, 120.0);
        let diff = (r_cb.completed as i64 - r_fifo.completed as i64).abs();
        assert!(diff <= 1, "cb {} vs fifo {}", r_cb.completed, r_fifo.completed);
    }
}
