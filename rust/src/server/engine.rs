//! The serve loop over the cost model: a request stream (Poisson or
//! closed-loop) served by a strategy under static or dynamic bandwidth —
//! regenerates Figure 6 and the throughput claims.

use crate::comm::trace::BandwidthTrace;
use crate::parallel::strategies::Strategy;
use crate::model::TransformerShape;
use crate::sim::latency::{evaluate_on_trace, SimParams};
use crate::util::rng::Rng;
use crate::util::stats::{Summary, WindowedCounter};

use super::batcher::{Batcher, Request};

/// Outcome of a serve run.
#[derive(Debug)]
pub struct ServeReport {
    pub completed: usize,
    pub horizon_s: f64,
    /// requests per second over the horizon
    pub throughput: f64,
    pub latency: Summary,
    pub queue_wait: Summary,
    /// per-10s-window completion counts (Fig 6 bars)
    pub windows: Vec<usize>,
}

/// Cost-model serving engine: one logical cluster, batch-1 execution (the
/// paper's Fig 6 setting), requests served FIFO through the batcher.
pub struct ServeEngine {
    pub shape: TransformerShape,
    pub strategy: Strategy,
    pub params: SimParams,
    pub trace: BandwidthTrace,
    pub batcher: Batcher,
}

impl ServeEngine {
    pub fn new(
        shape: TransformerShape,
        strategy: Strategy,
        params: SimParams,
        trace: BandwidthTrace,
    ) -> ServeEngine {
        ServeEngine { shape, strategy, params, trace, batcher: Batcher::new(1, 0.0) }
    }

    /// Serve an open-loop Poisson stream at `rate` req/s for `horizon_s`.
    pub fn serve_poisson(&mut self, rng: &mut Rng, rate: f64, horizon_s: f64) -> ServeReport {
        let mut arrivals = Vec::new();
        let mut t = 0.0;
        let mut id = 0u64;
        loop {
            t += rng.exp(rate);
            if t >= horizon_s {
                break;
            }
            id += 1;
            arrivals.push(Request { id, arrival_s: t, tokens: self.shape.seq_len });
        }
        self.serve_stream(arrivals, horizon_s)
    }

    /// Serve a fixed request list (closed set), FIFO, batch 1.
    pub fn serve_stream(&mut self, arrivals: Vec<Request>, horizon_s: f64) -> ServeReport {
        let sched = self.strategy.schedule(&self.shape);
        let mut now = 0.0f64;
        let mut latency = Summary::new();
        let mut wait = Summary::new();
        let mut windows = WindowedCounter::new(10.0);
        let mut completed = 0usize;
        let mut pending = arrivals.into_iter().peekable();
        loop {
            // admit everything that has arrived by `now`
            while let Some(r) = pending.peek() {
                if r.arrival_s <= now {
                    self.batcher.push(pending.next().unwrap());
                } else {
                    break;
                }
            }
            let batch = self.batcher.next_batch(now, true);
            if batch.is_empty() {
                match pending.peek() {
                    Some(r) => {
                        now = r.arrival_s;
                        continue;
                    }
                    None => break,
                }
            }
            for req in batch {
                if now >= horizon_s {
                    break;
                }
                let start = now.max(req.arrival_s);
                wait.add(start - req.arrival_s);
                let bd = evaluate_on_trace(&sched, &self.params, &self.trace, start);
                let done = start + bd.total();
                if done <= horizon_s {
                    completed += 1;
                    latency.add(done - req.arrival_s);
                    windows.record(done);
                }
                now = done;
            }
            if now >= horizon_s {
                break;
            }
        }
        ServeReport {
            completed,
            horizon_s,
            throughput: completed as f64 / horizon_s,
            latency,
            queue_wait: wait,
            windows: windows.bars().to_vec(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::shape::VqSetting;
    use crate::parallel::strategies::StrategyKind;

    fn engine(kind: StrategyKind, n: usize, trace: BandwidthTrace) -> ServeEngine {
        ServeEngine::new(
            TransformerShape::paper_encoder(1024),
            Strategy::new(kind, n),
            SimParams::paper_encoder(),
            trace,
        )
    }

    #[test]
    fn astra_outserves_single_device_on_dynamic_trace() {
        // Fig 6: ASTRA throughput > single device under a 20-100 Mbps trace
        let mut rng = Rng::new(42);
        let trace = BandwidthTrace::markovian(&mut rng, 20.0, 100.0, 9, 1.0, 600.0);
        let mut single = engine(StrategyKind::SingleDevice, 1, trace.clone());
        let mut astra = engine(
            StrategyKind::Astra { vq: VqSetting::new(16, 1024) }, 4, trace);
        // saturating closed-loop: everything arrives at t=0
        let reqs: Vec<Request> = (0..20_000)
            .map(|i| Request { id: i, arrival_s: 0.0, tokens: 1024 })
            .collect();
        let r_single = single.serve_stream(reqs.clone(), 600.0);
        let r_astra = astra.serve_stream(reqs, 600.0);
        // paper Fig 6: ASTRA's bars clear the single-device line; at G=16
        // over a 20-100 Mbps trace the margin is ~1.5-2x
        assert!(
            r_astra.completed as f64 > 1.3 * r_single.completed as f64,
            "astra {} vs single {}",
            r_astra.completed,
            r_single.completed
        );
    }

    #[test]
    fn sp_throughput_collapses_on_low_bandwidth_trace() {
        let mut rng = Rng::new(7);
        let trace = BandwidthTrace::markovian(&mut rng, 20.0, 100.0, 9, 1.0, 300.0);
        let mut single = engine(StrategyKind::SingleDevice, 1, trace.clone());
        let mut sp = engine(StrategyKind::SequenceParallel, 4, trace);
        let reqs: Vec<Request> = (0..10_000)
            .map(|i| Request { id: i, arrival_s: 0.0, tokens: 1024 })
            .collect();
        let r_single = single.serve_stream(reqs.clone(), 300.0);
        let r_sp = sp.serve_stream(reqs, 300.0);
        assert!(r_sp.completed < r_single.completed);
    }

    #[test]
    fn poisson_open_loop_latency_includes_wait() {
        let mut rng = Rng::new(1);
        let trace = BandwidthTrace::constant(200.0, 1e9);
        let mut e = engine(StrategyKind::Astra { vq: VqSetting::new(1, 1024) }, 4, trace);
        let report = e.serve_poisson(&mut rng, 5.0, 120.0);
        assert!(report.completed > 100, "{}", report.completed);
        assert!(report.latency.mean() > 0.0);
        // windows roughly cover the horizon
        assert!(report.windows.len() <= 13);
    }
}
