//! The serve loop over the cost model: a request stream (Poisson or
//! closed-loop) served by a strategy under static or dynamic bandwidth —
//! regenerates Figure 6 and the throughput claims.

use crate::comm::trace::BandwidthTrace;
use crate::parallel::strategies::Strategy;
use crate::model::TransformerShape;
use crate::sim::latency::{evaluate_on_trace, SimParams};
use crate::util::rng::Rng;
use crate::util::stats::{Summary, WindowedCounter};

use super::batcher::{Batcher, Request};

/// Outcome of a serve run.
#[derive(Debug)]
pub struct ServeReport {
    pub completed: usize,
    /// requests admitted (or still queued) but not completed inside the
    /// horizon — previously dropped silently with no wait accounting
    pub censored: usize,
    pub horizon_s: f64,
    /// requests per second over the horizon
    pub throughput: f64,
    /// requests per second up to the *last completion* — unbiased when
    /// arrivals end early and the tail of the horizon is idle
    pub throughput_completion: f64,
    pub latency: Summary,
    pub queue_wait: Summary,
    /// queue wait accrued by censored requests up to the horizon
    pub censored_wait: Summary,
    /// per-10s-window completion counts (Fig 6 bars), zero-padded to cover
    /// the whole horizon
    pub windows: Vec<usize>,
}

/// Cost-model serving engine: one logical cluster, batch-1 execution (the
/// paper's Fig 6 setting), requests served FIFO through the batcher.
pub struct ServeEngine {
    pub shape: TransformerShape,
    pub strategy: Strategy,
    pub params: SimParams,
    pub trace: BandwidthTrace,
    pub batcher: Batcher,
}

impl ServeEngine {
    pub fn new(
        shape: TransformerShape,
        strategy: Strategy,
        params: SimParams,
        trace: BandwidthTrace,
    ) -> ServeEngine {
        ServeEngine { shape, strategy, params, trace, batcher: Batcher::new(1, 0.0) }
    }

    /// Serve an open-loop Poisson stream at `rate` req/s for `horizon_s`.
    pub fn serve_poisson(&mut self, rng: &mut Rng, rate: f64, horizon_s: f64) -> ServeReport {
        let arrivals =
            super::batcher::poisson_arrivals(rng, rate, horizon_s, self.shape.seq_len);
        self.serve_stream(arrivals, horizon_s)
    }

    /// Serve a fixed request list (closed set), FIFO, batch 1.
    pub fn serve_stream(&mut self, arrivals: Vec<Request>, horizon_s: f64) -> ServeReport {
        let sched = self.strategy.schedule(&self.shape);
        let mut now = 0.0f64;
        let mut latency = Summary::new();
        let mut wait = Summary::new();
        let mut censored_wait = Summary::new();
        let mut windows = WindowedCounter::new(10.0);
        let mut completed = 0usize;
        let mut censored = 0usize;
        let mut last_completion = 0.0f64;
        let mut pending = arrivals.into_iter().peekable();
        loop {
            // admit everything that has arrived by `now`
            while let Some(r) = pending.peek() {
                if r.arrival_s <= now {
                    self.batcher.push(pending.next().unwrap());
                } else {
                    break;
                }
            }
            let batch = self.batcher.next_batch(now, true);
            if batch.is_empty() {
                match pending.peek() {
                    // jump to the next arrival, but never admit post-horizon
                    // arrivals (they are outside the run, not censored)
                    Some(r) if r.arrival_s < horizon_s => {
                        now = r.arrival_s;
                        continue;
                    }
                    _ => break,
                }
            }
            for req in batch {
                if now >= horizon_s {
                    // admitted but never started: censored, waited to horizon
                    censored += 1;
                    censored_wait.add((horizon_s - req.arrival_s).max(0.0));
                    continue;
                }
                let start = now.max(req.arrival_s);
                wait.add(start - req.arrival_s);
                let bd = evaluate_on_trace(&sched, &self.params, &self.trace, start);
                let done = start + bd.total();
                if done <= horizon_s {
                    completed += 1;
                    latency.add(done - req.arrival_s);
                    windows.record(done);
                    last_completion = done;
                } else {
                    // started but straddles the horizon
                    censored += 1;
                    censored_wait.add(start - req.arrival_s);
                }
                now = done;
            }
            if now >= horizon_s {
                break;
            }
        }
        // census the queue and any arrivals inside the horizon never admitted
        for req in self.batcher.drain_all() {
            censored += 1;
            censored_wait.add((horizon_s - req.arrival_s).max(0.0));
        }
        for req in pending {
            if req.arrival_s < horizon_s {
                censored += 1;
                censored_wait.add(horizon_s - req.arrival_s);
            }
        }
        ServeReport {
            completed,
            censored,
            horizon_s,
            throughput_completion: if last_completion > 0.0 {
                completed as f64 / last_completion
            } else {
                0.0
            },
            latency,
            queue_wait: wait,
            censored_wait,
            throughput: windows.rate_until(horizon_s),
            windows: windows.bars_until(horizon_s),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::shape::VqSetting;
    use crate::parallel::strategies::StrategyKind;

    fn engine(kind: StrategyKind, n: usize, trace: BandwidthTrace) -> ServeEngine {
        ServeEngine::new(
            TransformerShape::paper_encoder(1024),
            Strategy::new(kind, n),
            SimParams::paper_encoder(),
            trace,
        )
    }

    #[test]
    fn astra_outserves_single_device_on_dynamic_trace() {
        // Fig 6: ASTRA throughput > single device under a 20-100 Mbps trace
        let mut rng = Rng::new(42);
        let trace = BandwidthTrace::markovian(&mut rng, 20.0, 100.0, 9, 1.0, 600.0);
        let mut single = engine(StrategyKind::SingleDevice, 1, trace.clone());
        let mut astra = engine(
            StrategyKind::Astra { vq: VqSetting::new(16, 1024) }, 4, trace);
        // saturating closed-loop: everything arrives at t=0
        let reqs: Vec<Request> = (0..20_000)
            .map(|i| Request { id: i, arrival_s: 0.0, tokens: 1024 })
            .collect();
        let r_single = single.serve_stream(reqs.clone(), 600.0);
        let r_astra = astra.serve_stream(reqs, 600.0);
        // paper Fig 6: ASTRA's bars clear the single-device line; at G=16
        // over a 20-100 Mbps trace the margin is ~1.5-2x
        assert!(
            r_astra.completed as f64 > 1.3 * r_single.completed as f64,
            "astra {} vs single {}",
            r_astra.completed,
            r_single.completed
        );
    }

    #[test]
    fn sp_throughput_collapses_on_low_bandwidth_trace() {
        let mut rng = Rng::new(7);
        let trace = BandwidthTrace::markovian(&mut rng, 20.0, 100.0, 9, 1.0, 300.0);
        let mut single = engine(StrategyKind::SingleDevice, 1, trace.clone());
        let mut sp = engine(StrategyKind::SequenceParallel, 4, trace);
        let reqs: Vec<Request> = (0..10_000)
            .map(|i| Request { id: i, arrival_s: 0.0, tokens: 1024 })
            .collect();
        let r_single = single.serve_stream(reqs.clone(), 300.0);
        let r_sp = sp.serve_stream(reqs, 300.0);
        assert!(r_sp.completed < r_single.completed);
    }

    #[test]
    fn censored_requests_are_accounted() {
        // saturating load over a short horizon: most requests cannot finish
        let trace = BandwidthTrace::constant(100.0, 1e9);
        let mut e = engine(StrategyKind::Astra { vq: VqSetting::new(16, 1024) }, 4, trace);
        let total = 200usize;
        let reqs: Vec<Request> = (0..total as u64)
            .map(|i| Request { id: i, arrival_s: 0.0, tokens: 1024 })
            .collect();
        let r = e.serve_stream(reqs, 2.0);
        assert_eq!(r.completed + r.censored, total);
        assert!(r.censored > 0);
        // every censored request's queue wait is recorded
        assert_eq!(r.censored_wait.len(), r.censored);
        assert_eq!(r.windows.len(), 1); // ceil(2s / 10s window)
    }

    #[test]
    fn completion_throughput_unbiased_by_idle_tail() {
        // a handful of requests finishing early inside a long horizon
        let trace = BandwidthTrace::constant(200.0, 1e9);
        let mut e = engine(StrategyKind::Astra { vq: VqSetting::new(1, 1024) }, 4, trace);
        let reqs: Vec<Request> = (0..10)
            .map(|i| Request { id: i, arrival_s: 0.0, tokens: 1024 })
            .collect();
        let r = e.serve_stream(reqs, 600.0);
        assert_eq!(r.completed, 10);
        assert_eq!(r.censored, 0);
        // horizon-based throughput is diluted by the idle tail; the
        // completion-based figure is not
        assert!(r.throughput_completion > 10.0 * r.throughput);
        // bars span the whole horizon (idle tail = zero windows)
        assert_eq!(r.windows.len(), 60);
    }

    #[test]
    fn poisson_open_loop_latency_includes_wait() {
        let mut rng = Rng::new(1);
        let trace = BandwidthTrace::constant(200.0, 1e9);
        let mut e = engine(StrategyKind::Astra { vq: VqSetting::new(1, 1024) }, 4, trace);
        let report = e.serve_poisson(&mut rng, 5.0, 120.0);
        assert!(report.completed > 100, "{}", report.completed);
        assert!(report.latency.mean() > 0.0);
        // windows roughly cover the horizon
        assert!(report.windows.len() <= 13);
    }
}
