//! The default policy: classic FIFO admission, newest-slot eviction.

use super::{newest_by_admit_seq, AdmissionCandidate, SchedPolicy, SlotView};

/// First-in-first-out admission with head-blocking (nothing jumps a
/// request the KV gate rejects) and most-recently-admitted victim
/// selection — exactly the decisions the scheduler hard-coded before the
/// policy layer existed. With the default flags this reproduces the
/// pre-refactor event streams bit for bit; under a KV cap the streams
/// can differ only through the (deliberate) batcher trigger fix, which
/// now measures the fill/deadline trigger over the eligible set instead
/// of the raw queue. The regression the victim rule encodes: "newest"
/// is the largest per-episode `admit_seq`, never an `(admitted_at, id)`
/// tiebreak, so same-batch readmissions rank by their *current* admission.
#[derive(Debug, Clone, Copy, Default)]
pub struct Fifo;

impl SchedPolicy for Fifo {
    fn name(&self) -> &'static str {
        "fifo"
    }

    fn admission_order(&self, _now: f64, queue: &[AdmissionCandidate]) -> Vec<usize> {
        (0..queue.len()).collect()
    }

    fn victim(&self, _now: f64, slots: &[SlotView]) -> usize {
        newest_by_admit_seq(slots)
    }
}

#[cfg(test)]
mod tests {
    use super::super::slot_view;
    use super::*;

    #[test]
    fn victim_is_latest_admission_not_largest_id() {
        // regression (eviction victim selection): after an eviction wave
        // requeues [3, 2] and both readmit in one batch, id 3 holds the
        // earlier admission sequence. The victim must be id 2 — the most
        // recently readmitted slot — where the old (admitted_at, id)
        // tiebreak picked id 3 because the batch shared one timestamp.
        let slots = vec![
            slot_view(0, 0, 0, 0.0),
            slot_view(1, 1, 0, 0.0),
            slot_view(3, 4, 0, 0.0),
            slot_view(2, 5, 0, 0.0),
        ];
        assert_eq!(Fifo.victim(0.0, &slots), 3, "index of id 2 (seq 5)");
        // unique sequences: order of insertion never matters
        let slots = vec![slot_view(2, 5, 0, 0.0), slot_view(3, 4, 0, 0.0), slot_view(0, 0, 0, 0.0)];
        assert_eq!(Fifo.victim(0.0, &slots), 0);
    }

    #[test]
    fn admission_order_is_identity() {
        let q: Vec<AdmissionCandidate> = (0..4)
            .map(|i| AdmissionCandidate {
                id: i as u64,
                arrival_s: 0.0,
                queued_since: 0.0,
                tokens: 8,
                class: 0,
                deadline_s: 0.0,
                covered_tokens: 64 * (i % 2), // coverage must not matter
                decode_budget: 8 * (4 - i),   // neither must decode length
            })
            .collect();
        assert_eq!(Fifo.admission_order(5.0, &q), vec![0, 1, 2, 3]);
        assert!(!Fifo.reorders());
        assert!(!Fifo.preempts());
    }
}
