//! SLO / priority-class scheduling over `CbConfig::classes`.

use std::cmp::Reverse;

use super::{age_boost, AdmissionCandidate, Preemption, SchedPolicy, SlotView};

/// Priority-class policy: every request carries a class (derived as
/// `id % classes.len()`, identically on both backends) and each class a
/// latency deadline (`CbConfig::classes[class]`). **Higher class index =
/// higher priority**; the deadline is the class's SLO.
///
/// * **Admission** is ordered highest class first (FIFO within a class),
///   with the same aging bound as [`super::PrefixAware`]: one effective
///   class level per `age_bound_s` spent in the current queueing
///   episode, so a low-class request bypassed by a steady high-class
///   stream outranks it after `Δclass * age_bound_s` of waiting —
///   bounded bypass, no starvation.
/// * **Victims** under KV pressure are chosen lowest-class-first, ties
///   broken per-episode-admission-newest (the FIFO rule within the
///   class). Two exemptions apply, in order: the *longest-resident* slot
///   (smallest `admit_seq`) is never the victim while another exists —
///   the FIFO progress guarantee, without which a low-class slot could
///   be re-evicted forever under sustained high-class pressure, since
///   class rank would otherwise trump seniority every time it re-enters
///   — and a slot still *within its deadline budget* is preferred-exempt:
///   victims come from the already-late slots first, falling back to the
///   same ordering over the rest only when every candidate is exempt
///   (pressure must still evict someone).
/// * **Proactive preemption** ([`SchedPolicy::preempt`]): when every
///   slot is occupied and queued requests of strictly higher classes can
///   still meet their deadlines, the lowest-class in-flight slots that
///   have already blown their own deadlines are evicted to make room —
///   up to `preempt_budget` victims per iteration
///   (`--slo-preempt-budget`; the default 1 preserves the historical
///   one-victim streams bit for bit), each victim paired with its own
///   named beneficiary: k-th best salvageable queued request against
///   k-th cheapest blown slot, stopping at the first pair where the
///   victim's class is not strictly below the beneficiary's. Exempt
///   (within-budget) slots are never proactively preempted, so the hook
///   only ever trades blown SLOs for salvageable ones. Each decision
///   names its beneficiary ([`Preemption`]), and the loop enforces
///   feasibility before executing it: it never preempts for a request
///   the KV cap could never admit, nor when evicting the victim would
///   not open enough room for that named beneficiary's admission — the
///   policy decides, mechanism verifies.
#[derive(Debug, Clone, Copy)]
pub struct SloClass {
    /// seconds of sojourn per one effective class level of aging
    /// (`CbConfig::age_bound_s`; <= 0 disables aging)
    pub age_bound_s: f64,
    /// victims the proactive hook may name per iteration
    /// (`CbConfig::slo_preempt_budget`; clamped to >= 1). 1 reproduces
    /// the single-victim behavior exactly.
    pub preempt_budget: usize,
}

impl SloClass {
    fn score(&self, now: f64, c: &AdmissionCandidate) -> i64 {
        c.class as i64 + age_boost(now, c.queued_since, self.age_bound_s)
    }
}

impl SchedPolicy for SloClass {
    fn name(&self) -> &'static str {
        "slo-class"
    }

    fn reorders(&self) -> bool {
        true
    }

    fn preempts(&self) -> bool {
        true
    }

    fn admission_order(&self, now: f64, queue: &[AdmissionCandidate]) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..queue.len()).collect();
        idx.sort_by(|&a, &b| {
            self.score(now, &queue[b]).cmp(&self.score(now, &queue[a])).then(a.cmp(&b))
        });
        idx
    }

    fn victim(&self, now: f64, slots: &[SlotView]) -> usize {
        // seniority exemption: the longest-resident slot is never chosen
        // while another exists (the loop never calls this with a lone
        // slot), so the oldest resident always completes — the progress
        // guarantee that keeps class-ranked eviction starvation-free
        let oldest = (0..slots.len())
            .min_by_key(|&i| slots[i].admit_seq)
            .expect("victim called with no slots");
        let eligible: Vec<usize> = (0..slots.len()).filter(|&i| i != oldest).collect();
        let late: Vec<usize> =
            eligible.iter().copied().filter(|&i| !slots[i].within_deadline(now)).collect();
        let pool = if late.is_empty() { eligible } else { late };
        pool.into_iter()
            .min_by_key(|&i| (slots[i].class, Reverse(slots[i].admit_seq)))
            .unwrap_or(oldest)
    }

    fn preempt(
        &self,
        now: f64,
        queue: &[AdmissionCandidate],
        slots: &[SlotView],
    ) -> Vec<Preemption> {
        // the beneficiaries: queued requests that can still meet their
        // deadlines, best first — highest class, FIFO within the class
        // (the same order class-ordered admission would seat them); the
        // only kind of work worth evicting for
        let mut salvageable: Vec<(usize, &AdmissionCandidate)> =
            queue.iter().enumerate().filter(|(_, c)| c.within_deadline(now)).collect();
        salvageable.sort_by_key(|&(i, c)| (Reverse(c.class), i));
        // same seniority exemption as `victim`: the longest-resident
        // slot is never proactively preempted, so sustained high-class
        // arrivals cannot re-evict one low-class request forever.
        // Candidate victims are the remaining already-late slots,
        // cheapest first — lowest class, newest within the class.
        let oldest = (0..slots.len()).min_by_key(|&i| slots[i].admit_seq);
        let mut late: Vec<usize> = (0..slots.len())
            .filter(|&i| Some(i) != oldest)
            .filter(|&i| !slots[i].within_deadline(now))
            .collect();
        late.sort_by_key(|&i| (slots[i].class, Reverse(slots[i].admit_seq)));
        // pair k-th best beneficiary with k-th cheapest victim, up to the
        // budget. Beneficiary classes descend and victim classes ascend
        // along the pairing, so the first pair that fails the
        // strictly-lower-class test ends it — every later pair fails too.
        // Budget 1 reproduces the single-victim decision exactly.
        salvageable
            .iter()
            .zip(late.iter())
            .take(self.preempt_budget.max(1))
            .take_while(|((_, best), &vi)| slots[vi].class < best.class)
            .map(|(&(beneficiary, _), &victim)| Preemption { victim, beneficiary })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cand(id: u64, arrival_s: f64, class: usize, deadline_s: f64) -> AdmissionCandidate {
        AdmissionCandidate {
            id,
            arrival_s,
            queued_since: arrival_s,
            tokens: 64,
            class,
            deadline_s,
            covered_tokens: 0,
            decode_budget: 0,
        }
    }

    fn slot(id: u64, seq: u64, class: usize, arrival_s: f64, deadline_s: f64) -> SlotView {
        SlotView { id, arrival_s, class, deadline_s, admit_seq: seq }
    }

    #[test]
    fn admission_orders_high_class_first_fifo_within() {
        let p = SloClass { age_bound_s: 0.0, preempt_budget: 1 };
        let q = vec![cand(1, 0.0, 0, 8.0), cand(2, 0.0, 1, 0.5), cand(3, 0.0, 0, 8.0),
            cand(4, 0.0, 1, 0.5)];
        assert_eq!(p.admission_order(0.1, &q), vec![1, 3, 0, 2]);
        assert!(p.reorders() && p.preempts());
    }

    #[test]
    fn aging_lifts_a_bypassed_low_class_request() {
        let p = SloClass { age_bound_s: 0.5, preempt_budget: 1 };
        // low-class request queued at 0, fresh high-class at 1.0
        let q = vec![cand(1, 0.0, 0, 8.0), cand(2, 1.0, 1, 0.5)];
        // at 1.0 the low request has aged 2 levels: 0+2 > 1+0
        assert_eq!(p.admission_order(1.0, &q), vec![0, 1]);
        // young low request stays behind
        let q = vec![cand(1, 0.9, 0, 8.0), cand(2, 1.0, 1, 0.5)];
        assert_eq!(p.admission_order(1.0, &q), vec![1, 0]);
    }

    #[test]
    fn victims_are_lowest_class_first_newest_within_class_oldest_never() {
        let p = SloClass { age_bound_s: 0.5, preempt_budget: 1 };
        // all past deadline: lowest class loses, newest within the class
        // (the seniority-exempt oldest is a different slot here)
        let slots = vec![
            slot(1, 1, 1, 0.0, 0.1),
            slot(2, 2, 0, 0.0, 0.1),
            slot(3, 3, 0, 0.0, 0.1),
        ];
        assert_eq!(p.victim(1.0, &slots), 2, "newest of the lowest class");
        // the longest-resident slot is never the victim, even when it is
        // the only late one: the within-budget low-class slot loses
        // instead (progress guarantee trumps deadline exemption)
        let slots = vec![slot(1, 1, 1, 0.0, 0.1), slot(2, 2, 0, 0.0, 100.0)];
        assert_eq!(p.victim(1.0, &slots), 1);
        // everyone exempt: fall back to lowest class, newest, still
        // sparing the oldest
        let slots = vec![slot(1, 1, 1, 0.0, 100.0), slot(2, 2, 0, 0.0, 100.0)];
        assert_eq!(p.victim(1.0, &slots), 1);
    }

    #[test]
    fn preempt_trades_a_blown_slo_for_a_salvageable_one() {
        let p = SloClass { age_bound_s: 0.0, preempt_budget: 1 };
        // queued high-class request still inside its deadline
        let q = vec![cand(9, 0.9, 1, 0.5)];
        // slot 0: low class, past deadline, not the longest-resident ->
        // the victim, named for the queued beneficiary; slot 1 is the
        // seniority-exempt oldest
        let slots = vec![slot(1, 2, 0, 0.0, 0.2), slot(2, 1, 0, 0.0, 100.0)];
        assert_eq!(p.preempt(1.0, &q, &slots), vec![Preemption { victim: 0, beneficiary: 0 }]);
        // no preemption once the queued request has blown its own SLO
        let q_late = vec![cand(9, 0.0, 1, 0.5)];
        assert!(p.preempt(1.0, &q_late, &slots).is_empty());
        // no preemption of an equal or higher class
        let q_low = vec![cand(9, 0.9, 0, 0.5)];
        assert!(p.preempt(1.0, &q_low, &slots).is_empty());
        // the longest-resident slot is never proactively preempted, even
        // when it is the only late lower-class one
        let slots = vec![slot(1, 1, 0, 0.0, 0.2), slot(2, 2, 0, 0.0, 100.0)];
        assert!(p.preempt(1.0, &q, &slots).is_empty());
    }

    #[test]
    fn preempt_budget_pairs_multiple_victims_with_beneficiaries() {
        // two blown low-class slots (seqs 2 and 3) plus the exempt oldest,
        // two salvageable high-class queued requests
        let slots = vec![
            slot(1, 1, 1, 0.0, 100.0), // oldest: seniority-exempt
            slot(2, 2, 0, 0.0, 0.2),   // blown, newest of the low class
            slot(3, 3, 0, 0.0, 0.2),   // blown, newer still
        ];
        let q = vec![cand(8, 0.9, 1, 0.5), cand(9, 0.95, 1, 0.5)];
        // budget 1: identical to the historical single-victim decision —
        // best beneficiary (FIFO within the class) against the cheapest
        // victim (newest of the lowest class)
        let p1 = SloClass { age_bound_s: 0.0, preempt_budget: 1 };
        assert_eq!(p1.preempt(1.0, &q, &slots), vec![Preemption { victim: 2, beneficiary: 0 }]);
        // budget 2: both pairs fire, k-th best against k-th cheapest
        let p2 = SloClass { age_bound_s: 0.0, preempt_budget: 2 };
        assert_eq!(
            p2.preempt(1.0, &q, &slots),
            vec![
                Preemption { victim: 2, beneficiary: 0 },
                Preemption { victim: 1, beneficiary: 1 },
            ]
        );
        // the pairing stops at the first class-test failure: with one
        // low-class beneficiary in second place, only the first pair fires
        // even under a large budget
        let q_mixed = vec![cand(8, 0.9, 1, 0.5), cand(9, 0.95, 0, 8.0)];
        let p4 = SloClass { age_bound_s: 0.0, preempt_budget: 4 };
        assert_eq!(
            p4.preempt(1.0, &q_mixed, &slots),
            vec![Preemption { victim: 2, beneficiary: 0 }]
        );
    }
}
