//! SLO / priority-class scheduling over `CbConfig::classes`.

use std::cmp::Reverse;

use super::{age_boost, AdmissionCandidate, Preemption, SchedPolicy, SlotView};

/// Priority-class policy: every request carries a class (derived as
/// `id % classes.len()`, identically on both backends) and each class a
/// latency deadline (`CbConfig::classes[class]`). **Higher class index =
/// higher priority**; the deadline is the class's SLO.
///
/// * **Admission** is ordered highest class first (FIFO within a class),
///   with the same aging bound as [`super::PrefixAware`]: one effective
///   class level per `age_bound_s` spent in the current queueing
///   episode, so a low-class request bypassed by a steady high-class
///   stream outranks it after `Δclass * age_bound_s` of waiting —
///   bounded bypass, no starvation.
/// * **Victims** under KV pressure are chosen lowest-class-first, ties
///   broken per-episode-admission-newest (the FIFO rule within the
///   class). Two exemptions apply, in order: the *longest-resident* slot
///   (smallest `admit_seq`) is never the victim while another exists —
///   the FIFO progress guarantee, without which a low-class slot could
///   be re-evicted forever under sustained high-class pressure, since
///   class rank would otherwise trump seniority every time it re-enters
///   — and a slot still *within its deadline budget* is preferred-exempt:
///   victims come from the already-late slots first, falling back to the
///   same ordering over the rest only when every candidate is exempt
///   (pressure must still evict someone).
/// * **Proactive preemption** ([`SchedPolicy::preempt`]): when every
///   slot is occupied and a queued request of a strictly higher class
///   can still meet its deadline, the lowest-class in-flight slot that
///   has already blown its own deadline is evicted to make room — at
///   most one slot per iteration. Exempt (within-budget) slots are never
///   proactively preempted, so the hook only ever trades a blown SLO for
///   a salvageable one. Each decision names its beneficiary
///   ([`Preemption`]), and the loop enforces feasibility before
///   executing it: it never preempts for a request the KV cap could
///   never admit, nor when evicting the victim would not open enough
///   room for that named beneficiary's admission — the policy decides,
///   mechanism verifies.
#[derive(Debug, Clone, Copy)]
pub struct SloClass {
    /// seconds of sojourn per one effective class level of aging
    /// (`CbConfig::age_bound_s`; <= 0 disables aging)
    pub age_bound_s: f64,
}

impl SloClass {
    fn score(&self, now: f64, c: &AdmissionCandidate) -> i64 {
        c.class as i64 + age_boost(now, c.queued_since, self.age_bound_s)
    }
}

impl SchedPolicy for SloClass {
    fn name(&self) -> &'static str {
        "slo-class"
    }

    fn reorders(&self) -> bool {
        true
    }

    fn preempts(&self) -> bool {
        true
    }

    fn admission_order(&self, now: f64, queue: &[AdmissionCandidate]) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..queue.len()).collect();
        idx.sort_by(|&a, &b| {
            self.score(now, &queue[b]).cmp(&self.score(now, &queue[a])).then(a.cmp(&b))
        });
        idx
    }

    fn victim(&self, now: f64, slots: &[SlotView]) -> usize {
        // seniority exemption: the longest-resident slot is never chosen
        // while another exists (the loop never calls this with a lone
        // slot), so the oldest resident always completes — the progress
        // guarantee that keeps class-ranked eviction starvation-free
        let oldest = (0..slots.len())
            .min_by_key(|&i| slots[i].admit_seq)
            .expect("victim called with no slots");
        let eligible: Vec<usize> = (0..slots.len()).filter(|&i| i != oldest).collect();
        let late: Vec<usize> =
            eligible.iter().copied().filter(|&i| !slots[i].within_deadline(now)).collect();
        let pool = if late.is_empty() { eligible } else { late };
        pool.into_iter()
            .min_by_key(|&i| (slots[i].class, Reverse(slots[i].admit_seq)))
            .unwrap_or(oldest)
    }

    fn preempt(
        &self,
        now: f64,
        queue: &[AdmissionCandidate],
        slots: &[SlotView],
    ) -> Vec<Preemption> {
        // the beneficiary: the highest-class queued request that can
        // still meet its deadline (FIFO within the class — the same
        // request class-ordered admission would seat first); the only
        // kind of work worth evicting for
        let Some((beneficiary, best)) = queue
            .iter()
            .enumerate()
            .filter(|(_, c)| c.within_deadline(now))
            .min_by_key(|&(i, c)| (Reverse(c.class), i))
        else {
            return Vec::new();
        };
        // same seniority exemption as `victim`: the longest-resident
        // slot is never proactively preempted, so sustained high-class
        // arrivals cannot re-evict one low-class request forever
        let oldest = (0..slots.len()).min_by_key(|&i| slots[i].admit_seq);
        (0..slots.len())
            .filter(|&i| Some(i) != oldest)
            .filter(|&i| slots[i].class < best.class && !slots[i].within_deadline(now))
            .min_by_key(|&i| (slots[i].class, Reverse(slots[i].admit_seq)))
            .map(|victim| Preemption { victim, beneficiary })
            .into_iter()
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cand(id: u64, arrival_s: f64, class: usize, deadline_s: f64) -> AdmissionCandidate {
        AdmissionCandidate {
            id,
            arrival_s,
            queued_since: arrival_s,
            tokens: 64,
            class,
            deadline_s,
            covered_tokens: 0,
        }
    }

    fn slot(id: u64, seq: u64, class: usize, arrival_s: f64, deadline_s: f64) -> SlotView {
        SlotView { id, arrival_s, class, deadline_s, admit_seq: seq }
    }

    #[test]
    fn admission_orders_high_class_first_fifo_within() {
        let p = SloClass { age_bound_s: 0.0 };
        let q = vec![cand(1, 0.0, 0, 8.0), cand(2, 0.0, 1, 0.5), cand(3, 0.0, 0, 8.0),
            cand(4, 0.0, 1, 0.5)];
        assert_eq!(p.admission_order(0.1, &q), vec![1, 3, 0, 2]);
        assert!(p.reorders() && p.preempts());
    }

    #[test]
    fn aging_lifts_a_bypassed_low_class_request() {
        let p = SloClass { age_bound_s: 0.5 };
        // low-class request queued at 0, fresh high-class at 1.0
        let q = vec![cand(1, 0.0, 0, 8.0), cand(2, 1.0, 1, 0.5)];
        // at 1.0 the low request has aged 2 levels: 0+2 > 1+0
        assert_eq!(p.admission_order(1.0, &q), vec![0, 1]);
        // young low request stays behind
        let q = vec![cand(1, 0.9, 0, 8.0), cand(2, 1.0, 1, 0.5)];
        assert_eq!(p.admission_order(1.0, &q), vec![1, 0]);
    }

    #[test]
    fn victims_are_lowest_class_first_newest_within_class_oldest_never() {
        let p = SloClass { age_bound_s: 0.5 };
        // all past deadline: lowest class loses, newest within the class
        // (the seniority-exempt oldest is a different slot here)
        let slots = vec![
            slot(1, 1, 1, 0.0, 0.1),
            slot(2, 2, 0, 0.0, 0.1),
            slot(3, 3, 0, 0.0, 0.1),
        ];
        assert_eq!(p.victim(1.0, &slots), 2, "newest of the lowest class");
        // the longest-resident slot is never the victim, even when it is
        // the only late one: the within-budget low-class slot loses
        // instead (progress guarantee trumps deadline exemption)
        let slots = vec![slot(1, 1, 1, 0.0, 0.1), slot(2, 2, 0, 0.0, 100.0)];
        assert_eq!(p.victim(1.0, &slots), 1);
        // everyone exempt: fall back to lowest class, newest, still
        // sparing the oldest
        let slots = vec![slot(1, 1, 1, 0.0, 100.0), slot(2, 2, 0, 0.0, 100.0)];
        assert_eq!(p.victim(1.0, &slots), 1);
    }

    #[test]
    fn preempt_trades_a_blown_slo_for_a_salvageable_one() {
        let p = SloClass { age_bound_s: 0.0 };
        // queued high-class request still inside its deadline
        let q = vec![cand(9, 0.9, 1, 0.5)];
        // slot 0: low class, past deadline, not the longest-resident ->
        // the victim, named for the queued beneficiary; slot 1 is the
        // seniority-exempt oldest
        let slots = vec![slot(1, 2, 0, 0.0, 0.2), slot(2, 1, 0, 0.0, 100.0)];
        assert_eq!(p.preempt(1.0, &q, &slots), vec![Preemption { victim: 0, beneficiary: 0 }]);
        // no preemption once the queued request has blown its own SLO
        let q_late = vec![cand(9, 0.0, 1, 0.5)];
        assert!(p.preempt(1.0, &q_late, &slots).is_empty());
        // no preemption of an equal or higher class
        let q_low = vec![cand(9, 0.9, 0, 0.5)];
        assert!(p.preempt(1.0, &q_low, &slots).is_empty());
        // the longest-resident slot is never proactively preempted, even
        // when it is the only late lower-class one
        let slots = vec![slot(1, 1, 0, 0.0, 0.2), slot(2, 2, 0, 0.0, 100.0)];
        assert!(p.preempt(1.0, &q, &slots).is_empty());
    }
}
