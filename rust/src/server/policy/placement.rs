//! Placement-aware admission ordering for heterogeneous fleets.

use super::{age_boost, newest_by_admit_seq, AdmissionCandidate, SchedPolicy, SlotView};

/// Orders eligible admissions by the slot time their remaining decode
/// would pin, shortest first. On a skewed fleet every decode step is
/// gated by the slowest KV shard, so a slot-second is the scarce
/// resource: admitting the short-decode request first drains it quickly
/// and hands the slot on, where FIFO would let one long generation on a
/// slow-last-hop replica pin a slot while short work queues behind it.
/// The cost of a candidate is `decode_budget / decode_speed` — the
/// fleet's decode speed (its fastest device's weight,
/// [`crate::parallel::FleetProfile::max_weight`]) converts tokens into
/// modeled slot seconds, so the same policy is calibrated across
/// replicas of different strength.
///
/// Starvation bound: each `age_bound_s` spent in the current queueing
/// episode forgives one modeled slot-second of cost
/// ([`super::age_boost`]), so a long-decode request bypassed by shorter
/// arrivals outranks them once it has waited proportionally to its cost
/// disadvantage — bypass time is linear, never unbounded. Ties (equal
/// cost) fall back to queue order, so a uniform workload — every decode
/// budget equal — degenerates to exactly FIFO.
///
/// Victim selection is inherited from FIFO (most recently admitted):
/// decode length says nothing about who should *lose* a slot, and the
/// newest slot has the least sunk replay work.
#[derive(Debug, Clone, Copy)]
pub struct PlacementAware {
    /// fleet decode speed relative to the reference device
    /// (`FleetProfile::max_weight`; 1.0 on a uniform or unprofiled fleet)
    pub decode_speed: f64,
    /// seconds of sojourn per forgiven slot-second (`CbConfig::age_bound_s`;
    /// <= 0 disables aging)
    pub age_bound_s: f64,
}

impl PlacementAware {
    /// Modeled slot cost in integer milli-seconds (deterministic
    /// truncation, like the other reordering policies' integer scores);
    /// lower admits sooner.
    fn cost(&self, now: f64, c: &AdmissionCandidate) -> i64 {
        let ms = c.decode_budget as f64 / self.decode_speed.max(1e-6) * 1000.0;
        ms as i64 - age_boost(now, c.queued_since, self.age_bound_s) * 1000
    }
}

impl SchedPolicy for PlacementAware {
    fn name(&self) -> &'static str {
        "placement"
    }

    fn reorders(&self) -> bool {
        true
    }

    fn admission_order(&self, now: f64, queue: &[AdmissionCandidate]) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..queue.len()).collect();
        idx.sort_by(|&a, &b| {
            self.cost(now, &queue[a]).cmp(&self.cost(now, &queue[b])).then(a.cmp(&b))
        });
        idx
    }

    fn victim(&self, _now: f64, slots: &[SlotView]) -> usize {
        newest_by_admit_seq(slots)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cand(id: u64, arrival_s: f64, decode_budget: usize) -> AdmissionCandidate {
        AdmissionCandidate {
            id,
            arrival_s,
            queued_since: arrival_s,
            tokens: 128,
            class: 0,
            deadline_s: 0.0,
            covered_tokens: 0,
            decode_budget,
        }
    }

    #[test]
    fn short_decodes_jump_long_ones() {
        let p = PlacementAware { decode_speed: 1.0, age_bound_s: 0.5 };
        let q = vec![cand(1, 0.0, 64), cand(2, 0.0, 4), cand(3, 0.0, 16)];
        assert_eq!(p.admission_order(0.1, &q), vec![1, 2, 0]);
        assert!(p.reorders());
        assert!(!p.preempts());
    }

    #[test]
    fn equal_budgets_degenerate_to_fifo() {
        let p = PlacementAware { decode_speed: 4.0, age_bound_s: 0.5 };
        let q = vec![cand(5, 0.0, 8), cand(6, 0.0, 8), cand(7, 0.0, 8)];
        assert_eq!(p.admission_order(0.3, &q), vec![0, 1, 2]);
    }

    #[test]
    fn aging_eventually_outranks_a_shorter_decode() {
        let p = PlacementAware { decode_speed: 1.0, age_bound_s: 0.5 };
        // long head queued at 0 costs 3 modeled slot-seconds more
        let q = |t: f64| vec![cand(1, 0.0, 4), cand(2, t, 1)];
        // young long request is bypassed...
        assert_eq!(p.admission_order(1.0, &q(1.0)), vec![1, 0]);
        // ...but once it has aged 4 steps more than the short one its
        // forgiven 4 s outweigh the 3 s budget gap
        assert_eq!(p.admission_order(2.2, &q(2.0)), vec![0, 1]);
    }

    #[test]
    fn faster_fleets_shrink_the_cost_gap() {
        // at 4x decode speed the same 3-token gap is only 0.75 modeled
        // slot-seconds, so one aging step already flips the order
        let p = PlacementAware { decode_speed: 4.0, age_bound_s: 0.5 };
        let q = vec![cand(1, 0.0, 4), cand(2, 0.6, 1)];
        assert_eq!(p.admission_order(0.61, &q), vec![0, 1]);
    }
}
