//! Pluggable scheduling policies for the continuous-batching engine.
//!
//! The scheduler loop ([`crate::server::scheduler::CbEngine::serve_stream_with`])
//! owns all *mechanism* — the virtual clock, the KV pool, chunked prefill,
//! swap pricing, the event stream, the backends. A [`SchedPolicy`] owns
//! three *decisions* and nothing else:
//!
//!  1. **admission ordering** — which eligible queued request the
//!     [`crate::server::batcher::Batcher`] hands a free slot next
//!     ([`SchedPolicy::admission_order`]);
//!  2. **victim selection** — which in-flight slot a KV-pressure
//!     preemption evicts ([`SchedPolicy::victim`], replacing the old
//!     hard-coded newest-slot rule);
//!  3. **proactive preemption** — an optional per-iteration hook that may
//!     evict a slot *before* any memory pressure, to protect the SLOs of
//!     higher-priority queued work ([`SchedPolicy::preempt`]).
//!
//! # Contract: decisions only
//!
//! A policy never touches the clock, the KV pool, or a
//! [`crate::server::scheduler::DecodeBackend`] — it sees immutable
//! snapshots ([`AdmissionCandidate`], [`SlotView`]) plus the current
//! virtual time, and returns indices into them. Everything a policy reads
//! is derived identically on the cost-model and live backends (classes
//! from `(id, CbConfig::classes)`, prefix coverage from the shared radix
//! tree, waits from the shared virtual clock), so any policy keeps the
//! live-vs-model differential exact by construction: the decisions are
//! made once, in the shared loop, and both backends execute them.
//!
//! # Shipped policies
//!
//! * [`Fifo`] — the default, and the reference semantics: admission is
//!   the classic head-blocking FIFO walk and the eviction victim is the
//!   most recently (re)admitted slot. With `policy` left at its default
//!   the engine reproduces the pre-policy-layer event streams **bit for
//!   bit** (anchored by `tests/proptests.rs`).
//! * [`PrefixAware`] — orders eligible admissions by radix-tree covered
//!   prefix length (longest first), so cache-warm requests reach slots
//!   while their blocks are still resident; an aging boost bounds how
//!   long a cold request can be bypassed.
//! * [`SloClass`] — requests carry a priority class and a per-class
//!   latency deadline (`CbConfig::classes` / `--classes`): admissions are
//!   ordered highest class first (aging-bounded), KV-pressure victims are
//!   drawn lowest-class-first (then per-episode-admission-newest), a
//!   class is preemption-exempt while still within its deadline budget,
//!   and the proactive hook evicts a past-deadline lower-class slot when
//!   a higher-class request that can still meet its deadline is waiting
//!   with no free slot.
//! * [`PlacementAware`] — heterogeneous-fleet admission: on a skewed
//!   fleet every decode step is gated by the slowest KV shard, so slots
//!   are the scarce resource and the policy drains short-decode requests
//!   first (their slot time, scaled by the fleet's decode speed, is
//!   cheap) while an aging boost bounds how long a long-decode request
//!   can be bypassed.

use anyhow::{bail, Result};

mod fifo;
mod placement;
mod prefix_aware;
mod slo;

pub use fifo::Fifo;
pub use placement::PlacementAware;
pub use prefix_aware::PrefixAware;
pub use slo::SloClass;

/// Which [`SchedPolicy`] the engine builds (`CbConfig::policy`,
/// `--policy`). `Fifo` is the default and the bit-for-bit baseline.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PolicyKind {
    #[default]
    Fifo,
    PrefixAware,
    SloClass,
    Placement,
}

/// Parse a `--policy` value.
pub fn parse_policy(s: &str) -> Result<PolicyKind> {
    Ok(match s {
        "fifo" => PolicyKind::Fifo,
        "prefix-aware" | "prefix" => PolicyKind::PrefixAware,
        "slo-class" | "slo" => PolicyKind::SloClass,
        "placement" | "placement-aware" => PolicyKind::Placement,
        other => bail!("unknown policy `{other}` (fifo|prefix-aware|slo-class|placement)"),
    })
}

/// Immutable snapshot of one queued request, in queue order — what a
/// policy may read when ordering admissions or deciding to preempt.
#[derive(Debug, Clone)]
pub struct AdmissionCandidate {
    pub id: u64,
    /// original arrival — what class deadlines are measured against
    pub arrival_s: f64,
    /// when the current queueing episode began (arrival, or the last
    /// eviction) — what admission aging is measured against: an evicted
    /// request re-earns its boost, so a preemption victim cannot
    /// instantly outrank the higher-priority request it was evicted for
    pub queued_since: f64,
    /// prompt length
    pub tokens: usize,
    /// priority class (`CbConfig::class_of`; 0 when no classes are set)
    pub class: usize,
    /// the class latency deadline, seconds (<= 0: none)
    pub deadline_s: f64,
    /// leading prompt tokens covered by ready shared KV blocks
    /// ([`crate::kv::prefix::RadixTree::covered_tokens`]; 0 with the
    /// prefix cache off)
    pub covered_tokens: usize,
    /// decode tokens this request would still generate once admitted
    /// (`CbEngine::decode_budget`) — how long it will pin a slot; what
    /// [`PlacementAware`] orders by on skewed fleets
    pub decode_budget: usize,
}

impl AdmissionCandidate {
    /// Still inside its class deadline budget (measured from the
    /// original arrival, like [`SlotView::within_deadline`]). No
    /// deadline means the budget never runs out.
    pub fn within_deadline(&self, now: f64) -> bool {
        self.deadline_s <= 0.0 || now - self.arrival_s <= self.deadline_s
    }
}

/// Immutable snapshot of one in-flight slot.
#[derive(Debug, Clone)]
pub struct SlotView {
    pub id: u64,
    /// original arrival of the occupying request
    pub arrival_s: f64,
    /// priority class and its deadline (<= 0: none)
    pub class: usize,
    pub deadline_s: f64,
    /// unique per-episode admission sequence number — larger = more
    /// recently (re)admitted
    pub admit_seq: u64,
}

impl SlotView {
    /// Still inside its class deadline budget — preemption-exempt under
    /// [`SloClass`]. No deadline means the budget never runs out.
    pub fn within_deadline(&self, now: f64) -> bool {
        self.deadline_s <= 0.0 || now - self.arrival_s <= self.deadline_s
    }
}

/// One proactive preemption decision: evict `victim` (an index into the
/// slot snapshot) to open room for `beneficiary` (an index into the
/// candidate snapshot). Naming the beneficiary keeps the contract clean:
/// the policy judges *who deserves the slot*, and the loop verifies the
/// mechanism — that evicting the victim actually opens enough room for
/// that beneficiary's admission — refusing decisions that could only
/// churn.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Preemption {
    pub victim: usize,
    pub beneficiary: usize,
}

/// A scheduling policy: pure decision logic over queue/slot snapshots.
/// See the module docs for the contract; every method must be a
/// deterministic function of its arguments.
pub trait SchedPolicy {
    fn name(&self) -> &'static str;

    /// Whether admission uses [`Self::admission_order`] with misfit
    /// *skipping* instead of the classic head-blocking FIFO walk. False
    /// (the default) keeps the walk — and the event stream — identical
    /// to the pre-policy scheduler.
    fn reorders(&self) -> bool {
        false
    }

    /// Whether [`Self::preempt`] can ever return victims; lets the loop
    /// skip snapshot construction entirely for policies that never do.
    fn preempts(&self) -> bool {
        false
    }

    /// Whether [`Self::admission_order`] reads
    /// [`AdmissionCandidate::covered_tokens`]; the loop skips the
    /// per-candidate radix-tree coverage walk for policies that don't.
    fn uses_coverage(&self) -> bool {
        false
    }

    /// Preferred admission order: indices into `queue` (which is in FIFO
    /// queue order), most-preferred first. Must be a permutation of
    /// `0..queue.len()`. Only consulted when [`Self::reorders`] is true.
    fn admission_order(&self, _now: f64, queue: &[AdmissionCandidate]) -> Vec<usize> {
        (0..queue.len()).collect()
    }

    /// KV-pressure eviction victim: an index into `slots` (`slots` is
    /// never empty when this is called, and the loop never calls it with
    /// a lone slot).
    fn victim(&self, now: f64, slots: &[SlotView]) -> usize;

    /// Proactive preemption: victim/beneficiary pairs to act on this
    /// iteration to protect SLOs. Called only when every slot is
    /// occupied and the queue is non-empty; the loop executes a pair
    /// only if the eviction would actually open room for the named
    /// beneficiary. Default: never.
    fn preempt(
        &self,
        _now: f64,
        _queue: &[AdmissionCandidate],
        _slots: &[SlotView],
    ) -> Vec<Preemption> {
        Vec::new()
    }
}

/// Integer aging boost: one step per `age_bound_s` spent in the current
/// queueing episode. Reordering policies add this (scaled) to their score
/// so a bypassed request's rank grows without bound while it waits — the
/// starvation bound. Episode-based on purpose: requests that never reach
/// a slot age monotonically, while an evicted slot re-earns its boost
/// from zero. `<= 0` disables aging. Deterministic: IEEE division +
/// truncation.
pub(crate) fn age_boost(now: f64, queued_since: f64, age_bound_s: f64) -> i64 {
    if age_bound_s <= 0.0 {
        return 0;
    }
    ((now - queued_since).max(0.0) / age_bound_s) as i64
}

/// Index of the most recently (re)admitted slot — the shared default
/// victim rule (first maximum, exactly the pre-policy `newest_slot_index`
/// semantics; `admit_seq` is unique so ties cannot arise in practice).
pub(crate) fn newest_by_admit_seq(slots: &[SlotView]) -> usize {
    let mut best = 0;
    for (i, s) in slots.iter().enumerate().skip(1) {
        if s.admit_seq > slots[best].admit_seq {
            best = i;
        }
    }
    best
}

#[cfg(test)]
pub(crate) fn slot_view(id: u64, admit_seq: u64, class: usize, arrival_s: f64) -> SlotView {
    SlotView { id, arrival_s, class, deadline_s: 0.0, admit_seq }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_policy_names() {
        assert_eq!(parse_policy("fifo").unwrap(), PolicyKind::Fifo);
        assert_eq!(parse_policy("prefix-aware").unwrap(), PolicyKind::PrefixAware);
        assert_eq!(parse_policy("slo-class").unwrap(), PolicyKind::SloClass);
        assert!(parse_policy("lifo").is_err());
        assert_eq!(PolicyKind::default(), PolicyKind::Fifo);
    }

    #[test]
    fn age_boost_steps_and_disables() {
        assert_eq!(age_boost(0.0, 0.0, 0.5), 0);
        assert_eq!(age_boost(0.49, 0.0, 0.5), 0);
        assert_eq!(age_boost(0.5, 0.0, 0.5), 1);
        assert_eq!(age_boost(2.6, 0.0, 0.5), 5);
        // arrival in the future clamps to zero, disabled bound is zero
        assert_eq!(age_boost(0.0, 1.0, 0.5), 0);
        assert_eq!(age_boost(100.0, 0.0, 0.0), 0);
    }

    #[test]
    fn newest_is_first_max_by_admit_seq() {
        let slots =
            vec![slot_view(0, 0, 0, 0.0), slot_view(3, 4, 0, 0.0), slot_view(2, 5, 0, 0.0)];
        assert_eq!(newest_by_admit_seq(&slots), 2);
        let slots = vec![slot_view(2, 5, 0, 0.0), slot_view(3, 4, 0, 0.0)];
        assert_eq!(newest_by_admit_seq(&slots), 0);
    }
}
