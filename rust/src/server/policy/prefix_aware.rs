//! Prefix-aware admission ordering: cache-warm requests first.

use super::{age_boost, newest_by_admit_seq, AdmissionCandidate, SchedPolicy, SlotView};

/// Orders eligible admissions by radix-tree covered-prefix length,
/// longest first, so requests whose leading KV blocks are already
/// resident reach a slot while those blocks are still cached — lifting
/// the hit rate (and the recompute FLOPs saved) under mixed workloads
/// where FIFO would let hot prefixes age out behind cold prompts.
///
/// Starvation bound: a request's score also grows by one block's worth
/// of coverage per `age_bound_s` spent in its current queueing episode
/// ([`super::age_boost`]), so a cold (zero-coverage) request bypassed by
/// warm arrivals outranks them once it has waited
/// `covered_tokens / block_tokens * age_bound_s` — bypass time is linear
/// in the coverage advantage, never unbounded. Ties (equal score) fall
/// back to queue order, so with the prefix cache off — every coverage 0,
/// aging monotone in queue order — the ordering degenerates to exactly
/// FIFO.
///
/// Victim selection is inherited from FIFO (most recently admitted):
/// coverage says nothing about who should *lose* a slot, and the newest
/// slot has the least sunk replay work.
#[derive(Debug, Clone, Copy)]
pub struct PrefixAware {
    /// tokens per shared KV block (`CbConfig::kv_block_tokens`) — the
    /// aging step is one block of equivalent coverage
    pub block_tokens: usize,
    /// seconds of sojourn per aging step (`CbConfig::age_bound_s`;
    /// <= 0 disables aging)
    pub age_bound_s: f64,
}

impl PrefixAware {
    fn score(&self, now: f64, c: &AdmissionCandidate) -> i64 {
        c.covered_tokens as i64
            + age_boost(now, c.queued_since, self.age_bound_s) * self.block_tokens.max(1) as i64
    }
}

impl SchedPolicy for PrefixAware {
    fn name(&self) -> &'static str {
        "prefix-aware"
    }

    fn reorders(&self) -> bool {
        true
    }

    fn uses_coverage(&self) -> bool {
        true
    }

    fn admission_order(&self, now: f64, queue: &[AdmissionCandidate]) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..queue.len()).collect();
        idx.sort_by(|&a, &b| {
            self.score(now, &queue[b]).cmp(&self.score(now, &queue[a])).then(a.cmp(&b))
        });
        idx
    }

    fn victim(&self, _now: f64, slots: &[SlotView]) -> usize {
        newest_by_admit_seq(slots)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cand(id: u64, arrival_s: f64, covered: usize) -> AdmissionCandidate {
        AdmissionCandidate {
            id,
            arrival_s,
            queued_since: arrival_s,
            tokens: 128,
            class: 0,
            deadline_s: 0.0,
            covered_tokens: covered,
            decode_budget: 0,
        }
    }

    #[test]
    fn warm_requests_jump_cold_ones() {
        let p = PrefixAware { block_tokens: 16, age_bound_s: 0.5 };
        let q = vec![cand(1, 0.0, 0), cand(2, 0.0, 48), cand(3, 0.0, 16)];
        // equal waits: pure coverage order, ties impossible here
        assert_eq!(p.admission_order(0.1, &q), vec![1, 2, 0]);
        assert!(p.reorders());
    }

    #[test]
    fn equal_scores_fall_back_to_queue_order() {
        let p = PrefixAware { block_tokens: 16, age_bound_s: 0.5 };
        let q = vec![cand(5, 0.0, 32), cand(6, 0.0, 32), cand(7, 0.0, 32)];
        assert_eq!(p.admission_order(0.3, &q), vec![0, 1, 2]);
    }

    #[test]
    fn aging_boost_eventually_outranks_coverage() {
        let p = PrefixAware { block_tokens: 16, age_bound_s: 0.5 };
        // cold head queued at 0; warm request (3 blocks covered) at t
        let q = |t: f64| vec![cand(1, 0.0, 0), cand(2, t, 48)];
        // young cold request is bypassed...
        assert_eq!(p.admission_order(1.0, &q(1.0)), vec![1, 0]);
        // ...but after 3 aging steps more than the warm one it wins:
        // boost(cold) - boost(warm) = 4 blocks > 3 blocks of coverage
        let now = 2.2; // cold aged 4 steps, warm (arrived 2.0) aged 0
        assert_eq!(p.admission_order(now, &q(2.0)), vec![0, 1]);
    }
}
