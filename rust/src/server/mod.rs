//! Serving layer: request intake, dynamic batching, the serve loops over
//! the simulated cluster / cost model, metrics, and the CLI entrypoints.
//!
//! Three serve paths:
//!  * [`engine::ServeEngine`] — the paper's Fig-6 setting: batch-1 FIFO
//!    over the cost model.
//!  * [`scheduler::CbEngine`] — continuous batching: slot-based admission
//!    with batched prefill, interleaved batched decode steps, Sarathi-style
//!    chunked piggybacked prefill (`CbConfig::prefill_chunk_tokens`: prompt
//!    chunks fused into decode iterations instead of monopolizing the
//!    cluster), and KV-pressure admission over the block pool
//!    ([`crate::kv`]) — with `CbConfig::prefix_cache`, radix-tree prefix
//!    reuse attaches shared block-aligned prompt prefixes and replays only
//!    suffixes; with `CbConfig::swap_bandwidth_mbps`, preemption swaps
//!    victims over a priced host link instead of recomputing whenever the
//!    transfer is cheaper. Every discretionary *decision* — admission
//!    order, preemption victim, proactive SLO eviction — is delegated to
//!    a pluggable [`policy::SchedPolicy`] (`--policy`): FIFO (default,
//!    bit-for-bit the pre-policy streams), prefix-aware admission
//!    ordering, or SLO priority classes with per-class deadlines
//!    (`CbConfig::classes` / `--classes`) and per-class report breakdowns.
//!  * [`live`] — the same scheduler loop driving *real*
//!    [`crate::coordinator::decode::DecodeSession`]s through a
//!    [`scheduler::DecodeBackend`]: actual tensors, mixed-precision KV
//!    caches, greedy generations (`astra serve-cb --live`). The
//!    differential harness `tests/live_vs_model.rs` pins that live and
//!    cost-model runs make identical scheduling decisions — under every
//!    policy, since decisions are made once in the shared loop.
//!
//! Above the single-replica paths, [`cluster`] runs N actorized CB
//! engines under one deterministic cluster event loop (`--replicas N`):
//! the loop owns the shared virtual clock and the global arrival queue, a
//! pluggable [`cluster::RoutePolicy`] (`--route-policy`: round-robin,
//! least-loaded, prefix-affinity over per-replica shadow digests) decides
//! which replica each request joins, and a scheduled drain spills a
//! removed replica's queue to the survivors without losing a request.
//! The [`chaos`] layer drives the fleet through seeded deterministic
//! fault plans ([`crate::sim::fault::FaultPlan`]): unplanned replica
//! kills with checkpoint-restore recovery over the swap tier, link
//! degradation, swap slowdown, and arrival bursts — all events on the
//! virtual clock (engines never observe wall time), soaked over many
//! seeds by `astra soak` against the invariant checklist.

pub mod batcher;
pub mod chaos;
pub mod cli;
pub mod cluster;
pub mod engine;
pub mod live;
pub mod policy;
pub mod scheduler;

pub use batcher::{Batcher, Request};
pub use chaos::{assert_chaos_invariants, chaos_invariants, skew_arrivals};
pub use cluster::{
    parse_route, ClusterEngine, ClusterReport, ReplicaEvent, ReplicaView, RouteKind, RoutePolicy,
    ShadowDigest,
};
pub use engine::{ServeEngine, ServeReport};
pub use live::{serve_live, LiveBackend, LiveReport};
pub use policy::{PolicyKind, Preemption, SchedPolicy};
pub use scheduler::{
    AdmitBatch, AdmitEntry, CbConfig, CbEngine, CbEvent, CbReport, CheckpointRecord, ChunkPlan,
    ClassReport, DecodeBackend, KvBudget, ModelBackend, PrefixAttach, SlotState, StepBatch,
};
