//! Serving layer: request intake, dynamic batching, the serve loop over
//! the simulated cluster / cost model, metrics, and the CLI entrypoints.

pub mod batcher;
pub mod cli;
pub mod engine;

pub use batcher::{Batcher, Request};
pub use engine::{ServeEngine, ServeReport};
