//! Serving layer: request intake, dynamic batching, the serve loops over
//! the simulated cluster / cost model, metrics, and the CLI entrypoints.
//!
//! Two engines share the cost model:
//!  * [`engine::ServeEngine`] — the paper's Fig-6 setting: batch-1 FIFO.
//!  * [`scheduler::CbEngine`] — continuous batching: slot-based admission
//!    with batched prefill and interleaved batched decode steps.

pub mod batcher;
pub mod cli;
pub mod engine;
pub mod scheduler;

pub use batcher::{Batcher, Request};
pub use engine::{ServeEngine, ServeReport};
pub use scheduler::{CbConfig, CbEngine, CbReport};
