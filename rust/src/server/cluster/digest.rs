//! Shadow prefix digest: the router's read-only model of what each
//! replica's radix tree can serve from cache.
//!
//! The cluster loop cannot peek inside an [`super::super::CbEngine`]'s
//! radix tree without entangling routing with the engine's mutable state,
//! so each replica gets a shadow digest fed by a [`DigestTap`] wrapped
//! around its backend: every `register_block` / `drop_block` the engine
//! issues is mirrored here before it reaches the real backend. The digest
//! then answers the only question routing needs — "how many leading
//! prompt tokens of this request would replica r serve from shared
//! blocks?" — from immutable state, keeping [`super::RoutePolicy`] a pure
//! snapshot-in / decision-out function like `SchedPolicy`.

use std::collections::BTreeMap;

use anyhow::Result;

use super::super::live::prompt_stream_key;
use super::super::scheduler::{AdmitBatch, DecodeBackend, StepBatch};

/// Per-replica mirror of the shared-block spans the replica's engine has
/// registered, keyed by prompt stream (the same `prompt_stream_key` the
/// radix tree and the live backend use, so the digest and the tree agree
/// on which requests share a prompt).
#[derive(Debug, Clone, Default)]
pub struct ShadowDigest {
    prompt_groups: usize,
    /// block id -> (stream key, span start) — the reverse index that
    /// makes `drop_block` O(log n)
    blocks: BTreeMap<u64, (u64, usize)>,
    /// stream key -> span start -> (span end, backing block)
    spans: BTreeMap<u64, BTreeMap<usize, (usize, u64)>>,
}

impl ShadowDigest {
    pub fn new(prompt_groups: usize) -> ShadowDigest {
        ShadowDigest { prompt_groups, ..ShadowDigest::default() }
    }

    /// Mirror of [`DecodeBackend::register_block`]: slot `session`'s
    /// prompt rows `[lo, hi)` now back shared block `block`.
    pub fn register(&mut self, session: u64, block: u64, lo: usize, hi: usize) {
        let key = prompt_stream_key(self.prompt_groups, session);
        self.blocks.insert(block, (key, lo));
        self.spans.entry(key).or_default().insert(lo, (hi, block));
    }

    /// Mirror of [`DecodeBackend::drop_block`]: the engine reclaimed the
    /// block, so its span no longer counts as coverage.
    pub fn drop_block(&mut self, block: u64) {
        let Some((key, lo)) = self.blocks.remove(&block) else { return };
        if let Some(stream) = self.spans.get_mut(&key) {
            // a newer block may have re-registered the same span; only
            // remove the entry this block still backs
            if stream.get(&lo).is_some_and(|&(_, b)| b == block) {
                stream.remove(&lo);
            }
            if stream.is_empty() {
                self.spans.remove(&key);
            }
        }
    }

    /// Leading prompt tokens of request `id` (a `tokens`-token prompt)
    /// this replica would serve from shared blocks: walk the contiguous
    /// block-aligned span chain from token 0, exactly as the radix lookup
    /// attaches root-to-leaf.
    pub fn covered(&self, id: u64, tokens: usize) -> usize {
        let key = prompt_stream_key(self.prompt_groups, id);
        let Some(stream) = self.spans.get(&key) else { return 0 };
        let mut cov = 0usize;
        while cov < tokens {
            match stream.get(&cov) {
                Some(&(hi, _)) if hi > cov => cov = hi,
                _ => break,
            }
        }
        cov.min(tokens)
    }

    /// Forget everything — the replica was drained; its blocks die with it.
    pub fn clear(&mut self) {
        self.blocks.clear();
        self.spans.clear();
    }
}

/// Backend wrapper that mirrors block registrations into a replica's
/// [`ShadowDigest`] before forwarding to the real backend. Every other
/// method forwards untouched, so a tapped backend is observationally
/// identical to the bare one — the event streams the differential tests
/// pin cannot tell the difference.
pub(crate) struct DigestTap<'a, B: DecodeBackend + ?Sized> {
    pub(crate) inner: &'a mut B,
    pub(crate) digest: &'a mut ShadowDigest,
}

impl<B: DecodeBackend + ?Sized> DecodeBackend for DigestTap<'_, B> {
    fn admit(&mut self, batch: &AdmitBatch) -> Result<()> {
        self.inner.admit(batch)
    }

    fn step(&mut self, batch: &StepBatch) -> Result<()> {
        self.inner.step(batch)
    }

    fn complete(&mut self, id: u64) -> Result<()> {
        self.inner.complete(id)
    }

    fn evict(&mut self, id: u64) -> Result<()> {
        self.inner.evict(id)
    }

    fn cancel(&mut self, id: u64) -> Result<()> {
        self.inner.cancel(id)
    }

    fn register_block(
        &mut self,
        session: u64,
        block: u64,
        lo: usize,
        hi: usize,
        bytes: usize,
    ) -> Result<()> {
        self.digest.register(session, block, lo, hi);
        self.inner.register_block(session, block, lo, hi, bytes)
    }

    fn drop_block(&mut self, block: u64) -> Result<()> {
        self.digest.drop_block(block);
        self.inner.drop_block(block)
    }

    fn swap_out(&mut self, id: u64) -> Result<()> {
        self.inner.swap_out(id)
    }

    fn swap_in(&mut self, id: u64) -> Result<()> {
        self.inner.swap_in(id)
    }

    fn drop_swapped(&mut self, id: u64) -> Result<()> {
        self.inner.drop_swapped(id)
    }

    fn restore(
        &mut self,
        id: u64,
        tokens: usize,
        generated: usize,
        budget: usize,
        class: usize,
    ) -> Result<()> {
        self.inner.restore(id, tokens, generated, budget, class)
    }

    fn kv_bytes_in_flight(&self) -> usize {
        self.inner.kv_bytes_in_flight()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn covered_walks_the_contiguous_span_chain() {
        // 2 prompt groups: ids 0 and 2 share stream 0, id 1 is stream 1
        let mut d = ShadowDigest::new(2);
        d.register(0, 10, 0, 16);
        d.register(0, 11, 16, 32);
        // a span past a gap never counts
        d.register(0, 12, 48, 64);
        assert_eq!(d.covered(2, 64), 32, "chain stops at the gap");
        assert_eq!(d.covered(2, 20), 20, "coverage caps at the prompt length");
        assert_eq!(d.covered(1, 64), 0, "other streams share nothing");
        d.register(1, 20, 0, 16);
        assert_eq!(d.covered(1, 64), 16);
        assert_eq!(d.covered(3, 64), 16, "same stream via id % groups");
    }

    #[test]
    fn drop_block_removes_coverage_and_tolerates_reregistration() {
        let mut d = ShadowDigest::new(0);
        d.register(7, 10, 0, 16);
        d.register(7, 11, 16, 32);
        assert_eq!(d.covered(7, 64), 32);
        d.drop_block(10);
        assert_eq!(d.covered(7, 64), 0, "chain must restart at token 0");
        // re-register the same span under a new block, then drop the old
        // id again: the new entry must survive
        d.register(7, 12, 0, 16);
        d.drop_block(10);
        assert_eq!(d.covered(7, 64), 32);
        d.clear();
        assert_eq!(d.covered(7, 64), 0);
    }
}
