//! Multi-replica serving: N actorized continuous-batching engines under
//! one deterministic cluster event loop.
//!
//! The actor contract splits responsibilities sharply:
//!
//! * **Engines own mechanism.** Each replica is an
//!   [`EngineActor`] — admission, chunked prefill, decode, KV pressure,
//!   swap pricing, and `SchedPolicy` hooks all happen inside
//!   [`EngineActor::step`], exactly as in a single-replica run. An actor
//!   never sees the fleet; it reports its next wake time and its events.
//! * **The cluster loop owns time and admission.** [`ClusterEngine`]
//!   holds the one virtual clock, the global arrival queue, and the
//!   replica wake times; every iteration it advances to the earliest
//!   pending instant (a replica wake, an arrival, a scheduled drain),
//!   routes due arrivals, and steps every replica whose wake is due — in
//!   replica-index order, so the interleaved fleet stream is a pure
//!   function of the trace.
//! * **Routing sees snapshots only.** The [`RoutePolicy`] is handed
//!   immutable [`ReplicaView`]s (queue depth, in-flight slots, and — for
//!   affinity policies — how many prompt tokens the replica's shadow
//!   [`ShadowDigest`] says it could serve from cache) and returns a
//!   replica index. It can neither mutate an engine nor observe
//!   non-deterministic state.
//!
//! With one replica the loop degenerates to exactly the single-replica
//! driver in `scheduler::loop`: same clock jumps, same event stream, bit
//! for bit — `tests/cluster.rs` and the proptests pin this. Replica
//! removal ([`ClusterEngine::with_drain`]) tears one replica down
//! mid-run: its slots are evicted recompute-style, its host swap tier and
//! shared blocks die with it, and every queued request spills to the
//! survivors through the same routing policy, carrying its accounting so
//! no wait or first token is double-counted.
//!
//! On top of the scheduled drain sits unplanned chaos
//! ([`ClusterEngine::with_faults`], a [`FaultPlan`] from `sim/fault`):
//! replica *kills* on the virtual clock lose the victim's queue and host
//! tier outright — every held request is surrendered as
//! [`CbEvent::Killed`] and re-routed to a survivor, where it either
//! restores from the fleet checkpoint store (`CbConfig::checkpoint_every`
//! copies priced over the swap link, [`CbEvent::Restore`]) or replays
//! from its prompt; link windows degrade every replica's bandwidth trace
//! up front; swap windows slow the host tier per step; arrival bursts
//! collapse arrival spans. The empty plan injects nothing and reproduces
//! the fault-free stream bit for bit — `tests/chaos.rs` pins this.

mod digest;
mod route;

pub use digest::ShadowDigest;
pub use route::{
    parse_route, LeastLoaded, Placement, PrefixAffinity, ReplicaView, RouteKind, RoundRobin,
    RoutePolicy,
};

use std::collections::BTreeMap;

use anyhow::{ensure, Result};

use digest::DigestTap;

use super::batcher::Request;
use super::chaos::skew_arrivals;
use super::scheduler::{
    CbEngine, CbEvent, CbReport, CheckpointRecord, DecodeBackend, EngineActor, ModelBackend,
};
use crate::sim::fault::FaultPlan;
use crate::util::stats::Summary;

/// One scheduler event tagged with the replica that emitted it. A
/// single-replica fleet emits the identical `CbEvent` sequence all tagged
/// `replica: 0`, so existing single-replica fixtures never churn.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ReplicaEvent {
    pub replica: usize,
    pub event: CbEvent,
}

/// The multi-replica serve loop: N engines, one clock, one arrival
/// stream, a pluggable router.
pub struct ClusterEngine {
    engines: Vec<CbEngine>,
    route: RouteKind,
    /// scheduled mid-run removal: (replica index, virtual time)
    drain_at: Option<(usize, f64)>,
    /// seeded chaos schedule; `None` and the empty plan are identical
    faults: Option<FaultPlan>,
}

impl ClusterEngine {
    pub fn new(engines: Vec<CbEngine>, route: RouteKind) -> ClusterEngine {
        ClusterEngine { engines, route, drain_at: None, faults: None }
    }

    /// Schedule replica `replica` for removal at virtual time `at_s`: its
    /// in-flight work is evicted, its queue spills to the survivors. The
    /// drain is skipped if it would leave the fleet empty.
    pub fn with_drain(mut self, replica: usize, at_s: f64) -> ClusterEngine {
        self.drain_at = Some((replica, at_s));
        self
    }

    /// Attach a seeded fault plan ([`FaultPlan::seeded`]): replica kills,
    /// link degradation, swap slowdown, and arrival bursts, all on the
    /// virtual clock. An empty plan reproduces the fault-free run bit for
    /// bit.
    pub fn with_faults(mut self, plan: FaultPlan) -> ClusterEngine {
        self.faults = Some(plan);
        self
    }

    pub fn replicas(&self) -> usize {
        self.engines.len()
    }

    /// Serve over the cost model (one [`ModelBackend`] per replica).
    pub fn serve_stream(
        &mut self,
        arrivals: Vec<Request>,
        horizon_s: f64,
    ) -> Result<ClusterReport> {
        let mut backends: Vec<ModelBackend> = self.engines.iter().map(|_| ModelBackend).collect();
        self.serve_stream_with(&mut backends, arrivals, horizon_s)
    }

    /// Serve a fixed arrival list over per-replica backends (`backends[i]`
    /// executes replica `i`'s work). `arrivals` must be sorted by arrival.
    pub fn serve_stream_with<B: DecodeBackend>(
        &mut self,
        backends: &mut [B],
        arrivals: Vec<Request>,
        horizon_s: f64,
    ) -> Result<ClusterReport> {
        let n = self.engines.len();
        ensure!(n > 0, "cluster needs at least one replica");
        ensure!(backends.len() == n, "need one backend per replica");
        if let Some((victim, _)) = self.drain_at {
            ensure!(victim < n, "drain target {victim} out of range");
        }
        let plan = self.faults.clone().unwrap_or_default();
        // clock-skew bursts: collapse arrival spans before anything routes
        // (a no-op clone-free pass when the plan has no bursts)
        let arrivals =
            if plan.bursts.is_empty() { arrivals } else { skew_arrivals(&plan, arrivals) };
        // link windows degrade every replica's bandwidth trace up front —
        // the engines are immutable for the run, so the degradation is
        // applied once here rather than per transfer (no hot-path RNG,
        // and the actors see an ordinary time-varying trace)
        if !plan.links.is_empty() {
            for e in self.engines.iter_mut() {
                e.trace = plan.degraded_trace(&e.trace, horizon_s);
            }
        }
        let policy = self.route.make(self.engines[0].cfg.kv_block_tokens.max(1));
        let affinity = policy.uses_affinity();
        let mut actors: Vec<EngineActor> = self
            .engines
            .iter()
            .enumerate()
            .map(|(i, e)| EngineActor::with_replica(e.clone(), i))
            .collect();
        let mut digests: Vec<ShadowDigest> = self
            .engines
            .iter()
            .map(|e| ShadowDigest::new(e.cfg.prompt_groups))
            .collect();
        let mut alive = vec![true; n];
        // next wake per replica; None = idle (sleeps until an enqueue)
        let mut wake: Vec<Option<f64>> = vec![None; n];
        let mut drain_pending = self.drain_at;
        let mut pending = arrivals.into_iter().peekable();
        let mut seq: u64 = 0; // routed-request counter (the RR cursor)
        let mut routed = vec![0usize; n];
        let mut events: Vec<ReplicaEvent> = Vec::new();
        let mut drained: Option<usize> = None;
        let mut drain_skipped: Option<usize> = None;
        // fault-plan state: kills fire in at_s order; checkpoint copies
        // live at the FLEET level so they survive their replica's death
        let mut kill_idx = 0usize;
        let mut killed: Vec<usize> = Vec::new();
        let mut kills_skipped: Vec<usize> = Vec::new();
        let mut ckpt_store: BTreeMap<u64, CheckpointRecord> = BTreeMap::new();
        let mut restored_n = 0usize;
        let mut replayed_n = 0usize;

        loop {
            // ---- advance the shared clock to the earliest pending instant ----
            let next_wake = (0..n)
                .filter(|&i| alive[i])
                .filter_map(|i| wake[i])
                .fold(f64::INFINITY, f64::min);
            let next_arrival = pending.peek().map_or(f64::INFINITY, |r| r.arrival_s);
            let next_drain = drain_pending.map_or(f64::INFINITY, |(_, at)| at);
            let next_kill =
                plan.kills.get(kill_idx).map_or(f64::INFINITY, |k| k.at_s);
            let now = next_wake.min(next_arrival).min(next_drain).min(next_kill);
            if !now.is_finite() || now >= horizon_s {
                break;
            }

            // ---- drain first, so same-instant arrivals route to survivors ----
            if drain_pending.is_some_and(|(_, at)| at <= now) {
                let (victim, _) = drain_pending.take().unwrap();
                // never drain the last live replica — spilled work would
                // have nowhere to go
                if alive[victim] && alive.iter().filter(|&&a| a).count() >= 2 {
                    let mut tap = DigestTap {
                        inner: &mut backends[victim],
                        digest: &mut digests[victim],
                    };
                    let out = actors[victim].drain(&mut tap, now)?;
                    for event in out.events {
                        events.push(ReplicaEvent { replica: victim, event });
                    }
                    alive[victim] = false;
                    wake[victim] = None;
                    digests[victim].clear();
                    drained = Some(victim);
                    // spill the drained queue through the same router
                    for (req, st) in out.spilled {
                        let views = replica_views(&actors, &digests, &alive, &req, affinity);
                        let target = policy.route(seq, now, &req, &views);
                        seq += 1;
                        routed[target] += 1;
                        actors[target].adopt(req, st);
                        if wake[target].is_none() {
                            wake[target] = Some(now);
                        }
                    }
                } else {
                    // a drain targeting a dead or last-live replica used
                    // to no-op invisibly (`drained` stayed `None` and the
                    // CLI reported success); surface the skip instead
                    drain_skipped = Some(victim);
                }
            }

            // ---- unplanned kills due at this instant (after the drain,
            //      so a same-instant drain's spill never lands on a dying
            //      replica at this clock tick; arrivals route after both) ----
            while plan.kills.get(kill_idx).is_some_and(|k| k.at_s <= now) {
                let victim = plan.kills[kill_idx].replica;
                kill_idx += 1;
                // never kill the last live replica — the lost work would
                // have nowhere to go; an already-dead victim is a no-op.
                // Both are surfaced, never silent (the drain-skip lesson).
                if victim >= n || !alive[victim] || alive.iter().filter(|&&a| a).count() < 2 {
                    kills_skipped.push(victim);
                    continue;
                }
                let mut tap =
                    DigestTap { inner: &mut backends[victim], digest: &mut digests[victim] };
                let out = actors[victim].kill(&mut tap, now)?;
                // structural invariant: a kill must drain the victim's
                // pool to quiescence — leaked private bytes or block refs
                // here would silently corrupt fleet KV accounting
                ensure!(
                    actors[victim].pool_quiescent(),
                    "replica {victim}: pool not quiescent after kill"
                );
                for event in out.events {
                    events.push(ReplicaEvent { replica: victim, event });
                }
                alive[victim] = false;
                wake[victim] = None;
                digests[victim].clear();
                killed.push(victim);
                // re-route every lost request: restore from the fleet
                // checkpoint store when a copy exists, else replay from
                // the prompt on whatever replica the router picks
                for (req, st) in out.lost {
                    let views = replica_views(&actors, &digests, &alive, &req, affinity);
                    let target = policy.route(seq, now, &req, &views);
                    seq += 1;
                    routed[target] += 1;
                    match ckpt_store.remove(&req.id) {
                        Some(rec) => {
                            restored_n += 1;
                            actors[target].adopt_restored(req, st, &rec);
                        }
                        None => {
                            replayed_n += 1;
                            actors[target].adopt(req, st);
                        }
                    }
                    if wake[target].is_none() {
                        wake[target] = Some(now);
                    }
                }
            }

            // ---- route arrivals due at this instant ----
            while let Some(r) = pending.peek() {
                if r.arrival_s > now {
                    break;
                }
                let req = pending.next().unwrap();
                let views = replica_views(&actors, &digests, &alive, &req, affinity);
                let target = policy.route(seq, now, &req, &views);
                seq += 1;
                routed[target] += 1;
                actors[target].enqueue(req);
                if wake[target].is_none() {
                    wake[target] = Some(now);
                }
            }

            // ---- step every replica whose wake is due, in index order ----
            for i in 0..n {
                if !alive[i] || wake[i].is_none_or(|w| w > now) {
                    continue;
                }
                // swap-tier slowdown windows apply per step at the shared
                // clock (skipped entirely when the plan has none, keeping
                // the fault-free path untouched)
                if !plan.swaps.is_empty() {
                    actors[i].set_swap_slowdown(plan.swap_slowdown(now));
                }
                let mut tap = DigestTap { inner: &mut backends[i], digest: &mut digests[i] };
                let out = actors[i].step(&mut tap, now, horizon_s)?;
                for event in out.events {
                    // a completed or client-cancelled request's
                    // checkpoint copy is garbage — cancellation is
                    // terminal fleet-wide, so the copy must not restore
                    // a request nobody is waiting for after a kill
                    if let CbEvent::Complete { id } | CbEvent::Cancelled { id } = event {
                        ckpt_store.remove(&id);
                    }
                    events.push(ReplicaEvent { replica: i, event });
                }
                // checkpoint copies move to the fleet store immediately:
                // they must survive this replica's death
                for rec in actors[i].take_checkpoints() {
                    ckpt_store.insert(rec.id, rec);
                }
                wake[i] = out.until;
            }
        }

        // arrivals the run never reached are censored at the fleet level
        // (no replica ever owned them, so no per-replica tally moves)
        let unrouted = pending.filter(|r| r.arrival_s < horizon_s).count();

        let replicas: Vec<CbReport> = actors.into_iter().map(|a| a.finish(horizon_s)).collect();
        Ok(ClusterReport {
            replicas,
            events,
            horizon_s,
            routed,
            drained,
            drain_skipped,
            unrouted,
            killed,
            kills_skipped,
            restored: restored_n,
            replayed: replayed_n,
        })
    }
}

/// Immutable routing snapshots over the live replicas. Coverage lookups
/// are skipped unless the policy declared it reads them.
fn replica_views(
    actors: &[EngineActor],
    digests: &[ShadowDigest],
    alive: &[bool],
    req: &Request,
    want_coverage: bool,
) -> Vec<ReplicaView> {
    actors
        .iter()
        .enumerate()
        .filter(|&(i, _)| alive[i])
        .map(|(i, a)| {
            let covered_tokens = if want_coverage {
                digests[i].covered(req.id, req.tokens)
            } else {
                0
            };
            ReplicaView {
                replica: i,
                queued: a.queue_len(),
                in_flight: a.in_flight(),
                swapped: a.swapped_out(),
                covered_tokens,
                decode_speed: a.decode_speed(),
            }
        })
        .collect()
}

/// Outcome of a fleet serve run: per-replica reports plus fleet-level
/// rollups computed on the shared clock.
#[derive(Debug)]
pub struct ClusterReport {
    /// one full [`CbReport`] per replica, index == replica id
    pub replicas: Vec<CbReport>,
    /// the interleaved fleet decision stream, in processing order
    pub events: Vec<ReplicaEvent>,
    pub horizon_s: f64,
    /// requests routed to each replica (arrivals + drain spills)
    pub routed: Vec<usize>,
    /// the replica removed mid-run, if a scheduled drain executed
    pub drained: Option<usize>,
    /// a scheduled drain that could NOT execute (victim already dead, or
    /// the last live replica) — surfaced instead of silently no-opping
    pub drain_skipped: Option<usize>,
    /// arrivals inside the horizon the run ended before routing — censored
    /// at the fleet level only (they never reached any replica)
    pub unrouted: usize,
    /// replicas lost to unplanned fault-plan kills, in kill order
    pub killed: Vec<usize>,
    /// planned kills that could not execute (victim out of range, already
    /// dead, or the last live replica)
    pub kills_skipped: Vec<usize>,
    /// kill-lost requests re-admitted from a fleet checkpoint copy
    pub restored: usize,
    /// kill-lost requests re-routed without a copy (replay from prompt)
    pub replayed: usize,
}

impl ClusterReport {
    pub fn completed(&self) -> usize {
        self.replicas.iter().map(|r| r.completed).sum()
    }

    /// Fleet censored count: per-replica censored plus never-routed
    /// arrivals. With one replica this equals the single-engine
    /// `CbReport::censored` exactly.
    pub fn censored(&self) -> usize {
        self.replicas.iter().map(|r| r.censored).sum::<usize>() + self.unrouted
    }

    pub fn kv_rejected(&self) -> usize {
        self.replicas.iter().map(|r| r.kv_rejected).sum()
    }

    /// Fleet total of client-cancelled requests (`CbConfig::patience_s`).
    pub fn cancelled(&self) -> usize {
        self.replicas.iter().map(|r| r.cancelled).sum()
    }

    /// Fleet total of tokens decoded after their client abandoned the
    /// stream — the wasted-work metric the cancellation sweep minimizes.
    pub fn wasted_decode_tokens(&self) -> usize {
        self.replicas.iter().map(|r| r.wasted_decode_tokens).sum()
    }

    pub fn kv_violations(&self) -> usize {
        self.replicas.iter().map(|r| r.kv_violations).sum()
    }

    /// Fleet completions per second over the shared horizon.
    pub fn fleet_throughput(&self) -> f64 {
        if self.horizon_s > 0.0 {
            self.completed() as f64 / self.horizon_s
        } else {
            0.0
        }
    }

    /// Fleet within-SLO completions per second (sum of per-replica
    /// goodput — all replicas share the horizon).
    pub fn fleet_goodput(&self) -> f64 {
        self.replicas.iter().map(|r| r.goodput).sum()
    }

    /// Fleet prefix hit rate: shared-block prompt tokens over all admitted
    /// prompt tokens, pooled across replicas (NOT a mean of per-replica
    /// rates, which would overweight idle replicas).
    pub fn fleet_hit_rate(&self) -> f64 {
        let denom: usize = self.replicas.iter().map(|r| r.admitted_prompt_tokens).sum();
        if denom == 0 {
            return 0.0;
        }
        self.replicas.iter().map(|r| r.prefix_hit_tokens).sum::<usize>() as f64 / denom as f64
    }

    /// Pooled end-to-end latency: the union of every replica's completion
    /// samples, so fleet percentiles are true order statistics rather
    /// than averages of per-replica percentiles.
    pub fn fleet_latency(&self) -> Summary {
        let mut s = Summary::new();
        for r in &self.replicas {
            s.merge(&r.latency);
        }
        s
    }

    pub fn fleet_p95(&self) -> f64 {
        self.fleet_latency().p95()
    }

    /// Fleet completion bars on the shared clock: the element-wise sum of
    /// the per-replica windows. Every replica buckets on the same virtual
    /// clock with the same window width, so summing aligned bars is exact
    /// — re-bucketing merged completion timestamps would be, too, but only
    /// because the clocks agree; summing makes that invariant structural.
    pub fn fleet_windows(&self) -> Vec<usize> {
        let len = self.replicas.iter().map(|r| r.windows.len()).max().unwrap_or(0);
        let mut out = vec![0usize; len];
        for r in &self.replicas {
            for (i, &w) in r.windows.iter().enumerate() {
                out[i] += w;
            }
        }
        out
    }

    /// Routing imbalance: (max - min) / mean of per-replica routed
    /// counts; 0 for a perfectly balanced (or empty) fleet.
    pub fn load_skew(&self) -> f64 {
        if self.routed.is_empty() {
            return 0.0;
        }
        let max = *self.routed.iter().max().unwrap() as f64;
        let min = *self.routed.iter().min().unwrap() as f64;
        let mean = self.routed.iter().sum::<usize>() as f64 / self.routed.len() as f64;
        if mean == 0.0 {
            0.0
        } else {
            (max - min) / mean
        }
    }
}
