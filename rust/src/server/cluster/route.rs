//! Fleet routing policies: which replica a newly arrived request joins.
//!
//! `RoutePolicy` mirrors the engine-level `SchedPolicy` contract one
//! level up: the cluster loop hands the policy immutable per-replica
//! snapshots ([`ReplicaView`]) and a request, and gets back a replica
//! index — no policy ever touches an engine, a queue, or the clock.
//! Determinism falls out for free: the views are derived from the
//! deterministic actors on the shared virtual clock, so the same trace
//! always routes the same way.

use std::cmp::Reverse;

use crate::server::batcher::Request;

/// Immutable snapshot of one live replica at a routing instant.
#[derive(Debug, Clone, Copy)]
pub struct ReplicaView {
    /// replica index in the fleet
    pub replica: usize,
    /// requests waiting in the replica's admission queue
    pub queued: usize,
    /// requests seated in decode slots
    pub in_flight: usize,
    /// requests parked in the replica's host swap tier (these are also
    /// counted in `queued` — swapped requests stay in the batcher)
    pub swapped: usize,
    /// leading prompt tokens of the request being routed that this
    /// replica would serve from its shared KV blocks (0 unless the
    /// policy asked for coverage; see [`RoutePolicy::uses_affinity`])
    pub covered_tokens: usize,
    /// the replica's fleet decode speed (fastest profiled device's
    /// weight; 1.0 on uniform/unprofiled replicas) — how fast a unit of
    /// load drains here, what placement routing divides load by
    pub decode_speed: f64,
}

impl ReplicaView {
    /// Work the replica already owns: queue depth plus seated slots.
    pub fn load(&self) -> usize {
        self.queued + self.in_flight
    }
}

/// A fleet routing decision: immutable snapshots in, replica index out.
pub trait RoutePolicy {
    fn name(&self) -> &'static str;

    /// Whether [`RoutePolicy::route`] reads `covered_tokens` — when
    /// false the cluster loop skips the digest lookups entirely.
    fn uses_affinity(&self) -> bool {
        false
    }

    /// Pick the replica for `req`. `seq` counts routed requests (the
    /// round-robin cursor), `replicas` holds one view per LIVE replica —
    /// drained replicas never appear, so the returned value must be one
    /// of the views' `replica` indices, not a raw `seq % fleet_size`.
    fn route(&self, seq: u64, now: f64, req: &Request, replicas: &[ReplicaView]) -> usize;
}

/// Rotate over the live replicas in arrival order — the baseline every
/// smarter policy must beat.
#[derive(Debug, Clone, Copy, Default)]
pub struct RoundRobin;

impl RoutePolicy for RoundRobin {
    fn name(&self) -> &'static str {
        "round-robin"
    }

    fn route(&self, seq: u64, _now: f64, _req: &Request, replicas: &[ReplicaView]) -> usize {
        replicas[(seq % replicas.len() as u64) as usize].replica
    }
}

/// Join the shortest queue: minimum `queued + in_flight`, lowest replica
/// index on ties (deterministic under equal load).
#[derive(Debug, Clone, Copy, Default)]
pub struct LeastLoaded;

impl RoutePolicy for LeastLoaded {
    fn name(&self) -> &'static str {
        "least-loaded"
    }

    fn route(&self, _seq: u64, _now: f64, _req: &Request, replicas: &[ReplicaView]) -> usize {
        replicas
            .iter()
            .min_by_key(|v| (v.load(), v.replica))
            .expect("route called with no live replicas")
            .replica
    }
}

/// Prefix-affinity routing: send a request where its prompt prefix is
/// already cached, unless that replica is overloaded enough that queueing
/// behind the hot spot costs more than recomputing the prefix elsewhere.
///
/// The score trades cached blocks against load skew in commensurate
/// units: each fully covered block counts +1, each unit of load above the
/// fleet minimum counts -1. A replica holding the whole prompt but three
/// requests deeper than the idlest peer only wins while the prompt spans
/// more than three blocks — hot prefixes concentrate, but bounded by how
/// much cache value the concentration actually buys.
#[derive(Debug, Clone, Copy)]
pub struct PrefixAffinity {
    /// KV block granularity (`CbConfig::kv_block_tokens`): converts
    /// covered tokens into blocks, the unit a cache hit actually saves
    pub block_tokens: usize,
}

impl RoutePolicy for PrefixAffinity {
    fn name(&self) -> &'static str {
        "prefix-affinity"
    }

    fn uses_affinity(&self) -> bool {
        true
    }

    fn route(&self, _seq: u64, _now: f64, _req: &Request, replicas: &[ReplicaView]) -> usize {
        let min_load = replicas.iter().map(ReplicaView::load).min().unwrap_or(0);
        replicas
            .iter()
            .max_by_key(|v| {
                let blocks = (v.covered_tokens / self.block_tokens.max(1)) as i64;
                let skew = (v.load() - min_load) as i64;
                // distinct final key per view (Reverse(replica)) so
                // max_by_key's last-max rule never decides anything
                (blocks - skew, Reverse(v.load()), Reverse(v.replica))
            })
            .expect("route called with no live replicas")
            .replica
    }
}

/// Placement-aware routing for heterogeneous fleets: join the replica
/// with the least *drain time*, not the least load. A fast replica
/// (decode speed 4) clears four units of queued work in the time a
/// reference replica clears one, so the score is
/// `(load + 1) / decode_speed` — the `+ 1` counts the request being
/// placed, which is what makes an idle slow replica lose to a busy fast
/// one exactly when the fast one would still finish first. On a uniform
/// fleet every speed is 1.0 and this degenerates to [`LeastLoaded`]
/// (including its lowest-index tie-break).
#[derive(Debug, Clone, Copy, Default)]
pub struct Placement;

impl RoutePolicy for Placement {
    fn name(&self) -> &'static str {
        "placement"
    }

    fn route(&self, _seq: u64, _now: f64, _req: &Request, replicas: &[ReplicaView]) -> usize {
        replicas
            .iter()
            .min_by(|a, b| {
                let da = (a.load() as f64 + 1.0) / a.decode_speed.max(1e-6);
                let db = (b.load() as f64 + 1.0) / b.decode_speed.max(1e-6);
                da.total_cmp(&db).then(a.replica.cmp(&b.replica))
            })
            .expect("route called with no live replicas")
            .replica
    }
}

/// Parseable routing-policy selector (`--route-policy`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum RouteKind {
    #[default]
    RoundRobin,
    LeastLoaded,
    PrefixAffinity,
    Placement,
}

impl RouteKind {
    pub fn name(&self) -> &'static str {
        match self {
            RouteKind::RoundRobin => "round-robin",
            RouteKind::LeastLoaded => "least-loaded",
            RouteKind::PrefixAffinity => "prefix-affinity",
            RouteKind::Placement => "placement",
        }
    }

    /// Instantiate the policy; `block_tokens` parameterizes affinity
    /// scoring (ignored by the load-only policies).
    pub fn make(&self, block_tokens: usize) -> Box<dyn RoutePolicy> {
        match self {
            RouteKind::RoundRobin => Box::new(RoundRobin),
            RouteKind::LeastLoaded => Box::new(LeastLoaded),
            RouteKind::PrefixAffinity => Box::new(PrefixAffinity { block_tokens }),
            RouteKind::Placement => Box::new(Placement),
        }
    }
}

/// Parse a `--route-policy` value.
pub fn parse_route(s: &str) -> Option<RouteKind> {
    match s {
        "round-robin" | "rr" => Some(RouteKind::RoundRobin),
        "least-loaded" | "least" => Some(RouteKind::LeastLoaded),
        "prefix-affinity" | "affinity" => Some(RouteKind::PrefixAffinity),
        "placement" | "placement-aware" => Some(RouteKind::Placement),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn view(replica: usize, queued: usize, in_flight: usize, covered: usize) -> ReplicaView {
        ReplicaView {
            replica,
            queued,
            in_flight,
            swapped: 0,
            covered_tokens: covered,
            decode_speed: 1.0,
        }
    }

    fn req() -> Request {
        Request { id: 0, arrival_s: 0.0, tokens: 64 }
    }

    #[test]
    fn round_robin_rotates_over_live_replicas() {
        let p = RoundRobin;
        // replica 1 drained: views are [0, 2, 3]
        let views = vec![view(0, 0, 0, 0), view(2, 0, 0, 0), view(3, 0, 0, 0)];
        let picks: Vec<usize> = (0..6).map(|s| p.route(s, 0.0, &req(), &views)).collect();
        assert_eq!(picks, vec![0, 2, 3, 0, 2, 3]);
    }

    #[test]
    fn least_loaded_joins_shortest_queue_lowest_index_on_ties() {
        let p = LeastLoaded;
        let views = vec![view(0, 3, 2, 0), view(1, 1, 1, 0), view(2, 0, 2, 0)];
        assert_eq!(p.route(0, 0.0, &req(), &views), 2);
        let tied = vec![view(0, 1, 1, 0), view(1, 0, 2, 0)];
        assert_eq!(p.route(0, 0.0, &req(), &tied), 0);
    }

    #[test]
    fn affinity_trades_cached_blocks_against_load_skew() {
        let p = PrefixAffinity { block_tokens: 16 };
        // replica 1 holds 2 blocks of the prompt but is 1 deeper: wins
        let views = vec![view(0, 0, 0, 0), view(1, 1, 0, 32)];
        assert_eq!(p.route(0, 0.0, &req(), &views), 1);
        // 2 blocks cached but 3 deeper: the skew outweighs the cache
        let views = vec![view(0, 0, 0, 0), view(1, 3, 0, 32)];
        assert_eq!(p.route(0, 0.0, &req(), &views), 0);
        // cold fleet, equal load: lowest index (no accidental hot spot)
        let views = vec![view(0, 1, 0, 0), view(1, 1, 0, 0)];
        assert_eq!(p.route(0, 0.0, &req(), &views), 0);
        // equal score, unequal load: the lighter replica wins
        let views = vec![view(0, 2, 0, 16), view(1, 1, 0, 0)];
        assert_eq!(p.route(0, 0.0, &req(), &views), 1);
    }

    #[test]
    fn placement_routes_by_drain_time_not_load() {
        let p = Placement;
        let fast = |replica, load| ReplicaView {
            replica,
            queued: load,
            in_flight: 0,
            swapped: 0,
            covered_tokens: 0,
            decode_speed: 4.0,
        };
        // fast replica 3 deep drains (3+1)/4 = 1.0; idle slow drains
        // (0+1)/1 = 1.0 — tie goes to the lower index
        let views = vec![view(0, 0, 0, 0), fast(1, 3)];
        assert_eq!(p.route(0, 0.0, &req(), &views), 0);
        // one unit shallower and the fast replica wins outright
        let views = vec![view(0, 0, 0, 0), fast(1, 2)];
        assert_eq!(p.route(0, 0.0, &req(), &views), 1);
        // uniform speeds: exactly least-loaded, lowest index on ties
        let views = vec![view(0, 1, 1, 0), view(1, 0, 2, 0)];
        assert_eq!(p.route(0, 0.0, &req(), &views), 0);
    }

    #[test]
    fn route_kind_parses_and_makes() {
        assert_eq!(parse_route("rr"), Some(RouteKind::RoundRobin));
        assert_eq!(parse_route("least-loaded"), Some(RouteKind::LeastLoaded));
        assert_eq!(parse_route("affinity"), Some(RouteKind::PrefixAffinity));
        assert_eq!(parse_route("placement-aware"), Some(RouteKind::Placement));
        assert_eq!(parse_route("nope"), None);
        assert_eq!(RouteKind::default().make(16).name(), "round-robin");
        assert!(RouteKind::PrefixAffinity.make(16).uses_affinity());
        assert_eq!(RouteKind::Placement.make(16).name(), "placement");
    }
}
