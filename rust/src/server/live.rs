//! Live continuous-batching execution: real [`DecodeSession`]s driven by
//! the [`CbEngine`] slot scheduler.
//!
//! The cost-model engine ([`super::scheduler`]) owns the virtual clock and
//! every scheduling decision — admission order, batch composition, KV
//! admission, eviction. This module plugs a [`LiveBackend`] into that loop
//! so each decision executes for real: an admission replays the request's
//! variable-length prompt into a fresh mixed-precision KV cache
//! ([`DecodeSession::with_budget`], sized prompt + decode budget) — or,
//! under chunked prefill, opens a deferred session and replays only the
//! admission chunk, the rest arriving chunk by chunk through
//! [`DecodeSession::replay_range`] as the scheduler fuses it into decode
//! iterations — a batched decode step greedily generates one token per
//! in-flight slot, and an eviction drops the session for later recompute.
//! Per-request latency comes from the shared virtual clock; real generated
//! tokens and measured host compute come from the sessions.
//!
//! Because the decisions are made by the shared loop, a live run and a
//! [`ModelBackend`](super::scheduler::ModelBackend) run over the same
//! arrivals must produce identical [`CbEvent`](super::scheduler::CbEvent)
//! streams — the differential harness in `tests/live_vs_model.rs` pins
//! that, and [`LiveBackend::kv_bytes`] lets it check that the *actual*
//! session memory never exceeds the configured cap.

use std::collections::BTreeMap;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::comm::trace::BandwidthTrace;
use crate::coordinator::decode::DecodeSession;
use crate::coordinator::Cluster;
use crate::model::shape::VqSetting;
use crate::model::TransformerShape;
use crate::parallel::strategies::{Strategy, StrategyKind};
use crate::sim::latency::SimParams;
use crate::util::rng::Rng;

use super::batcher::Request;
use super::scheduler::{CbConfig, CbEngine, CbReport, DecodeBackend};

/// Deterministic synthetic prompt for request `id`: `tokens` ids drawn
/// from a stream forked from (seed, id), so repeated runs — and the model
/// run the differential harness compares against — see the same workload.
pub fn synth_prompt(seed: u64, id: u64, tokens: usize, vocab: usize) -> Vec<usize> {
    let mut rng = Rng::new(seed ^ id.wrapping_mul(0x9e37_79b9_7f4a_7c15));
    (0..tokens).map(|_| rng.below(vocab)).collect()
}

/// Poisson arrivals with variable-length prompts uniform in
/// [seq_len/2, seq_len] — exercises the variable-length prefill path the
/// fixed-`tokens` [`super::batcher::poisson_arrivals`] cannot.
pub fn live_arrivals(rng: &mut Rng, rate: f64, horizon_s: f64, seq_len: usize) -> Vec<Request> {
    let lo = (seq_len / 2).max(1);
    let mut out = Vec::new();
    let mut t = 0.0;
    let mut id = 0u64;
    loop {
        t += rng.exp(rate);
        if t >= horizon_s {
            break;
        }
        id += 1;
        out.push(Request { id, arrival_s: t, tokens: lo + rng.below(seq_len - lo + 1) });
    }
    out
}

/// The live execution backend: one [`DecodeSession`] per in-flight slot.
pub struct LiveBackend<'a> {
    cluster: &'a Cluster,
    sessions: BTreeMap<u64, DecodeSession<'a>>,
    /// generated token ids of finished requests (empty for prefill-only)
    pub generations: BTreeMap<u64, Vec<usize>>,
    prompt_seed: u64,
    /// measured host seconds spent in real prefill + decode compute
    pub host_compute_s: f64,
    /// real single-token decode steps executed
    pub steps: usize,
}

impl<'a> LiveBackend<'a> {
    pub fn new(cluster: &'a Cluster, prompt_seed: u64) -> LiveBackend<'a> {
        LiveBackend {
            cluster,
            sessions: BTreeMap::new(),
            generations: BTreeMap::new(),
            prompt_seed,
            host_compute_s: 0.0,
            steps: 0,
        }
    }

    /// Actual Appendix-G bytes the in-flight sessions hold right now
    /// (prompt rows mixed-precision + generated rows full-precision).
    /// This must track the scheduler's per-slot accounting exactly — the
    /// loop counts a `kv_violations` whenever it exceeds the cap.
    pub fn kv_bytes(&self) -> usize {
        self.sessions.values().map(|s| s.cache_bytes_mixed()).sum()
    }

    /// In-flight sessions (censored work at the end of a run).
    pub fn in_flight(&self) -> usize {
        self.sessions.len()
    }
}

impl DecodeBackend for LiveBackend<'_> {
    fn admit(
        &mut self,
        batch: &[Request],
        decode_tokens: usize,
        prefill_limit: usize,
    ) -> Result<()> {
        if decode_tokens == 0 {
            return Ok(()); // prefill-only: nothing to hold between events
        }
        let meta = &self.cluster.artifact.meta;
        for req in batch {
            if req.tokens == 0 || req.tokens > meta.seq_len {
                bail!(
                    "live request {} has {} prompt tokens; artifact supports 1..={}",
                    req.id,
                    req.tokens,
                    meta.seq_len
                );
            }
            let prompt = synth_prompt(self.prompt_seed, req.id, req.tokens, meta.vocab_size);
            let t0 = Instant::now();
            let sess = if prefill_limit >= req.tokens {
                // classic path: the whole prompt replays at admission
                DecodeSession::with_budget(self.cluster, &prompt, req.tokens + decode_tokens)
                    .with_context(|| format!("admitting request {}", req.id))?
            } else {
                // chunked path: replay only the admission chunk; the rest
                // arrives through prefill_chunk calls as the scheduler
                // fuses it into decode iterations
                let mut sess =
                    DecodeSession::deferred(self.cluster, &prompt, req.tokens + decode_tokens)
                        .with_context(|| format!("admitting request {}", req.id))?;
                sess.replay_range(0, prefill_limit)
                    .with_context(|| format!("admission chunk of request {}", req.id))?;
                sess
            };
            self.host_compute_s += t0.elapsed().as_secs_f64();
            self.sessions.insert(req.id, sess);
        }
        Ok(())
    }

    fn prefill_chunk(&mut self, id: u64, lo: usize, hi: usize) -> Result<()> {
        let t0 = Instant::now();
        let sess = self
            .sessions
            .get_mut(&id)
            .with_context(|| format!("no live session for prefilling slot {id}"))?;
        sess.replay_range(lo, hi)?;
        self.host_compute_s += t0.elapsed().as_secs_f64();
        Ok(())
    }

    fn step(&mut self, ids: &[u64]) -> Result<()> {
        let t0 = Instant::now();
        for &id in ids {
            let sess = self
                .sessions
                .get_mut(&id)
                .with_context(|| format!("no live session for slot {id}"))?;
            sess.step()?;
        }
        self.steps += ids.len();
        self.host_compute_s += t0.elapsed().as_secs_f64();
        Ok(())
    }

    fn complete(&mut self, id: u64) -> Result<()> {
        // prefill-only requests never opened a session; record them empty
        let generated = self.sessions.remove(&id).map(|s| s.generated).unwrap_or_default();
        self.generations.insert(id, generated);
        Ok(())
    }

    fn evict(&mut self, id: u64) -> Result<()> {
        // recompute-style preemption: drop the cache; re-admission rebuilds
        self.sessions
            .remove(&id)
            .map(drop)
            .with_context(|| format!("evicting unknown slot {id}"))
    }

    fn kv_bytes_in_flight(&self) -> usize {
        self.kv_bytes()
    }
}

/// Outcome of a live continuous-batching run.
#[derive(Debug)]
pub struct LiveReport {
    /// the scheduler's report (virtual clock, events, KV accounting)
    pub report: CbReport,
    /// (request id, generated token ids) for every finished request
    pub generations: Vec<(u64, Vec<usize>)>,
    /// measured host seconds of real prefill + decode compute
    pub host_compute_s: f64,
    /// real single-token decode steps executed
    pub live_steps: usize,
}

/// The cost-model engine whose clock drives a live cluster: shape,
/// ASTRA strategy, and device count mirror the artifact meta, so modeled
/// KV projections line up with what the sessions actually allocate.
pub fn live_engine(
    cluster: &Cluster,
    cfg: CbConfig,
    params: SimParams,
    trace: BandwidthTrace,
) -> CbEngine {
    let meta = &cluster.artifact.meta;
    let shape = TransformerShape {
        n_layers: meta.n_layers,
        d_model: meta.d_model,
        n_heads: meta.n_heads,
        d_ff: meta.d_ff,
        seq_len: meta.seq_len,
        elem_bytes: 4,
    };
    let strategy = Strategy::new(
        StrategyKind::Astra { vq: VqSetting::new(meta.groups, meta.codebook_size) },
        cluster.partition.n_devices(),
    );
    CbEngine::new(shape, strategy, params, trace, cfg)
}

/// Drive real `DecodeSession`s through the continuous-batching scheduler:
/// the headline live path behind `astra serve-cb --live`.
pub fn serve_live(
    cluster: &Cluster,
    cfg: CbConfig,
    params: SimParams,
    trace: BandwidthTrace,
    arrivals: Vec<Request>,
    horizon_s: f64,
) -> Result<LiveReport> {
    if !cluster.artifact.meta.causal {
        bail!("live continuous batching requires a decoder (causal) artifact");
    }
    let mut engine = live_engine(cluster, cfg, params, trace);
    let mut backend = LiveBackend::new(cluster, cluster.config.seed);
    let report = engine.serve_stream_with(&mut backend, arrivals, horizon_s)?;
    Ok(LiveReport {
        report,
        generations: backend.generations.into_iter().collect(),
        host_compute_s: backend.host_compute_s,
        live_steps: backend.steps,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RunConfig;

    fn tiny_cluster(seed: u64) -> Cluster {
        let shape = TransformerShape {
            n_layers: 2,
            d_model: 16,
            n_heads: 2,
            d_ff: 32,
            seq_len: 16,
            elem_bytes: 4,
        };
        let config = RunConfig { n_devices: 2, ..RunConfig::default() };
        Cluster::synthetic_decoder(&shape, 32, VqSetting::new(2, 8), config, seed).unwrap()
    }

    fn burst(n: u64, tokens: usize) -> Vec<Request> {
        (1..=n).map(|id| Request { id, arrival_s: 0.0, tokens }).collect()
    }

    #[test]
    fn live_serve_produces_real_deterministic_generations() {
        let cluster = tiny_cluster(11);
        let cfg = CbConfig { max_slots: 3, max_batch: 3, decode_tokens: 4, ..CbConfig::default() };
        let arrivals = live_arrivals(&mut Rng::new(4), 10.0, 2.0, 16);
        assert!(arrivals.len() > 3, "{}", arrivals.len());
        let run = |cluster: &Cluster| {
            serve_live(
                cluster,
                cfg.clone(),
                SimParams::paper_encoder(),
                BandwidthTrace::constant(100.0, 1e9),
                arrivals.clone(),
                1e4,
            )
            .unwrap()
        };
        let live = run(&cluster);
        assert_eq!(live.report.completed, arrivals.len());
        assert_eq!(live.generations.len(), arrivals.len());
        let vocab = cluster.artifact.meta.vocab_size;
        for (id, toks) in &live.generations {
            assert_eq!(toks.len(), 4, "request {id}");
            assert!(toks.iter().all(|&t| t < vocab));
        }
        assert_eq!(live.live_steps, 4 * arrivals.len());
        assert!(live.host_compute_s > 0.0);
        // per-request latency is reported on the shared virtual clock
        let mut r = live.report;
        assert!(r.latency.p50() > 0.0);
        // bit-for-bit reproducible
        let again = run(&cluster);
        assert_eq!(again.generations, live.generations);
    }

    #[test]
    fn live_kv_cap_is_respected_by_actual_sessions() {
        let cluster = tiny_cluster(11);
        let base = CbConfig { max_slots: 4, max_batch: 4, decode_tokens: 8, ..CbConfig::default() };
        let probe = live_engine(
            &cluster,
            base.clone(),
            SimParams::paper_encoder(),
            BandwidthTrace::constant(100.0, 1e9),
        );
        let cap = 2 * probe.kv_projection(16) + probe.kv_step_bytes();
        let cfg = CbConfig { kv_cap_bytes: cap, ..base };
        let live = serve_live(
            &cluster,
            cfg,
            SimParams::paper_encoder(),
            BandwidthTrace::constant(100.0, 1e9),
            burst(6, 16),
            1e4,
        )
        .unwrap();
        assert_eq!(live.report.completed, 6, "{:?}", live.report);
        // the loop's modeled accounting and the sessions' actual bytes
        // both stayed under the cap at every decision point
        assert_eq!(live.report.kv_violations, 0);
        assert!(live.report.kv_peak_bytes <= cap);
        for (_, toks) in &live.generations {
            assert_eq!(toks.len(), 8);
        }
    }

    #[test]
    fn chunked_live_run_matches_unchunked_generations() {
        // chunked prefill reshapes the schedule (chunk events, deferred
        // TTFT) but must not change what any request decodes: incremental
        // replay_range builds the same mixed cache as one-shot replay
        let cluster = tiny_cluster(11);
        let base = CbConfig { max_slots: 3, max_batch: 3, decode_tokens: 5, ..CbConfig::default() };
        let chunked = CbConfig { prefill_chunk_tokens: 6, ..base.clone() };
        let arrivals = live_arrivals(&mut Rng::new(8), 12.0, 3.0, 16);
        assert!(arrivals.len() > 4, "{}", arrivals.len());
        assert!(arrivals.iter().any(|r| r.tokens > 6), "need prompts longer than the budget");
        let run = |cfg: &CbConfig| {
            serve_live(
                &cluster,
                cfg.clone(),
                SimParams::paper_encoder(),
                BandwidthTrace::constant(100.0, 1e9),
                arrivals.clone(),
                1e4,
            )
            .unwrap()
        };
        let plain = run(&base);
        let chunky = run(&chunked);
        assert_eq!(plain.report.completed, arrivals.len());
        assert_eq!(chunky.report.completed, arrivals.len());
        assert!(chunky.report.prefill_chunks > 0);
        // different schedules...
        assert_ne!(plain.report.events, chunky.report.events);
        // ...identical greedy generations, token for token
        assert_eq!(plain.generations, chunky.generations);
        // and the chunked run is reproducible bit for bit
        let again = run(&chunked);
        assert_eq!(again.report.events, chunky.report.events);
        assert_eq!(again.generations, chunky.generations);
    }

    #[test]
    fn synth_prompts_are_stable_and_in_vocab() {
        let a = synth_prompt(7, 3, 12, 32);
        let b = synth_prompt(7, 3, 12, 32);
        assert_eq!(a, b);
        assert_eq!(a.len(), 12);
        assert!(a.iter().all(|&t| t < 32));
        assert_ne!(synth_prompt(7, 4, 12, 32), a);
        let arr = live_arrivals(&mut Rng::new(1), 20.0, 5.0, 16);
        assert!(arr.iter().all(|r| (8..=16).contains(&r.tokens)));
        assert!(arr.windows(2).all(|w| w[0].arrival_s <= w[1].arrival_s));
    }
}
