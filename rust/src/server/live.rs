//! Live continuous-batching execution: real [`DecodeSession`]s driven by
//! the [`CbEngine`] slot scheduler.
//!
//! The cost-model engine ([`super::scheduler`]) owns the virtual clock and
//! every scheduling decision — admission order, batch composition, KV
//! admission, eviction. This module plugs a [`LiveBackend`] into that loop
//! so each decision executes for real: an admission replays the request's
//! variable-length prompt into a fresh mixed-precision KV cache (built
//! through [`SessionBuilder`](crate::coordinator::SessionBuilder), sized
//! prompt + decode budget) — or, under chunked prefill, opens a deferred
//! session and replays only the admission chunk, the rest arriving chunk
//! by chunk as the scheduler fuses it into decode iterations — and an
//! eviction drops the session for later recompute. Per-request latency
//! comes from the shared virtual clock; real generated tokens and measured
//! host compute come from the sessions.
//!
//! Execution crosses the backend boundary once per scheduler iteration:
//! [`DecodeBackend::step`] receives a [`StepBatch`] naming the planned
//! prefill chunks *and* the decoding slots. Chunk replays fan out across
//! `std::thread::scope` threads (each chunk owns a distinct session, so
//! the `&mut` borrows are disjoint), and all decoding slots advance
//! together through [`step_batch`] — one fused batched GEMM per layer
//! across the whole batch, bit-identical per row to stepping each session
//! alone. `CbConfig::serial_decode` is the escape hatch: the same batch
//! executes one session at a time through the single-session kernels,
//! anchoring the tokens/sec benchmarks in `live_bench`.
//!
//! Because the decisions are made by the shared loop, a live run and a
//! [`ModelBackend`](super::scheduler::ModelBackend) run over the same
//! arrivals must produce identical [`CbEvent`](super::scheduler::CbEvent)
//! streams — the differential harness in `tests/live_vs_model.rs` pins
//! that, and [`LiveBackend::kv_bytes`] lets it check that the *actual*
//! session memory never exceeds the configured cap.
//!
//! # Prefix sharing and swap, live
//!
//! Under `CbConfig::prefix_cache` the backend keeps a [`KvArena`]: when
//! the scheduler marks a slot's prompt block ready
//! ([`DecodeBackend::register_block`]) the real K/V rows are exported
//! *once* into a refcounted arena entry; an admission carrying a
//! [`PrefixAttach`](super::scheduler::PrefixAttach) attaches those rows
//! zero-copy ([`DecodeSession::attach_block`] clones an `Arc`, no float
//! moves) and replays only the uncovered suffix — bit-identical to a full
//! replay, so generations are independent of sharing, and an attached
//! block outlives both its creator session and its arena entry.
//! [`DecodeBackend::swap_out`] moves a whole session into a host-tier map
//! (decode progress preserved) and [`DecodeBackend::swap_in`] restores it;
//! the scheduler prices the transfers. After a replica kill,
//! [`DecodeBackend::restore`] rebuilds a checkpointed session from scratch
//! — prompt replay plus deterministic greedy re-decode, bit-identical to
//! the lost cache — because the victim's host tier died with it; the fleet
//! store only keeps the checkpoint *metadata*, and the scheduler prices
//! the restore as a host-tier transfer. [`LiveBackend::kv_bytes`] counts
//! shared rows once: the arena's blocks plus each session's bytes beyond
//! its arena-backed prefix.

use std::collections::{BTreeMap, BTreeSet};
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use crate::comm::trace::BandwidthTrace;
use crate::coordinator::decode::{step_batch, DecodeSession};
use crate::coordinator::Cluster;
use crate::kv::arena::{BlockRows, KvArena};
use crate::model::shape::VqSetting;
use crate::model::TransformerShape;
use crate::parallel::strategies::{Strategy, StrategyKind};
use crate::sim::latency::SimParams;
use crate::util::rng::Rng;

use super::batcher::Request;
use super::scheduler::{AdmitBatch, CbConfig, CbEngine, CbReport, DecodeBackend, StepBatch};

/// Deterministic synthetic prompt for request `id`: `tokens` ids drawn
/// from a stream forked from (seed, id), so repeated runs — and the model
/// run the differential harness compares against — see the same workload.
pub fn synth_prompt(seed: u64, id: u64, tokens: usize, vocab: usize) -> Vec<usize> {
    let mut rng = Rng::new(seed ^ id.wrapping_mul(0x9e37_79b9_7f4a_7c15));
    (0..tokens).map(|_| rng.below(vocab)).collect()
}

/// The prompt-content stream a request draws from: its own id, or its
/// group (`id % prompt_groups`) when grouped workloads are on — requests
/// in one group then share leading token ids, the prefix-cache workload.
/// Used identically by the scheduler's radix lookups and this backend's
/// sessions, so both sides see one workload.
pub fn prompt_stream_key(prompt_groups: usize, id: u64) -> u64 {
    if prompt_groups > 0 {
        id % prompt_groups as u64
    } else {
        id
    }
}

/// Poisson arrivals with variable-length prompts uniform in
/// [seq_len/2, seq_len] — exercises the variable-length prefill path the
/// fixed-`tokens` [`super::batcher::poisson_arrivals`] cannot.
pub fn live_arrivals(rng: &mut Rng, rate: f64, horizon_s: f64, seq_len: usize) -> Vec<Request> {
    let lo = (seq_len / 2).max(1);
    let mut out = Vec::new();
    let mut t = 0.0;
    let mut id = 0u64;
    loop {
        t += rng.exp(rate);
        if t >= horizon_s {
            break;
        }
        id += 1;
        out.push(Request { id, arrival_s: t, tokens: lo + rng.below(seq_len - lo + 1) });
    }
    out
}

/// The live execution backend: one [`DecodeSession`] per in-flight slot,
/// plus the shared block arena and the swap host tier.
pub struct LiveBackend<'a> {
    cluster: &'a Cluster,
    sessions: BTreeMap<u64, DecodeSession<'a>>,
    /// generated token ids of finished requests (empty for prefill-only)
    pub generations: BTreeMap<u64, Vec<usize>>,
    prompt_seed: u64,
    /// prompt-content classes (0 = every id its own stream)
    prompt_groups: usize,
    /// positional-locality sessions + block arena active (prefix cache)
    positional: bool,
    /// execute the step batch one session at a time through the
    /// single-session kernels (`CbConfig::serial_decode`) — scheduling
    /// never reads the flag, so the event stream is identical either way
    serial: bool,
    /// shared block arena: sealed rows exported once at
    /// [`DecodeBackend::register_block`], every attach a zero-copy
    /// refcount bump
    store: KvArena,
    /// per-session tokens whose rows are backed by the arena (attached
    /// prefix, growing past each of the creator's registered blocks) —
    /// subtracted from the session's bytes so shared rows count once
    blocked: BTreeMap<u64, usize>,
    /// swapped-out sessions, decode progress intact
    swapped: BTreeMap<u64, DecodeSession<'a>>,
    /// priority class per in-flight request (`CbConfig::class_of`,
    /// plumbed through [`DecodeBackend::admit`]; pruned on complete and
    /// evict, so it is bounded by the active set) — the QoS tag a real
    /// deployment would key placement on; the scheduler has already made
    /// every class-driven decision by the time it reaches this backend
    pub classes: BTreeMap<u64, usize>,
    /// measured host seconds spent in real prefill + decode compute
    pub host_compute_s: f64,
    /// real single-token decode steps executed
    pub steps: usize,
}

impl<'a> LiveBackend<'a> {
    pub fn new(cluster: &'a Cluster, prompt_seed: u64) -> LiveBackend<'a> {
        LiveBackend {
            cluster,
            sessions: BTreeMap::new(),
            generations: BTreeMap::new(),
            prompt_seed,
            prompt_groups: 0,
            positional: false,
            serial: false,
            store: KvArena::new(),
            blocked: BTreeMap::new(),
            swapped: BTreeMap::new(),
            classes: BTreeMap::new(),
            host_compute_s: 0.0,
            steps: 0,
        }
    }

    /// Configure the backend from the serving config: the prompt streams
    /// must match what the scheduler's radix lookups derive, and prefix
    /// caching switches sessions to positional locality.
    pub fn for_config(cluster: &'a Cluster, cfg: &CbConfig) -> LiveBackend<'a> {
        let mut b = LiveBackend::new(cluster, cfg.seed);
        b.prompt_groups = cfg.prompt_groups;
        b.positional = cfg.prefix_cache && cfg.decode_tokens > 0;
        b.serial = cfg.serial_decode;
        b
    }

    fn prompt(&self, id: u64, tokens: usize) -> Vec<usize> {
        let meta = &self.cluster.artifact.meta;
        synth_prompt(
            self.prompt_seed,
            prompt_stream_key(self.prompt_groups, id),
            tokens,
            meta.vocab_size,
        )
    }

    /// Actual Appendix-G bytes held right now: the shared block arena plus
    /// every in-flight session's bytes beyond its arena-backed prefix
    /// (shared rows count once however many sessions attach). Swapped-out
    /// sessions live in host memory and do not count. This must track the
    /// scheduler's pool accounting exactly — the loop counts a
    /// `kv_violations` whenever it exceeds the cap.
    pub fn kv_bytes(&self) -> usize {
        self.store.total_bytes()
            + self
                .sessions
                .iter()
                .map(|(id, s)| {
                    let blocked = self.blocked.get(id).copied().unwrap_or(0);
                    s.cache_bytes_mixed().saturating_sub(s.prefix_bytes(blocked))
                })
                .sum::<usize>()
    }

    /// In-flight sessions (censored work at the end of a run).
    pub fn in_flight(&self) -> usize {
        self.sessions.len()
    }

    /// Blocks currently held in the shared arena (diagnostics).
    pub fn stored_blocks(&self) -> usize {
        self.store.len()
    }

    /// Sessions parked in the swap host tier (diagnostics).
    pub fn swapped_out(&self) -> usize {
        self.swapped.len()
    }
}

impl DecodeBackend for LiveBackend<'_> {
    fn admit(&mut self, batch: &AdmitBatch) -> Result<()> {
        let meta = &self.cluster.artifact.meta;
        for entry in &batch.entries {
            let req = &entry.req;
            self.classes.insert(req.id, entry.class);
            if entry.budget == 0 {
                continue; // prefill-only: nothing to hold between events
            }
            if req.tokens == 0 || req.tokens > meta.seq_len {
                bail!(
                    "live request {} has {} prompt tokens; artifact supports 1..={}",
                    req.id,
                    req.tokens,
                    meta.seq_len
                );
            }
            let prompt = self.prompt(req.id, req.tokens);
            let t0 = Instant::now();
            let sess = if self.positional {
                // prefix-cache path: positional-locality session; covered
                // blocks attach as zero-copy arena references, then only
                // the uncovered suffix replays (bit-identical to a full
                // replay — attached rows ARE the creator's rows)
                let pre = &entry.prefix;
                let mut sess = DecodeSession::builder(self.cluster, &prompt)
                    .budget(req.tokens + entry.budget)
                    .deferred()
                    .positional()
                    .build()
                    .with_context(|| format!("admitting request {}", req.id))?;
                for &b in &pre.blocks {
                    let rows = self
                        .store
                        .attach(b)
                        .with_context(|| format!("attach to unknown block {b}"))?;
                    sess.attach_block(rows)
                        .with_context(|| format!("attaching block {b} for request {}", req.id))?;
                }
                let first = (req.tokens - pre.tokens).min(batch.prefill_limit);
                if first > 0 {
                    sess.replay_range(pre.tokens, pre.tokens + first).with_context(|| {
                        format!("admission suffix of request {}", req.id)
                    })?;
                }
                self.blocked.insert(req.id, pre.tokens);
                sess
            } else if batch.prefill_limit >= req.tokens {
                // classic path: the whole prompt replays at admission;
                // an active heterogeneous plan re-weights which rows this
                // rank keeps full-precision (in-flight sessions admitted
                // under an older plan keep their split untouched)
                let mut b = DecodeSession::builder(self.cluster, &prompt)
                    .budget(req.tokens + entry.budget);
                if let Some(w) = &batch.split_weights {
                    b = b.split_weights(w.clone());
                }
                b.build().with_context(|| format!("admitting request {}", req.id))?
            } else {
                // chunked path: replay only the admission chunk; the rest
                // arrives inside StepBatch chunk plans as the scheduler
                // fuses it into decode iterations
                let mut b = DecodeSession::builder(self.cluster, &prompt)
                    .budget(req.tokens + entry.budget)
                    .deferred();
                if let Some(w) = &batch.split_weights {
                    b = b.split_weights(w.clone());
                }
                let mut sess =
                    b.build().with_context(|| format!("admitting request {}", req.id))?;
                sess.replay_range(0, batch.prefill_limit)
                    .with_context(|| format!("admission chunk of request {}", req.id))?;
                sess
            };
            self.host_compute_s += t0.elapsed().as_secs_f64();
            self.sessions.insert(req.id, sess);
        }
        Ok(())
    }

    fn register_block(
        &mut self,
        session: u64,
        block: u64,
        lo: usize,
        hi: usize,
        bytes: usize,
    ) -> Result<()> {
        let meta = &self.cluster.artifact.meta;
        let sess = self
            .sessions
            .get(&session)
            .with_context(|| format!("registering block {block} from unknown session {session}"))?;
        let layers = sess
            .export_rows(lo, hi)
            .with_context(|| format!("exporting block {block} rows from session {session}"))?;
        let rows = BlockRows::new(lo, hi, layers, meta.n_heads, meta.d_model / meta.n_heads)
            .with_context(|| format!("sealing block {block} from session {session}"))?;
        self.store.insert(block, bytes, rows);
        // the creator's own rows are arena-backed from here on
        let blocked = self.blocked.entry(session).or_insert(0);
        *blocked = (*blocked).max(hi);
        Ok(())
    }

    fn drop_block(&mut self, block: u64) -> Result<()> {
        // sessions holding an attached reference keep the rows alive —
        // only the arena entry (and its byte accounting) goes away
        self.store
            .remove(block)
            .with_context(|| format!("dropping unknown block {block}"))?;
        Ok(())
    }

    fn swap_out(&mut self, id: u64) -> Result<()> {
        let sess = self
            .sessions
            .remove(&id)
            .with_context(|| format!("swapping out unknown slot {id}"))?;
        self.blocked.remove(&id);
        self.swapped.insert(id, sess);
        Ok(())
    }

    fn swap_in(&mut self, id: u64) -> Result<()> {
        let sess = self
            .swapped
            .remove(&id)
            .with_context(|| format!("swapping in request {id} that is not in the host tier"))?;
        // restored sessions are fully private: their rows are their own
        self.blocked.insert(id, 0);
        self.sessions.insert(id, sess);
        Ok(())
    }

    fn drop_swapped(&mut self, id: u64) -> Result<()> {
        // replica drain: the host tier dies with the replica, so the
        // parked session is discarded outright — the request re-enters a
        // survivor's queue and rebuilds from scratch on admission there
        self.swapped
            .remove(&id)
            .map(drop)
            .with_context(|| format!("dropping request {id} that is not in the host tier"))?;
        self.classes.remove(&id);
        Ok(())
    }

    fn restore(
        &mut self,
        id: u64,
        tokens: usize,
        generated: usize,
        budget: usize,
        class: usize,
    ) -> Result<()> {
        // checkpoint restore after a replica kill: the parked session died
        // with its replica, so rebuild it from scratch — replay the prompt
        // and re-run the `generated` greedy decode steps. Greedy decode is
        // deterministic, so the rebuilt cache is bit-identical to the lost
        // one; the scheduler prices the restore as a host-tier transfer.
        let meta = &self.cluster.artifact.meta;
        if tokens == 0 || tokens > meta.seq_len {
            bail!(
                "restoring request {id} with {tokens} prompt tokens; artifact supports 1..={}",
                meta.seq_len
            );
        }
        let prompt = self.prompt(id, tokens);
        let t0 = Instant::now();
        let mut sess = if self.positional {
            let mut sess = DecodeSession::builder(self.cluster, &prompt)
                .budget(tokens + budget)
                .deferred()
                .positional()
                .build()
                .with_context(|| format!("restoring request {id}"))?;
            sess.replay_range(0, tokens)
                .with_context(|| format!("replaying prompt of restored request {id}"))?;
            sess
        } else {
            DecodeSession::builder(self.cluster, &prompt)
                .budget(tokens + budget)
                .build()
                .with_context(|| format!("restoring request {id}"))?
        };
        for _ in 0..generated {
            sess.step().with_context(|| format!("re-decoding restored request {id}"))?;
        }
        self.steps += generated;
        self.host_compute_s += t0.elapsed().as_secs_f64();
        // restored sessions are fully private: their rows are their own
        self.blocked.insert(id, 0);
        self.classes.insert(id, class);
        self.sessions.insert(id, sess);
        Ok(())
    }

    fn step(&mut self, batch: &StepBatch) -> Result<()> {
        let t0 = Instant::now();
        if self.serial {
            // escape hatch: the same batch, one session at a time through
            // the single-session kernels — the benchmark anchor
            for c in &batch.chunks {
                let sess = self
                    .sessions
                    .get_mut(&c.id)
                    .with_context(|| format!("no live session for prefilling slot {}", c.id))?;
                sess.replay_range(c.lo, c.hi)
                    .with_context(|| format!("replaying chunk [{}, {}) of slot {}", c.lo, c.hi, c.id))?;
            }
            for &id in &batch.decode_ids {
                let sess = self
                    .sessions
                    .get_mut(&id)
                    .with_context(|| format!("no live session for slot {id}"))?;
                sess.step()?;
            }
        } else {
            if !batch.chunks.is_empty() {
                // prefill-chunk replay fans out across scoped threads:
                // the scheduler plans at most one chunk per slot per
                // iteration, so each thread owns a distinct session and
                // the &mut borrows are disjoint
                let want: BTreeSet<u64> = batch.chunks.iter().map(|c| c.id).collect();
                let mut grabbed: BTreeMap<u64, &mut DecodeSession<'_>> = self
                    .sessions
                    .iter_mut()
                    .filter(|(id, _)| want.contains(id))
                    .map(|(id, s)| (*id, s))
                    .collect();
                for c in &batch.chunks {
                    if !grabbed.contains_key(&c.id) {
                        bail!("no live session for prefilling slot {}", c.id);
                    }
                }
                let joined = std::thread::scope(|scope| {
                    let handles: Vec<_> = batch
                        .chunks
                        .iter()
                        .map(|&c| {
                            let sess = grabbed.remove(&c.id).expect("chunk ids are distinct");
                            scope.spawn(move || sess.replay_range(c.lo, c.hi))
                        })
                        .collect();
                    handles.into_iter().map(|h| h.join()).collect::<Vec<_>>()
                });
                // surface failures deterministically, in chunk order
                for (c, r) in batch.chunks.iter().zip(joined) {
                    r.map_err(|_| anyhow!("replay thread for slot {} panicked", c.id))?
                        .with_context(|| {
                            format!("replaying chunk [{}, {}) of slot {}", c.lo, c.hi, c.id)
                        })?;
                }
            }
            if !batch.decode_ids.is_empty() {
                // every decoding slot advances through one fused batched
                // GEMM per layer — bit-identical per row to serial steps
                let want: BTreeSet<u64> = batch.decode_ids.iter().copied().collect();
                let mut slots: Vec<&mut DecodeSession<'_>> = self
                    .sessions
                    .iter_mut()
                    .filter(|(id, _)| want.contains(id))
                    .map(|(_, s)| s)
                    .collect();
                if slots.len() != want.len() {
                    bail!(
                        "decode batch names {} slots but only {} have live sessions",
                        want.len(),
                        slots.len()
                    );
                }
                step_batch(&mut slots)?;
            }
        }
        self.steps += batch.decode_ids.len();
        self.host_compute_s += t0.elapsed().as_secs_f64();
        Ok(())
    }

    fn complete(&mut self, id: u64) -> Result<()> {
        // prefill-only requests never opened a session; record them empty.
        // The session goes away but any rows it registered live on in the
        // block arena — the "recently freed" prefix reuse window.
        let generated = self.sessions.remove(&id).map(|s| s.generated).unwrap_or_default();
        self.blocked.remove(&id);
        self.classes.remove(&id);
        self.generations.insert(id, generated);
        Ok(())
    }

    fn evict(&mut self, id: u64) -> Result<()> {
        // recompute-style preemption: drop the cache; re-admission rebuilds
        // (including the class tag, which admit re-inserts)
        self.blocked.remove(&id);
        self.classes.remove(&id);
        self.sessions
            .remove(&id)
            .map(drop)
            .with_context(|| format!("evicting unknown slot {id}"))
    }

    fn kv_bytes_in_flight(&self) -> usize {
        self.kv_bytes()
    }
}

/// Outcome of a live continuous-batching run.
#[derive(Debug)]
pub struct LiveReport {
    /// the scheduler's report (virtual clock, events, KV accounting)
    pub report: CbReport,
    /// (request id, generated token ids) for every finished request
    pub generations: Vec<(u64, Vec<usize>)>,
    /// measured host seconds of real prefill + decode compute
    pub host_compute_s: f64,
    /// real single-token decode steps executed
    pub live_steps: usize,
}

/// The cost-model engine whose clock drives a live cluster: shape,
/// ASTRA strategy, and device count mirror the artifact meta, so modeled
/// KV projections line up with what the sessions actually allocate. The
/// workload-content knobs (`seed`, `prompt_vocab`) are pinned to the
/// cluster so the engine's radix-tree lookups and decode-jitter draws see
/// exactly the prompts and budgets the live sessions will — whichever
/// backend runs, the decisions match.
pub fn live_engine(
    cluster: &Cluster,
    mut cfg: CbConfig,
    params: SimParams,
    trace: BandwidthTrace,
) -> CbEngine {
    let meta = &cluster.artifact.meta;
    let shape = TransformerShape {
        n_layers: meta.n_layers,
        d_model: meta.d_model,
        n_heads: meta.n_heads,
        d_ff: meta.d_ff,
        seq_len: meta.seq_len,
        elem_bytes: 4,
    };
    let strategy = Strategy::new(
        StrategyKind::Astra { vq: VqSetting::new(meta.groups, meta.codebook_size) },
        cluster.partition.n_devices(),
    );
    cfg.seed = cluster.config.seed;
    cfg.prompt_vocab = meta.vocab_size;
    CbEngine::new(shape, strategy, params, trace, cfg)
}

/// Drive real `DecodeSession`s through the continuous-batching scheduler:
/// the headline live path behind `astra serve-cb --live`.
pub fn serve_live(
    cluster: &Cluster,
    cfg: CbConfig,
    params: SimParams,
    trace: BandwidthTrace,
    arrivals: Vec<Request>,
    horizon_s: f64,
) -> Result<LiveReport> {
    if !cluster.artifact.meta.causal {
        bail!("live continuous batching requires a decoder (causal) artifact");
    }
    let mut engine = live_engine(cluster, cfg.clone(), params, trace);
    let mut backend = LiveBackend::for_config(cluster, &engine.cfg);
    let report = engine.serve_stream_with(&mut backend, arrivals, horizon_s)?;
    Ok(LiveReport {
        report,
        generations: backend.generations.into_iter().collect(),
        host_compute_s: backend.host_compute_s,
        live_steps: backend.steps,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RunConfig;

    fn tiny_cluster(seed: u64) -> Cluster {
        let shape = TransformerShape {
            n_layers: 2,
            d_model: 16,
            n_heads: 2,
            d_ff: 32,
            seq_len: 16,
            elem_bytes: 4,
        };
        let config = RunConfig { n_devices: 2, ..RunConfig::default() };
        Cluster::synthetic_decoder(&shape, 32, VqSetting::new(2, 8), config, seed).unwrap()
    }

    fn burst(n: u64, tokens: usize) -> Vec<Request> {
        (1..=n).map(|id| Request { id, arrival_s: 0.0, tokens }).collect()
    }

    #[test]
    fn live_serve_produces_real_deterministic_generations() {
        let cluster = tiny_cluster(11);
        let cfg = CbConfig { max_slots: 3, max_batch: 3, decode_tokens: 4, ..CbConfig::default() };
        let arrivals = live_arrivals(&mut Rng::new(4), 10.0, 2.0, 16);
        assert!(arrivals.len() > 3, "{}", arrivals.len());
        let run = |cluster: &Cluster| {
            serve_live(
                cluster,
                cfg.clone(),
                SimParams::paper_encoder(),
                BandwidthTrace::constant(100.0, 1e9),
                arrivals.clone(),
                1e4,
            )
            .unwrap()
        };
        let live = run(&cluster);
        assert_eq!(live.report.completed, arrivals.len());
        assert_eq!(live.generations.len(), arrivals.len());
        let vocab = cluster.artifact.meta.vocab_size;
        for (id, toks) in &live.generations {
            assert_eq!(toks.len(), 4, "request {id}");
            assert!(toks.iter().all(|&t| t < vocab));
        }
        assert_eq!(live.live_steps, 4 * arrivals.len());
        assert!(live.host_compute_s > 0.0);
        // per-request latency is reported on the shared virtual clock
        let mut r = live.report;
        assert!(r.latency.p50() > 0.0);
        // bit-for-bit reproducible
        let again = run(&cluster);
        assert_eq!(again.generations, live.generations);
    }

    #[test]
    fn live_kv_cap_is_respected_by_actual_sessions() {
        let cluster = tiny_cluster(11);
        let base = CbConfig { max_slots: 4, max_batch: 4, decode_tokens: 8, ..CbConfig::default() };
        let probe = live_engine(
            &cluster,
            base.clone(),
            SimParams::paper_encoder(),
            BandwidthTrace::constant(100.0, 1e9),
        );
        let cap = 2 * probe.kv_projection(16) + probe.kv_step_bytes();
        let cfg = CbConfig { kv_cap_bytes: cap, ..base };
        let live = serve_live(
            &cluster,
            cfg,
            SimParams::paper_encoder(),
            BandwidthTrace::constant(100.0, 1e9),
            burst(6, 16),
            1e4,
        )
        .unwrap();
        assert_eq!(live.report.completed, 6, "{:?}", live.report);
        // the loop's modeled accounting and the sessions' actual bytes
        // both stayed under the cap at every decision point
        assert_eq!(live.report.kv_violations, 0);
        assert!(live.report.kv_peak_bytes <= cap);
        for (_, toks) in &live.generations {
            assert_eq!(toks.len(), 8);
        }
    }

    #[test]
    fn chunked_live_run_matches_unchunked_generations() {
        // chunked prefill reshapes the schedule (chunk events, deferred
        // TTFT) but must not change what any request decodes: incremental
        // replay_range builds the same mixed cache as one-shot replay
        let cluster = tiny_cluster(11);
        let base = CbConfig { max_slots: 3, max_batch: 3, decode_tokens: 5, ..CbConfig::default() };
        let chunked = CbConfig { prefill_chunk_tokens: 6, ..base.clone() };
        let arrivals = live_arrivals(&mut Rng::new(8), 12.0, 3.0, 16);
        assert!(arrivals.len() > 4, "{}", arrivals.len());
        assert!(arrivals.iter().any(|r| r.tokens > 6), "need prompts longer than the budget");
        let run = |cfg: &CbConfig| {
            serve_live(
                &cluster,
                cfg.clone(),
                SimParams::paper_encoder(),
                BandwidthTrace::constant(100.0, 1e9),
                arrivals.clone(),
                1e4,
            )
            .unwrap()
        };
        let plain = run(&base);
        let chunky = run(&chunked);
        assert_eq!(plain.report.completed, arrivals.len());
        assert_eq!(chunky.report.completed, arrivals.len());
        assert!(chunky.report.prefill_chunks > 0);
        // different schedules...
        assert_ne!(plain.report.events, chunky.report.events);
        // ...identical greedy generations, token for token
        assert_eq!(plain.generations, chunky.generations);
        // and the chunked run is reproducible bit for bit
        let again = run(&chunked);
        assert_eq!(again.report.events, chunky.report.events);
        assert_eq!(again.generations, chunky.generations);
    }

    #[test]
    fn serial_decode_matches_batched_default_bit_for_bit() {
        // `serial_decode` only changes how the backend executes the step
        // batch — the scheduler never reads it — so the event stream is
        // identical by construction and the generations must match token
        // for token; chunked prefill keeps the scoped-thread replay path
        // hot on the batched side
        let cluster = tiny_cluster(11);
        let base = CbConfig {
            max_slots: 4,
            max_batch: 4,
            decode_tokens: 5,
            prefill_chunk_tokens: 6,
            ..CbConfig::default()
        };
        let serial = CbConfig { serial_decode: true, ..base.clone() };
        let arrivals = live_arrivals(&mut Rng::new(9), 12.0, 3.0, 16);
        assert!(arrivals.len() > 4, "{}", arrivals.len());
        assert!(arrivals.iter().any(|r| r.tokens > 6), "need prompts longer than the budget");
        let run = |cfg: &CbConfig| {
            serve_live(
                &cluster,
                cfg.clone(),
                SimParams::paper_encoder(),
                BandwidthTrace::constant(100.0, 1e9),
                arrivals.clone(),
                1e4,
            )
            .unwrap()
        };
        let batched = run(&base);
        let one_by_one = run(&serial);
        assert_eq!(batched.report.completed, arrivals.len());
        assert_eq!(batched.report.events, one_by_one.report.events);
        assert_eq!(batched.generations, one_by_one.generations);
        assert_eq!(batched.live_steps, one_by_one.live_steps);
    }

    #[test]
    fn class_tags_track_in_flight_sessions() {
        // the class plumbed through DecodeBackend::admit must tag exactly
        // the in-flight sessions with the scheduler's own class mapping,
        // and be pruned once a request completes
        let cluster = tiny_cluster(11);
        let cfg = CbConfig {
            max_slots: 2,
            max_batch: 2,
            decode_tokens: 4,
            classes: vec![1.0, 5.0],
            ..CbConfig::default()
        };
        let params = SimParams::paper_encoder();
        let trace = BandwidthTrace::constant(100.0, 1e9);
        // a horizon that ends mid-flight: the admitted sessions stay
        // resident (censored), tags intact
        let mut engine = live_engine(&cluster, cfg.clone(), params.clone(), trace.clone());
        let mut backend = LiveBackend::for_config(&cluster, &engine.cfg);
        let r = engine.serve_stream_with(&mut backend, burst(4, 16), 1e-6).unwrap();
        assert_eq!(r.completed, 0);
        assert!(backend.in_flight() > 0);
        assert_eq!(backend.classes.len(), backend.in_flight());
        for (id, class) in &backend.classes {
            assert_eq!(*class, engine.cfg.class_of(*id), "request {id}");
        }
        // a drained run prunes every tag with the sessions
        let mut engine = live_engine(&cluster, cfg, params, trace);
        let mut backend = LiveBackend::for_config(&cluster, &engine.cfg);
        let r = engine.serve_stream_with(&mut backend, burst(4, 16), 1e4).unwrap();
        assert_eq!(r.completed, 4);
        assert_eq!(backend.in_flight(), 0);
        assert!(backend.classes.is_empty());
    }

    #[test]
    fn synth_prompts_are_stable_and_in_vocab() {
        let a = synth_prompt(7, 3, 12, 32);
        let b = synth_prompt(7, 3, 12, 32);
        assert_eq!(a, b);
        assert_eq!(a.len(), 12);
        assert!(a.iter().all(|&t| t < 32));
        assert_ne!(synth_prompt(7, 4, 12, 32), a);
        let arr = live_arrivals(&mut Rng::new(1), 20.0, 5.0, 16);
        assert!(arr.iter().all(|r| (8..=16).contains(&r.tokens)));
        assert!(arr.windows(2).all(|w| w[0].arrival_s <= w[1].arrival_s));
    }
}
