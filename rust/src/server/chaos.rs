//! Chaos layer: interpreting a seeded [`FaultPlan`] against the fleet.
//!
//! **Module contract: faults are events on the virtual clock; engines
//! never observe wall time.** A fault plan is pure data drawn once from a
//! seed ([`crate::sim::fault`]); everything here — collapsing arrival
//! spans into bursts, checking post-run invariants — is a deterministic
//! function of that plan and the run's own virtual-clock state. No wall
//! clock, no ambient randomness: replaying a seed replays the faults,
//! bit for bit, which is what makes a failing soak seed a *repro*, not
//! an anecdote.
//!
//! The injection sites live in [`super::cluster`] (kills, swap slowdown,
//! trace degradation are applied by the cluster loop); this module holds
//! the pieces that are independent of the loop:
//!
//! * [`skew_arrivals`] — the arrival-burst transform, applied to the
//!   arrival list before anything routes;
//! * [`chaos_invariants`] / [`assert_chaos_invariants`] — the soak
//!   checklist every seeded plan must pass: no request lost or
//!   double-completed, no double-rejects, no double-cancels,
//!   `completed + rejected + censored + cancelled == arrivals`,
//!   `kv_violations == 0`. (Pool refcount quiescence after a kill is
//!   enforced *structurally*, by an `ensure!` at the kill site — it
//!   cannot be observed from a report.)

use anyhow::{ensure, Result};

use super::batcher::Request;
use super::cluster::ClusterReport;
use super::scheduler::CbEvent;
use crate::sim::fault::FaultPlan;

/// Apply the plan's arrival bursts: every arrival originally scheduled
/// inside a burst window `[at_s, at_s + window_s)` lands at exactly
/// `at_s` (the first matching burst wins), then the list is re-sorted —
/// stably, so same-instant arrivals keep their id order — because
/// overlapping windows can reorder raw arrival times and the cluster
/// loop requires a sorted stream. With no bursts the list is returned
/// untouched.
pub fn skew_arrivals(plan: &FaultPlan, mut arrivals: Vec<Request>) -> Vec<Request> {
    if plan.bursts.is_empty() {
        return arrivals;
    }
    for r in arrivals.iter_mut() {
        for b in &plan.bursts {
            if r.arrival_s >= b.at_s && r.arrival_s < b.at_s + b.window_s {
                r.arrival_s = b.at_s;
                break;
            }
        }
    }
    arrivals.sort_by(|a, b| a.arrival_s.total_cmp(&b.arrival_s));
    arrivals
}

/// The soak checklist over a finished fleet run that was handed
/// `n_arrivals` requests: each entry is (invariant name, held, detail).
/// Holds for *any* fault schedule — including the empty one — which is
/// what makes it worth soaking over hundreds of seeds.
pub fn chaos_invariants(n_arrivals: usize, report: &ClusterReport) -> Vec<(&'static str, bool, String)> {
    let mut completes: Vec<u64> = Vec::new();
    let mut rejects: Vec<u64> = Vec::new();
    let mut cancels: Vec<u64> = Vec::new();
    for e in &report.events {
        match &e.event {
            CbEvent::Complete { id } => completes.push(*id),
            CbEvent::Reject { id } => rejects.push(*id),
            CbEvent::Cancelled { id } => cancels.push(*id),
            _ => {}
        }
    }
    let total_completes = completes.len();
    let total_rejects = rejects.len();
    let total_cancels = cancels.len();
    completes.sort_unstable();
    completes.dedup();
    rejects.sort_unstable();
    rejects.dedup();
    cancels.sort_unstable();
    cancels.dedup();
    let accounted = completes.len() + rejects.len() + report.censored() + cancels.len();
    vec![
        (
            "no double-completed request",
            completes.len() == total_completes,
            format!("{} Complete events over {} ids", total_completes, completes.len()),
        ),
        (
            "no double-rejected request",
            rejects.len() == total_rejects,
            format!("{} Reject events over {} ids", total_rejects, rejects.len()),
        ),
        (
            "no double-cancelled request",
            cancels.len() == total_cancels,
            format!("{} Cancelled events over {} ids", total_cancels, cancels.len()),
        ),
        (
            "no request lost (completed + rejected + censored + cancelled == arrivals)",
            accounted == n_arrivals,
            format!(
                "{} completed + {} rejected + {} censored + {} cancelled == {} of {} arrivals",
                completes.len(),
                rejects.len(),
                report.censored(),
                cancels.len(),
                accounted,
                n_arrivals
            ),
        ),
        (
            "zero kv_violations fleet-wide",
            report.kv_violations() == 0,
            format!("{} violations", report.kv_violations()),
        ),
    ]
}

/// [`chaos_invariants`], failing loudly: the error names the first broken
/// invariant with its detail line.
pub fn assert_chaos_invariants(n_arrivals: usize, report: &ClusterReport) -> Result<()> {
    for (name, ok, detail) in chaos_invariants(n_arrivals, report) {
        ensure!(ok, "chaos invariant broken: {name} ({detail})");
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::fault::ArrivalBurst;

    fn reqs(times: &[f64]) -> Vec<Request> {
        times
            .iter()
            .enumerate()
            .map(|(i, &t)| Request { id: i as u64, arrival_s: t, tokens: 8 })
            .collect()
    }

    #[test]
    fn bursts_collapse_and_restore_sort_order() {
        let plan = FaultPlan {
            bursts: vec![ArrivalBurst { at_s: 1.0, window_s: 0.5 }],
            ..FaultPlan::default()
        };
        let out = skew_arrivals(&plan, reqs(&[0.5, 1.1, 1.2, 1.6, 2.0]));
        let times: Vec<f64> = out.iter().map(|r| r.arrival_s).collect();
        assert_eq!(times, vec![0.5, 1.0, 1.0, 1.6, 2.0]);
        // stable: collapsed arrivals keep their original relative order
        let ids: Vec<u64> = out.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3, 4]);
        assert!(out.windows(2).all(|w| w[0].arrival_s <= w[1].arrival_s));
    }

    #[test]
    fn no_bursts_is_identity() {
        let plan = FaultPlan::empty();
        let input = reqs(&[0.3, 0.7]);
        let out = skew_arrivals(&plan, input.clone());
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].arrival_s.to_bits(), input[0].arrival_s.to_bits());
    }
}
