//! Request queue + dynamic batcher.
//!
//! The paper's serving experiment (Fig 6) uses batch size 1; the batcher
//! still exists as a first-class component: it groups compatible queued
//! requests up to `max_batch` and a `max_wait` deadline (vLLM-style
//! continuous batching degenerates to FIFO at batch 1).

use std::collections::VecDeque;

use crate::util::rng::Rng;

/// One inference request.
#[derive(Debug, Clone, PartialEq)]
pub struct Request {
    pub id: u64,
    pub arrival_s: f64,
    /// number of content tokens (must match the AOT shape for live runs)
    pub tokens: usize,
}

/// Open-loop Poisson arrival stream: exponential inter-arrivals at `rate`
/// req/s, truncated at `horizon_s`, ids starting at 1. Shared by both
/// serve engines so the workload convention cannot drift between them.
pub fn poisson_arrivals(rng: &mut Rng, rate: f64, horizon_s: f64, tokens: usize) -> Vec<Request> {
    let mut arrivals = Vec::new();
    let mut t = 0.0;
    let mut id = 0u64;
    loop {
        t += rng.exp(rate);
        if t >= horizon_s {
            break;
        }
        id += 1;
        arrivals.push(Request { id, arrival_s: t, tokens });
    }
    arrivals
}

/// FIFO queue with batch formation.
#[derive(Debug)]
pub struct Batcher {
    queue: VecDeque<Request>,
    pub max_batch: usize,
    pub max_wait_s: f64,
    pub enqueued: u64,
}

impl Batcher {
    pub fn new(max_batch: usize, max_wait_s: f64) -> Batcher {
        Batcher { queue: VecDeque::new(), max_batch, max_wait_s, enqueued: 0 }
    }

    pub fn push(&mut self, req: Request) {
        self.enqueued += 1;
        self.queue.push_back(req);
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Iterate the queued requests front-to-back without disturbing them
    /// (the cancellation sweep's read pass).
    pub fn iter(&self) -> impl Iterator<Item = &Request> {
        self.queue.iter()
    }

    /// Remove a queued request by id wherever it sits (client
    /// cancellation — unlike `next_batch*`, not restricted to the head).
    /// `enqueued` is a lifetime counter and stays untouched. Returns the
    /// request, or `None` if it was not queued.
    pub fn remove(&mut self, id: u64) -> Option<Request> {
        let pos = self.queue.iter().position(|r| r.id == id)?;
        self.queue.remove(pos)
    }

    /// Form the next batch at time `now`: returns requests if either the
    /// batch is full or the oldest request has waited past max_wait (or the
    /// queue is non-empty and `force`).
    pub fn next_batch(&mut self, now: f64, force: bool) -> Vec<Request> {
        self.next_batch_capped(now, force, usize::MAX)
    }

    /// `next_batch` additionally capped at `cap` requests — the continuous
    /// batching admission path, where the cap is the number of free decode
    /// slots.
    pub fn next_batch_capped(&mut self, now: f64, force: bool, cap: usize) -> Vec<Request> {
        self.next_batch_filtered(now, force, cap, |_| true)
    }

    /// `next_batch_capped` with a per-request admission predicate: requests
    /// are taken front-to-back (FIFO — no reordering around a blocked
    /// head) and the batch stops at the first request `fits` rejects.
    /// The continuous-batching scheduler uses this for the KV-pressure
    /// gate, where `fits` checks the request's projected cache bytes (net
    /// of any shared-prefix blocks) against the remaining room in the
    /// [`crate::kv::pool::KvPool`].
    ///
    /// The full/deadline trigger is evaluated over the *eligible* set —
    /// the admissible FIFO prefix, up to `max_batch` — not the raw queue:
    /// an ineligible head can no longer fire an empty batch, and
    /// ineligible requests inflating the queue length no longer fire an
    /// undersized batch before the fill deadline. (With a trivial `fits`
    /// the eligible set *is* the queue head, so the trigger is unchanged.)
    /// `cap` (free slots) limits how much of a triggered batch is handed
    /// out, never whether a batch's worth of work is deemed waiting.
    pub fn next_batch_filtered(
        &mut self,
        now: f64,
        force: bool,
        cap: usize,
        mut fits: impl FnMut(&Request) -> bool,
    ) -> Vec<Request> {
        if self.queue.is_empty() || cap == 0 {
            return Vec::new();
        }
        // eligible prefix, assessed in place (nothing pops unless the
        // trigger fires)
        let mut eligible = 0usize;
        for r in self.queue.iter().take(self.max_batch) {
            if !fits(r) {
                break;
            }
            eligible += 1;
        }
        if eligible == 0 {
            return Vec::new();
        }
        let oldest_wait = now - self.queue.front().unwrap().arrival_s;
        if eligible >= self.max_batch || oldest_wait >= self.max_wait_s || force {
            return (0..eligible.min(cap)).map(|_| self.queue.pop_front().unwrap()).collect();
        }
        Vec::new()
    }

    /// Policy-ordered batch formation for reordering
    /// [`crate::server::policy::SchedPolicy`]s: `order` lists queue
    /// positions (front = 0) most-preferred first, and requests `fits`
    /// rejects are *skipped*, not head-blocking. The full/deadline
    /// trigger is evaluated over the eligible picks exactly like
    /// [`Self::next_batch_filtered`] (the deadline clock runs from the
    /// oldest eligible pick), and `cap` truncates the handed-out batch.
    /// Returns the admitted requests in pick order — the order slots are
    /// seated and `Admit` ids are recorded.
    pub fn next_batch_ordered(
        &mut self,
        now: f64,
        force: bool,
        cap: usize,
        order: &[usize],
        mut fits: impl FnMut(&Request) -> bool,
    ) -> Vec<Request> {
        if self.queue.is_empty() || cap == 0 {
            return Vec::new();
        }
        let mut picks: Vec<usize> = Vec::new();
        let mut oldest = f64::INFINITY;
        for &qi in order {
            if picks.len() >= self.max_batch {
                break;
            }
            let Some(r) = self.queue.get(qi) else { continue };
            if fits(r) {
                picks.push(qi);
                oldest = oldest.min(r.arrival_s);
            }
        }
        if picks.is_empty() {
            return Vec::new();
        }
        if picks.len() >= self.max_batch || now - oldest >= self.max_wait_s || force {
            picks.truncate(cap);
            // remove back-to-front so earlier indices stay valid, then
            // restore pick order
            let mut by_index: Vec<(usize, usize)> =
                picks.iter().enumerate().map(|(pos, &qi)| (qi, pos)).collect();
            by_index.sort_unstable();
            let mut out: Vec<Option<Request>> = vec![None; picks.len()];
            for &(qi, pos) in by_index.iter().rev() {
                out[pos] = self.queue.remove(qi);
            }
            return out.into_iter().map(|r| r.expect("ordered pick vanished")).collect();
        }
        Vec::new()
    }

    /// Iterate the queued requests in FIFO order (policy snapshots).
    pub fn iter(&self) -> impl Iterator<Item = &Request> {
        self.queue.iter()
    }

    /// The request at the head of the queue, if any.
    pub fn front(&self) -> Option<&Request> {
        self.queue.front()
    }

    /// Pop the head of the queue (KV-pressure rejection path).
    pub fn pop_front(&mut self) -> Option<Request> {
        self.queue.pop_front()
    }

    /// Arrival time of the oldest queued request (None when the queue is
    /// empty); `now - oldest_arrival()` is its current fill-deadline wait.
    pub fn oldest_arrival(&self) -> Option<f64> {
        self.queue.front().map(|r| r.arrival_s)
    }

    /// Remove and return everything still queued (end-of-horizon census).
    pub fn drain_all(&mut self) -> Vec<Request> {
        self.queue.drain(..).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, t: f64) -> Request {
        Request { id, arrival_s: t, tokens: 64 }
    }

    #[test]
    fn fifo_order() {
        let mut b = Batcher::new(2, 0.0);
        b.push(req(1, 0.0));
        b.push(req(2, 0.1));
        b.push(req(3, 0.2));
        let batch = b.next_batch(0.2, false);
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 2]);
        assert_eq!(b.len(), 1);
    }

    #[test]
    fn waits_for_fill_until_deadline() {
        let mut b = Batcher::new(4, 0.5);
        b.push(req(1, 0.0));
        assert!(b.next_batch(0.1, false).is_empty()); // not full, not old
        let batch = b.next_batch(0.6, false); // deadline passed
        assert_eq!(batch.len(), 1);
    }

    #[test]
    fn force_drains() {
        let mut b = Batcher::new(8, 100.0);
        b.push(req(1, 0.0));
        assert_eq!(b.next_batch(0.0, true).len(), 1);
        assert!(b.is_empty());
    }

    #[test]
    fn filtered_batch_stops_at_first_misfit_fifo() {
        let mut b = Batcher::new(4, 0.0);
        for i in 0..4 {
            b.push(req(i, 0.0));
        }
        // requests 0 and 1 fit; 2 does not — 3 must NOT jump the queue
        let batch = b.next_batch_filtered(0.0, true, 4, |r| r.id != 2);
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1]);
        assert_eq!(b.front().map(|r| r.id), Some(2));
        assert_eq!(b.pop_front().map(|r| r.id), Some(2));
        assert_eq!(b.len(), 1);
    }

    #[test]
    fn ineligible_requests_no_longer_fire_empty_or_undersized_batches() {
        // regression (trigger/eligibility consistency): the full and
        // deadline triggers used to inspect the whole queue even though
        // admission stops at the first misfit, so an ineligible head
        // fired an "empty batch" and misfits behind an eligible head
        // inflated the count into firing an undersized batch early.
        let mut b = Batcher::new(2, 10.0);
        for i in 1..=3 {
            b.push(req(i, 0.0));
        }
        // ineligible head: no trigger at all (previously the len >= 2
        // full trigger fired and produced an empty batch)
        assert!(b.next_batch_filtered(0.0, false, 4, |r| r.id != 1).is_empty());
        assert_eq!(b.len(), 3);
        // eligible head, misfit at 2: eligible set is [1] — below the
        // fill target and inside the deadline, so nothing fires yet...
        assert!(b.next_batch_filtered(0.0, false, 4, |r| r.id != 2).is_empty());
        assert_eq!(b.len(), 3);
        // ...until the deadline passes, when the eligible prefix goes out
        let batch = b.next_batch_filtered(10.0, false, 4, |r| r.id != 2);
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1]);
        assert_eq!(b.front().map(|r| r.id), Some(2));
        // a fully eligible queue still full-triggers immediately
        let batch = b.next_batch_filtered(0.0, false, 4, |_| true);
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![2, 3]);
    }

    #[test]
    fn ordered_batches_skip_misfits_and_keep_pick_order() {
        let mut b = Batcher::new(3, 10.0);
        for i in 0..4 {
            b.push(req(i, 0.0));
        }
        // policy prefers 3, 1, 0, 2; request 1 does not fit and is
        // skipped (not head-blocking); force admits the rest in pick
        // order capped at 2
        let batch = b.next_batch_ordered(0.0, true, 2, &[3, 1, 0, 2], |r| r.id != 1);
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![3, 0]);
        assert_eq!(b.len(), 2);
        // remaining queue keeps FIFO order
        assert_eq!(b.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 2]);
        // trigger discipline matches the filtered path: nothing fires
        // below the fill target before the deadline...
        assert!(b.next_batch_ordered(0.0, false, 4, &[0, 1], |_| true).is_empty());
        // ...and the deadline clock runs from the oldest eligible pick
        let batch = b.next_batch_ordered(10.0, false, 4, &[1, 0], |_| true);
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![2, 1]);
        assert!(b.is_empty());
    }

    #[test]
    fn capped_batch_respects_free_slots() {
        let mut b = Batcher::new(4, 0.0);
        for i in 0..4 {
            b.push(req(i, 0.0));
        }
        // full batch available, but only 2 slots free
        let batch = b.next_batch_capped(0.0, false, 2);
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1]);
        assert_eq!(b.len(), 2);
        // zero cap admits nothing
        assert!(b.next_batch_capped(0.0, true, 0).is_empty());
        assert_eq!(b.oldest_arrival(), Some(0.0));
        assert_eq!(b.drain_all().len(), 2);
        assert!(b.is_empty());
        assert_eq!(b.oldest_arrival(), None);
    }
}
