//! Request queue + dynamic batcher.
//!
//! The paper's serving experiment (Fig 6) uses batch size 1; the batcher
//! still exists as a first-class component: it groups compatible queued
//! requests up to `max_batch` and a `max_wait` deadline (vLLM-style
//! continuous batching degenerates to FIFO at batch 1).

use std::collections::VecDeque;

use crate::util::rng::Rng;

/// One inference request.
#[derive(Debug, Clone, PartialEq)]
pub struct Request {
    pub id: u64,
    pub arrival_s: f64,
    /// number of content tokens (must match the AOT shape for live runs)
    pub tokens: usize,
}

/// Open-loop Poisson arrival stream: exponential inter-arrivals at `rate`
/// req/s, truncated at `horizon_s`, ids starting at 1. Shared by both
/// serve engines so the workload convention cannot drift between them.
pub fn poisson_arrivals(rng: &mut Rng, rate: f64, horizon_s: f64, tokens: usize) -> Vec<Request> {
    let mut arrivals = Vec::new();
    let mut t = 0.0;
    let mut id = 0u64;
    loop {
        t += rng.exp(rate);
        if t >= horizon_s {
            break;
        }
        id += 1;
        arrivals.push(Request { id, arrival_s: t, tokens });
    }
    arrivals
}

/// FIFO queue with batch formation.
#[derive(Debug)]
pub struct Batcher {
    queue: VecDeque<Request>,
    pub max_batch: usize,
    pub max_wait_s: f64,
    pub enqueued: u64,
}

impl Batcher {
    pub fn new(max_batch: usize, max_wait_s: f64) -> Batcher {
        Batcher { queue: VecDeque::new(), max_batch, max_wait_s, enqueued: 0 }
    }

    pub fn push(&mut self, req: Request) {
        self.enqueued += 1;
        self.queue.push_back(req);
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Form the next batch at time `now`: returns requests if either the
    /// batch is full or the oldest request has waited past max_wait (or the
    /// queue is non-empty and `force`).
    pub fn next_batch(&mut self, now: f64, force: bool) -> Vec<Request> {
        self.next_batch_capped(now, force, usize::MAX)
    }

    /// `next_batch` additionally capped at `cap` requests — the continuous
    /// batching admission path, where the cap is the number of free decode
    /// slots. The full/deadline trigger still looks at the whole queue.
    pub fn next_batch_capped(&mut self, now: f64, force: bool, cap: usize) -> Vec<Request> {
        self.next_batch_filtered(now, force, cap, |_| true)
    }

    /// `next_batch_capped` with a per-request admission predicate: requests
    /// are popped front-to-back (FIFO — no reordering around a blocked
    /// head) and the batch stops at the first request `fits` rejects. The
    /// continuous-batching scheduler uses this for the KV-pressure gate,
    /// where `fits` checks the request's projected cache bytes (net of any
    /// shared-prefix blocks) against the remaining room in the
    /// [`crate::kv::pool::KvPool`].
    pub fn next_batch_filtered(
        &mut self,
        now: f64,
        force: bool,
        cap: usize,
        mut fits: impl FnMut(&Request) -> bool,
    ) -> Vec<Request> {
        if self.queue.is_empty() || cap == 0 {
            return Vec::new();
        }
        let oldest_wait = now - self.queue.front().unwrap().arrival_s;
        if self.queue.len() >= self.max_batch || oldest_wait >= self.max_wait_s || force {
            let take = self.queue.len().min(self.max_batch).min(cap);
            let mut out = Vec::with_capacity(take);
            while out.len() < take {
                let admissible = match self.queue.front() {
                    Some(r) => fits(r),
                    None => false,
                };
                if !admissible {
                    break;
                }
                out.push(self.queue.pop_front().unwrap());
            }
            return out;
        }
        Vec::new()
    }

    /// The request at the head of the queue, if any.
    pub fn front(&self) -> Option<&Request> {
        self.queue.front()
    }

    /// Pop the head of the queue (KV-pressure rejection path).
    pub fn pop_front(&mut self) -> Option<Request> {
        self.queue.pop_front()
    }

    /// Arrival time of the oldest queued request (None when the queue is
    /// empty); `now - oldest_arrival()` is its current fill-deadline wait.
    pub fn oldest_arrival(&self) -> Option<f64> {
        self.queue.front().map(|r| r.arrival_s)
    }

    /// Remove and return everything still queued (end-of-horizon census).
    pub fn drain_all(&mut self) -> Vec<Request> {
        self.queue.drain(..).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, t: f64) -> Request {
        Request { id, arrival_s: t, tokens: 64 }
    }

    #[test]
    fn fifo_order() {
        let mut b = Batcher::new(2, 0.0);
        b.push(req(1, 0.0));
        b.push(req(2, 0.1));
        b.push(req(3, 0.2));
        let batch = b.next_batch(0.2, false);
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 2]);
        assert_eq!(b.len(), 1);
    }

    #[test]
    fn waits_for_fill_until_deadline() {
        let mut b = Batcher::new(4, 0.5);
        b.push(req(1, 0.0));
        assert!(b.next_batch(0.1, false).is_empty()); // not full, not old
        let batch = b.next_batch(0.6, false); // deadline passed
        assert_eq!(batch.len(), 1);
    }

    #[test]
    fn force_drains() {
        let mut b = Batcher::new(8, 100.0);
        b.push(req(1, 0.0));
        assert_eq!(b.next_batch(0.0, true).len(), 1);
        assert!(b.is_empty());
    }

    #[test]
    fn filtered_batch_stops_at_first_misfit_fifo() {
        let mut b = Batcher::new(4, 0.0);
        for i in 0..4 {
            b.push(req(i, 0.0));
        }
        // requests 0 and 1 fit; 2 does not — 3 must NOT jump the queue
        let batch = b.next_batch_filtered(0.0, true, 4, |r| r.id != 2);
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1]);
        assert_eq!(b.front().map(|r| r.id), Some(2));
        assert_eq!(b.pop_front().map(|r| r.id), Some(2));
        assert_eq!(b.len(), 1);
    }

    #[test]
    fn capped_batch_respects_free_slots() {
        let mut b = Batcher::new(4, 0.0);
        for i in 0..4 {
            b.push(req(i, 0.0));
        }
        // full batch available, but only 2 slots free
        let batch = b.next_batch_capped(0.0, false, 2);
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1]);
        assert_eq!(b.len(), 2);
        // zero cap admits nothing
        assert!(b.next_batch_capped(0.0, true, 0).is_empty());
        assert_eq!(b.oldest_arrival(), Some(0.0));
        assert_eq!(b.drain_all().len(), 2);
        assert!(b.is_empty());
        assert_eq!(b.oldest_arrival(), None);
    }
}
