//! Request queue + dynamic batcher.
//!
//! The paper's serving experiment (Fig 6) uses batch size 1; the batcher
//! still exists as a first-class component: it groups compatible queued
//! requests up to `max_batch` and a `max_wait` deadline (vLLM-style
//! continuous batching degenerates to FIFO at batch 1).

use std::collections::VecDeque;

/// One inference request.
#[derive(Debug, Clone, PartialEq)]
pub struct Request {
    pub id: u64,
    pub arrival_s: f64,
    /// number of content tokens (must match the AOT shape for live runs)
    pub tokens: usize,
}

/// FIFO queue with batch formation.
#[derive(Debug)]
pub struct Batcher {
    queue: VecDeque<Request>,
    pub max_batch: usize,
    pub max_wait_s: f64,
    pub enqueued: u64,
}

impl Batcher {
    pub fn new(max_batch: usize, max_wait_s: f64) -> Batcher {
        Batcher { queue: VecDeque::new(), max_batch, max_wait_s, enqueued: 0 }
    }

    pub fn push(&mut self, req: Request) {
        self.enqueued += 1;
        self.queue.push_back(req);
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Form the next batch at time `now`: returns requests if either the
    /// batch is full or the oldest request has waited past max_wait (or the
    /// queue is non-empty and `force`).
    pub fn next_batch(&mut self, now: f64, force: bool) -> Vec<Request> {
        if self.queue.is_empty() {
            return Vec::new();
        }
        let oldest_wait = now - self.queue.front().unwrap().arrival_s;
        if self.queue.len() >= self.max_batch || oldest_wait >= self.max_wait_s || force {
            let take = self.queue.len().min(self.max_batch);
            return self.queue.drain(..take).collect();
        }
        Vec::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, t: f64) -> Request {
        Request { id, arrival_s: t, tokens: 64 }
    }

    #[test]
    fn fifo_order() {
        let mut b = Batcher::new(2, 0.0);
        b.push(req(1, 0.0));
        b.push(req(2, 0.1));
        b.push(req(3, 0.2));
        let batch = b.next_batch(0.2, false);
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 2]);
        assert_eq!(b.len(), 1);
    }

    #[test]
    fn waits_for_fill_until_deadline() {
        let mut b = Batcher::new(4, 0.5);
        b.push(req(1, 0.0));
        assert!(b.next_batch(0.1, false).is_empty()); // not full, not old
        let batch = b.next_batch(0.6, false); // deadline passed
        assert_eq!(batch.len(), 1);
    }

    #[test]
    fn force_drains() {
        let mut b = Batcher::new(8, 100.0);
        b.push(req(1, 0.0));
        assert_eq!(b.next_batch(0.0, true).len(), 1);
        assert!(b.is_empty());
    }
}
