//! Typed configuration for the launcher (`astra` CLI) and examples.
//!
//! Config files are JSON (parsed by [`crate::util::json`]); every field has
//! a default so a minimal file (or none) works. See `configs/` for the
//! shipped presets.

use std::path::Path;

use anyhow::{Context, Result};

use crate::model::shape::{TransformerShape, VqSetting};
use crate::util::json::Json;

/// Cluster + network + strategy settings for a run.
#[derive(Debug, Clone)]
pub struct RunConfig {
    pub artifacts_dir: String,
    pub n_devices: usize,
    pub bandwidth_mbps: f64,
    pub latency_s: f64,
    pub loss_rate: f64,
    pub retransmit: bool,
    /// heterogeneous token split (len n_devices, sums to seq_len); empty = even
    pub token_split: Vec<usize>,
    pub seed: u64,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            artifacts_dir: "artifacts".into(),
            n_devices: 4,
            bandwidth_mbps: 100.0,
            latency_s: 0.0005,
            loss_rate: 0.0,
            retransmit: true,
            token_split: Vec::new(),
            seed: 42,
        }
    }
}

impl RunConfig {
    pub fn from_file(path: &Path) -> Result<RunConfig> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        Self::from_json(&Json::parse(&text)?)
    }

    pub fn from_json(j: &Json) -> Result<RunConfig> {
        let mut c = RunConfig::default();
        if let Some(v) = j.opt("artifacts_dir") {
            c.artifacts_dir = v.as_str()?.to_string();
        }
        if let Some(v) = j.opt("n_devices") {
            c.n_devices = v.as_usize()?;
        }
        if let Some(v) = j.opt("bandwidth_mbps") {
            c.bandwidth_mbps = v.as_f64()?;
        }
        if let Some(v) = j.opt("latency_s") {
            c.latency_s = v.as_f64()?;
        }
        if let Some(v) = j.opt("loss_rate") {
            c.loss_rate = v.as_f64()?;
        }
        if let Some(v) = j.opt("retransmit") {
            c.retransmit = v.as_bool()?;
        }
        if let Some(v) = j.opt("seed") {
            c.seed = v.as_f64()? as u64;
        }
        if let Some(v) = j.opt("token_split") {
            c.token_split = v
                .as_arr()?
                .iter()
                .map(|x| x.as_usize())
                .collect::<Result<Vec<_>>>()?;
        }
        Ok(c)
    }
}

/// Shape presets addressable from the CLI (`--model vit-base` etc.).
pub fn shape_preset(name: &str, seq_len: usize) -> Result<TransformerShape> {
    Ok(match name {
        "vit-base" | "paper-encoder" => TransformerShape::vit_base(seq_len),
        "gpt2-s" => TransformerShape::gpt2_small(seq_len),
        "gpt2-m" => TransformerShape::gpt2_medium(seq_len),
        "llama3-8b" => TransformerShape::llama3_8b(seq_len),
        "tiny" => TransformerShape::tiny(seq_len),
        other => anyhow::bail!("unknown model preset `{other}`"),
    })
}

/// VQ presets: "g16k1024" style strings.
pub fn vq_preset(s: &str) -> Result<VqSetting> {
    let rest = s.strip_prefix('g').context("vq preset must look like g16k1024")?;
    let (g, k) = rest.split_once('k').context("vq preset must look like g16k1024")?;
    Ok(VqSetting::new(g.parse()?, k.parse()?))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_and_overrides() {
        let j = Json::parse(
            r#"{"n_devices": 8, "bandwidth_mbps": 20.5, "token_split": [4, 4, 4, 4],
                "loss_rate": 0.05, "retransmit": false}"#,
        )
        .unwrap();
        let c = RunConfig::from_json(&j).unwrap();
        assert_eq!(c.n_devices, 8);
        assert_eq!(c.bandwidth_mbps, 20.5);
        assert_eq!(c.token_split, vec![4, 4, 4, 4]);
        assert!(!c.retransmit);
        assert_eq!(c.seed, 42); // default
    }

    #[test]
    fn presets() {
        assert_eq!(shape_preset("vit-base", 1024).unwrap().d_model, 768);
        assert_eq!(shape_preset("llama3-8b", 512).unwrap().n_layers, 32);
        assert!(shape_preset("nope", 1).is_err());
        let vq = vq_preset("g16k1024").unwrap();
        assert_eq!((vq.groups, vq.codebook_size), (16, 1024));
        assert!(vq_preset("16x1024").is_err());
    }
}
