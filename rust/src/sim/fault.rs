//! Seeded deterministic fault plans for chaos soaking.
//!
//! A [`FaultPlan`] is a *pure data* schedule of faults drawn once from a
//! seed: replica kills, link-degradation windows, swap-tier slowdown
//! windows, and arrival bursts. Everything is expressed in virtual-clock
//! seconds — the serving stack (`server/chaos`, `server/cluster`) replays
//! the plan against its own deterministic event loop, so the same seed
//! always produces the same faults at the same points in the same run, no
//! matter how fast the host executes. The empty plan is the identity: a
//! run with `FaultPlan::empty()` must be bit-identical to a run with no
//! plan at all, which is the anchor property the chaos test suite pins.
//!
//! The plan deliberately knows nothing about engines, requests, or
//! backends: it answers only "what multiplies the link bandwidth at time
//! t", "what slows the swap tier at time t", "which replicas die when",
//! and "which arrival spans collapse into a burst". The *interpretation*
//! (losing a queue, restoring from a checkpoint) lives above, in
//! `server/chaos` and the cluster loop.

use crate::comm::trace::BandwidthTrace;
use crate::util::rng::Rng;

/// Unplanned death of a replica: unlike `--drain-at`, the victim's queue
/// and host swap tier are *lost*, not spilled cleanly.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ReplicaKill {
    pub replica: usize,
    pub at_s: f64,
}

/// A link-degradation window: while active, effective bandwidth is scaled
/// by `bandwidth_scale` and a Bernoulli per-packet loss of `loss_rate` is
/// applied on top. A reliable (retransmitting) link converts loss into
/// extra copies — expected billed bytes are `bytes / (1 - p)` (see
/// `comm/link.rs::prop_retransmit_expected_bytes`) — so loss shows up as a
/// further goodput factor of `1 - loss_rate`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinkWindow {
    pub from_s: f64,
    pub to_s: f64,
    /// multiplies the trace bandwidth (0 < scale <= 1)
    pub bandwidth_scale: f64,
    /// Bernoulli per-packet loss applied during the window
    pub loss_rate: f64,
}

/// A swap/checkpoint-tier slowdown window: while active, the host link's
/// bandwidth is divided (and latency multiplied) by `slowdown`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SwapWindow {
    pub from_s: f64,
    pub to_s: f64,
    /// >= 1.0; 1.0 is the identity
    pub slowdown: f64,
}

/// A clock-skew burst: every arrival scheduled inside
/// `[at_s, at_s + window_s)` lands at exactly `at_s` instead — the
/// thundering herd a fleet sees when a partition heals and queued clients
/// all reconnect at once.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ArrivalBurst {
    pub at_s: f64,
    pub window_s: f64,
}

/// A complete seeded fault schedule for one run.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    /// sorted by `at_s`
    pub kills: Vec<ReplicaKill>,
    pub links: Vec<LinkWindow>,
    pub swaps: Vec<SwapWindow>,
    pub bursts: Vec<ArrivalBurst>,
}

impl FaultPlan {
    /// The identity plan: injects nothing, perturbs nothing.
    pub fn empty() -> FaultPlan {
        FaultPlan::default()
    }

    pub fn is_empty(&self) -> bool {
        self.kills.is_empty()
            && self.links.is_empty()
            && self.swaps.is_empty()
            && self.bursts.is_empty()
    }

    /// Draw a plan from a seed for a `replicas`-wide fleet over
    /// `horizon_s` virtual seconds. Deterministic: same inputs, same plan.
    pub fn seeded(seed: u64, replicas: usize, horizon_s: f64) -> FaultPlan {
        let mut rng = Rng::new(seed ^ 0xfa17_7b1a_9e37_79b9);
        let mut plan = FaultPlan::default();

        // Kills: up to replicas-1 distinct victims (someone must survive to
        // adopt the dead replica's work), in the middle of the run so the
        // victims are actually mid-decode. The cluster loop additionally
        // refuses to kill the last live replica at execution time.
        if replicas > 1 {
            let n_kills = rng.below(replicas); // 0..replicas-1
            let mut victims: Vec<usize> = Vec::new();
            for _ in 0..n_kills {
                let v = rng.below(replicas);
                if !victims.contains(&v) {
                    victims.push(v);
                }
            }
            for v in victims {
                let at_s = (0.1 + 0.7 * rng.f64()) * horizon_s;
                plan.kills.push(ReplicaKill { replica: v, at_s });
            }
            plan.kills.sort_by(|a, b| a.at_s.total_cmp(&b.at_s));
        }

        // Link-degradation windows: 0..3 windows, each spanning at least
        // 20% of the horizon, bandwidth scaled into [0.3, 1.0) with loss
        // up to 0.5 on top.
        for _ in 0..rng.below(3) {
            let from_s = rng.f64() * 0.7 * horizon_s;
            let span = (0.2 + 0.6 * rng.f64()) * horizon_s;
            plan.links.push(LinkWindow {
                from_s,
                to_s: (from_s + span).min(horizon_s),
                bandwidth_scale: 0.3 + 0.7 * rng.f64(),
                loss_rate: 0.5 * rng.f64(),
            });
        }

        // Swap-tier slowdowns: 0..3 windows, 1x..8x.
        for _ in 0..rng.below(3) {
            let from_s = rng.f64() * 0.7 * horizon_s;
            let span = (0.1 + 0.5 * rng.f64()) * horizon_s;
            plan.swaps.push(SwapWindow {
                from_s,
                to_s: (from_s + span).min(horizon_s),
                slowdown: 1.0 + 7.0 * rng.f64(),
            });
        }

        // Arrival bursts: 0..4 collapse windows of 5-15% of the horizon.
        for _ in 0..rng.below(4) {
            let at_s = rng.f64() * 0.8 * horizon_s;
            plan.bursts.push(ArrivalBurst { at_s, window_s: (0.05 + 0.10 * rng.f64()) * horizon_s });
        }

        plan
    }

    /// Combined goodput multiplier on inter-device links at time `t`:
    /// the product over active windows of `bandwidth_scale * (1 - loss)`
    /// (loss on a reliable link costs `1/(1-p)` extra copies, i.e. a
    /// `1-p` goodput factor). 1.0 outside every window.
    pub fn link_factor(&self, t: f64) -> f64 {
        let mut f = 1.0;
        for w in &self.links {
            if t >= w.from_s && t < w.to_s {
                f *= w.bandwidth_scale * (1.0 - w.loss_rate);
            }
        }
        f
    }

    /// Swap/checkpoint-tier slowdown factor at time `t` (product over
    /// active windows; 1.0 outside every window).
    pub fn swap_slowdown(&self, t: f64) -> f64 {
        let mut f = 1.0;
        for w in &self.swaps {
            if t >= w.from_s && t < w.to_s {
                f *= w.slowdown;
            }
        }
        f
    }

    /// A copy of `trace` with every link window applied: resampled on a
    /// fine fixed grid with each slot's bandwidth multiplied by
    /// [`FaultPlan::link_factor`] at the slot midpoint. With no link
    /// windows the trace is returned unchanged (clone), preserving
    /// bit-identical transfer integrals for the empty plan.
    pub fn degraded_trace(&self, trace: &BandwidthTrace, horizon_s: f64) -> BandwidthTrace {
        if self.links.is_empty() {
            return trace.clone();
        }
        let slot_s = 0.1f64;
        let n = (horizon_s / slot_s).ceil().max(1.0) as usize;
        let mbps = (0..n)
            .map(|i| {
                let t_mid = (i as f64 + 0.5) * slot_s;
                trace.at(t_mid) * self.link_factor(t_mid)
            })
            .collect();
        BandwidthTrace { slot_s, mbps }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seeded_plans_are_deterministic_and_bounded() {
        for seed in 0..200u64 {
            let a = FaultPlan::seeded(seed, 4, 10.0);
            let b = FaultPlan::seeded(seed, 4, 10.0);
            assert_eq!(a, b, "seed {seed} not deterministic");
            assert!(a.kills.len() < 4, "must leave a survivor");
            for k in &a.kills {
                assert!(k.replica < 4);
                assert!(k.at_s > 0.0 && k.at_s < 10.0);
            }
            for w in &a.links {
                assert!(w.from_s < w.to_s && w.to_s <= 10.0);
                assert!(w.bandwidth_scale >= 0.3 && w.bandwidth_scale <= 1.0);
                assert!((0.0..0.5).contains(&w.loss_rate));
            }
            for w in &a.swaps {
                assert!(w.slowdown >= 1.0 && w.slowdown <= 8.0);
            }
            assert!(a.kills.windows(2).all(|p| p[0].at_s <= p[1].at_s), "kills sorted");
        }
    }

    #[test]
    fn single_replica_plans_never_kill() {
        for seed in 0..50u64 {
            assert!(FaultPlan::seeded(seed, 1, 10.0).kills.is_empty());
        }
    }

    #[test]
    fn empty_plan_is_the_identity() {
        let plan = FaultPlan::empty();
        assert!(plan.is_empty());
        assert_eq!(plan.link_factor(3.0), 1.0);
        assert_eq!(plan.swap_slowdown(3.0), 1.0);
        let trace = BandwidthTrace::constant(80.0, 10.0);
        let same = plan.degraded_trace(&trace, 10.0);
        assert_eq!(same.slot_s.to_bits(), trace.slot_s.to_bits());
        assert_eq!(same.mbps.len(), trace.mbps.len());
        assert_eq!(same.mbps[0].to_bits(), trace.mbps[0].to_bits());
    }

    #[test]
    fn factors_apply_only_inside_windows() {
        let plan = FaultPlan {
            links: vec![LinkWindow { from_s: 2.0, to_s: 4.0, bandwidth_scale: 0.5, loss_rate: 0.2 }],
            swaps: vec![SwapWindow { from_s: 1.0, to_s: 3.0, slowdown: 4.0 }],
            ..FaultPlan::default()
        };
        assert_eq!(plan.link_factor(1.0), 1.0);
        assert!((plan.link_factor(3.0) - 0.5 * 0.8).abs() < 1e-12);
        assert_eq!(plan.link_factor(4.0), 1.0, "window is half-open");
        assert_eq!(plan.swap_slowdown(0.5), 1.0);
        assert_eq!(plan.swap_slowdown(2.0), 4.0);
        // degraded trace: inside the window the 100 Mbps constant drops
        let trace = BandwidthTrace::constant(100.0, 10.0);
        let deg = plan.degraded_trace(&trace, 10.0);
        assert!((deg.at(3.0) - 40.0).abs() < 1e-9);
        assert!((deg.at(7.0) - 100.0).abs() < 1e-9);
    }
}
