//! Minimal discrete-event engine: a time-ordered queue of closures.
//!
//! Deliberately simple — events are `FnOnce(&mut Engine)` scheduled at
//! absolute times; the run loop pops in time order. State lives in the
//! caller's structures (captured via `Rc<RefCell<..>>` or indices), which
//! keeps the engine generic across the serving simulator and tests.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// An event: fires `action` at `time`.
pub struct Event {
    pub time: f64,
    seq: u64,
    action: Box<dyn FnOnce(&mut Engine)>,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl Eq for Event {}
impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Event {
    fn cmp(&self, other: &Self) -> Ordering {
        // min-heap by (time, seq): reverse for BinaryHeap
        other
            .time
            .partial_cmp(&self.time)
            .unwrap_or(Ordering::Equal)
            .then(other.seq.cmp(&self.seq))
    }
}

/// The simulation clock + event queue.
#[derive(Default)]
pub struct Engine {
    now: f64,
    queue: BinaryHeap<Event>,
    seq: u64,
    processed: u64,
}

impl Engine {
    pub fn new() -> Engine {
        Engine::default()
    }

    pub fn now(&self) -> f64 {
        self.now
    }

    pub fn processed(&self) -> u64 {
        self.processed
    }

    /// Schedule `action` `delay` seconds from now.
    pub fn after(&mut self, delay: f64, action: impl FnOnce(&mut Engine) + 'static) {
        self.at(self.now + delay, action);
    }

    /// Schedule `action` at absolute time `time` (clamped to now).
    pub fn at(&mut self, time: f64, action: impl FnOnce(&mut Engine) + 'static) {
        let time = time.max(self.now);
        self.seq += 1;
        self.queue.push(Event { time, seq: self.seq, action: Box::new(action) });
    }

    /// Run until the queue drains or the horizon passes.
    pub fn run_until(&mut self, horizon: f64) {
        while let Some(ev) = self.queue.peek() {
            if ev.time > horizon {
                break;
            }
            let ev = self.queue.pop().unwrap();
            self.now = ev.time;
            self.processed += 1;
            (ev.action)(self);
        }
        self.now = self.now.max(horizon.min(self.now + 0.0));
    }

    /// Run to quiescence.
    pub fn run(&mut self) {
        self.run_until(f64::INFINITY);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::cell::RefCell;
    use std::rc::Rc;

    #[test]
    fn events_fire_in_time_order() {
        let log = Rc::new(RefCell::new(Vec::new()));
        let mut e = Engine::new();
        for (t, tag) in [(3.0, 'c'), (1.0, 'a'), (2.0, 'b')] {
            let log = log.clone();
            e.at(t, move |_| log.borrow_mut().push(tag));
        }
        e.run();
        assert_eq!(*log.borrow(), vec!['a', 'b', 'c']);
        assert_eq!(e.now(), 3.0);
    }

    #[test]
    fn ties_fire_in_insertion_order() {
        let log = Rc::new(RefCell::new(Vec::new()));
        let mut e = Engine::new();
        for tag in 0..5 {
            let log = log.clone();
            e.at(1.0, move |_| log.borrow_mut().push(tag));
        }
        e.run();
        assert_eq!(*log.borrow(), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn cascading_events() {
        let count = Rc::new(RefCell::new(0));
        let mut e = Engine::new();
        fn chain(e: &mut Engine, left: usize, count: Rc<RefCell<usize>>) {
            if left == 0 {
                return;
            }
            e.after(1.0, move |e| {
                *count.borrow_mut() += 1;
                chain(e, left - 1, count);
            });
        }
        chain(&mut e, 10, count.clone());
        e.run();
        assert_eq!(*count.borrow(), 10);
        assert_eq!(e.now(), 10.0);
    }

    #[test]
    fn horizon_cuts_off() {
        let count = Rc::new(RefCell::new(0));
        let mut e = Engine::new();
        for t in 0..10 {
            let count = count.clone();
            e.at(t as f64, move |_| *count.borrow_mut() += 1);
        }
        e.run_until(4.5);
        assert_eq!(*count.borrow(), 5); // t = 0..4
    }
}
