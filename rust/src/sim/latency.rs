//! Schedule evaluation: latency + compute/comm breakdown under static or
//! time-varying bandwidth. Regenerates the per-request numbers behind
//! Figures 1, 3, 4, 5 and Tables 4, 7.

use crate::comm::trace::BandwidthTrace;
use crate::parallel::cost::{DeviceModel, Schedule};

/// Environment a schedule is evaluated in.
#[derive(Debug, Clone)]
pub struct SimParams {
    pub device: DeviceModel,
    /// per-collective-stage sync latency (protocol overhead), seconds
    pub stage_latency_s: f64,
}

impl SimParams {
    pub fn paper_encoder() -> SimParams {
        SimParams { device: DeviceModel::paper_1660ti(), stage_latency_s: 0.0006 }
    }

    pub fn paper_llama() -> SimParams {
        SimParams { device: DeviceModel::paper_titanx_llama(), stage_latency_s: 0.002 }
    }
}

/// Latency breakdown of one prefill.
#[derive(Debug, Clone, Copy, Default)]
pub struct Breakdown {
    pub compute_s: f64,
    pub comm_s: f64,
}

impl Breakdown {
    pub fn total(&self) -> f64 {
        self.compute_s + self.comm_s
    }

    /// Fraction of total latency spent communicating (paper Fig 3 reports
    /// 58.6–93.5% for the baselines below 100 Mbps).
    pub fn comm_fraction(&self) -> f64 {
        if self.total() == 0.0 {
            0.0
        } else {
            self.comm_s / self.total()
        }
    }

    /// Add another breakdown into this one — serving loops sum many
    /// schedule evaluations (prefills + decode steps) into one report.
    pub fn accumulate(&mut self, other: &Breakdown) {
        self.compute_s += other.compute_s;
        self.comm_s += other.comm_s;
    }
}

/// Evaluate under a static bandwidth.
pub fn evaluate(sched: &Schedule, params: &SimParams, bandwidth_mbps: f64) -> Breakdown {
    let (compute_s, comm_s) =
        sched.latency_breakdown(&params.device, bandwidth_mbps, params.stage_latency_s);
    Breakdown { compute_s, comm_s }
}

/// Evaluate against a time-varying trace starting at absolute time `t0`;
/// phases execute sequentially, transfers integrate the trace.
pub fn evaluate_on_trace(
    sched: &Schedule,
    params: &SimParams,
    trace: &BandwidthTrace,
    t0: f64,
) -> Breakdown {
    let mut t = t0;
    let mut bd = Breakdown::default();
    for p in &sched.phases {
        let c = params.device.phase_compute_time(p.compute_flops, p.launches, p.mem_bytes);
        t += c;
        bd.compute_s += c;
        if p.comm.bits > 0.0 || p.comm.stages > 0 {
            let m = trace.transfer_time(t, p.comm.bits)
                + p.comm.stages as f64 * params.stage_latency_s;
            t += m;
            bd.comm_s += m;
        }
    }
    bd
}

/// Evaluate a schedule executed by a batch of `b` requests at once under a
/// static bandwidth: per-request FLOPs/bits scale with `b`; launches, sync
/// stages, and the weight-streaming floor are paid once (see
/// [`crate::parallel::cost::Phase::for_batch`]).
pub fn evaluate_batched(
    sched: &Schedule,
    params: &SimParams,
    bandwidth_mbps: f64,
    batch: usize,
) -> Breakdown {
    evaluate(&sched.for_batch(batch.max(1)), params, bandwidth_mbps)
}

/// Batched evaluation against a time-varying trace starting at `t0`.
pub fn evaluate_on_trace_batched(
    sched: &Schedule,
    params: &SimParams,
    trace: &BandwidthTrace,
    t0: f64,
    batch: usize,
) -> Breakdown {
    evaluate_on_trace(&sched.for_batch(batch.max(1)), params, trace, t0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::shape::{TransformerShape, VqSetting};
    use crate::parallel::strategies::{Strategy, StrategyKind};

    fn shape() -> TransformerShape {
        TransformerShape::paper_encoder(1024)
    }

    #[test]
    fn static_equals_constant_trace() {
        let s = Strategy::new(StrategyKind::SequenceParallel, 4).schedule(&shape());
        let p = SimParams::paper_encoder();
        let a = evaluate(&s, &p, 50.0);
        let tr = BandwidthTrace::constant(50.0, 1e9);
        let b = evaluate_on_trace(&s, &p, &tr, 0.0);
        assert!((a.total() - b.total()).abs() < 1e-9);
        assert!((a.comm_s - b.comm_s).abs() < 1e-9);
    }

    #[test]
    fn baselines_comm_dominated_below_100mbps() {
        // paper Fig 3: comm is 58.6-93.5% of baseline latency under 100 Mbps
        let p = SimParams::paper_encoder();
        for mbps in [20.0, 50.0, 100.0] {
            for s in [
                Strategy::new(StrategyKind::BlockParallel { n_b: 1, sp_variant: false }, 4),
                Strategy::new(StrategyKind::BlockParallel { n_b: 1, sp_variant: true }, 4),
            ] {
                let bd = evaluate(&s.schedule(&shape()), &p, mbps);
                assert!(
                    bd.comm_fraction() > 0.45,
                    "{} @ {mbps}: {}",
                    s.name(),
                    bd.comm_fraction()
                );
            }
        }
    }

    #[test]
    fn astra_not_comm_dominated() {
        let p = SimParams::paper_encoder();
        let astra = Strategy::new(
            StrategyKind::Astra { vq: VqSetting::new(1, 1024) }, 4);
        let bd = evaluate(&astra.schedule(&shape()), &p, 20.0);
        assert!(bd.comm_fraction() < 0.3, "{}", bd.comm_fraction());
    }

    #[test]
    fn batched_amortizes_stage_latency() {
        let p = SimParams::paper_encoder();
        let s = Strategy::new(
            StrategyKind::Astra { vq: VqSetting::new(16, 1024) }, 4)
            .schedule(&shape());
        let b1 = evaluate_batched(&s, &p, 100.0, 1);
        let b8 = evaluate_batched(&s, &p, 100.0, 8);
        // batch-1 equals the unbatched evaluation
        let plain = evaluate(&s, &p, 100.0);
        assert!((b1.total() - plain.total()).abs() < 1e-12);
        // 8 requests cost less than 8 separate prefills (launches + sync
        // stages amortize) but more than one
        assert!(b8.total() < 8.0 * b1.total());
        assert!(b8.total() > b1.total());
        // trace and static variants agree on a constant trace
        let tr = BandwidthTrace::constant(100.0, 1e9);
        let b8t = evaluate_on_trace_batched(&s, &p, &tr, 0.0, 8);
        assert!((b8.total() - b8t.total()).abs() < 1e-9);
    }

    #[test]
    fn batch1_trace_evaluation_is_exactly_unbatched() {
        // the continuous-batching engine at batch 1 must price work
        // identically to the unbatched evaluator (the live-vs-model
        // differential harness relies on this identity)
        let p = SimParams::paper_encoder();
        let s = Strategy::new(StrategyKind::Astra { vq: VqSetting::new(16, 1024) }, 4)
            .schedule(&shape());
        let tr = BandwidthTrace::constant(42.0, 1e9);
        for t0 in [0.0, 3.7, 100.0] {
            let a = evaluate_on_trace(&s, &p, &tr, t0);
            let b = evaluate_on_trace_batched(&s, &p, &tr, t0, 1);
            assert_eq!(a.compute_s, b.compute_s);
            assert_eq!(a.comm_s, b.comm_s);
        }
    }

    #[test]
    fn fused_chunk_iteration_prices_like_its_schedule() {
        // the chunked-prefill scheduler evaluates fused iterations through
        // `evaluate_on_trace` (the chunk token count is explicit in the
        // schedule, so no batch scaling applies); on a constant trace that
        // must agree with the static evaluation, and a chunk-free fused
        // iteration must price exactly like the batched decode step the
        // unchunked scheduler uses — the bit-identity anchor
        let p = SimParams::paper_encoder();
        let s = Strategy::new(StrategyKind::Astra { vq: VqSetting::new(16, 1024) }, 4);
        let shape = shape();
        let tr = BandwidthTrace::constant(100.0, 1e9);
        let fused = s.fused_iteration_schedule(&shape, 128, 512, 8, 1024);
        let a = evaluate(&fused, &p, 100.0);
        let b = evaluate_on_trace(&fused, &p, &tr, 3.0);
        assert!((a.total() - b.total()).abs() < 1e-9);
        assert!((a.comm_s - b.comm_s).abs() < 1e-9);
        let nochunk = s.fused_iteration_schedule(&shape, 0, 0, 8, 1024);
        let step = s.decode_step_schedule(&shape, 1024);
        let x = evaluate_on_trace(&nochunk, &p, &tr, 3.0);
        let y = evaluate_on_trace_batched(&step, &p, &tr, 3.0, 8);
        assert_eq!(x.compute_s, y.compute_s);
        assert_eq!(x.comm_s, y.comm_s);
        // piggybacked decode makes the fused iteration dearer than the
        // bare chunk, but far cheaper than chunk + separate decode step
        let bare = evaluate(&s.prefill_chunk_schedule(&shape, 128, 512), &p, 100.0);
        assert!(a.total() > bare.total());
        assert!(a.total() < bare.total() + y.total());
    }

    #[test]
    fn accumulate_sums_componentwise() {
        let mut acc = Breakdown::default();
        acc.accumulate(&Breakdown { compute_s: 1.0, comm_s: 2.0 });
        acc.accumulate(&Breakdown { compute_s: 0.5, comm_s: 0.25 });
        assert!((acc.compute_s - 1.5).abs() < 1e-12);
        assert!((acc.comm_s - 2.25).abs() < 1e-12);
        assert!((acc.total() - 3.75).abs() < 1e-12);
    }

    #[test]
    fn trace_slowdown_under_low_bandwidth_slot() {
        let p = SimParams::paper_encoder();
        let s = Strategy::new(StrategyKind::SequenceParallel, 4).schedule(&shape());
        let hi = BandwidthTrace::constant(100.0, 1e9);
        let lo = BandwidthTrace::constant(10.0, 1e9);
        let t_hi = evaluate_on_trace(&s, &p, &hi, 0.0).total();
        let t_lo = evaluate_on_trace(&s, &p, &lo, 0.0).total();
        assert!(t_lo > 5.0 * t_hi);
    }
}
