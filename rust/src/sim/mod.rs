//! Discrete-event latency simulation.
//!
//! Two layers:
//!  * [`latency`] — evaluates a [`crate::parallel::Schedule`] against a
//!    device model and a (possibly time-varying) bandwidth, producing the
//!    compute/communication breakdown used by Figures 1, 3–5 and
//!    Tables 4/7.
//!  * [`engine`] — a general binary-heap event queue used by the serving
//!    simulator (request streams under dynamic bandwidth, Figure 6) and
//!    by failure-injection tests.
//!
//! Plus [`fault`]: seeded deterministic [`fault::FaultPlan`]s (replica
//! kills, link degradation, swap slowdown, arrival bursts) expressed on
//! the virtual clock, consumed by `server/chaos` and the cluster loop.

pub mod engine;
pub mod fault;
pub mod latency;

pub use engine::{Engine, Event};
pub use fault::{ArrivalBurst, FaultPlan, LinkWindow, ReplicaKill, SwapWindow};
pub use latency::{
    evaluate, evaluate_batched, evaluate_on_trace, evaluate_on_trace_batched, Breakdown, SimParams,
};
