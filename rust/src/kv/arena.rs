//! Arena-backed storage for sealed KV blocks.
//!
//! A sealed block's rows are exported once from the creating
//! [`crate::coordinator::decode::DecodeSession`] into an immutable
//! [`BlockRows`] arena entry; every later attach is an `Arc` clone
//! ([`BlockRef`]) instead of the old `export_rows`/`import_rows` row copy.
//! Because the entry is refcounted, dropping the creator session (or even
//! evicting the block from the arena) never invalidates sessions that
//! already attached it.
//!
//! ## Layout contract with [`crate::kv::pool`]
//!
//! The pool accounts blocks as `[lo, hi)` token ranges; the arena stores the
//! matching rows per layer in the same head-major order a session's cache
//! tensor uses, so reads are stride-compatible with the private cache:
//!
//! ```text
//! layers[li].0  (K) and .1 (V):  index = (head * (hi - lo) + (i - lo)) * d_head + j
//! ```
//!
//! i.e. exactly [`crate::coordinator::decode::DecodeSession::export_rows`]'s
//! flattening. Decode attention resolves rows `i < attached_hi` through the
//! attached blocks and everything later through the session's own tensor,
//! in ascending-`i` order either way, which is what keeps arena attach
//! bit-identical to row-copy import.

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{bail, Result};

/// Immutable rows of one sealed block: per-layer `(k, v)` in the head-major
/// flattening documented at module level.
#[derive(Debug)]
pub struct BlockRows {
    /// First token row covered (inclusive).
    pub lo: usize,
    /// One past the last token row covered.
    pub hi: usize,
    /// Per-layer `(k, v)` row data.
    pub layers: Vec<(Vec<f32>, Vec<f32>)>,
}

impl BlockRows {
    /// Validate the flattening against the model geometry.
    pub fn new(
        lo: usize,
        hi: usize,
        layers: Vec<(Vec<f32>, Vec<f32>)>,
        n_heads: usize,
        d_head: usize,
    ) -> Result<BlockRows> {
        if hi <= lo {
            bail!("block rows [{lo}, {hi}) are empty");
        }
        let want = n_heads * (hi - lo) * d_head;
        for (li, (k, v)) in layers.iter().enumerate() {
            if k.len() != want || v.len() != want {
                bail!(
                    "layer {li} block rows have {}/{} floats, expected {want}",
                    k.len(),
                    v.len()
                );
            }
        }
        Ok(BlockRows { lo, hi, layers })
    }

    /// Token rows covered.
    pub fn rows(&self) -> usize {
        self.hi - self.lo
    }

    /// K row slice for `(li, head, i)` (absolute token index).
    #[inline]
    pub fn k_row(&self, li: usize, head: usize, i: usize, d_head: usize) -> &[f32] {
        let off = (head * self.rows() + (i - self.lo)) * d_head;
        &self.layers[li].0[off..off + d_head]
    }

    /// V row slice for `(li, head, i)` (absolute token index).
    #[inline]
    pub fn v_row(&self, li: usize, head: usize, i: usize, d_head: usize) -> &[f32] {
        let off = (head * self.rows() + (i - self.lo)) * d_head;
        &self.layers[li].1[off..off + d_head]
    }
}

/// Shared handle to a sealed block's rows. Cloning is the whole attach.
pub type BlockRef = Arc<BlockRows>;

/// The arena: sealed blocks by pool block id, with byte accounting that
/// mirrors what [`crate::kv::pool::KvPool`] charged for each block.
#[derive(Debug, Default)]
pub struct KvArena {
    entries: BTreeMap<u64, (usize, BlockRef)>,
    bytes: usize,
}

impl KvArena {
    pub fn new() -> KvArena {
        KvArena::default()
    }

    /// Seal `rows` under `block`, accounted at `bytes` (the pool's modeled
    /// mixed-precision charge, not the f32 arena footprint).
    pub fn insert(&mut self, block: u64, bytes: usize, rows: BlockRows) -> BlockRef {
        let rf: BlockRef = Arc::new(rows);
        if let Some((old, _)) = self.entries.insert(block, (bytes, rf.clone())) {
            self.bytes -= old;
        }
        self.bytes += bytes;
        rf
    }

    /// Zero-copy attach: an `Arc` clone of the sealed rows.
    pub fn attach(&self, block: u64) -> Option<BlockRef> {
        self.entries.get(&block).map(|(_, rf)| rf.clone())
    }

    /// Drop the arena's own reference; returns the accounted bytes.
    /// Outstanding [`BlockRef`]s keep the rows alive.
    pub fn remove(&mut self, block: u64) -> Option<usize> {
        let (bytes, _) = self.entries.remove(&block)?;
        self.bytes -= bytes;
        Some(bytes)
    }

    /// Total accounted bytes of resident blocks.
    pub fn total_bytes(&self) -> usize {
        self.bytes
    }

    /// Number of resident blocks.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rows(lo: usize, hi: usize, layers: usize, hh: usize, dh: usize, fill: f32) -> BlockRows {
        let n = hh * (hi - lo) * dh;
        let layers = (0..layers).map(|_| (vec![fill; n], vec![-fill; n])).collect();
        BlockRows::new(lo, hi, layers, hh, dh).unwrap()
    }

    #[test]
    fn attach_is_refcounted_and_survives_removal() {
        let mut arena = KvArena::new();
        arena.insert(7, 100, rows(0, 4, 2, 2, 8, 1.5));
        let rf = arena.attach(7).unwrap();
        assert_eq!(arena.total_bytes(), 100);
        assert_eq!(arena.remove(7), Some(100));
        assert_eq!(arena.total_bytes(), 0);
        assert!(arena.attach(7).is_none());
        // the outstanding ref still reads the sealed rows
        assert_eq!(rf.k_row(1, 1, 3, 8)[0], 1.5);
        assert_eq!(rf.v_row(0, 0, 0, 8)[7], -1.5);
    }

    #[test]
    fn shape_validation_rejects_bad_flattenings() {
        assert!(BlockRows::new(2, 2, vec![], 2, 8).is_err());
        let bad = vec![(vec![0.0; 3], vec![0.0; 3])];
        assert!(BlockRows::new(0, 4, bad, 2, 8).is_err());
    }

    #[test]
    fn reinserting_a_block_id_replaces_its_accounting() {
        let mut arena = KvArena::new();
        arena.insert(1, 60, rows(0, 2, 1, 2, 4, 0.0));
        arena.insert(1, 80, rows(0, 2, 1, 2, 4, 0.0));
        assert_eq!(arena.total_bytes(), 80);
        assert_eq!(arena.len(), 1);
    }
}
