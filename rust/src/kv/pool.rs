//! Refcounted block pool over the serving scheduler's KV cap.
//!
//! The pool tracks three byte classes against one device cap:
//!
//! * **private** — per-slot bytes not covered by any shared block (the
//!   partial tail of a prompt, rows replayed but not yet block-aligned,
//!   and full-precision generated rows);
//! * **block** — ready shared blocks referenced by at least one slot
//!   (counted once however many slots attach);
//! * **cached** — ready blocks whose refcount dropped to zero. They stay
//!   resident (a later request sharing the prefix re-attaches for free)
//!   but are reclaimable on demand, LRU-first.
//!
//! A block's bytes are supplied by the caller as the Appendix-G prefix
//! difference `bytes([0, hi)) - bytes([0, lo))`, so `private + block +
//! cached` telescopes to exactly the bytes the flat per-slot accounting
//! would charge — block bookkeeping changes *what is shared*, never *how
//! much a token costs*. With sharing disabled no blocks exist and the pool
//! degenerates to the old `KvBudget` counters (same `fits`, same peak).
//!
//! Blocks are created **unready** (their rows still replaying in the
//! creator slot; bytes counted in the creator's private share) and marked
//! ready once the rows exist — only ready blocks are attachable, and an
//! unready block whose creator is evicted is dropped, never cached.
//!
//! The pool tracks *accounting* only; the rows themselves live in
//! [`crate::kv::arena::KvArena`] under the matching block id, laid out per
//! layer as `(head * block_tokens + (i - lo)) * d_head + j` — the same
//! flattening `DecodeSession::export_rows` produces. Live attach hands a
//! refcounted arena view straight to the session (no row copies); the
//! pool's lo/hi/bytes stay the single source of truth for what fits.

use std::collections::BTreeMap;

/// One shared KV block: `block_tokens` prompt rows at absolute positions
/// `[lo, hi)`, bytes priced by the Appendix-G prefix difference.
#[derive(Debug, Clone)]
pub struct Block {
    pub lo: usize,
    pub hi: usize,
    pub bytes: usize,
    pub refs: usize,
    /// rows replayed and registered with the backend; only ready blocks
    /// are attachable or cacheable
    pub ready: bool,
    /// logical tick of the last attach/detach — LRU reclaim order
    pub last_use: u64,
}

/// The pool: byte classes + the block slab. See the module docs.
#[derive(Debug, Default)]
pub struct KvPool {
    /// device cap in bytes (0 = unlimited, every `fits` succeeds)
    pub cap_bytes: usize,
    private_bytes: usize,
    block_bytes: usize,
    cached_bytes: usize,
    /// high-water mark of resident bytes (private + block + cached)
    pub peak_bytes: usize,
    blocks: BTreeMap<u64, Block>,
    next_id: u64,
    tick: u64,
}

impl KvPool {
    pub fn new(cap_bytes: usize) -> KvPool {
        KvPool { cap_bytes, ..KvPool::default() }
    }

    /// Bytes currently resident on the device (all three classes).
    pub fn resident_bytes(&self) -> usize {
        self.private_bytes + self.block_bytes + self.cached_bytes
    }

    /// Bytes that cannot be reclaimed without evicting a slot (private +
    /// referenced blocks) — the basis for admission and growth decisions,
    /// since cached blocks can always be dropped to make room.
    pub fn pinned_bytes(&self) -> usize {
        self.private_bytes + self.block_bytes
    }

    pub fn private_bytes(&self) -> usize {
        self.private_bytes
    }

    pub fn cached_bytes(&self) -> usize {
        self.cached_bytes
    }

    /// Would `bytes` more fit under the cap, assuming every cached block
    /// can be reclaimed first? With no blocks this is exactly the old
    /// `KvBudget::fits`.
    pub fn fits(&self, bytes: usize) -> bool {
        self.cap_bytes == 0 || self.pinned_bytes() + bytes <= self.cap_bytes
    }

    /// Does `bytes` more fit *right now*, without reclaiming anything?
    pub fn fits_resident(&self, bytes: usize) -> bool {
        self.cap_bytes == 0 || self.resident_bytes() + bytes <= self.cap_bytes
    }

    fn note_peak(&mut self) {
        self.peak_bytes = self.peak_bytes.max(self.resident_bytes());
    }

    pub fn acquire_private(&mut self, bytes: usize) {
        self.private_bytes += bytes;
        self.note_peak();
    }

    pub fn release_private(&mut self, bytes: usize) {
        self.private_bytes = self.private_bytes.saturating_sub(bytes);
    }

    /// Create an unready block (rows still replaying in the creator slot;
    /// its bytes remain in the creator's private share until
    /// [`Self::mark_ready`]). The creator holds the initial reference.
    pub fn create_block(&mut self, lo: usize, hi: usize, bytes: usize) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        self.tick += 1;
        self.blocks.insert(
            id,
            Block { lo, hi, bytes, refs: 1, ready: false, last_use: self.tick },
        );
        id
    }

    pub fn block(&self, id: u64) -> Option<&Block> {
        self.blocks.get(&id)
    }

    /// Is this block attachable (rows registered with the backend)?
    pub fn block_ready(&self, id: u64) -> bool {
        self.blocks.get(&id).map(|b| b.ready).unwrap_or(false)
    }

    /// The creator's rows for this block now exist: move its bytes from
    /// the creator's private share into the shared block class. The caller
    /// must shrink the creator slot's private tally by the same amount.
    pub fn mark_ready(&mut self, id: u64) -> usize {
        let b = self.blocks.get_mut(&id).expect("mark_ready: unknown block");
        assert!(!b.ready, "block {id} marked ready twice");
        b.ready = true;
        let bytes = b.bytes;
        self.private_bytes = self.private_bytes.saturating_sub(bytes);
        self.block_bytes += bytes;
        self.note_peak();
        bytes
    }

    /// Attach one more slot to a ready block.
    pub fn ref_block(&mut self, id: u64) {
        self.tick += 1;
        let tick = self.tick;
        let b = self.blocks.get_mut(&id).expect("ref_block: unknown block");
        assert!(b.ready, "attaching to unready block {id}");
        if b.refs == 0 {
            // resurrect from the cached class: bytes stay resident
            self.cached_bytes = self.cached_bytes.saturating_sub(b.bytes);
            self.block_bytes += b.bytes;
        }
        b.refs += 1;
        b.last_use = tick;
    }

    /// Detach a slot. A ready block at refcount 0 stays resident as
    /// *cached* (the "recently-freed" reuse window) until reclaimed.
    pub fn unref_block(&mut self, id: u64) {
        self.tick += 1;
        let tick = self.tick;
        let b = self.blocks.get_mut(&id).expect("unref_block: unknown block");
        assert!(b.refs > 0, "unref of unreferenced block {id}");
        b.refs -= 1;
        b.last_use = tick;
        if b.refs == 0 {
            self.block_bytes = self.block_bytes.saturating_sub(b.bytes);
            self.cached_bytes += b.bytes;
        }
    }

    /// Drop an unready block whose creator was evicted mid-prefill (its
    /// bytes were never moved out of the creator's private share, which
    /// the eviction releases separately).
    pub fn drop_unready(&mut self, id: u64) {
        let b = self.blocks.remove(&id).expect("drop_unready: unknown block");
        assert!(!b.ready && b.refs <= 1, "drop_unready on a shared/ready block {id}");
    }

    /// Reclaim a cached (refcount-0, ready) block chosen by the caller —
    /// typically from [`Self::lru_cached`] — removing its bytes from the
    /// device.
    pub fn drop_cached(&mut self, id: u64) -> usize {
        let b = self.blocks.remove(&id).expect("drop_cached: unknown block");
        assert!(b.ready && b.refs == 0, "drop_cached on a referenced block {id}");
        self.cached_bytes = self.cached_bytes.saturating_sub(b.bytes);
        b.bytes
    }

    /// The least-recently-used cached block (refcount 0, ready) — the next
    /// reclaim victim. Ties break on the smaller id, so reclaim order is
    /// deterministic.
    pub fn lru_cached(&self) -> Option<u64> {
        self.blocks
            .iter()
            .filter(|(_, b)| b.ready && b.refs == 0)
            .min_by_key(|(id, b)| (b.last_use, **id))
            .map(|(id, _)| *id)
    }

    /// Number of live block records (ready or not) — leak checks.
    pub fn block_count(&self) -> usize {
        self.blocks.len()
    }

    /// True when no slot holds any reference and no private bytes remain
    /// (cached blocks may still be resident).
    pub fn quiescent(&self) -> bool {
        self.private_bytes == 0 && self.blocks.values().all(|b| b.refs == 0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn private_only_pool_matches_old_kvbudget_arithmetic() {
        // with no blocks the pool is the old KvBudget: fits/peak identical
        let mut p = KvPool::new(1000);
        assert!(p.fits(1000));
        p.acquire_private(600);
        assert!(p.fits(400));
        assert!(!p.fits(401));
        p.acquire_private(300);
        assert_eq!(p.resident_bytes(), 900);
        assert_eq!(p.peak_bytes, 900);
        p.release_private(600);
        assert_eq!(p.resident_bytes(), 300);
        assert_eq!(p.peak_bytes, 900);
        // cap 0 disables the gate
        let q = KvPool::new(0);
        assert!(q.fits(usize::MAX / 2));
    }

    #[test]
    fn block_lifecycle_moves_bytes_between_classes() {
        let mut p = KvPool::new(0);
        // creator replays 100 bytes of rows, 60 of which form one block
        p.acquire_private(100);
        let b = p.create_block(0, 4, 60);
        assert!(!p.block_ready(b));
        assert_eq!(p.resident_bytes(), 100);
        p.mark_ready(b);
        assert_eq!(p.private_bytes(), 40);
        assert_eq!(p.pinned_bytes(), 100);
        assert_eq!(p.resident_bytes(), 100); // telescoping: nothing moved
        // a second slot attaches: shared bytes counted once
        p.ref_block(b);
        assert_eq!(p.resident_bytes(), 100);
        // both detach: block becomes cached, still resident
        p.unref_block(b);
        p.unref_block(b);
        assert_eq!(p.cached_bytes(), 60);
        assert_eq!(p.pinned_bytes(), 40);
        assert!(p.quiescent() || p.private_bytes() == 40);
        // re-attach resurrects it
        p.ref_block(b);
        assert_eq!(p.cached_bytes(), 0);
        p.unref_block(b);
        // reclaim drops the bytes
        assert_eq!(p.lru_cached(), Some(b));
        assert_eq!(p.drop_cached(b), 60);
        assert_eq!(p.resident_bytes(), 40);
        assert_eq!(p.block_count(), 0);
    }

    #[test]
    fn lru_prefers_oldest_cached_block() {
        let mut p = KvPool::new(0);
        let a = p.create_block(0, 4, 10);
        let b = p.create_block(4, 8, 10);
        p.mark_ready(a);
        p.mark_ready(b);
        p.unref_block(a);
        p.unref_block(b);
        // a was released first -> older last_use -> first victim
        assert_eq!(p.lru_cached(), Some(a));
        // touching a (re-attach + detach) makes b the victim
        p.ref_block(a);
        p.unref_block(a);
        assert_eq!(p.lru_cached(), Some(b));
    }

    #[test]
    fn unready_blocks_are_dropped_not_cached() {
        let mut p = KvPool::new(0);
        p.acquire_private(50);
        let b = p.create_block(0, 4, 30);
        // creator evicted mid-prefill: rows never registered
        p.drop_unready(b);
        p.release_private(50);
        assert_eq!(p.resident_bytes(), 0);
        assert_eq!(p.block_count(), 0);
        assert!(p.lru_cached().is_none());
    }
}
