//! Radix tree over token-id prompt prefixes at KV-block granularity.
//!
//! Each edge is one *full block* of `block_tokens` token ids; a node
//! carries the pool block holding that range's KV rows. A prompt's
//! shareable prefix is the deepest chain of **ready** blocks matching its
//! leading token ids — partial tail blocks are never shared, so attachment
//! is always block-aligned and the uncovered suffix replays through the
//! ordinary chunked-prefill path.
//!
//! Reference counting lives in [`super::pool::KvPool`]; the tree only maps
//! token content to block ids. The invariant that makes subtree reclaim
//! safe: every slot that attaches references *all* blocks on its covered
//! path, so a refcount-0 block can never have a referenced descendant —
//! reclaiming a cached block may therefore drop its whole subtree.

use std::collections::BTreeMap;

#[derive(Debug)]
struct Node {
    parent: usize,
    /// the edge key from the parent (this node's block of token ids)
    key: Vec<usize>,
    block: u64,
    children: BTreeMap<Vec<usize>, usize>,
}

/// The tree. Node 0 is the synthetic root (no block).
#[derive(Debug)]
pub struct RadixTree {
    pub block_tokens: usize,
    nodes: Vec<Option<Node>>,
    by_block: BTreeMap<u64, usize>,
    free: Vec<usize>,
}

impl RadixTree {
    pub fn new(block_tokens: usize) -> RadixTree {
        let root = Node {
            parent: usize::MAX,
            key: Vec::new(),
            block: u64::MAX,
            children: BTreeMap::new(),
        };
        RadixTree {
            block_tokens: block_tokens.max(1),
            nodes: vec![Some(root)],
            by_block: BTreeMap::new(),
            free: Vec::new(),
        }
    }

    fn node(&self, i: usize) -> &Node {
        self.nodes[i].as_ref().expect("dangling node index")
    }

    /// Longest chain of ready blocks matching `prompt`'s leading full
    /// blocks. Returns the block ids in root-to-leaf order plus whether
    /// the walk ended at a *missing* child (true: the caller may extend
    /// the path with newly created blocks) or at an existing-but-unready
    /// child (false: another slot is still replaying those rows — neither
    /// attach nor create past it).
    pub fn lookup(&self, prompt: &[usize], is_ready: &dyn Fn(u64) -> bool) -> (Vec<u64>, bool) {
        let b = self.block_tokens;
        let mut out = Vec::new();
        let mut ni = 0usize;
        for k in 0..prompt.len() / b {
            let key = &prompt[k * b..(k + 1) * b];
            match self.node(ni).children.get(key) {
                Some(&ci) => {
                    let block = self.node(ci).block;
                    if is_ready(block) {
                        out.push(block);
                        ni = ci;
                    } else {
                        return (out, false);
                    }
                }
                None => return (out, true),
            }
        }
        (out, true)
    }

    /// Number of leading `prompt` tokens covered by ready blocks — the
    /// same walk as [`Self::lookup`] without materializing the block
    /// list. This is the coverage query admission-ordering policies rank
    /// candidates by (`server/policy`), called once per queued request
    /// per scheduling decision, so it must stay allocation-free.
    pub fn covered_tokens(&self, prompt: &[usize], is_ready: &dyn Fn(u64) -> bool) -> usize {
        let b = self.block_tokens;
        let mut ni = 0usize;
        let mut covered = 0usize;
        for k in 0..prompt.len() / b {
            let key = &prompt[k * b..(k + 1) * b];
            match self.node(ni).children.get(key) {
                Some(&ci) if is_ready(self.node(ci).block) => {
                    covered += b;
                    ni = ci;
                }
                _ => return covered,
            }
        }
        covered
    }

    /// Extend the path for `prompt` past its first `from_blocks` blocks
    /// (which must already exist — the chain [`Self::lookup`] just
    /// returned), creating one block per remaining full block via
    /// `create(lo, hi)`. Stops early if it meets an existing child (a
    /// concurrent creator owns that range). Returns the created ids in
    /// order.
    pub fn extend(
        &mut self,
        prompt: &[usize],
        from_blocks: usize,
        create: &mut dyn FnMut(usize, usize) -> u64,
    ) -> Vec<u64> {
        let b = self.block_tokens;
        let mut ni = 0usize;
        for k in 0..from_blocks {
            let key = prompt[k * b..(k + 1) * b].to_vec();
            ni = *self
                .node(ni)
                .children
                .get(&key)
                .expect("extend: covered path vanished between lookup and extend");
        }
        let mut created = Vec::new();
        for k in from_blocks..prompt.len() / b {
            let key = prompt[k * b..(k + 1) * b].to_vec();
            if self.node(ni).children.contains_key(&key) {
                break; // someone else is already replaying this range
            }
            let block = create(k * b, (k + 1) * b);
            let child = Node { parent: ni, key: key.clone(), block, children: BTreeMap::new() };
            let ci = match self.free.pop() {
                Some(slot) => {
                    self.nodes[slot] = Some(child);
                    slot
                }
                None => {
                    self.nodes.push(Some(child));
                    self.nodes.len() - 1
                }
            };
            self.nodes[ni].as_mut().unwrap().children.insert(key, ci);
            self.by_block.insert(block, ci);
            created.push(block);
            ni = ci;
        }
        created
    }

    /// Remove the node carrying `block` and its whole subtree, returning
    /// every removed block id (root-first). Safe to call only when no
    /// removed block is referenced — guaranteed by the attach-whole-path
    /// invariant whenever the root of the removal is refcount-0.
    pub fn remove_subtree(&mut self, block: u64) -> Vec<u64> {
        let Some(&start) = self.by_block.get(&block) else {
            return Vec::new();
        };
        // detach from the parent
        let (parent, key) = {
            let n = self.node(start);
            (n.parent, n.key.clone())
        };
        if parent != usize::MAX {
            self.nodes[parent].as_mut().unwrap().children.remove(&key);
        }
        // DFS-collect the subtree
        let mut out = Vec::new();
        let mut stack = vec![start];
        while let Some(i) = stack.pop() {
            let n = self.nodes[i].take().expect("subtree node already freed");
            stack.extend(n.children.values().copied());
            self.by_block.remove(&n.block);
            out.push(n.block);
            self.free.push(i);
        }
        out
    }

    /// Number of blocks currently indexed (leak checks).
    pub fn block_count(&self) -> usize {
        self.by_block.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn always(_: u64) -> bool {
        true
    }

    #[test]
    fn lookup_matches_block_aligned_prefixes_only() {
        let mut t = RadixTree::new(4);
        let prompt: Vec<usize> = (0..10).collect(); // 2 full blocks + tail 2
        let mut next = 0u64;
        let created = t.extend(&prompt, 0, &mut |_, _| {
            next += 1;
            next
        });
        assert_eq!(created, vec![1, 2]); // the 2-token tail makes no block
        assert_eq!(t.block_count(), 2);
        // identical prompt: full coverage
        let (hit, ext) = t.lookup(&prompt, &always);
        assert_eq!(hit, vec![1, 2]);
        assert!(ext);
        // shares only the first block
        let other: Vec<usize> = (0..4).chain(100..106).collect();
        let (hit, ext) = t.lookup(&other, &always);
        assert_eq!(hit, vec![1]);
        assert!(ext, "missing child leaves the path extendable");
        // shorter than a block: nothing to share
        let (hit, _) = t.lookup(&prompt[..3], &always);
        assert!(hit.is_empty());
    }

    #[test]
    fn covered_tokens_agrees_with_lookup() {
        let mut t = RadixTree::new(4);
        let prompt: Vec<usize> = (0..10).collect();
        let mut next = 0u64;
        t.extend(&prompt, 0, &mut |_, _| {
            next += 1;
            next
        });
        for probe in [
            prompt.clone(),
            prompt[..3].to_vec(),
            (0..4).chain(100..106).collect::<Vec<usize>>(),
            (50..60).collect::<Vec<usize>>(),
        ] {
            let (hit, _) = t.lookup(&probe, &always);
            assert_eq!(t.covered_tokens(&probe, &always), hit.len() * 4, "{probe:?}");
        }
        // readiness gates coverage exactly like lookup
        let first_only = |b: u64| b == 1;
        let (hit, _) = t.lookup(&prompt, &first_only);
        assert_eq!(t.covered_tokens(&prompt, &first_only), hit.len() * 4);
        assert_eq!(t.covered_tokens(&prompt, &first_only), 4);
    }

    #[test]
    fn unready_blocks_stop_both_attach_and_extend() {
        let mut t = RadixTree::new(2);
        let prompt = vec![7usize, 8, 9, 10];
        let mut next = 10u64;
        t.extend(&prompt, 0, &mut |_, _| {
            next += 1;
            next
        });
        // first block ready, second still replaying
        let ready = |b: u64| b == 11;
        let (hit, ext) = t.lookup(&prompt, &ready);
        assert_eq!(hit, vec![11]);
        assert!(!ext, "existing unready child must not be extendable");
        // extend from the covered depth stops at the existing child
        let created = t.extend(&prompt, 1, &mut |_, _| unreachable!("must not create"));
        assert!(created.is_empty());
    }

    #[test]
    fn remove_subtree_cascades_and_frees_slots() {
        let mut t = RadixTree::new(2);
        let a = vec![1usize, 2, 3, 4, 5, 6];
        let b = vec![1usize, 2, 3, 4, 9, 9];
        let mut next = 0u64;
        let mut mk = |_: usize, _: usize| {
            next += 1;
            next
        };
        t.extend(&a, 0, &mut mk); // blocks 1,2,3
        let (hit, _) = t.lookup(&b, &always);
        t.extend(&b, hit.len(), &mut mk); // block 4 under block 2
        assert_eq!(t.block_count(), 4);
        // removing block 2 takes its two children (3 and 4) with it
        let mut removed = t.remove_subtree(2);
        removed.sort();
        assert_eq!(removed, vec![2, 3, 4]);
        assert_eq!(t.block_count(), 1);
        // block 1 still matches; the removed range is re-creatable
        let (hit, ext) = t.lookup(&a, &always);
        assert_eq!(hit, vec![1]);
        assert!(ext);
        let created = t.extend(&a, 1, &mut mk);
        assert_eq!(created.len(), 2);
        assert_eq!(t.block_count(), 3);
        // removing an unknown block is a no-op
        assert!(t.remove_subtree(999).is_empty());
    }
}
