//! Bandwidth-priced swap preemption policy.
//!
//! When KV pressure forces a slot out, the scheduler has two ways to get
//! its memory back:
//!
//! * **recompute** (the pre-swap behavior): drop the cache, requeue the
//!   request, and on readmission re-prefill the prompt and regenerate
//!   every token produced so far — pure compute, no transfer;
//! * **swap**: move the slot's cache to a host-memory tier over a
//!   constrained link and move it back on readmission, preserving decode
//!   progress — pure transfer, no compute.
//!
//! The policy prices both and picks the cheaper, per eviction: the swap
//! side is two transfers of the slot's current occupancy over a link
//! modeled exactly like [`crate::comm::link::SimLink::transfer_time`]
//! (propagation latency + bytes over bandwidth — ASTRA's whole premise is
//! that this link is the scarce resource, so it is priced, not assumed
//! free); the recompute side is supplied by the caller from the cost
//! model (prompt prefill + one decode step per token already generated).
//! Both inputs are deterministic functions of scheduler state, so the
//! decision stream stays identical between the cost-model and live
//! backends.
//!
//! The host tier does double duty as a *checkpoint* tier under chaos
//! (`CbConfig::checkpoint_every` / `server/chaos`): every K decode steps a
//! slot's full occupancy is copied out over this same priced link, and an
//! unplanned replica kill restores the slot on a survivor from the latest
//! copy instead of replaying its whole prompt. Fault plans can also
//! degrade the tier itself — [`SwapPolicy::slowed`] scales bandwidth down
//! and latency up for the duration of a slowdown window, with factor 1.0
//! the bit-exact identity.

/// Host-link description for swap transfers.
#[derive(Debug, Clone, Copy)]
pub struct SwapPolicy {
    /// host-link bandwidth in Mbps; <= 0 disables swapping entirely
    pub bandwidth_mbps: f64,
    /// one-way propagation + protocol latency per transfer (seconds)
    pub latency_s: f64,
}

impl SwapPolicy {
    pub fn new(bandwidth_mbps: f64, latency_s: f64) -> SwapPolicy {
        SwapPolicy { bandwidth_mbps, latency_s }
    }

    pub fn enabled(&self) -> bool {
        self.bandwidth_mbps > 0.0
    }

    /// One transfer of `bytes` over the host link (the same formula as
    /// `SimLink::transfer_time` on a constant trace).
    pub fn transfer_s(&self, bytes: usize) -> f64 {
        if !self.enabled() {
            return f64::INFINITY;
        }
        self.latency_s + bytes as f64 * 8.0 / (self.bandwidth_mbps * 1e6)
    }

    /// Round trip: swap-out now plus swap-in at readmission.
    pub fn round_trip_s(&self, bytes: usize) -> f64 {
        2.0 * self.transfer_s(bytes)
    }

    /// The decision rule: swap iff moving `bytes` out and back is cheaper
    /// than the modeled `recompute_s` (re-prefill + regenerate).
    pub fn swap_beats_recompute(&self, bytes: usize, recompute_s: f64) -> bool {
        self.enabled() && self.round_trip_s(bytes) < recompute_s
    }

    /// The tier under a fault-plan slowdown window: bandwidth divided and
    /// latency multiplied by `factor`. A factor of 1.0 returns the policy
    /// bit for bit, so an empty plan cannot perturb any priced decision.
    pub fn slowed(&self, factor: f64) -> SwapPolicy {
        if factor == 1.0 {
            return *self;
        }
        SwapPolicy { bandwidth_mbps: self.bandwidth_mbps / factor, latency_s: self.latency_s * factor }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_time_is_latency_plus_bits_over_bandwidth() {
        let p = SwapPolicy::new(8.0, 0.0005); // 8 Mbps = 1 MB/s
        let t = p.transfer_s(1_000_000);
        assert!((t - 1.0005).abs() < 1e-9, "{t}");
        assert!((p.round_trip_s(1_000_000) - 2.001).abs() < 1e-9);
    }

    #[test]
    fn disabled_policy_never_swaps() {
        let p = SwapPolicy::new(0.0, 0.0005);
        assert!(!p.enabled());
        assert!(!p.swap_beats_recompute(1, f64::INFINITY));
        assert!(p.transfer_s(100).is_infinite());
    }

    #[test]
    fn decision_follows_the_bandwidth() {
        // 1 MiB cache, recompute modeled at 50 ms: a fast host link swaps,
        // a slow one recomputes
        let bytes = 1 << 20;
        let fast = SwapPolicy::new(1000.0, 0.0005); // ~8.4 ms one way
        let slow = SwapPolicy::new(10.0, 0.0005); // ~839 ms one way
        assert!(fast.swap_beats_recompute(bytes, 0.050));
        assert!(!slow.swap_beats_recompute(bytes, 0.050));
        // and a trivial recompute is never worth a transfer
        assert!(!fast.swap_beats_recompute(bytes, 1e-6));
    }

    #[test]
    fn slowdown_identity_and_scaling() {
        let p = SwapPolicy::new(8.0, 0.0005);
        let same = p.slowed(1.0);
        assert_eq!(same.bandwidth_mbps.to_bits(), p.bandwidth_mbps.to_bits());
        assert_eq!(same.latency_s.to_bits(), p.latency_s.to_bits());
        let slow = p.slowed(4.0);
        assert!((slow.bandwidth_mbps - 2.0).abs() < 1e-12);
        assert!((slow.latency_s - 0.002).abs() < 1e-12);
        // a 4x slowdown makes the same transfer ~4x slower (latency term included)
        assert!(slow.transfer_s(1_000_000) > 3.9 * p.transfer_s(1_000_000));
    }
}
