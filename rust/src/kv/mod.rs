//! Block-based KV memory management for the serving scheduler.
//!
//! Three pieces, composed by [`crate::server::scheduler::CbEngine`]:
//!
//! * [`pool`] — a refcounted pool of fixed-token-count KV *blocks* plus
//!   per-slot private bytes. Block bytes are defined as prefix differences
//!   of the Appendix-G accounting function, so summing a slot's blocks and
//!   private remainder telescopes to exactly the bytes the old `KvBudget`
//!   charged — with sharing disabled the pool IS the old byte arithmetic,
//!   which is how every flag-off path reproduces the pre-pool event
//!   streams bit for bit.
//! * [`prefix`] — a radix tree over token-id prompt prefixes at block
//!   granularity. A request whose prompt shares a block-aligned prefix
//!   with a resident or recently-freed cache attaches to those blocks
//!   (refcount++) and only replays the uncovered suffix; completed slots
//!   leave their blocks behind at refcount 0 ("recently freed"), evicted
//!   lazily under capacity pressure, LRU by subtree.
//! * [`swap`] — bandwidth-priced swap preemption: when KV pressure evicts
//!   a decoding slot, the policy compares the modeled recompute time
//!   (re-prefill the prompt + regenerate the tokens produced so far)
//!   against moving the cache over a host link at a configured bandwidth
//!   ([`crate::comm::link`]-style pricing: latency + bytes/bandwidth), and
//!   swaps instead of dropping when the transfer is cheaper.
//!
//! Shared-prefix *content* correctness lives in
//! [`crate::coordinator::decode::DecodeSession`]: in positional-locality
//! mode the mixed-precision row selection depends only on a token's
//! absolute position (not the prompt's total length), so a block's K/V
//! rows are a pure function of the token-id prefix and can be shared
//! between sessions bit for bit. The *storage* for shared blocks is
//! [`arena`]: sealed rows are exported once into a refcounted
//! [`arena::BlockRows`] entry and every attach is a zero-copy
//! [`arena::BlockRef`] clone.

pub mod arena;
pub mod pool;
pub mod prefix;
pub mod swap;

pub use arena::{BlockRef, BlockRows, KvArena};
pub use pool::KvPool;
pub use prefix::RadixTree;
pub use swap::SwapPolicy;
