//! Deterministic PRNG (xoshiro256++) + distributions.
//!
//! Used by the simulator (packet loss, traces), the k-means init, property
//! tests, and workload generators. Seeded explicitly everywhere so every
//! experiment in EXPERIMENTS.md is reproducible bit-for-bit.

/// xoshiro256++ with splitmix64 seeding.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // splitmix64 expansion of the seed
        let mut x = seed;
        let mut next = || {
            x = x.wrapping_add(0x9e3779b97f4a7c15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    /// Derive an independent stream (for per-device / per-link rngs).
    pub fn fork(&mut self, salt: u64) -> Rng {
        Rng::new(self.next_u64() ^ salt.wrapping_mul(0x9e3779b97f4a7c15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        // Lemire-style rejection-free enough for our n << 2^64
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Bernoulli trial.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal f32 with mean/std.
    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal() as f32
    }

    /// Exponential with the given rate (for Poisson arrivals).
    pub fn exp(&mut self, rate: f64) -> f64 {
        -self.f64().max(1e-300).ln() / rate
    }

    /// Fill a slice with standard-normal f32s.
    pub fn fill_normal(&mut self, out: &mut [f32]) {
        for v in out.iter_mut() {
            *v = self.normal() as f32;
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample k distinct indices from [0, n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_range() {
        let mut r = Rng::new(1);
        let mut mean = 0.0;
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
            mean += v;
        }
        mean /= 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(2);
        let n = 20_000;
        let (mut m, mut v) = (0.0, 0.0);
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        for &x in &xs {
            m += x;
        }
        m /= n as f64;
        for &x in &xs {
            v += (x - m) * (x - m);
        }
        v /= n as f64;
        assert!(m.abs() < 0.05, "mean {m}");
        assert!((v - 1.0).abs() < 0.1, "var {v}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_differ() {
        let mut a = Rng::new(7);
        let mut b = a.fork(1);
        let mut c = Rng::new(7).fork(2);
        assert_ne!(b.next_u64(), c.next_u64());
    }
}
