//! Tiny CLI argument parser (`--flag`, `--key value`, positionals).

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

/// Parsed command line: positionals + `--key value` options + `--flag`s.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (without argv[0]).
    /// `known_flags` lists options that take no value.
    pub fn parse<I: IntoIterator<Item = String>>(
        argv: I,
        known_flags: &[&'static str],
    ) -> Result<Args> {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if known_flags.contains(&name) {
                    out.flags.push(name.to_string());
                } else {
                    let v = it
                        .next()
                        .ok_or_else(|| anyhow!("option --{name} expects a value"))?;
                    out.options.insert(name.to_string(), v);
                }
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    pub fn from_env(known_flags: &[&'static str]) -> Result<Args> {
        Self::parse(std::env::args().skip(1), known_flags)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{name}: bad integer `{v}`")),
        }
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{name}: bad number `{v}`")),
        }
    }

    /// Comma-separated list of numbers, e.g. `--bw 10,20,50`.
    pub fn f64_list_or(&self, name: &str, default: &[f64]) -> Result<Vec<f64>> {
        match self.get(name) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|p| p.trim().parse().map_err(|_| anyhow!("--{name}: bad number `{p}`")))
                .collect(),
        }
    }

    pub fn usize_list_or(&self, name: &str, default: &[usize]) -> Result<Vec<usize>> {
        match self.get(name) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|p| p.trim().parse().map_err(|_| anyhow!("--{name}: bad integer `{p}`")))
                .collect(),
        }
    }

    /// First positional or error.
    pub fn command(&self) -> Result<&str> {
        self.positional
            .first()
            .map(|s| s.as_str())
            .ok_or_else(|| bail_usage())
    }
}

fn bail_usage() -> anyhow::Error {
    anyhow!("missing subcommand (try `--help`)")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(v: &[&str]) -> Args {
        Args::parse(v.iter().map(|s| s.to_string()), &["verbose", "fast"]).unwrap()
    }

    #[test]
    fn positional_and_options() {
        let a = parse(&["run", "--devices", "4", "--bw=20.5", "--verbose", "extra"]);
        assert_eq!(a.positional, vec!["run", "extra"]);
        assert_eq!(a.usize_or("devices", 1).unwrap(), 4);
        assert_eq!(a.f64_or("bw", 0.0).unwrap(), 20.5);
        assert!(a.flag("verbose"));
        assert!(!a.flag("fast"));
    }

    #[test]
    fn lists() {
        let a = parse(&["x", "--bw", "10,20,50"]);
        assert_eq!(a.f64_list_or("bw", &[]).unwrap(), vec![10.0, 20.0, 50.0]);
        assert_eq!(a.usize_list_or("n", &[2, 4]).unwrap(), vec![2, 4]);
    }

    #[test]
    fn missing_value_is_error() {
        assert!(Args::parse(vec!["--devices".to_string()], &[]).is_err());
    }
}
