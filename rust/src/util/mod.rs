//! Dependency-light utilities: JSON, PRNG, CLI parsing, bench harness.
//!
//! The build image has no network access and only the `xla` crate's
//! transitive dependencies vendored, so the usual suspects (serde, clap,
//! rand, criterion) are implemented here at the size this project needs.

pub mod bench;
pub mod cli;
pub mod json;
pub mod rng;
pub mod stats;
