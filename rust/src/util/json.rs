//! Minimal JSON parser/emitter (enough for manifest.json + config files).
//!
//! Supports the full JSON grammar except `\u` surrogate pairs are passed
//! through unvalidated. Numbers are kept as f64; integer accessors check
//! round-trip exactness.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Context, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing characters at byte {}", p.i);
        }
        Ok(v)
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => Err(anyhow!("expected object, got {self:?}")),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            _ => Err(anyhow!("expected array, got {self:?}")),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => Err(anyhow!("expected string, got {self:?}")),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => Err(anyhow!("expected number, got {self:?}")),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        let u = n as usize;
        if u as f64 != n {
            bail!("expected non-negative integer, got {n}");
        }
        Ok(u)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => Err(anyhow!("expected bool, got {self:?}")),
        }
    }

    /// Object field access with a useful error message.
    pub fn get(&self, key: &str) -> Result<&Json> {
        self.as_obj()?
            .get(key)
            .with_context(|| format!("missing field `{key}`"))
    }

    /// Optional object field.
    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Serialize (compact).
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience constructors for building JSON output.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn arr(items: Vec<Json>) -> Json {
    Json::Arr(items)
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let txt = std::str::from_utf8(&self.b[start..self.i])?;
        let n: f64 = txt
            .parse()
            .with_context(|| format!("bad number `{txt}` at byte {start}"))?;
        Ok(Json::Num(n))
    }

    fn string(&mut self) -> Result<String> {
        if self.peek()? != b'"' {
            bail!("expected string at byte {}", self.i);
        }
        self.i += 1;
        let mut out = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape \\{} at byte {}", e as char, self.i),
                    }
                }
                c => {
                    // copy raw UTF-8 bytes through
                    let start = self.i - 1;
                    let width = utf8_width(c);
                    self.i = start + width;
                    out.push_str(std::str::from_utf8(&self.b[start..self.i])?);
                }
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.i += 1; // {
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            if self.peek()? != b':' {
                bail!("expected `:` at byte {}", self.i);
            }
            self.i += 1;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected `,` or `}}`, got `{}` at byte {}", c as char, self.i),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.i += 1; // [
        let mut a = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                c => bail!("expected `,` or `]`, got `{}` at byte {}", c as char, self.i),
            }
        }
    }
}

fn utf8_width(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str().unwrap(),
            "x"
        );
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"x":[1,2.5,"s\\\"q"],"y":{"z":false}}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse("\"héllo → ∞\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo → ∞");
        let v = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "Aé");
    }

    #[test]
    fn errors() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("“smart”").is_err());
    }

    #[test]
    fn usize_exactness() {
        assert_eq!(Json::parse("42").unwrap().as_usize().unwrap(), 42);
        assert!(Json::parse("42.5").unwrap().as_usize().is_err());
    }
}
