//! Latency statistics: online summaries and percentile estimation.

/// Collects samples; computes mean / percentiles / throughput summaries.
#[derive(Debug, Default, Clone)]
pub struct Summary {
    samples: Vec<f64>,
    sorted: bool,
}

impl Summary {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, v: f64) {
        self.samples.push(v);
        self.sorted = false;
    }

    /// Fold another summary's samples into this one — fleet rollups merge
    /// raw samples so percentiles come from the union, not from averaging
    /// per-replica percentiles.
    pub fn merge(&mut self, other: &Summary) {
        self.samples.extend_from_slice(&other.samples);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.samples.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    pub fn std(&self) -> f64 {
        let m = self.mean();
        if self.samples.len() < 2 {
            return 0.0;
        }
        (self.samples.iter().map(|x| (x - m) * (x - m)).sum::<f64>()
            / (self.samples.len() - 1) as f64)
            .sqrt()
    }

    /// Nearest-rank percentile, p in [0, 100].
    pub fn percentile(&mut self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        if !self.sorted {
            self.samples
                .sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
            self.sorted = true;
        }
        let rank = ((p / 100.0) * (self.samples.len() as f64 - 1.0)).round() as usize;
        self.samples[rank.min(self.samples.len() - 1)]
    }

    pub fn p50(&mut self) -> f64 {
        self.percentile(50.0)
    }

    pub fn p95(&mut self) -> f64 {
        self.percentile(95.0)
    }

    pub fn p99(&mut self) -> f64 {
        self.percentile(99.0)
    }
}

/// Windowed throughput counter (events per window) — Fig 6 style bars.
#[derive(Debug, Clone)]
pub struct WindowedCounter {
    window: f64,
    counts: Vec<usize>,
}

impl WindowedCounter {
    pub fn new(window_s: f64) -> Self {
        Self { window: window_s, counts: Vec::new() }
    }

    /// Record an event at absolute time t (seconds).
    pub fn record(&mut self, t: f64) {
        let idx = (t / self.window).floor() as usize;
        if idx >= self.counts.len() {
            self.counts.resize(idx + 1, 0);
        }
        self.counts[idx] += 1;
    }

    pub fn bars(&self) -> &[usize] {
        &self.counts
    }

    /// Bars covering the full `[0, horizon)` span: zero-padded past the last
    /// event so an idle tail shows up as empty windows instead of being
    /// silently truncated (the `bars()` behaviour).
    pub fn bars_until(&self, horizon_s: f64) -> Vec<usize> {
        let n = (horizon_s / self.window).ceil().max(0.0) as usize;
        let mut out = self.counts.clone();
        if out.len() < n {
            out.resize(n, 0);
        }
        out
    }

    pub fn total(&self) -> usize {
        self.counts.iter().sum()
    }

    /// Events/sec over the span that saw events (up to the last non-empty
    /// window). NOTE: an idle tail after the last event is NOT counted —
    /// use [`rate_until`](Self::rate_until) with an explicit horizon for
    /// unbiased serve-throughput numbers.
    pub fn rate(&self) -> f64 {
        if self.counts.is_empty() {
            return 0.0;
        }
        self.total() as f64 / (self.counts.len() as f64 * self.window)
    }

    /// Events/sec over an explicit `[0, horizon)` span.
    pub fn rate_until(&self, horizon_s: f64) -> f64 {
        if horizon_s <= 0.0 {
            return 0.0;
        }
        self.total() as f64 / horizon_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let mut s = Summary::new();
        for v in [1.0, 2.0, 3.0, 4.0, 5.0] {
            s.add(v);
        }
        assert_eq!(s.mean(), 3.0);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 5.0);
        assert_eq!(s.p50(), 3.0);
        assert!((s.std() - 1.5811).abs() < 1e-3);
    }

    #[test]
    fn percentiles_on_uniform() {
        let mut s = Summary::new();
        for i in 0..1000 {
            s.add(i as f64);
        }
        assert!((s.percentile(95.0) - 949.0).abs() <= 1.0);
        assert!((s.p99() - 989.0).abs() <= 1.0);
    }

    #[test]
    fn windowed_counter() {
        let mut w = WindowedCounter::new(10.0);
        for t in [0.0, 1.0, 9.9, 10.0, 25.0] {
            w.record(t);
        }
        assert_eq!(w.bars(), &[3, 1, 1]);
        assert_eq!(w.total(), 5);
        assert!((w.rate() - 5.0 / 30.0).abs() < 1e-9);
    }

    #[test]
    fn windowed_counter_horizon() {
        let mut w = WindowedCounter::new(10.0);
        for t in [0.0, 1.0, 25.0] {
            w.record(t);
        }
        // bars() truncates at the last event; bars_until pads the idle tail
        assert_eq!(w.bars(), &[2, 0, 1]);
        assert_eq!(w.bars_until(60.0), vec![2, 0, 1, 0, 0, 0]);
        // and never shrinks below recorded events
        assert_eq!(w.bars_until(5.0), vec![2, 0, 1]);
        // rate() is inflated by ignoring the idle tail; rate_until is not
        assert!((w.rate() - 3.0 / 30.0).abs() < 1e-12);
        assert!((w.rate_until(60.0) - 3.0 / 60.0).abs() < 1e-12);
        assert_eq!(w.rate_until(0.0), 0.0);
    }

    #[test]
    fn summary_merge_unions_samples() {
        let mut a = Summary::new();
        let mut b = Summary::new();
        for v in [1.0, 2.0] {
            a.add(v);
        }
        for v in [10.0, 20.0] {
            b.add(v);
        }
        a.merge(&b);
        assert_eq!(a.len(), 4);
        assert_eq!(a.max(), 20.0);
        // percentiles come from the union of samples
        assert_eq!(a.p50(), 10.0);
        a.merge(&Summary::new());
        assert_eq!(a.len(), 4);
    }

    #[test]
    fn empty_summary_is_nan() {
        let mut s = Summary::new();
        assert!(s.mean().is_nan());
        assert!(s.p50().is_nan());
    }
}
