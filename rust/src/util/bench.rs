//! Micro-benchmark harness (criterion is not vendored in this image).
//!
//! Usage in a `harness = false` bench target:
//! ```no_run
//! use astra::util::bench::Bench;
//! let mut b = Bench::new("vq");
//! b.run("encode_1024", || { /* work */ });
//! b.finish();
//! ```
//! Each case is warmed up, then timed for a target wall budget; reports
//! mean / p50 / p95 per iteration and iterations/sec.
//!
//! # Deterministic bench metrics + the CI regression gate
//!
//! Wall-clock numbers are useless as a CI gate (shared runners jitter by
//! 2x), so the serving benches also expose a `--json` mode that emits
//! *modeled* metrics — virtual-clock p50/p95/TTFT/throughput on
//! fixed-seed traces, bit-reproducible on any machine — via
//! [`MetricSet`]. `astra bench-gate` ([`gate_cli`]) compares such a file
//! against a checked-in baseline and fails when any metric regresses by
//! more than the tolerance (latencies up, throughputs down; count and
//! checksum pins must match exactly). A baseline
//! containing `"bootstrap": true` accepts the current numbers (first run
//! pins them: download the workflow artifact and commit it).

use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use super::json::{self, Json};
use super::stats::Summary;

pub struct Bench {
    group: String,
    budget: Duration,
    min_iters: usize,
    results: Vec<(String, Summary)>,
}

impl Bench {
    pub fn new(group: &str) -> Self {
        // honor ASTRA_BENCH_BUDGET_MS for quick CI runs
        let ms = std::env::var("ASTRA_BENCH_BUDGET_MS")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(500u64);
        Bench {
            group: group.to_string(),
            budget: Duration::from_millis(ms),
            min_iters: 5,
            results: Vec::new(),
        }
    }

    /// Time `f` repeatedly; prevents trivial dead-code elimination by
    /// requiring the closure to return a value that is black-boxed.
    pub fn run<R>(&mut self, name: &str, mut f: impl FnMut() -> R) {
        // warmup
        for _ in 0..2 {
            black_box(f());
        }
        let mut s = Summary::new();
        let start = Instant::now();
        while start.elapsed() < self.budget || s.len() < self.min_iters {
            let t0 = Instant::now();
            black_box(f());
            s.add(t0.elapsed().as_secs_f64());
            if s.len() > 1_000_000 {
                break;
            }
        }
        self.report(name, &mut s);
        self.results.push((name.to_string(), s));
    }

    fn report(&self, name: &str, s: &mut Summary) {
        println!(
            "{:<40} {:>12} {:>12} {:>12} {:>14}",
            format!("{}/{}", self.group, name),
            fmt_time(s.mean()),
            fmt_time(s.p50()),
            fmt_time(s.p95()),
            format!("{:.0} it/s", 1.0 / s.mean()),
        );
    }

    pub fn finish(self) {
        println!("{} cases done: {}", self.group, self.results.len());
    }
}

/// Opaque identity that inhibits constant folding.
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Named deterministic metrics collected by a bench's `--json` mode.
#[derive(Debug, Default)]
pub struct MetricSet {
    group: String,
    metrics: Vec<(String, f64)>,
}

impl MetricSet {
    pub fn new(group: &str) -> MetricSet {
        MetricSet { group: group.to_string(), metrics: Vec::new() }
    }

    /// Record `scenario/metric = value` (keys are emitted sorted, so the
    /// JSON file diffs stably across runs).
    pub fn push(&mut self, scenario: &str, metric: &str, value: f64) {
        self.metrics.push((format!("{scenario}/{metric}"), value));
    }

    pub fn is_empty(&self) -> bool {
        self.metrics.is_empty()
    }

    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("group", json::s(&self.group)),
            (
                "metrics",
                Json::Obj(
                    self.metrics.iter().map(|(k, v)| (k.clone(), json::num(*v))).collect(),
                ),
            ),
        ])
    }

    /// Write the metric file (the workflow artifact the gate consumes).
    pub fn write(&self, path: &str) -> Result<()> {
        std::fs::write(path, self.to_json().to_string())
            .with_context(|| format!("writing bench metrics to {path}"))?;
        println!("wrote {} deterministic metrics to {path}", self.metrics.len());
        Ok(())
    }
}

/// Is a larger value better for this metric? Throughput-like metrics,
/// cache hit rates, and SLO attainment regress downward; everything else
/// (latencies, TTFT, ITL, swap traffic) upward.
fn higher_is_better(name: &str) -> bool {
    ["throughput", "goodput", "hit_rate", "attainment", "tokens_per_s"]
        .iter()
        .any(|k| name.contains(k))
}

/// Integer-valued determinism pins — completion/step/event counts and the
/// generation checksum. These carry no cross-platform float noise, and any
/// drift in either direction is exactly what they exist to catch, so the
/// gate holds them to equality rather than the directional tolerance.
fn exact_pin(name: &str) -> bool {
    ["checksum", "completed", "chunks", "events", "steps"].iter().any(|k| name.contains(k))
}

/// Compare a current metric file against a baseline; returns the list of
/// regressions beyond `tolerance` (fractional, e.g. 0.02 = 2%; exact-pin
/// metrics must match exactly). Metrics missing from the baseline are
/// reported as regressions too — a silently dropped scenario must not pass
/// the gate. A baseline with `"bootstrap": true` matches nothing and
/// returns no regressions.
pub fn compare_metrics(baseline: &Json, current: &Json, tolerance: f64) -> Result<Vec<String>> {
    if baseline.opt("bootstrap").is_some() {
        println!(
            "baseline is a bootstrap placeholder: accepting current metrics \
             (pin them by committing the workflow artifact as the baseline)"
        );
        return Ok(Vec::new());
    }
    let base = baseline.get("metrics")?.as_obj()?;
    let cur = current.get("metrics")?.as_obj()?;
    let mut regressions = Vec::new();
    for (name, bv) in base {
        let b = bv.as_f64()?;
        let Some(cv) = cur.get(name) else {
            regressions.push(format!("{name}: missing from current run (baseline {b})"));
            continue;
        };
        let c = cv.as_f64()?;
        let worse = if exact_pin(name) {
            c != b
        } else if higher_is_better(name) {
            c < b * (1.0 - tolerance) - 1e-12
        } else {
            c > b * (1.0 + tolerance) + 1e-12
        };
        if worse {
            let pct = if b.abs() > 1e-12 { (c - b) / b * 100.0 } else { f64::INFINITY };
            regressions.push(format!("{name}: {b} -> {c} ({pct:+.2}%)"));
        }
    }
    Ok(regressions)
}

/// `astra bench-gate --baseline FILE --current FILE [--tolerance 0.02]` —
/// the CI regression gate over deterministic bench metrics: exits non-zero
/// listing every regressed metric.
pub fn gate_cli(args: &super::cli::Args) -> Result<()> {
    let baseline_path =
        args.get("baseline").context("--baseline FILE is required")?.to_string();
    let current_path = args.get("current").context("--current FILE is required")?.to_string();
    let tolerance = args.f64_or("tolerance", 0.02)?;
    let baseline = Json::parse(
        &std::fs::read_to_string(&baseline_path)
            .with_context(|| format!("reading baseline {baseline_path}"))?,
    )?;
    let current = Json::parse(
        &std::fs::read_to_string(&current_path)
            .with_context(|| format!("reading current metrics {current_path}"))?,
    )?;
    let regressions = compare_metrics(&baseline, &current, tolerance)?;
    let total = baseline.opt("metrics").map(|m| m.as_obj().map(|o| o.len()).unwrap_or(0));
    println!(
        "bench-gate: {} vs {} (tolerance {:.1}%): {} of {} metrics regressed",
        current_path,
        baseline_path,
        tolerance * 100.0,
        regressions.len(),
        total.unwrap_or(0),
    );
    for r in &regressions {
        println!("  REGRESSED {r}");
    }
    if !regressions.is_empty() {
        bail!("{} bench metrics regressed beyond {:.1}%", regressions.len(), tolerance * 100.0);
    }
    println!("bench-gate: ok");
    Ok(())
}

pub fn fmt_time(secs: f64) -> String {
    if secs.is_nan() {
        "n/a".into()
    } else if secs < 1e-6 {
        format!("{:.1} ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2} µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2} ms", secs * 1e3)
    } else {
        format!("{:.3} s", secs)
    }
}

/// Header line for bench output tables.
pub fn header() {
    println!(
        "{:<40} {:>12} {:>12} {:>12} {:>14}",
        "benchmark", "mean", "p50", "p95", "rate"
    );
    println!("{}", "-".repeat(94));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_time_ranges() {
        assert!(fmt_time(5e-10).contains("ns"));
        assert!(fmt_time(5e-5).contains("µs"));
        assert!(fmt_time(5e-3).contains("ms"));
        assert!(fmt_time(5.0).contains(" s"));
    }

    #[test]
    fn bench_runs() {
        std::env::set_var("ASTRA_BENCH_BUDGET_MS", "10");
        let mut b = Bench::new("test");
        let mut acc = 0u64;
        b.run("noop", || {
            acc = acc.wrapping_add(1);
            acc
        });
        b.finish();
    }

    fn metric_json(pairs: &[(&str, f64)]) -> Json {
        let mut m = MetricSet::new("test");
        for (k, v) in pairs {
            let (s, name) = k.split_once('/').unwrap();
            m.push(s, name, *v);
        }
        Json::parse(&m.to_json().to_string()).unwrap()
    }

    #[test]
    fn metric_set_roundtrips_through_json() {
        let j = metric_json(&[("cb8/p95", 0.125), ("cb8/throughput", 31.5)]);
        assert_eq!(j.get("group").unwrap().as_str().unwrap(), "test");
        let m = j.get("metrics").unwrap().as_obj().unwrap();
        assert_eq!(m["cb8/p95"].as_f64().unwrap(), 0.125);
        assert_eq!(m["cb8/throughput"].as_f64().unwrap(), 31.5);
    }

    #[test]
    fn gate_fails_on_injected_five_percent_latency_regression() {
        // the acceptance check for the CI gate: a 5% modeled-latency bump
        // must trip the default 2% tolerance; a 1% wobble must not
        let base = metric_json(&[("serve/p95", 0.200), ("serve/throughput", 30.0)]);
        let ok = metric_json(&[("serve/p95", 0.202), ("serve/throughput", 29.9)]);
        assert!(compare_metrics(&base, &ok, 0.02).unwrap().is_empty());
        let regressed = metric_json(&[("serve/p95", 0.210), ("serve/throughput", 30.0)]);
        let r = compare_metrics(&base, &regressed, 0.02).unwrap();
        assert_eq!(r.len(), 1, "{r:?}");
        assert!(r[0].contains("serve/p95"), "{r:?}");
        // throughput regresses in the opposite direction
        let slow = metric_json(&[("serve/p95", 0.200), ("serve/throughput", 28.0)]);
        let r = compare_metrics(&base, &slow, 0.02).unwrap();
        assert_eq!(r.len(), 1, "{r:?}");
        assert!(r[0].contains("throughput"), "{r:?}");
        // improvements never trip the gate
        let better = metric_json(&[("serve/p95", 0.150), ("serve/throughput", 40.0)]);
        assert!(compare_metrics(&base, &better, 0.02).unwrap().is_empty());
        // prefix hit rate regresses downward (like throughput); swap
        // traffic regresses upward (like a latency)
        let kv = metric_json(&[("serve/prefix_hit_rate", 0.8), ("serve/swap_bytes", 1000.0)]);
        let worse = metric_json(&[("serve/prefix_hit_rate", 0.7), ("serve/swap_bytes", 1000.0)]);
        let r = compare_metrics(&kv, &worse, 0.02).unwrap();
        assert_eq!(r.len(), 1, "{r:?}");
        assert!(r[0].contains("hit_rate"), "{r:?}");
        let bloated = metric_json(&[("serve/prefix_hit_rate", 0.8), ("serve/swap_bytes", 1100.0)]);
        let r = compare_metrics(&kv, &bloated, 0.02).unwrap();
        assert_eq!(r.len(), 1, "{r:?}");
        assert!(r[0].contains("swap_bytes"), "{r:?}");
        let improved = metric_json(&[("serve/prefix_hit_rate", 0.9), ("serve/swap_bytes", 500.0)]);
        assert!(compare_metrics(&kv, &improved, 0.02).unwrap().is_empty());
        // per-class SLO attainment regresses downward (like throughput);
        // per-class tail latency upward
        let slo = metric_json(&[
            ("classes/class1_slo_attainment", 0.9),
            ("classes/class1_p95", 0.300),
        ]);
        let dropped = metric_json(&[
            ("classes/class1_slo_attainment", 0.8),
            ("classes/class1_p95", 0.300),
        ]);
        let r = compare_metrics(&slo, &dropped, 0.02).unwrap();
        assert_eq!(r.len(), 1, "{r:?}");
        assert!(r[0].contains("attainment"), "{r:?}");
        let slower = metric_json(&[
            ("classes/class1_slo_attainment", 0.9),
            ("classes/class1_p95", 0.330),
        ]);
        let r = compare_metrics(&slo, &slower, 0.02).unwrap();
        assert_eq!(r.len(), 1, "{r:?}");
        assert!(r[0].contains("class1_p95"), "{r:?}");
        let better = metric_json(&[
            ("classes/class1_slo_attainment", 1.0),
            ("classes/class1_p95", 0.200),
        ]);
        assert!(compare_metrics(&slo, &better, 0.02).unwrap().is_empty());
    }

    #[test]
    fn gate_holds_determinism_pins_to_exact_equality() {
        // checksums and counts are identity pins: sub-tolerance drift in
        // EITHER direction must fail (a 2% window would wave through most
        // numeric drift the generation checksum exists to catch)
        let base = metric_json(&[
            ("live/generation_checksum", 5_000_000.0),
            ("live/completed", 30.0),
            ("serve/p95", 0.2),
        ]);
        let same = metric_json(&[
            ("live/generation_checksum", 5_000_000.0),
            ("live/completed", 30.0),
            ("serve/p95", 0.2),
        ]);
        assert!(compare_metrics(&base, &same, 0.02).unwrap().is_empty());
        for drifted in [4_999_999.0, 5_000_001.0] {
            let cur = metric_json(&[
                ("live/generation_checksum", drifted),
                ("live/completed", 30.0),
                ("serve/p95", 0.2),
            ]);
            let r = compare_metrics(&base, &cur, 0.02).unwrap();
            assert_eq!(r.len(), 1, "{r:?}");
            assert!(r[0].contains("checksum"), "{r:?}");
        }
        // a completion-count change trips it too, even an "improvement"
        let cur = metric_json(&[
            ("live/generation_checksum", 5_000_000.0),
            ("live/completed", 31.0),
            ("serve/p95", 0.2),
        ]);
        let r = compare_metrics(&base, &cur, 0.02).unwrap();
        assert_eq!(r.len(), 1, "{r:?}");
        assert!(r[0].contains("completed"), "{r:?}");
    }

    #[test]
    fn gate_flags_missing_metrics_and_accepts_bootstrap() {
        let base = metric_json(&[("serve/p95", 0.2), ("serve/ttft_p50", 0.05)]);
        let cur = metric_json(&[("serve/p95", 0.2)]);
        let r = compare_metrics(&base, &cur, 0.02).unwrap();
        assert_eq!(r.len(), 1);
        assert!(r[0].contains("ttft_p50") && r[0].contains("missing"), "{r:?}");
        // a bootstrap placeholder matches nothing and passes everything
        let boot = Json::parse(r#"{"bootstrap": true}"#).unwrap();
        assert!(compare_metrics(&boot, &cur, 0.02).unwrap().is_empty());
    }
}
