//! Micro-benchmark harness (criterion is not vendored in this image).
//!
//! Usage in a `harness = false` bench target:
//! ```no_run
//! use astra::util::bench::Bench;
//! let mut b = Bench::new("vq");
//! b.run("encode_1024", || { /* work */ });
//! b.finish();
//! ```
//! Each case is warmed up, then timed for a target wall budget; reports
//! mean / p50 / p95 per iteration and iterations/sec.

use std::time::{Duration, Instant};

use super::stats::Summary;

pub struct Bench {
    group: String,
    budget: Duration,
    min_iters: usize,
    results: Vec<(String, Summary)>,
}

impl Bench {
    pub fn new(group: &str) -> Self {
        // honor ASTRA_BENCH_BUDGET_MS for quick CI runs
        let ms = std::env::var("ASTRA_BENCH_BUDGET_MS")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(500u64);
        Bench {
            group: group.to_string(),
            budget: Duration::from_millis(ms),
            min_iters: 5,
            results: Vec::new(),
        }
    }

    /// Time `f` repeatedly; prevents trivial dead-code elimination by
    /// requiring the closure to return a value that is black-boxed.
    pub fn run<R>(&mut self, name: &str, mut f: impl FnMut() -> R) {
        // warmup
        for _ in 0..2 {
            black_box(f());
        }
        let mut s = Summary::new();
        let start = Instant::now();
        while start.elapsed() < self.budget || s.len() < self.min_iters {
            let t0 = Instant::now();
            black_box(f());
            s.add(t0.elapsed().as_secs_f64());
            if s.len() > 1_000_000 {
                break;
            }
        }
        self.report(name, &mut s);
        self.results.push((name.to_string(), s));
    }

    fn report(&self, name: &str, s: &mut Summary) {
        println!(
            "{:<40} {:>12} {:>12} {:>12} {:>14}",
            format!("{}/{}", self.group, name),
            fmt_time(s.mean()),
            fmt_time(s.p50()),
            fmt_time(s.p95()),
            format!("{:.0} it/s", 1.0 / s.mean()),
        );
    }

    pub fn finish(self) {
        println!("{} cases done: {}", self.group, self.results.len());
    }
}

/// Opaque identity that inhibits constant folding.
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

pub fn fmt_time(secs: f64) -> String {
    if secs.is_nan() {
        "n/a".into()
    } else if secs < 1e-6 {
        format!("{:.1} ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2} µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2} ms", secs * 1e3)
    } else {
        format!("{:.3} s", secs)
    }
}

/// Header line for bench output tables.
pub fn header() {
    println!(
        "{:<40} {:>12} {:>12} {:>12} {:>14}",
        "benchmark", "mean", "p50", "p95", "rate"
    );
    println!("{}", "-".repeat(94));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_time_ranges() {
        assert!(fmt_time(5e-10).contains("ns"));
        assert!(fmt_time(5e-5).contains("µs"));
        assert!(fmt_time(5e-3).contains("ms"));
        assert!(fmt_time(5.0).contains(" s"));
    }

    #[test]
    fn bench_runs() {
        std::env::set_var("ASTRA_BENCH_BUDGET_MS", "10");
        let mut b = Bench::new("test");
        let mut acc = 0u64;
        b.run("noop", || {
            acc = acc.wrapping_add(1);
            acc
        });
        b.finish();
    }
}
