//! Per-token delivery records and the pure waste accounting built on
//! them.
//!
//! The engine appends one timestamp per *newly generated* token to a
//! request's [`TokenStream`] (re-generation after a recompute eviction
//! does not re-deliver — the client already has those tokens), so a
//! stream is exactly what the client saw: time-to-each-token, not just
//! TTFT/ITL summaries. [`abandon_time`] and [`wasted_deliveries`] are
//! pure functions of a stream — the same arithmetic scores a
//! cancellation-aware run (where the engine stopped at the abandon
//! point) and a cancellation-blind baseline (where it decoded on for a
//! client that had already left), which is what makes the wasted-work
//! acceptance comparison apples-to-apples.

/// The delivery record of one request: its arrival and the virtual-clock
/// timestamp of every token the engine handed to the client.
#[derive(Debug, Clone, PartialEq)]
pub struct TokenStream {
    pub arrival_s: f64,
    /// Delivery time of token `i` (monotone non-decreasing).
    pub deliveries: Vec<f64>,
}

impl TokenStream {
    pub fn new(arrival_s: f64) -> TokenStream {
        TokenStream { arrival_s, deliveries: Vec::new() }
    }

    /// Tokens delivered so far.
    pub fn delivered(&self) -> usize {
        self.deliveries.len()
    }

    /// Time of the most recent delivery (the arrival when none yet) —
    /// the client's last observed sign of life.
    pub fn last_seen(&self) -> f64 {
        self.deliveries.last().copied().unwrap_or(self.arrival_s)
    }
}

/// When a client with the given `patience` between observed events walks
/// away from this stream: the first gap (arrival→token or token→token)
/// longer than `patience` ends the wait at `last_seen + patience`; a
/// stream with no such gap is abandoned `patience` after its final
/// delivery (the client eventually stops listening either way — tokens
/// delivered before that point are all useful).
pub fn abandon_time(arrival_s: f64, deliveries: &[f64], patience_s: f64) -> f64 {
    let mut last = arrival_s;
    for &d in deliveries {
        if d - last > patience_s {
            return last + patience_s;
        }
        last = d;
    }
    last + patience_s
}

/// Tokens delivered strictly after the client abandoned the stream —
/// decode work the engine burned for nobody. Zero for a client with
/// infinite patience.
pub fn wasted_deliveries(arrival_s: f64, deliveries: &[f64], patience_s: f64) -> usize {
    if !patience_s.is_finite() {
        return 0;
    }
    let gone = abandon_time(arrival_s, deliveries, patience_s);
    deliveries.iter().filter(|&&d| d > gone).count()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn abandon_at_first_long_gap() {
        // arrival 0, tokens at 1, 2, 6, 7 with patience 2: the 2→6 gap
        // kills it at 4; tokens 6 and 7 are wasted.
        let d = [1.0, 2.0, 6.0, 7.0];
        assert_eq!(abandon_time(0.0, &d, 2.0), 4.0);
        assert_eq!(wasted_deliveries(0.0, &d, 2.0), 2);
    }

    #[test]
    fn patient_client_wastes_nothing() {
        let d = [1.0, 2.0, 6.0, 7.0];
        assert_eq!(abandon_time(0.0, &d, 10.0), 17.0);
        assert_eq!(wasted_deliveries(0.0, &d, 10.0), 0);
        assert_eq!(wasted_deliveries(0.0, &d, f64::INFINITY), 0);
    }

    #[test]
    fn never_served_abandons_after_arrival() {
        assert_eq!(abandon_time(3.0, &[], 1.5), 4.5);
        assert_eq!(wasted_deliveries(3.0, &[], 1.5), 0);
    }

    #[test]
    fn last_seen_tracks_deliveries() {
        let mut s = TokenStream::new(2.0);
        assert_eq!(s.last_seen(), 2.0);
        s.deliveries.push(3.5);
        assert_eq!(s.last_seen(), 3.5);
        assert_eq!(s.delivered(), 1);
    }
}
