//! The client model: per-request patience and heavy-tailed decode
//! lengths, drawn statelessly from `(seed, id)`.
//!
//! Both draws follow the per-request stream idiom already used by
//! `CbEngine::decode_budget` and `FaultPlan::seeded` — a fresh
//! [`Rng`] keyed on `seed ^ id * GOLDEN ^ SALT` — so a request's
//! patience and budget are properties of the *workload*, identical
//! across replicas, backends, and re-admissions.

use crate::util::rng::Rng;

const GOLDEN: u64 = 0x9e37_79b9_7f4a_7c15;
/// Salt for patience draws (distinct from the decode-jitter and fault
/// salts so the streams never alias).
const PATIENCE_SALT: u64 = 0xc2b2_ae3d_27d4_eb4f;
/// Salt for heavy-tailed budget draws.
const TAIL_SALT: u64 = 0x9ddf_ea08_eb38_2d69;

/// How long request `id`'s client waits between observed events (arrival
/// or a delivered token) before abandoning the request.
///
/// `patience_s <= 0` disables the client model (infinite patience —
/// the historical behavior). `spread == 0` gives every client exactly
/// `patience_s`; `spread > 0` draws log-uniformly over
/// `[patience_s / (1+spread), patience_s * (1+spread)]`, so the median
/// stays at `patience_s` while individual clients vary multiplicatively.
pub fn patience_for(seed: u64, id: u64, patience_s: f64, spread: f64) -> f64 {
    if patience_s <= 0.0 {
        return f64::INFINITY;
    }
    if spread <= 0.0 {
        return patience_s;
    }
    let s = 1.0 + spread;
    let mut rng = Rng::new(seed ^ id.wrapping_mul(GOLDEN) ^ PATIENCE_SALT);
    patience_s / s * (s * s).powf(rng.f64())
}

/// Heavy-tailed decode budget for request `id`: a bounded Pareto draw on
/// `[1, decode_tokens]` with tail index `alpha` — the EOS/stop-sequence
/// model, where most generations stop early but a heavy tail runs to the
/// configured maximum. Smaller `alpha` = heavier tail (more long
/// generations); `alpha <= 0` is handled by the caller as "off".
///
/// Inverse-CDF of the bounded Pareto with lower bound 1 and upper bound
/// `h = decode_tokens`: `x = (1 - u (1 - h^-alpha))^(-1/alpha)`.
pub fn tail_budget(seed: u64, id: u64, decode_tokens: usize, alpha: f64) -> usize {
    debug_assert!(alpha > 0.0);
    if decode_tokens <= 1 {
        return decode_tokens;
    }
    let h = decode_tokens as f64;
    let mut rng = Rng::new(seed ^ id.wrapping_mul(GOLDEN) ^ TAIL_SALT);
    let u = rng.f64();
    let x = (1.0 - u * (1.0 - h.powf(-alpha))).powf(-1.0 / alpha);
    (x.floor() as usize).clamp(1, decode_tokens)
}
