//! Generative arrival processes: seeded, pure-data request traces.
//!
//! A [`WorkloadSpec`] expands to a plain `Vec<Request>` up front — the
//! engine never sees the generator, only the trace. Time-varying
//! processes use Lewis–Shedler thinning: candidate points are drawn as a
//! Poisson stream at the peak rate and accepted with probability
//! `rate(t) / peak`. The plain-Poisson configuration skips the accept
//! draw entirely, so its RNG consumption — and therefore the emitted
//! trace — is bit-identical to the historical
//! [`poisson_arrivals`](crate::server::batcher::poisson_arrivals) (fixed
//! prompts) and [`live_arrivals`](crate::server::live::live_arrivals)
//! (variable prompts) generators it replaces.

use crate::comm::trace::BandwidthTrace;
use crate::server::batcher::Request;
use crate::util::rng::Rng;

/// Salt for the burst-curve RNG stream, so the Markov rate curve and the
/// candidate-point stream are independent draws from one seed.
const CURVE_SALT: u64 = 0x2545_f491_4f6c_dd1d;

/// The arrival-rate process over the run horizon (requests per second).
#[derive(Debug, Clone, PartialEq)]
pub enum ArrivalProcess {
    /// Homogeneous Poisson at `rate` req/s — the historical workload.
    Poisson { rate: f64 },
    /// Sinusoidal diurnal load curve: starts at `base_rate`, peaks at
    /// `peak_rate` half a `period_s` in, and returns — a day of traffic
    /// compressed into the horizon.
    Diurnal { base_rate: f64, peak_rate: f64, period_s: f64 },
    /// Markov-modulated bursts: the rate follows a
    /// [`BandwidthTrace::markovian`] chain over `states` levels in
    /// [`lo_rate`, `hi_rate`] req/s, dwelling `dwell_s` per slot — the
    /// `sim/` trace machinery reused as a piecewise-constant rate curve
    /// (the "Mbps" samples are read as req/s here).
    MarkovBursts { lo_rate: f64, hi_rate: f64, states: usize, dwell_s: f64 },
}

impl ArrivalProcess {
    /// The thinning envelope: the maximum instantaneous rate.
    pub fn peak_rate(&self) -> f64 {
        match self {
            ArrivalProcess::Poisson { rate } => *rate,
            ArrivalProcess::Diurnal { base_rate, peak_rate, .. } => base_rate.max(*peak_rate),
            ArrivalProcess::MarkovBursts { hi_rate, .. } => *hi_rate,
        }
    }
}

/// Prompt-length distribution for generated requests.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PromptLengths {
    /// Every prompt is exactly this many tokens (the
    /// [`poisson_arrivals`](crate::server::batcher::poisson_arrivals)
    /// convention).
    Fixed(usize),
    /// Uniform in `[seq_len/2, seq_len]` — the
    /// [`live_arrivals`](crate::server::live::live_arrivals) convention
    /// (live runs must not exceed the AOT `seq_len`).
    UniformHalf(usize),
}

/// A complete, seeded workload description. `generate()` is a pure
/// function of this struct — same spec, same trace, any backend.
#[derive(Debug, Clone, PartialEq)]
pub struct WorkloadSpec {
    pub seed: u64,
    pub horizon_s: f64,
    pub process: ArrivalProcess,
    pub prompts: PromptLengths,
    /// Multi-tenant mix weights. Empty = single-tenant (ids are the plain
    /// 1..N sequence, and *no extra RNG draws happen* — the bit-for-bit
    /// anchor). With `T` non-empty weights, each arrival draws a tenant
    /// `k` proportional to weight and gets id `n*T + k`, so the
    /// scheduler's `id % classes.len()` class mapping routes tenant `k`
    /// to QoS class `k` when `--classes` lists `T` deadlines.
    pub tenant_weights: Vec<f64>,
}

impl WorkloadSpec {
    /// The historical fixed-rate workload as a spec (bit-identical to
    /// [`poisson_arrivals`](crate::server::batcher::poisson_arrivals)).
    pub fn poisson(seed: u64, rate: f64, horizon_s: f64, tokens: usize) -> WorkloadSpec {
        WorkloadSpec {
            seed,
            horizon_s,
            process: ArrivalProcess::Poisson { rate },
            prompts: PromptLengths::Fixed(tokens),
            tenant_weights: Vec::new(),
        }
    }

    /// Instantaneous arrival rate at time `t` (`curve` is the
    /// pre-drawn Markov rate trace, unused by the other processes).
    fn rate_at(&self, curve: Option<&BandwidthTrace>, t: f64) -> f64 {
        match &self.process {
            ArrivalProcess::Poisson { rate } => *rate,
            ArrivalProcess::Diurnal { base_rate, peak_rate, period_s } => {
                let phase = std::f64::consts::TAU * t / period_s.max(1e-9);
                base_rate + (peak_rate - base_rate) * 0.5 * (1.0 - phase.cos())
            }
            ArrivalProcess::MarkovBursts { .. } => curve.expect("burst curve pre-drawn").at(t),
        }
    }

    /// Expand the spec into an arrival trace, deterministically from the
    /// seed. Ids start at 1 (tenant mixes remap them onto `n*T + k`, see
    /// [`WorkloadSpec::tenant_weights`]); arrivals are strictly inside
    /// the horizon and sorted by time.
    pub fn generate(&self) -> Vec<Request> {
        let peak = self.process.peak_rate();
        assert!(peak > 0.0, "arrival process needs a positive peak rate");
        let curve = match &self.process {
            ArrivalProcess::MarkovBursts { lo_rate, hi_rate, states, dwell_s } => {
                Some(BandwidthTrace::markovian(
                    &mut Rng::new(self.seed ^ CURVE_SALT),
                    *lo_rate,
                    *hi_rate,
                    *states,
                    *dwell_s,
                    self.horizon_s,
                ))
            }
            _ => None,
        };
        let thinning = !matches!(self.process, ArrivalProcess::Poisson { .. });
        let tenants = self.tenant_weights.len();
        let weight_sum: f64 = self.tenant_weights.iter().sum();
        let mixed = tenants > 0 && weight_sum > 0.0;
        let mut rng = Rng::new(self.seed);
        let mut out = Vec::new();
        let mut t = 0.0;
        let mut n = 0u64;
        loop {
            t += rng.exp(peak);
            if t >= self.horizon_s {
                break;
            }
            // Thinning accept; skipped (not just always-true) for plain
            // Poisson so the RNG stream matches the historical generators.
            if thinning && !rng.chance(self.rate_at(curve.as_ref(), t) / peak) {
                continue;
            }
            n += 1;
            let tokens = match self.prompts {
                PromptLengths::Fixed(k) => k,
                PromptLengths::UniformHalf(seq_len) => {
                    let lo = (seq_len / 2).max(1);
                    lo + rng.below(seq_len - lo + 1)
                }
            };
            let id = if mixed {
                let mut u = rng.f64() * weight_sum;
                let mut k = tenants - 1;
                for (i, w) in self.tenant_weights.iter().enumerate() {
                    if u < *w {
                        k = i;
                        break;
                    }
                    u -= w;
                }
                n * tenants as u64 + k as u64
            } else {
                n
            };
            out.push(Request { id, arrival_s: t, tokens });
        }
        out
    }
}
