//! Production workload models: generative arrival traces, impatient
//! streaming clients, and per-token delivery records.
//!
//! # Module contract
//!
//! Everything in this module is **pure data drawn deterministically from a
//! seed** — the same contract as [`crate::sim::fault::FaultPlan`]. A
//! [`WorkloadSpec`](arrivals::WorkloadSpec) expands to a plain
//! `Vec<Request>` before the engine starts; the client-model draws
//! ([`client::patience_for`], [`client::tail_budget`]) are stateless
//! functions of `(seed, id)`. The serving engine owns *all* state
//! transitions: it decides when a request is `Cancelled`, frees the slot
//! and KV blocks, and records the [`TokenStream`](stream::TokenStream)
//! deliveries. Generators never observe engine state, so any trace can be
//! replayed bit-for-bit against any backend — the property the
//! `tests/live_vs_model.rs` differential harness and the chaos soak both
//! lean on.
//!
//! Three pieces:
//!
//! - [`arrivals`] — arrival processes beyond fixed-rate Poisson: diurnal
//!   load curves and Markov-modulated bursts (thinning over the `sim/`
//!   bandwidth-trace machinery), plus multi-tenant mixes layered on the
//!   `--classes` QoS ids. The plain-Poisson configuration reproduces the
//!   historical [`poisson_arrivals`](crate::server::batcher::poisson_arrivals)
//!   and [`live_arrivals`](crate::server::live::live_arrivals) streams bit
//!   for bit.
//! - [`client`] — per-request patience (log-uniform spread around
//!   `--patience`) and heavy-tailed decode budgets (bounded Pareto,
//!   generalizing `--decode-jitter`).
//! - [`stream`] — per-token delivery timestamps and the pure post-hoc
//!   waste accounting (`abandon_time` / `wasted_deliveries`) that defines
//!   `wasted_decode_tokens`.

pub mod arrivals;
pub mod client;
pub mod stream;

pub use arrivals::{ArrivalProcess, PromptLengths, WorkloadSpec};
pub use client::{patience_for, tail_budget};
pub use stream::{abandon_time, wasted_deliveries, TokenStream};
