//! Simulated point-to-point link: bandwidth (static or trace), propagation
//! latency, Bernoulli packet loss with optional retransmission.
//!
//! Used two ways:
//!  * by the live threaded cluster ([`crate::coordinator`]): `send` sleeps
//!    for the modeled transfer time before the payload is delivered, so
//!    wall-clock latency of the end-to-end examples includes network time;
//!  * by the discrete-event simulator ([`crate::sim`]): `transfer_time`
//!    computes durations without sleeping.

use std::sync::Mutex;

use super::trace::BandwidthTrace;
use crate::util::rng::Rng;

/// Static description of a link.
#[derive(Debug, Clone)]
pub struct LinkSpec {
    pub trace: BandwidthTrace,
    /// one-way propagation + protocol latency per message (seconds)
    pub latency_s: f64,
    /// Bernoulli per-packet loss probability
    pub loss_rate: f64,
    /// MTU for loss accounting (bytes per packet)
    pub mtu: usize,
    /// if true, lost packets are retransmitted (reliable link); otherwise
    /// they are simply dropped from the payload (paper Table 11 setting)
    pub retransmit: bool,
}

impl LinkSpec {
    pub fn ideal(mbps: f64) -> LinkSpec {
        LinkSpec {
            trace: BandwidthTrace::constant(mbps, 1e9),
            latency_s: 0.0005,
            loss_rate: 0.0,
            mtu: 1500,
            retransmit: true,
        }
    }

    pub fn with_latency(mut self, s: f64) -> LinkSpec {
        self.latency_s = s;
        self
    }

    pub fn with_loss(mut self, p: f64, retransmit: bool) -> LinkSpec {
        self.loss_rate = p;
        self.retransmit = retransmit;
        self
    }

    pub fn with_trace(mut self, trace: BandwidthTrace) -> LinkSpec {
        self.trace = trace;
        self
    }
}

/// Outcome of pushing a payload through a link.
#[derive(Debug, Clone)]
pub struct Delivery {
    /// total modeled time from send start to full delivery (seconds)
    pub elapsed_s: f64,
    /// per-packet delivered flags (false = dropped, only when !retransmit)
    pub delivered: Vec<bool>,
    /// number of retransmitted packets
    pub retransmissions: usize,
    /// bytes billed to the link: payload plus every retransmitted copy at
    /// that packet's true size (the final packet may be shorter than MTU)
    pub billed_bytes: usize,
    /// packets that exhausted the retransmission cap and were abandoned —
    /// a fully-flapped link fails loudly instead of "delivering" cheaply;
    /// every gave-up packet is also `delivered: false` at its index
    pub gave_up: usize,
}

/// A simulated link with its own RNG stream (loss) and a running clock
/// offset for trace lookups.
#[derive(Debug)]
pub struct SimLink {
    pub spec: LinkSpec,
    rng: Mutex<Rng>,
}

impl SimLink {
    pub fn new(spec: LinkSpec, seed: u64) -> SimLink {
        SimLink { spec, rng: Mutex::new(Rng::new(seed)) }
    }

    /// Pure transfer time of `bytes` starting at absolute time `t0`
    /// (bandwidth + latency only; no loss).
    pub fn transfer_time(&self, t0: f64, bytes: usize) -> f64 {
        self.spec.latency_s + self.spec.trace.transfer_time(t0, bytes as f64 * 8.0)
    }

    /// Model a send of `bytes` at time `t0`, applying loss.
    ///
    /// With retransmission every packet eventually arrives (each lost copy
    /// costs one extra packet transfer + latency) — unless 64 consecutive
    /// copies are lost, in which case the sender gives up on that packet:
    /// it is billed but recorded `delivered: false` and counted in
    /// `gave_up`, so a fully-flapped link fails visibly instead of
    /// "succeeding" for the price of 64 copies. Without retransmission,
    /// dropped packets are recorded in `delivered` and the receiver must
    /// cope (for VQ payloads the coordinator substitutes stale codes).
    pub fn send(&self, t0: f64, bytes: usize) -> Delivery {
        let mtu = self.spec.mtu;
        let n_packets = bytes.div_ceil(mtu).max(1);
        let mut rng = self.rng.lock().unwrap();
        let mut delivered = Vec::with_capacity(n_packets);
        let mut extra_packets = 0usize;
        let mut extra_bytes = 0usize;
        let mut gave_up = 0usize;
        for p in 0..n_packets {
            // the final packet carries only the payload remainder
            let pkt_bytes = if p + 1 == n_packets { bytes - (n_packets - 1) * mtu } else { mtu };
            if self.spec.loss_rate > 0.0 && rng.chance(self.spec.loss_rate) {
                if self.spec.retransmit {
                    // geometric number of retries, capped: a link that eats
                    // 64 copies in a row is dead for this packet, and the
                    // caller must see the failure (the copies sent are
                    // still billed — the link burned that bandwidth)
                    let mut tries = 1usize;
                    let mut capped = false;
                    while rng.chance(self.spec.loss_rate) {
                        tries += 1;
                        if tries > 64 {
                            capped = true;
                            break;
                        }
                    }
                    extra_packets += tries;
                    extra_bytes += tries * pkt_bytes;
                    if capped {
                        gave_up += 1;
                        delivered.push(false);
                    } else {
                        delivered.push(true);
                    }
                } else {
                    delivered.push(false);
                }
            } else {
                delivered.push(true);
            }
        }
        let total_bytes = bytes + extra_bytes;
        let elapsed =
            self.spec.latency_s + self.spec.trace.transfer_time(t0, total_bytes as f64 * 8.0)
                + extra_packets as f64 * self.spec.latency_s; // each retry pays RTT-ish
        Delivery {
            elapsed_s: elapsed,
            delivered,
            retransmissions: extra_packets,
            billed_bytes: total_bytes,
            gave_up,
        }
    }
}

/// Full-mesh network of N devices. Links are "parallel" (the paper's cost
/// model: concurrent point-to-point transfers do not contend — see
/// DESIGN.md §Substitutions; a shared-medium mode divides bandwidth by the
/// number of concurrent senders for Wi-Fi-style contention studies).
#[derive(Debug)]
pub struct Network {
    pub n: usize,
    links: Vec<SimLink>, // dense [n*n], diagonal unused
    pub shared_medium: bool,
}

impl Network {
    pub fn full_mesh(n: usize, spec: &LinkSpec, seed: u64) -> Network {
        let mut rng = Rng::new(seed);
        let links = (0..n * n)
            .map(|i| SimLink::new(spec.clone(), rng.fork(i as u64).next_u64()))
            .collect();
        Network { n, links, shared_medium: false }
    }

    pub fn link(&self, from: usize, to: usize) -> &SimLink {
        assert!(from != to, "no self-link");
        &self.links[from * self.n + to]
    }

    /// Effective per-link bandwidth divisor under concurrent senders.
    pub fn contention_factor(&self, concurrent_senders: usize) -> f64 {
        if self.shared_medium {
            concurrent_senders.max(1) as f64
        } else {
            1.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_time_basics() {
        let l = SimLink::new(LinkSpec::ideal(8.0), 1); // 8 Mbps = 1 MB/s
        let t = l.transfer_time(0.0, 1_000_000);
        assert!((t - (1.0 + 0.0005)).abs() < 1e-6, "{t}");
    }

    #[test]
    fn lossless_send_delivers_all() {
        let l = SimLink::new(LinkSpec::ideal(100.0), 2);
        let d = l.send(0.0, 15_000);
        assert_eq!(d.delivered.len(), 10);
        assert!(d.delivered.iter().all(|&x| x));
        assert_eq!(d.retransmissions, 0);
    }

    #[test]
    fn lossy_no_retransmit_drops_about_p() {
        let l = SimLink::new(LinkSpec::ideal(100.0).with_loss(0.05, false), 3);
        let mut dropped = 0usize;
        let mut total = 0usize;
        for _ in 0..200 {
            let d = l.send(0.0, 150_000); // 100 packets
            dropped += d.delivered.iter().filter(|&&x| !x).count();
            total += d.delivered.len();
        }
        let rate = dropped as f64 / total as f64;
        assert!((rate - 0.05).abs() < 0.01, "drop rate {rate}");
    }

    #[test]
    fn lossy_retransmit_costs_time() {
        let spec = LinkSpec::ideal(10.0);
        let clean = SimLink::new(spec.clone(), 4);
        let lossy = SimLink::new(spec.with_loss(0.2, true), 4);
        let bytes = 1_500_000; // 1000 packets
        let t_clean = clean.send(0.0, bytes).elapsed_s;
        let d = lossy.send(0.0, bytes);
        assert!(d.retransmissions > 100, "{}", d.retransmissions);
        assert!(d.elapsed_s > t_clean);
        assert!(d.delivered.iter().all(|&x| x));
    }

    #[test]
    fn final_short_packet_billed_at_true_size() {
        // 1 packet of 100 bytes on a lossy retransmitting link: every
        // retransmission must bill 100 bytes, not a full 1500-byte MTU.
        let l = SimLink::new(LinkSpec::ideal(100.0).with_loss(0.9, true), 11);
        for _ in 0..50 {
            let d = l.send(0.0, 100);
            assert_eq!(d.billed_bytes, 100 * (1 + d.retransmissions));
            if d.retransmissions > 0 {
                return;
            }
        }
        panic!("no loss in 50 sends at p=0.9");
    }

    #[test]
    fn prop_retransmit_expected_bytes() {
        // With retransmission, E[billed bytes] = bytes / (1 - p): each
        // packet's transmission count is geometric with mean 1/(1-p).
        let p = 0.2;
        let bytes = 150_100; // 100 full packets + one 100-byte tail
        let l = SimLink::new(LinkSpec::ideal(100.0).with_loss(p, true), 12);
        let trials = 400;
        let mut total = 0usize;
        for _ in 0..trials {
            let d = l.send(0.0, bytes);
            assert!(d.delivered.iter().all(|&x| x));
            total += d.billed_bytes;
        }
        let mean = total as f64 / trials as f64;
        let want = bytes as f64 / (1.0 - p);
        // ~40k samples of a geometric: the sample mean sits within 2%
        assert!((mean / want - 1.0).abs() < 0.02, "mean {mean} want {want}");
    }

    #[test]
    fn prop_dead_link_gives_up_instead_of_delivering() {
        // loss_rate 1.0: every draw loses, so every packet hits the retry
        // cap. The old behavior pushed `delivered: true` after billing 64
        // copies — a dead link must instead fail every packet explicitly.
        for seed in 0..20 {
            let l = SimLink::new(LinkSpec::ideal(100.0).with_loss(1.0, true), seed);
            let d = l.send(0.0, 15_000); // 10 packets
            assert_eq!(d.delivered.len(), 10);
            assert!(d.delivered.iter().all(|&x| !x), "seed {seed}: dead link delivered");
            assert_eq!(d.gave_up, 10, "seed {seed}");
            // the 65 copies per packet are still billed: the bandwidth was burned
            assert_eq!(d.retransmissions, 65 * 10, "seed {seed}");
            assert_eq!(d.billed_bytes, 15_000 + 65 * 15_000, "seed {seed}");
        }
        // sub-1.0 loss with retransmit still delivers everything and never
        // reports a give-up at moderate loss
        let l = SimLink::new(LinkSpec::ideal(100.0).with_loss(0.3, true), 9);
        let d = l.send(0.0, 150_000);
        assert!(d.delivered.iter().all(|&x| x));
        assert_eq!(d.gave_up, 0);
    }

    #[test]
    fn full_mesh_links_independent_rngs() {
        let net = Network::full_mesh(3, &LinkSpec::ideal(50.0).with_loss(0.5, false), 5);
        let a = net.link(0, 1).send(0.0, 150_000);
        let b = net.link(1, 2).send(0.0, 150_000);
        assert_ne!(a.delivered, b.delivered); // overwhelmingly likely
    }
}
