//! Time-varying bandwidth traces.
//!
//! `BandwidthTrace::markovian` reproduces the paper's Appendix E setup: a
//! Markov chain over bandwidth states in [lo, hi] Mbps with transitions
//! biased toward nearby states (temporal correlation), following the
//! Pensieve trace generator (Mao et al., 2017). Traces are piecewise
//! constant; `transfer_time` integrates bits over the trace.

use crate::util::rng::Rng;

/// Piecewise-constant bandwidth over time (Mbps per slot).
#[derive(Debug, Clone)]
pub struct BandwidthTrace {
    /// slot duration in seconds
    pub slot_s: f64,
    /// bandwidth per slot, Mbps
    pub mbps: Vec<f64>,
}

impl BandwidthTrace {
    pub fn constant(mbps: f64, horizon_s: f64) -> Self {
        BandwidthTrace { slot_s: horizon_s.max(1.0), mbps: vec![mbps] }
    }

    /// Markovian trace: `states` evenly spaced bandwidth levels in
    /// [lo_mbps, hi_mbps]; each slot transitions to a nearby state with
    /// geometric preference (stay 50%, ±1 30%, ±2 14%, ...).
    pub fn markovian(
        rng: &mut Rng,
        lo_mbps: f64,
        hi_mbps: f64,
        states: usize,
        slot_s: f64,
        horizon_s: f64,
    ) -> Self {
        assert!(states >= 2);
        let levels: Vec<f64> = (0..states)
            .map(|i| lo_mbps + (hi_mbps - lo_mbps) * i as f64 / (states - 1) as f64)
            .collect();
        let slots = (horizon_s / slot_s).ceil() as usize;
        let mut state = rng.below(states);
        let mut mbps = Vec::with_capacity(slots);
        for _ in 0..slots {
            mbps.push(levels[state]);
            // biased random walk: step size geometric, direction uniform
            let r = rng.f64();
            let step = if r < 0.5 {
                0
            } else if r < 0.8 {
                1
            } else if r < 0.94 {
                2
            } else {
                3
            };
            if step > 0 {
                let dir_up = rng.chance(0.5);
                let s = state as isize + if dir_up { step } else { -step };
                state = s.clamp(0, states as isize - 1) as usize;
            }
        }
        BandwidthTrace { slot_s, mbps }
    }

    /// Bandwidth at absolute time t (clamped to the last slot).
    pub fn at(&self, t: f64) -> f64 {
        if self.mbps.is_empty() {
            return 0.0;
        }
        let idx = ((t / self.slot_s).floor() as usize).min(self.mbps.len() - 1);
        self.mbps[idx]
    }

    /// Time to move `bits` starting at time `t0`, integrating the trace.
    pub fn transfer_time(&self, t0: f64, bits: f64) -> f64 {
        if bits <= 0.0 {
            return 0.0;
        }
        let mut remaining = bits;
        let mut t = t0;
        loop {
            let bw = self.at(t) * 1e6; // bits/s
            let slot_end = ((t / self.slot_s).floor() + 1.0) * self.slot_s;
            let span = slot_end - t;
            let cap = bw * span;
            if cap >= remaining || (t / self.slot_s) as usize >= self.mbps.len() {
                // final (or clamped-last) slot: finish at current rate
                return t - t0 + remaining / bw.max(1.0);
            }
            remaining -= cap;
            t = slot_end;
        }
    }

    pub fn horizon(&self) -> f64 {
        self.slot_s * self.mbps.len() as f64
    }

    pub fn mean_mbps(&self) -> f64 {
        self.mbps.iter().sum::<f64>() / self.mbps.len().max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_trace() {
        let tr = BandwidthTrace::constant(100.0, 600.0);
        assert_eq!(tr.at(0.0), 100.0);
        assert_eq!(tr.at(599.0), 100.0);
        // 100 Mbit at 100 Mbps = 1 s
        assert!((tr.transfer_time(0.0, 100e6) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn markovian_in_range_and_correlated() {
        let mut rng = Rng::new(42);
        let tr = BandwidthTrace::markovian(&mut rng, 20.0, 100.0, 9, 1.0, 600.0);
        assert_eq!(tr.mbps.len(), 600);
        assert!(tr.mbps.iter().all(|&b| (20.0..=100.0).contains(&b)));
        // temporal correlation: mean |diff| much smaller than range
        let diffs: f64 = tr.mbps.windows(2).map(|w| (w[1] - w[0]).abs()).sum::<f64>()
            / (tr.mbps.len() - 1) as f64;
        assert!(diffs < 20.0, "mean step {diffs}");
        // it does actually vary
        assert!(tr.mbps.iter().any(|&b| b != tr.mbps[0]));
    }

    #[test]
    fn transfer_spans_slots() {
        // 2 slots: 10 Mbps then 90 Mbps, 1 s each.
        let tr = BandwidthTrace { slot_s: 1.0, mbps: vec![10.0, 90.0] };
        // 55 Mbit: 10 in slot 0 (1 s), 45 at 90 Mbps (0.5 s) = 1.5 s
        assert!((tr.transfer_time(0.0, 55e6) - 1.5).abs() < 1e-9);
        // starting mid-slot
        assert!((tr.transfer_time(0.5, 5e6) - 0.5).abs() < 1e-9);
    }

    #[test]
    fn clamps_past_horizon() {
        let tr = BandwidthTrace { slot_s: 1.0, mbps: vec![10.0] };
        // past horizon keeps last bandwidth
        let t = tr.transfer_time(5.0, 20e6);
        assert!((t - 2.0).abs() < 1e-9);
    }
}
