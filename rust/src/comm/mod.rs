//! Communication substrate: simulated links, bandwidth traces, collectives,
//! and the message wire format.
//!
//! The paper deploys on laptops over bandwidth-capped Wi-Fi; here every
//! inter-device byte flows through [`link::SimLink`]s instead, with
//! configurable bandwidth (static or a Markovian time-varying trace),
//! propagation latency, and Bernoulli packet loss. Messages carry *real*
//! payloads (bit-packed VQ indices or raw f32 embeddings), so measured
//! message sizes are the paper's bits-per-token numbers, not estimates.

pub mod collective;
pub mod link;
pub mod message;
pub mod trace;

pub use link::{LinkSpec, SimLink};
pub use message::Message;
pub use trace::BandwidthTrace;
