//! Collective communication cost primitives.
//!
//! The latency model follows the paper's measurement setting (DESIGN.md §2):
//! point-to-point transfers between distinct pairs proceed in parallel
//! ("parallel links"); a collective is a sequence of stages, each paying
//! the bottleneck link's bits/bandwidth plus one sync latency.
//!
//! Costs are expressed as (bits on the bottleneck link, number of latency
//! stages); the simulator turns them into seconds against a (possibly
//! time-varying) bandwidth.

/// One communication step of a schedule: the bottleneck link carries
/// `bits`; `stages` sync latencies are paid.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CommCost {
    pub bits: f64,
    pub stages: usize,
}

impl CommCost {
    pub const ZERO: CommCost = CommCost { bits: 0.0, stages: 0 };

    pub fn plus(self, other: CommCost) -> CommCost {
        CommCost { bits: self.bits + other.bits, stages: self.stages + other.stages }
    }

    /// Seconds under a static bandwidth (Mbps) and per-stage latency.
    pub fn seconds(&self, bandwidth_mbps: f64, stage_latency_s: f64) -> f64 {
        self.bits / (bandwidth_mbps * 1e6) + self.stages as f64 * stage_latency_s
    }
}

/// Ring all-gather of a tensor of `total_bits` sharded over `n` devices:
/// each device ends with the full tensor. Bottleneck link carries
/// (n-1)/n * total, over n-1 pipelined stages.
pub fn allgather(total_bits: f64, n: usize) -> CommCost {
    if n <= 1 {
        return CommCost::ZERO;
    }
    CommCost { bits: total_bits * (n as f64 - 1.0) / n as f64, stages: n - 1 }
}

/// Ring all-reduce (reduce-scatter + all-gather) of a replicated tensor of
/// `total_bits`: 2*(n-1)/n * total per link, 2*(n-1) stages.
pub fn allreduce(total_bits: f64, n: usize) -> CommCost {
    if n <= 1 {
        return CommCost::ZERO;
    }
    CommCost { bits: 2.0 * total_bits * (n as f64 - 1.0) / n as f64, stages: 2 * (n - 1) }
}

/// ASTRA's code exchange: every device multicasts its local tokens' VQ
/// codes (`chunk_bits`) to all peers; transfers are pairwise-parallel so
/// the bottleneck carries one chunk. One stage.
pub fn code_multicast(chunk_bits: f64, n: usize) -> CommCost {
    if n <= 1 {
        return CommCost::ZERO;
    }
    CommCost { bits: chunk_bits, stages: 1 }
}

/// Unicast all-to-all variant (no multicast offload): the sender's NIC
/// serializes n-1 copies of its chunk. Used for the ablation comparing
/// multicast-capable vs plain-TCP deployments.
pub fn code_unicast_fanout(chunk_bits: f64, n: usize) -> CommCost {
    if n <= 1 {
        return CommCost::ZERO;
    }
    CommCost { bits: chunk_bits * (n as f64 - 1.0), stages: 1 }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_device_is_free() {
        assert_eq!(allgather(1e6, 1), CommCost::ZERO);
        assert_eq!(allreduce(1e6, 1), CommCost::ZERO);
        assert_eq!(code_multicast(1e6, 1), CommCost::ZERO);
    }

    #[test]
    fn ring_costs() {
        let ag = allgather(100.0, 4);
        assert!((ag.bits - 75.0).abs() < 1e-9);
        assert_eq!(ag.stages, 3);
        let ar = allreduce(100.0, 4);
        assert!((ar.bits - 150.0).abs() < 1e-9);
        assert_eq!(ar.stages, 6);
    }

    #[test]
    fn allreduce_is_twice_allgather() {
        for n in [2, 4, 8] {
            let ag = allgather(1e6, n);
            let ar = allreduce(1e6, n);
            assert!((ar.bits - 2.0 * ag.bits).abs() < 1e-6);
        }
    }

    #[test]
    fn seconds_composition() {
        let c = CommCost { bits: 10e6, stages: 2 };
        // 10 Mbit at 10 Mbps = 1 s, + 2 * 5 ms
        assert!((c.seconds(10.0, 0.005) - 1.01).abs() < 1e-9);
        let sum = c.plus(CommCost { bits: 5e6, stages: 1 });
        assert_eq!(sum.stages, 3);
        assert!((sum.bits - 15e6).abs() < 1e-9);
    }

    #[test]
    fn unicast_scales_with_peers() {
        let m = code_multicast(1e6, 4);
        let u = code_unicast_fanout(1e6, 4);
        assert!((u.bits / m.bits - 3.0).abs() < 1e-9);
    }
}
