//! Wire format for inter-device messages.
//!
//! Two payload kinds cross the network during prefill:
//!  * `VqCodes` — bit-packed grouped-VQ indices (ASTRA path);
//!  * `Dense`   — raw little-endian f32 embeddings (baseline paths).
//!
//! A fixed 16-byte header carries (kind, layer, sender, token count) so a
//! receiver can reassemble without out-of-band state. Header overhead is
//! accounted in every latency number (the paper's bits/token figures are
//! payload-only; `Message::payload_bits` reports that number, while
//! `wire_bytes` is what the link actually carries).

use anyhow::{bail, Result};

use crate::tensor::Tensor;
use crate::vq::{pack_indices, unpack_indices};

pub const HEADER_BYTES: usize = 16;

#[derive(Debug, Clone, PartialEq)]
pub enum Payload {
    /// Grouped VQ codes for `tokens` tokens, `groups` indices each, packed
    /// at `bits` bits per index.
    VqCodes { tokens: usize, groups: usize, bits: usize, packed: Vec<u8> },
    /// Dense f32 token embeddings [tokens, d].
    Dense { tokens: usize, d: usize, bytes: Vec<u8> },
}

#[derive(Debug, Clone, PartialEq)]
pub struct Message {
    pub layer: u16,
    pub sender: u16,
    pub payload: Payload,
}

impl Message {
    pub fn vq(layer: usize, sender: usize, indices: &[u32], tokens: usize, groups: usize, bits: usize) -> Result<Message> {
        if indices.len() != tokens * groups {
            bail!("vq message: {} indices != {tokens} x {groups}", indices.len());
        }
        Ok(Message {
            layer: layer as u16,
            sender: sender as u16,
            payload: Payload::VqCodes {
                tokens,
                groups,
                bits,
                packed: pack_indices(indices, bits)?,
            },
        })
    }

    pub fn dense(layer: usize, sender: usize, x: &Tensor) -> Result<Message> {
        let (tokens, d) = x.dims2()?;
        let mut bytes = Vec::with_capacity(x.data.len() * 4);
        for v in &x.data {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        Ok(Message {
            layer: layer as u16,
            sender: sender as u16,
            payload: Payload::Dense { tokens, d, bytes },
        })
    }

    /// Decode a VQ payload back to indices.
    pub fn vq_indices(&self) -> Result<Vec<u32>> {
        match &self.payload {
            Payload::VqCodes { tokens, groups, bits, packed } => {
                unpack_indices(packed, tokens * groups, *bits)
            }
            _ => bail!("not a VQ message"),
        }
    }

    /// Decode a dense payload back to a tensor.
    pub fn dense_tensor(&self) -> Result<Tensor> {
        match &self.payload {
            Payload::Dense { tokens, d, bytes } => {
                let mut data = Vec::with_capacity(tokens * d);
                for c in bytes.chunks_exact(4) {
                    data.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
                }
                Tensor::from_vec(&[*tokens, *d], data)
            }
            _ => bail!("not a dense message"),
        }
    }

    /// Payload-only bits (the paper's accounting unit).
    pub fn payload_bits(&self) -> usize {
        match &self.payload {
            Payload::VqCodes { tokens, groups, bits, .. } => tokens * groups * bits,
            Payload::Dense { tokens, d, .. } => tokens * d * 32,
        }
    }

    /// Bytes the link actually carries (packed payload + header).
    pub fn wire_bytes(&self) -> usize {
        HEADER_BYTES
            + match &self.payload {
                Payload::VqCodes { packed, .. } => packed.len(),
                Payload::Dense { bytes, .. } => bytes.len(),
            }
    }

    /// Per transmitted token payload bits.
    pub fn bits_per_token(&self) -> f64 {
        let tokens = match &self.payload {
            Payload::VqCodes { tokens, .. } | Payload::Dense { tokens, .. } => *tokens,
        };
        self.payload_bits() as f64 / tokens.max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::vq::packed_len_bytes;

    #[test]
    fn vq_roundtrip() {
        let idx: Vec<u32> = (0..16 * 8).map(|i| (i * 37) % 1024).collect();
        let m = Message::vq(3, 1, &idx, 16, 8, 10).unwrap();
        assert_eq!(m.vq_indices().unwrap(), idx);
        assert_eq!(m.payload_bits(), 16 * 8 * 10);
        assert_eq!(m.wire_bytes(), HEADER_BYTES + packed_len_bytes(16 * 8, 10));
        assert_eq!(m.bits_per_token(), 80.0);
    }

    #[test]
    fn dense_roundtrip() {
        let x = Tensor::from_vec(&[2, 3], vec![1.0, -2.5, 3.25, 0.0, 1e-9, -1e9]).unwrap();
        let m = Message::dense(0, 2, &x).unwrap();
        assert_eq!(m.dense_tensor().unwrap(), x);
        assert_eq!(m.payload_bits(), 2 * 3 * 32);
        assert_eq!(m.bits_per_token(), 96.0);
    }

    #[test]
    fn compression_vs_dense() {
        // paper headline: 10-bit codes vs 768 f32 dims = 2457.6x
        let t = 4;
        let idx = vec![0u32; t];
        let vq = Message::vq(0, 0, &idx, t, 1, 10).unwrap();
        let dense = Message::dense(0, 0, &Tensor::zeros(&[t, 768])).unwrap();
        let ratio = dense.payload_bits() as f64 / vq.payload_bits() as f64;
        assert!((ratio - 2457.6).abs() < 0.1);
    }

    #[test]
    fn kind_mismatch_errors() {
        let m = Message::dense(0, 0, &Tensor::zeros(&[1, 4])).unwrap();
        assert!(m.vq_indices().is_err());
        let v = Message::vq(0, 0, &[1, 2], 2, 1, 4).unwrap();
        assert!(v.dense_tensor().is_err());
    }
}
