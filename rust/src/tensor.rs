//! Minimal dense f32 tensor used by the native reference model, the VQ
//! engine, and host-side staging for the PJRT runtime.
//!
//! Row-major, owned storage. This is deliberately not a general ndarray:
//! the hot paths (matmul, layernorm, attention) are hand-written for the
//! 2-D shapes the coordinator needs, with a cache-blocked matmul that the
//! §Perf pass tunes.

use anyhow::{bail, Result};

/// Dense row-major f32 tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {shape:?} wants {n} elements, got {}", data.len());
        }
        Ok(Tensor { shape: shape.to_vec(), data })
    }

    pub fn scalar(v: f32) -> Tensor {
        Tensor { shape: vec![], data: vec![v] }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// Rows/cols of a 2-D tensor.
    pub fn dims2(&self) -> Result<(usize, usize)> {
        if self.shape.len() != 2 {
            bail!("expected rank-2, got shape {:?}", self.shape);
        }
        Ok((self.shape[0], self.shape[1]))
    }

    pub fn row(&self, i: usize) -> &[f32] {
        let (_, c) = (self.shape[0], self.shape[1]);
        &self.data[i * c..(i + 1) * c]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        let c = self.shape[1];
        &mut self.data[i * c..(i + 1) * c]
    }

    pub fn reshape(mut self, shape: &[usize]) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if n != self.data.len() {
            bail!("cannot reshape {:?} -> {shape:?}", self.shape);
        }
        self.shape = shape.to_vec();
        Ok(self)
    }

    /// Stack rows of `parts` (all [ri, C]) into one [sum ri, C] tensor.
    pub fn vcat(parts: &[&Tensor]) -> Result<Tensor> {
        if parts.is_empty() {
            bail!("vcat of nothing");
        }
        let c = parts[0].shape[1];
        let mut data = Vec::new();
        let mut rows = 0;
        for p in parts {
            let (r, pc) = p.dims2()?;
            if pc != c {
                bail!("vcat width mismatch: {c} vs {pc}");
            }
            rows += r;
            data.extend_from_slice(&p.data);
        }
        Tensor::from_vec(&[rows, c], data)
    }

    /// Slice rows [start, start+len) of a 2-D tensor.
    pub fn rows(&self, start: usize, len: usize) -> Result<Tensor> {
        let (r, c) = self.dims2()?;
        if start + len > r {
            bail!("row slice {start}+{len} out of {r}");
        }
        Ok(Tensor {
            shape: vec![len, c],
            data: self.data[start * c..(start + len) * c].to_vec(),
        })
    }
}

/// C = A @ B for A [m, k], B [k, n]. Cache-blocked over k with an
/// accumulate-into-row inner loop (auto-vectorizes well on one core).
///
/// Every output row is an independent function of its input row, and the
/// inner accumulation walks k in one fixed order regardless of m — so
/// stacking per-slot hidden states into one [batch, d_model] activation
/// (the fused live-decode path) produces bit-identical floats to running
/// the rows one at a time. The batched-vs-serial pins in
/// `tests/live_vs_model.rs` lean on exactly this property.
pub fn matmul(a: &Tensor, b: &Tensor) -> Result<Tensor> {
    let (m, k) = a.dims2()?;
    let (kb, n) = b.dims2()?;
    if k != kb {
        bail!("matmul inner dim mismatch {k} vs {kb}");
    }
    let mut out = vec![0.0f32; m * n];
    // ikj order: for each a[i, kk], axpy into out row i. Streams B rows.
    for i in 0..m {
        let arow = &a.data[i * k..(i + 1) * k];
        let orow = &mut out[i * n..(i + 1) * n];
        for (kk, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let brow = &b.data[kk * n..(kk + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow.iter()) {
                *o += av * bv;
            }
        }
    }
    Tensor::from_vec(&[m, n], out)
}

/// C = A @ B^T for A [m, k], B [n, k] — the attention QK^T shape.
pub fn matmul_bt(a: &Tensor, b: &Tensor) -> Result<Tensor> {
    let (m, k) = a.dims2()?;
    let (n, kb) = b.dims2()?;
    if k != kb {
        bail!("matmul_bt inner dim mismatch {k} vs {kb}");
    }
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        let arow = &a.data[i * k..(i + 1) * k];
        for j in 0..n {
            let brow = &b.data[j * k..(j + 1) * k];
            let mut acc = 0.0f32;
            for (x, y) in arow.iter().zip(brow.iter()) {
                acc += x * y;
            }
            out[i * n + j] = acc;
        }
    }
    Tensor::from_vec(&[m, n], out)
}

/// y = x + b broadcast over rows (x [m, n], b [n]).
pub fn add_bias(x: &mut Tensor, b: &[f32]) {
    let n = b.len();
    for row in x.data.chunks_mut(n) {
        for (v, bv) in row.iter_mut().zip(b.iter()) {
            *v += bv;
        }
    }
}

/// Element-wise a += b.
pub fn add_inplace(a: &mut Tensor, b: &Tensor) {
    debug_assert_eq!(a.shape, b.shape);
    for (x, y) in a.data.iter_mut().zip(b.data.iter()) {
        *x += y;
    }
}

/// LayerNorm over the last axis of a 2-D tensor.
pub fn layer_norm(x: &Tensor, gamma: &[f32], beta: &[f32], eps: f32) -> Tensor {
    let (m, n) = (x.shape[0], x.shape[1]);
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        let row = &x.data[i * n..(i + 1) * n];
        let mean = row.iter().sum::<f32>() / n as f32;
        let var = row.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / n as f32;
        let inv = 1.0 / (var + eps).sqrt();
        let orow = &mut out[i * n..(i + 1) * n];
        for j in 0..n {
            orow[j] = (row[j] - mean) * inv * gamma[j] + beta[j];
        }
    }
    Tensor { shape: vec![m, n], data: out }
}

/// GELU with the tanh approximation (matches kernels/ref.py exactly).
pub fn gelu(x: &mut Tensor) {
    for v in x.data.iter_mut() {
        let h = *v;
        *v = 0.5 * h * (1.0 + (0.7978845608028654 * (h + 0.044715 * h * h * h)).tanh());
    }
}

/// Row-wise softmax with additive bias (bias same shape, may be -1e30).
pub fn softmax_rows(x: &mut Tensor, bias: Option<&Tensor>) {
    let (m, n) = (x.shape[0], x.shape[1]);
    for i in 0..m {
        let row = &mut x.data[i * n..(i + 1) * n];
        if let Some(b) = bias {
            for (v, bv) in row.iter_mut().zip(b.data[i * n..(i + 1) * n].iter()) {
                *v += bv;
            }
        }
        let max = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0f32;
        for v in row.iter_mut() {
            *v = (*v - max).exp();
            sum += *v;
        }
        let inv = 1.0 / sum;
        for v in row.iter_mut() {
            *v *= inv;
        }
    }
}

/// Max |a - b| over all elements.
pub fn max_abs_diff(a: &Tensor, b: &Tensor) -> f32 {
    a.data
        .iter()
        .zip(b.data.iter())
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f32::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_small() {
        let a = Tensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let b = Tensor::from_vec(&[2, 2], vec![1.0, 1.0, 1.0, 1.0]).unwrap();
        let c = matmul(&a, &b).unwrap();
        assert_eq!(c.data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_bt_matches_matmul() {
        let a = Tensor::from_vec(&[2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]).unwrap();
        let bt = Tensor::from_vec(&[2, 3], vec![1.0, 0.0, 1.0, 0.0, 1.0, 0.0]).unwrap();
        // b = bt^T = [3, 2]
        let b = Tensor::from_vec(&[3, 2], vec![1.0, 0.0, 0.0, 1.0, 1.0, 0.0]).unwrap();
        assert_eq!(matmul_bt(&a, &bt).unwrap().data, matmul(&a, &b).unwrap().data);
    }

    #[test]
    fn layer_norm_zero_mean_unit_var() {
        let x = Tensor::from_vec(&[1, 4], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let g = vec![1.0; 4];
        let b = vec![0.0; 4];
        let y = layer_norm(&x, &g, &b, 1e-5);
        let mean: f32 = y.data.iter().sum::<f32>() / 4.0;
        assert!(mean.abs() < 1e-5);
        let var: f32 = y.data.iter().map(|v| v * v).sum::<f32>() / 4.0;
        assert!((var - 1.0).abs() < 1e-3);
    }

    #[test]
    fn softmax_rows_sums_to_one() {
        let mut x = Tensor::from_vec(&[2, 3], vec![1.0, 2.0, 3.0, 0.0, 0.0, 0.0]).unwrap();
        softmax_rows(&mut x, None);
        for i in 0..2 {
            let s: f32 = x.row(i).iter().sum();
            assert!((s - 1.0).abs() < 1e-6);
        }
    }

    #[test]
    fn softmax_respects_mask() {
        let mut x = Tensor::from_vec(&[1, 3], vec![5.0, 5.0, 5.0]).unwrap();
        let bias = Tensor::from_vec(&[1, 3], vec![0.0, -1e30, 0.0]).unwrap();
        softmax_rows(&mut x, Some(&bias));
        assert!(x.data[1] < 1e-12);
        assert!((x.data[0] - 0.5).abs() < 1e-6);
    }

    #[test]
    fn vcat_and_rows() {
        let a = Tensor::from_vec(&[1, 2], vec![1.0, 2.0]).unwrap();
        let b = Tensor::from_vec(&[2, 2], vec![3.0, 4.0, 5.0, 6.0]).unwrap();
        let c = Tensor::vcat(&[&a, &b]).unwrap();
        assert_eq!(c.shape, vec![3, 2]);
        assert_eq!(c.rows(1, 2).unwrap().data, vec![3.0, 4.0, 5.0, 6.0]);
    }

    #[test]
    fn shape_errors() {
        let a = Tensor::from_vec(&[2, 2], vec![0.0; 4]).unwrap();
        let b = Tensor::from_vec(&[3, 2], vec![0.0; 6]).unwrap();
        assert!(matmul(&a, &b).is_err());
        assert!(Tensor::from_vec(&[2, 3], vec![0.0; 4]).is_err());
    }
}
