//! Pure-rust reference transformer — numerically mirrors the python graph
//! builders in `python/compile/model.py` (same GELU approximation, same
//! LayerNorm epsilon, same block structure), so PJRT outputs can be
//! cross-checked end-to-end and arbitrary shapes can run without artifacts.

use anyhow::Result;

use crate::tensor::{
    add_bias, add_inplace, gelu, layer_norm, matmul, matmul_bt, softmax_rows, Tensor,
};

pub const NEG: f32 = -1e30;
const LN_EPS: f32 = 1e-5;

/// Weights of one transformer block, mirroring BLOCK_WEIGHT_NAMES order.
#[derive(Debug, Clone)]
pub struct BlockWeights {
    pub ln1_g: Vec<f32>,
    pub ln1_b: Vec<f32>,
    pub wq: Tensor,
    pub bq: Vec<f32>,
    pub wk: Tensor,
    pub bk: Vec<f32>,
    pub wv: Tensor,
    pub bv: Vec<f32>,
    pub wo: Tensor,
    pub bo: Vec<f32>,
    pub ln2_g: Vec<f32>,
    pub ln2_b: Vec<f32>,
    pub w1: Tensor,
    pub b1: Vec<f32>,
    pub w2: Tensor,
    pub b2: Vec<f32>,
}

impl BlockWeights {
    /// Flat ordered tensor list matching python's block_weights_list.
    pub fn as_list(&self) -> Vec<Tensor> {
        vec![
            Tensor::from_vec(&[self.ln1_g.len()], self.ln1_g.clone()).unwrap(),
            Tensor::from_vec(&[self.ln1_b.len()], self.ln1_b.clone()).unwrap(),
            self.wq.clone(),
            Tensor::from_vec(&[self.bq.len()], self.bq.clone()).unwrap(),
            self.wk.clone(),
            Tensor::from_vec(&[self.bk.len()], self.bk.clone()).unwrap(),
            self.wv.clone(),
            Tensor::from_vec(&[self.bv.len()], self.bv.clone()).unwrap(),
            self.wo.clone(),
            Tensor::from_vec(&[self.bo.len()], self.bo.clone()).unwrap(),
            Tensor::from_vec(&[self.ln2_g.len()], self.ln2_g.clone()).unwrap(),
            Tensor::from_vec(&[self.ln2_b.len()], self.ln2_b.clone()).unwrap(),
            self.w1.clone(),
            Tensor::from_vec(&[self.b1.len()], self.b1.clone()).unwrap(),
            self.w2.clone(),
            Tensor::from_vec(&[self.b2.len()], self.b2.clone()).unwrap(),
        ]
    }

    /// Random init for tests (mirrors scale of python init loosely).
    pub fn random(rng: &mut crate::util::rng::Rng, d: usize, f: usize) -> Self {
        let mk = |rng: &mut crate::util::rng::Rng, r: usize, c: usize| {
            let mut t = Tensor::zeros(&[r, c]);
            let scale = (r as f32).powf(-0.5);
            for v in t.data.iter_mut() {
                *v = rng.normal_f32(0.0, scale);
            }
            t
        };
        BlockWeights {
            ln1_g: vec![1.0; d],
            ln1_b: vec![0.0; d],
            wq: mk(rng, d, d),
            bq: vec![0.0; d],
            wk: mk(rng, d, d),
            bk: vec![0.0; d],
            wv: mk(rng, d, d),
            bv: vec![0.0; d],
            wo: mk(rng, d, d),
            bo: vec![0.0; d],
            ln2_g: vec![1.0; d],
            ln2_b: vec![0.0; d],
            w1: mk(rng, d, f),
            b1: vec![0.0; f],
            w2: mk(rng, f, d),
            b2: vec![0.0; d],
        }
    }
}

/// Multi-head attention: q [Tq, D], k/v [S, D], bias [Tq, S] or None.
/// Returns [Tq, D] (pre-output-projection).
pub fn attention(q: &Tensor, k: &Tensor, v: &Tensor, bias: Option<&Tensor>, n_heads: usize) -> Result<Tensor> {
    let (tq, d) = q.dims2()?;
    let (s, _) = k.dims2()?;
    let dh = d / n_heads;
    let scale = 1.0 / (dh as f32).sqrt();
    let mut out = Tensor::zeros(&[tq, d]);
    // per-head views without copying whole matrices: gather head columns
    for h in 0..n_heads {
        let col0 = h * dh;
        let take = |m: &Tensor, rows: usize| -> Tensor {
            let mut t = Tensor::zeros(&[rows, dh]);
            for i in 0..rows {
                t.row_mut(i).copy_from_slice(&m.row(i)[col0..col0 + dh]);
            }
            t
        };
        let qh = take(q, tq);
        let kh = take(k, s);
        let vh = take(v, s);
        let mut logits = matmul_bt(&qh, &kh)?;
        for val in logits.data.iter_mut() {
            *val *= scale;
        }
        softmax_rows(&mut logits, bias);
        let oh = matmul(&logits, &vh)?;
        for i in 0..tq {
            out.row_mut(i)[col0..col0 + dh].copy_from_slice(oh.row(i));
        }
    }
    Ok(out)
}

fn project(x: &Tensor, w: &Tensor, b: &[f32]) -> Result<Tensor> {
    let mut y = matmul(x, w)?;
    add_bias(&mut y, b);
    Ok(y)
}

fn mlp(blk: &BlockWeights, x: &Tensor) -> Result<Tensor> {
    let xn = layer_norm(x, &blk.ln2_g, &blk.ln2_b, LN_EPS);
    let mut h = project(&xn, &blk.w1, &blk.b1)?;
    gelu(&mut h);
    project(&h, &blk.w2, &blk.b2)
}

/// Full-precision transformer block over the whole sequence —
/// mirrors python `baseline_block`.
pub fn baseline_block(h: &Tensor, bias: Option<&Tensor>, blk: &BlockWeights, n_heads: usize) -> Result<Tensor> {
    let xn = layer_norm(h, &blk.ln1_g, &blk.ln1_b, LN_EPS);
    let q = project(&xn, &blk.wq, &blk.bq)?;
    let k = project(&xn, &blk.wk, &blk.bk)?;
    let v = project(&xn, &blk.wv, &blk.bv)?;
    let att = attention(&q, &k, &v, bias, n_heads)?;
    let mut h1 = project(&att, &blk.wo, &blk.bo)?;
    add_inplace(&mut h1, h);
    let m = mlp(blk, &h1)?;
    let mut out = h1;
    add_inplace(&mut out, &m);
    Ok(out)
}

/// Mixed-Precision Attention block on one device —
/// mirrors python `astra_block_device`: local rows full precision,
/// remote rows are dequantized VQ embeddings.
pub fn astra_block(
    h_local: &Tensor,
    x_hat_remote: &Tensor,
    bias: Option<&Tensor>,
    blk: &BlockWeights,
    n_heads: usize,
) -> Result<Tensor> {
    let ln_l = layer_norm(h_local, &blk.ln1_g, &blk.ln1_b, LN_EPS);
    let ln_r = layer_norm(x_hat_remote, &blk.ln1_g, &blk.ln1_b, LN_EPS);
    let q = project(&ln_l, &blk.wq, &blk.bq)?;
    let k_l = project(&ln_l, &blk.wk, &blk.bk)?;
    let v_l = project(&ln_l, &blk.wv, &blk.bv)?;
    let k_r = project(&ln_r, &blk.wk, &blk.bk)?;
    let v_r = project(&ln_r, &blk.wv, &blk.bv)?;
    let k = Tensor::vcat(&[&k_l, &k_r])?;
    let v = Tensor::vcat(&[&v_l, &v_r])?;
    let att = attention(&q, &k, &v, bias, n_heads)?;
    let mut h1 = project(&att, &blk.wo, &blk.bo)?;
    add_inplace(&mut h1, h_local);
    let m = mlp(blk, &h1)?;
    let mut out = h1;
    add_inplace(&mut out, &m);
    Ok(out)
}

/// Distributed Class Token aggregation + classifier head —
/// mirrors python `head_graph`.
pub fn head(cls_stack: &Tensor, lnf_g: &[f32], lnf_b: &[f32], w: &Tensor, b: &[f32]) -> Result<Tensor> {
    let (n, d) = cls_stack.dims2()?;
    let mut pooled = Tensor::zeros(&[1, d]);
    for i in 0..n {
        for (p, v) in pooled.row_mut(0).iter_mut().zip(cls_stack.row(i)) {
            *p += v / n as f32;
        }
    }
    let normed = layer_norm(&pooled, lnf_g, lnf_b, LN_EPS);
    project(&normed, w, b)
}

/// LM head — mirrors python `lm_head_graph`.
pub fn lm_head(h: &Tensor, lnf_g: &[f32], lnf_b: &[f32], w: &Tensor, b: &[f32]) -> Result<Tensor> {
    let normed = layer_norm(h, lnf_g, lnf_b, LN_EPS);
    project(&normed, w, b)
}

/// Causal bias [t, t] (0 allowed, NEG future).
pub fn causal_bias(t: usize) -> Tensor {
    let mut b = Tensor::zeros(&[t, t]);
    for i in 0..t {
        for j in (i + 1)..t {
            b.data[i * t + j] = NEG;
        }
    }
    b
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn randn(rng: &mut Rng, shape: &[usize]) -> Tensor {
        let mut t = Tensor::zeros(shape);
        rng.fill_normal(&mut t.data);
        t
    }

    #[test]
    fn attention_uniform_when_logits_equal() {
        // all-zero q => uniform attention => output = mean of v rows
        let q = Tensor::zeros(&[1, 8]);
        let mut rng = Rng::new(0);
        let k = randn(&mut rng, &[4, 8]);
        let v = randn(&mut rng, &[4, 8]);
        let out = attention(&q, &k, &v, None, 2).unwrap();
        for j in 0..8 {
            let want: f32 = (0..4).map(|i| v.row(i)[j]).sum::<f32>() / 4.0;
            assert!((out.row(0)[j] - want).abs() < 1e-5);
        }
    }

    #[test]
    fn causal_first_row_attends_self_only() {
        let mut rng = Rng::new(1);
        let q = randn(&mut rng, &[3, 8]);
        let k = randn(&mut rng, &[3, 8]);
        let v = randn(&mut rng, &[3, 8]);
        let bias = causal_bias(3);
        let out = attention(&q, &k, &v, Some(&bias), 2).unwrap();
        for j in 0..8 {
            assert!((out.row(0)[j] - v.row(0)[j]).abs() < 1e-5);
        }
    }

    #[test]
    fn astra_block_equals_baseline_when_remote_is_exact() {
        // If the "quantized" remote rows equal the true remote rows and the
        // bias admits everything, astra_block(local) must equal the local
        // rows of baseline_block over the concatenated sequence (local rows
        // first — attention is permutation-covariant in keys).
        let mut rng = Rng::new(2);
        let d = 16;
        let blk = BlockWeights::random(&mut rng, d, 32);
        let local = randn(&mut rng, &[3, d]);
        let remote = randn(&mut rng, &[5, d]);
        let full = Tensor::vcat(&[&local, &remote]).unwrap();
        let base = baseline_block(&full, None, &blk, 4).unwrap();
        let astra = astra_block(&local, &remote, None, &blk, 4).unwrap();
        for i in 0..3 {
            for j in 0..d {
                assert!(
                    (astra.row(i)[j] - base.row(i)[j]).abs() < 1e-4,
                    "row {i} col {j}"
                );
            }
        }
    }

    #[test]
    fn head_pools_cls_replicas() {
        let cls = Tensor::from_vec(&[2, 4], vec![1.0, 2.0, 3.0, 4.0, 3.0, 4.0, 5.0, 6.0]).unwrap();
        let w = Tensor::from_vec(&[4, 2], vec![1.0, 0.0, 0.0, 1.0, 0.0, 0.0, 0.0, 0.0]).unwrap();
        let out = head(&cls, &[1.0; 4], &[0.0; 4], &w, &[0.0, 0.0]).unwrap();
        assert_eq!(out.shape, vec![1, 2]);
        // pooled = [2,3,4,5]; ln then project — just check finiteness/shape
        assert!(out.data.iter().all(|v| v.is_finite()));
    }
}
