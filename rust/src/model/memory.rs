//! Appendix G memory models: VQ codebook storage and mixed KV-cache cost.

use super::shape::{ceil_log2, TransformerShape};

/// Codebook bytes: L * C * K * d * b (independent of the group count —
/// grouped VQ partitions d into G slices of d/G).
pub fn codebook_bytes(
    layers: usize,
    codebooks_per_layer: usize,
    k: usize,
    d_model: usize,
    elem_bytes: usize,
) -> usize {
    layers * codebooks_per_layer * k * d_model * elem_bytes
}

/// Original full-precision KV cache: 2 * N * L * d * b.
pub fn kv_cache_bytes_full(shape: &TransformerShape, seq_len: usize, elem_bytes: usize) -> usize {
    2 * seq_len * shape.n_layers * shape.d_model * elem_bytes
}

/// ASTRA mixed KV cache (Appendix G Eq. 39): local tokens full precision,
/// non-local tokens as G VQ indices of log2(K) bits each. The tail device
/// (which runs decode and owns the cache) holds the remainder when
/// `seq_len` does not divide evenly, so every token is accounted exactly —
/// `seq_len / n_devices` alone silently undercounted the tail remainder.
pub fn kv_cache_bytes_astra(
    shape: &TransformerShape,
    seq_len: usize,
    elem_bytes: usize,
    n_devices: usize,
    groups: usize,
    k: usize,
) -> usize {
    let n = n_devices.max(1);
    let local_tokens = seq_len / n + seq_len % n;
    let remote_tokens = seq_len - local_tokens;
    let local = local_tokens * shape.n_layers * shape.d_model * elem_bytes;
    let nonlocal_bits = remote_tokens * shape.n_layers * groups * ceil_log2(k);
    2 * (local + nonlocal_bits.div_ceil(8))
}

/// Full-precision K+V bytes one appended token costs across all layers —
/// the per-step growth of a decode session's cache on the tail device.
pub fn kv_token_bytes_full(shape: &TransformerShape, elem_bytes: usize) -> usize {
    2 * shape.n_layers * shape.d_model * elem_bytes
}

/// Positional-locality variant of the Appendix-G mixed cache, used by the
/// block-based KV pool (`crate::kv`) when prefix sharing is enabled.
///
/// The classic accounting ([`kv_cache_bytes_astra_live`]) decides which
/// tokens are full precision by scaling the token partition to the
/// prompt's *total length* — two prompts of different lengths that share
/// leading token ids therefore hold *different* bytes (and different
/// rows) for the same positions, which makes their caches unshareable.
/// Here locality is a pure function of a token's absolute position: the
/// tail device owns the last `seq_len / N + seq_len % N` positions of the
/// artifact's full window, and a prompt of `prompt_len` tokens holds in
/// full precision exactly the positions it occupies inside that window.
/// Block bytes become prefix differences of this function, identical for
/// every prompt sharing the prefix. At `prompt_len == seq_len` it equals
/// [`kv_cache_bytes_astra`] exactly.
pub fn kv_cache_bytes_astra_positional(
    shape: &TransformerShape,
    prompt_len: usize,
    generated: usize,
    elem_bytes: usize,
    n_devices: usize,
    groups: usize,
    k: usize,
) -> usize {
    let n = n_devices.max(1);
    let seq = shape.seq_len.max(1);
    let local_window = seq / n + seq % n;
    let local_start = seq - local_window;
    let local_tokens = prompt_len.saturating_sub(local_start);
    let remote_tokens = prompt_len - local_tokens;
    let local = local_tokens * shape.n_layers * shape.d_model * elem_bytes;
    let nonlocal_bits = remote_tokens * shape.n_layers * groups * ceil_log2(k);
    2 * (local + nonlocal_bits.div_ceil(8))
        + generated * kv_token_bytes_full(shape, elem_bytes)
}

/// Memory held by a live decode slot: the Appendix-G mixed cache over the
/// `prompt_len` prefill tokens plus `generated` decode tokens appended in
/// full precision on the tail device. This is the quantity the serving
/// scheduler's KV admission gate (`crate::kv::pool::KvPool`) tracks per
/// slot when prefix sharing is off.
pub fn kv_cache_bytes_astra_live(
    shape: &TransformerShape,
    prompt_len: usize,
    generated: usize,
    elem_bytes: usize,
    n_devices: usize,
    groups: usize,
    k: usize,
) -> usize {
    kv_cache_bytes_astra(shape, prompt_len, elem_bytes, n_devices, groups, k)
        + generated * kv_token_bytes_full(shape, elem_bytes)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn llama_codebook_128mib() {
        // Appendix G: L=32, C=2, K=1024, d=1024, b=2 -> 128 MiB
        assert_eq!(codebook_bytes(32, 2, 1024, 1024, 2), 134_217_728);
    }

    #[test]
    fn llama_kv_cache_example() {
        // Appendix G Eqs. 40-41 use d=1024 (the paper's worked numbers).
        let shape = TransformerShape {
            n_layers: 32,
            d_model: 1024,
            n_heads: 32,
            d_ff: 14336,
            seq_len: 1024,
            elem_bytes: 2,
        };
        assert_eq!(kv_cache_bytes_full(&shape, 1024, 2), 134_217_728);
        let astra = kv_cache_bytes_astra(&shape, 1024, 2, 4, 32, 1024);
        assert_eq!(astra, 35_520_512);
        // ~26.5% of original
        let ratio = astra as f64 / 134_217_728.0;
        assert!((ratio - 0.2646).abs() < 0.01, "{ratio}");
    }

    #[test]
    fn non_divisible_seq_len_counts_the_tail_remainder() {
        // regression: seq_len / n_devices silently dropped the remainder
        // tokens the tail device owns. 7 tokens over 2 devices: the tail
        // holds 4 locally (3 + the remainder 1), 3 arrive as codes.
        let shape = TransformerShape {
            n_layers: 2,
            d_model: 8,
            n_heads: 2,
            d_ff: 16,
            seq_len: 7,
            elem_bytes: 4,
        };
        // local: 4 tok * 2 L * 8 D * 4 B = 256; remote: 3 * 2 * 4 groups
        // * 4 bits (K=16) = 96 bits = 12 B; K and V each -> 2 * 268
        assert_eq!(kv_cache_bytes_astra(&shape, 7, 4, 2, 4, 16), 536);
        // the old formula dropped the `seq_len % n` remainder tokens from
        // BOTH the local and remote counts; the fix never under-counts,
        // and strictly exceeds the buggy value whenever a remainder exists
        for n in [2usize, 3, 4] {
            for s in 1..64 {
                let fixed = kv_cache_bytes_astra(&shape, s, 4, n, 4, 16);
                let local_old = s / n * shape.n_layers * shape.d_model * 4;
                let bits_old = (n - 1) * (s / n) * shape.n_layers * 4 * 4;
                let old = 2 * (local_old + bits_old / 8);
                assert!(fixed >= old, "n={n} s={s}: {fixed} < {old}");
                if s % n != 0 {
                    assert!(fixed > old, "n={n} s={s}: remainder still uncounted");
                }
            }
        }
    }

    #[test]
    fn live_cache_adds_full_precision_decode_rows() {
        let shape = TransformerShape {
            n_layers: 2,
            d_model: 8,
            n_heads: 2,
            d_ff: 16,
            seq_len: 7,
            elem_bytes: 4,
        };
        let base = kv_cache_bytes_astra(&shape, 7, 4, 2, 4, 16);
        let per_tok = kv_token_bytes_full(&shape, 4);
        assert_eq!(per_tok, 2 * 2 * 8 * 4);
        assert_eq!(kv_cache_bytes_astra_live(&shape, 7, 0, 4, 2, 4, 16), base);
        assert_eq!(
            kv_cache_bytes_astra_live(&shape, 7, 5, 4, 2, 4, 16),
            base + 5 * per_tok
        );
    }

    #[test]
    fn positional_accounting_matches_classic_at_full_length_and_telescopes() {
        let shape = TransformerShape {
            n_layers: 2,
            d_model: 8,
            n_heads: 2,
            d_ff: 16,
            seq_len: 16,
            elem_bytes: 4,
        };
        for n in [1usize, 2, 3, 4] {
            // at the full window the two accountings agree exactly
            assert_eq!(
                kv_cache_bytes_astra_positional(&shape, 16, 0, 4, n, 4, 16),
                kv_cache_bytes_astra(&shape, 16, 4, n, 4, 16),
                "n={n}"
            );
            // prefix-difference block bytes telescope to the total, so the
            // pool's block + private sum equals the flat accounting
            let total = kv_cache_bytes_astra_positional(&shape, 13, 0, 4, n, 4, 16);
            let mut sum = 0usize;
            for (lo, hi) in [(0usize, 4usize), (4, 8), (8, 12), (12, 13)] {
                sum += kv_cache_bytes_astra_positional(&shape, hi, 0, 4, n, 4, 16)
                    - kv_cache_bytes_astra_positional(&shape, lo, 0, 4, n, 4, 16);
            }
            assert_eq!(sum, total, "n={n}");
            // monotone in prompt length; generated rows append full rows
            let mut prev = 0;
            for t in 0..=16 {
                let b = kv_cache_bytes_astra_positional(&shape, t, 0, 4, n, 4, 16);
                assert!(b >= prev, "n={n} t={t}");
                prev = b;
            }
            assert_eq!(
                kv_cache_bytes_astra_positional(&shape, 7, 3, 4, n, 4, 16),
                kv_cache_bytes_astra_positional(&shape, 7, 0, 4, n, 4, 16)
                    + 3 * kv_token_bytes_full(&shape, 4)
            );
        }
        // a short prompt outside the tail window holds only quantized rows
        let short = kv_cache_bytes_astra_positional(&shape, 4, 0, 4, 4, 4, 16);
        let bits = 4 * shape.n_layers * 4 * 4; // 4 tok * 2 L * G=4 * log2(16)
        assert_eq!(short, 2 * (bits / 8));
    }

    #[test]
    fn astra_cache_always_smaller_with_compression() {
        let shape = TransformerShape::paper_encoder(1024);
        let full = kv_cache_bytes_full(&shape, 1024, 4);
        for n in [2, 4, 8] {
            for g in [1, 16, 32] {
                let a = kv_cache_bytes_astra(&shape, 1024, 4, n, g, 1024);
                assert!(a < full, "n={n} g={g}: {a} vs {full}");
            }
        }
    }
}
