//! Appendix G memory models: VQ codebook storage and mixed KV-cache cost.

use super::shape::{ceil_log2, TransformerShape};

/// Codebook bytes: L * C * K * d * b (independent of the group count —
/// grouped VQ partitions d into G slices of d/G).
pub fn codebook_bytes(
    layers: usize,
    codebooks_per_layer: usize,
    k: usize,
    d_model: usize,
    elem_bytes: usize,
) -> usize {
    layers * codebooks_per_layer * k * d_model * elem_bytes
}

/// Original full-precision KV cache: 2 * N * L * d * b.
pub fn kv_cache_bytes_full(shape: &TransformerShape, seq_len: usize, elem_bytes: usize) -> usize {
    2 * seq_len * shape.n_layers * shape.d_model * elem_bytes
}

/// ASTRA mixed KV cache (Appendix G Eq. 39): local tokens full precision,
/// non-local tokens as G VQ indices of log2(K) bits each.
pub fn kv_cache_bytes_astra(
    shape: &TransformerShape,
    seq_len: usize,
    elem_bytes: usize,
    n_devices: usize,
    groups: usize,
    k: usize,
) -> usize {
    let local = seq_len / n_devices * shape.n_layers * shape.d_model * elem_bytes;
    let nonlocal_bits =
        (n_devices - 1) * (seq_len / n_devices) * shape.n_layers * groups * ceil_log2(k);
    2 * (local + nonlocal_bits / 8)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn llama_codebook_128mib() {
        // Appendix G: L=32, C=2, K=1024, d=1024, b=2 -> 128 MiB
        assert_eq!(codebook_bytes(32, 2, 1024, 1024, 2), 134_217_728);
    }

    #[test]
    fn llama_kv_cache_example() {
        // Appendix G Eqs. 40-41 use d=1024 (the paper's worked numbers).
        let shape = TransformerShape {
            n_layers: 32,
            d_model: 1024,
            n_heads: 32,
            d_ff: 14336,
            seq_len: 1024,
            elem_bytes: 2,
        };
        assert_eq!(kv_cache_bytes_full(&shape, 1024, 2), 134_217_728);
        let astra = kv_cache_bytes_astra(&shape, 1024, 2, 4, 32, 1024);
        assert_eq!(astra, 35_520_512);
        // ~26.5% of original
        let ratio = astra as f64 / 134_217_728.0;
        assert!((ratio - 0.2646).abs() < 0.01, "{ratio}");
    }

    #[test]
    fn astra_cache_always_smaller_with_compression() {
        let shape = TransformerShape::paper_encoder(1024);
        let full = kv_cache_bytes_full(&shape, 1024, 4);
        for n in [2, 4, 8] {
            for g in [1, 16, 32] {
                let a = kv_cache_bytes_astra(&shape, 1024, 4, n, g, 1024);
                assert!(a < full, "n={n} g={g}: {a} vs {full}");
            }
        }
    }
}
