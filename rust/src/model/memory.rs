//! Appendix G memory models: VQ codebook storage and mixed KV-cache cost.

use super::shape::{ceil_log2, TransformerShape};

/// Codebook bytes: L * C * K * d * b (independent of the group count —
/// grouped VQ partitions d into G slices of d/G).
pub fn codebook_bytes(
    layers: usize,
    codebooks_per_layer: usize,
    k: usize,
    d_model: usize,
    elem_bytes: usize,
) -> usize {
    layers * codebooks_per_layer * k * d_model * elem_bytes
}

/// Original full-precision KV cache: 2 * N * L * d * b.
pub fn kv_cache_bytes_full(shape: &TransformerShape, seq_len: usize, elem_bytes: usize) -> usize {
    2 * seq_len * shape.n_layers * shape.d_model * elem_bytes
}

/// ASTRA mixed KV cache (Appendix G Eq. 39): local tokens full precision,
/// non-local tokens as G VQ indices of log2(K) bits each. The tail device
/// (which runs decode and owns the cache) holds the remainder when
/// `seq_len` does not divide evenly, so every token is accounted exactly —
/// `seq_len / n_devices` alone silently undercounted the tail remainder.
pub fn kv_cache_bytes_astra(
    shape: &TransformerShape,
    seq_len: usize,
    elem_bytes: usize,
    n_devices: usize,
    groups: usize,
    k: usize,
) -> usize {
    let n = n_devices.max(1);
    let local_tokens = seq_len / n + seq_len % n;
    let remote_tokens = seq_len - local_tokens;
    let local = local_tokens * shape.n_layers * shape.d_model * elem_bytes;
    let nonlocal_bits = remote_tokens * shape.n_layers * groups * ceil_log2(k);
    2 * (local + nonlocal_bits.div_ceil(8))
}

/// Full-precision K+V bytes one appended token costs across all layers —
/// the per-step growth of a decode session's cache on the tail device.
pub fn kv_token_bytes_full(shape: &TransformerShape, elem_bytes: usize) -> usize {
    2 * shape.n_layers * shape.d_model * elem_bytes
}

/// Memory held by a live decode slot: the Appendix-G mixed cache over the
/// `prompt_len` prefill tokens plus `generated` decode tokens appended in
/// full precision on the tail device. This is the quantity the serving
/// scheduler's `KvBudget` admission gate tracks per slot.
pub fn kv_cache_bytes_astra_live(
    shape: &TransformerShape,
    prompt_len: usize,
    generated: usize,
    elem_bytes: usize,
    n_devices: usize,
    groups: usize,
    k: usize,
) -> usize {
    kv_cache_bytes_astra(shape, prompt_len, elem_bytes, n_devices, groups, k)
        + generated * kv_token_bytes_full(shape, elem_bytes)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn llama_codebook_128mib() {
        // Appendix G: L=32, C=2, K=1024, d=1024, b=2 -> 128 MiB
        assert_eq!(codebook_bytes(32, 2, 1024, 1024, 2), 134_217_728);
    }

    #[test]
    fn llama_kv_cache_example() {
        // Appendix G Eqs. 40-41 use d=1024 (the paper's worked numbers).
        let shape = TransformerShape {
            n_layers: 32,
            d_model: 1024,
            n_heads: 32,
            d_ff: 14336,
            seq_len: 1024,
            elem_bytes: 2,
        };
        assert_eq!(kv_cache_bytes_full(&shape, 1024, 2), 134_217_728);
        let astra = kv_cache_bytes_astra(&shape, 1024, 2, 4, 32, 1024);
        assert_eq!(astra, 35_520_512);
        // ~26.5% of original
        let ratio = astra as f64 / 134_217_728.0;
        assert!((ratio - 0.2646).abs() < 0.01, "{ratio}");
    }

    #[test]
    fn non_divisible_seq_len_counts_the_tail_remainder() {
        // regression: seq_len / n_devices silently dropped the remainder
        // tokens the tail device owns. 7 tokens over 2 devices: the tail
        // holds 4 locally (3 + the remainder 1), 3 arrive as codes.
        let shape = TransformerShape {
            n_layers: 2,
            d_model: 8,
            n_heads: 2,
            d_ff: 16,
            seq_len: 7,
            elem_bytes: 4,
        };
        // local: 4 tok * 2 L * 8 D * 4 B = 256; remote: 3 * 2 * 4 groups
        // * 4 bits (K=16) = 96 bits = 12 B; K and V each -> 2 * 268
        assert_eq!(kv_cache_bytes_astra(&shape, 7, 4, 2, 4, 16), 536);
        // the old formula dropped the `seq_len % n` remainder tokens from
        // BOTH the local and remote counts; the fix never under-counts,
        // and strictly exceeds the buggy value whenever a remainder exists
        for n in [2usize, 3, 4] {
            for s in 1..64 {
                let fixed = kv_cache_bytes_astra(&shape, s, 4, n, 4, 16);
                let local_old = s / n * shape.n_layers * shape.d_model * 4;
                let bits_old = (n - 1) * (s / n) * shape.n_layers * 4 * 4;
                let old = 2 * (local_old + bits_old / 8);
                assert!(fixed >= old, "n={n} s={s}: {fixed} < {old}");
                if s % n != 0 {
                    assert!(fixed > old, "n={n} s={s}: remainder still uncounted");
                }
            }
        }
    }

    #[test]
    fn live_cache_adds_full_precision_decode_rows() {
        let shape = TransformerShape {
            n_layers: 2,
            d_model: 8,
            n_heads: 2,
            d_ff: 16,
            seq_len: 7,
            elem_bytes: 4,
        };
        let base = kv_cache_bytes_astra(&shape, 7, 4, 2, 4, 16);
        let per_tok = kv_token_bytes_full(&shape, 4);
        assert_eq!(per_tok, 2 * 2 * 8 * 4);
        assert_eq!(kv_cache_bytes_astra_live(&shape, 7, 0, 4, 2, 4, 16), base);
        assert_eq!(
            kv_cache_bytes_astra_live(&shape, 7, 5, 4, 2, 4, 16),
            base + 5 * per_tok
        );
    }

    #[test]
    fn astra_cache_always_smaller_with_compression() {
        let shape = TransformerShape::paper_encoder(1024);
        let full = kv_cache_bytes_full(&shape, 1024, 4);
        for n in [2, 4, 8] {
            for g in [1, 16, 32] {
                let a = kv_cache_bytes_astra(&shape, 1024, 4, n, g, 1024);
                assert!(a < full, "n={n} g={g}: {a} vs {full}");
            }
        }
    }
}
