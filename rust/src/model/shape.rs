//! Transformer shape + FLOP/byte accounting used by the cost model and the
//! paper-table harness. All counts are per *single* forward pass (batch 1),
//! matching the paper's per-request latency setting.

/// Architecture of the transformer being served (paper notation:
/// L layers, D hidden, T tokens).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TransformerShape {
    pub n_layers: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub seq_len: usize,
    /// bytes per element of activations/weights on the wire and in compute
    /// (4 = f32, 1 = int8 for the Table 5/7 quantized settings).
    pub elem_bytes: usize,
}

impl TransformerShape {
    /// The 12-layer, 768-dim encoder used for Figures 1, 3–5 / Table 4.
    pub fn paper_encoder(seq_len: usize) -> Self {
        TransformerShape {
            n_layers: 12,
            d_model: 768,
            n_heads: 12,
            d_ff: 3072,
            seq_len,
            elem_bytes: 4,
        }
    }

    /// ViT-Base (Table 1/2/5): identical backbone to `paper_encoder`.
    pub fn vit_base(seq_len: usize) -> Self {
        Self::paper_encoder(seq_len)
    }

    /// GPT2-Small (Table 3).
    pub fn gpt2_small(seq_len: usize) -> Self {
        TransformerShape {
            n_layers: 12,
            d_model: 768,
            n_heads: 12,
            d_ff: 3072,
            seq_len,
            elem_bytes: 4,
        }
    }

    /// GPT2-Medium (Table 3).
    pub fn gpt2_medium(seq_len: usize) -> Self {
        TransformerShape {
            n_layers: 24,
            d_model: 1024,
            n_heads: 16,
            d_ff: 4096,
            seq_len,
            elem_bytes: 4,
        }
    }

    /// Llama-3-8B under 8-bit quantization (Tables 6/7). d_ff uses the
    /// gated-MLP effective 2x(11008-ish) rounded to the paper's comm math
    /// (bits/token = 8 * 4096 * 32 = 1,048,576 matches D=4096, L=32, 8-bit).
    pub fn llama3_8b(seq_len: usize) -> Self {
        TransformerShape {
            n_layers: 32,
            d_model: 4096,
            n_heads: 32,
            d_ff: 14336,
            seq_len,
            elem_bytes: 1,
        }
    }

    /// The small AstraFormer shipped in artifacts/ (tiny-enc default).
    pub fn tiny(seq_len: usize) -> Self {
        TransformerShape {
            n_layers: 4,
            d_model: 128,
            n_heads: 4,
            d_ff: 512,
            seq_len,
            elem_bytes: 4,
        }
    }

    pub fn d_head(&self) -> usize {
        self.d_model / self.n_heads
    }

    /// FLOPs of one transformer block over `t` tokens attending to `s`
    /// key/value positions (2*m*n*k per matmul).
    pub fn block_flops(&self, t: usize, s: usize) -> f64 {
        let d = self.d_model as f64;
        let f = self.d_ff as f64;
        let tq = t as f64;
        let kv = s as f64;
        // q projection for t tokens; k/v projections for s positions
        let qkv = 2.0 * tq * d * d + 2.0 * 2.0 * kv * d * d;
        let attn = 2.0 * tq * kv * d /* QK^T */ + 2.0 * tq * kv * d /* PV */;
        let proj = 2.0 * tq * d * d;
        let mlp = 2.0 * tq * d * f * 2.0;
        qkv + attn + proj + mlp
    }

    /// Whole-model FLOPs single-device (every token attends everywhere).
    pub fn total_flops(&self) -> f64 {
        self.n_layers as f64 * self.block_flops(self.seq_len, self.seq_len)
    }

    /// FLOPs of the grouped-VQ encode of `t` tokens (distance matmul):
    /// per group: t*K*(2*Dg) plus argmin ~ t*K.
    pub fn vq_encode_flops(&self, t: usize, groups: usize, k: usize) -> f64 {
        let dg = (self.d_model / groups) as f64;
        groups as f64 * (t as f64 * k as f64 * (2.0 * dg + 1.0))
    }

    /// Cost of the VQ decode. The serving implementation is a codebook
    /// *gather* (one row copy per group), so the cost is O(t*D) data
    /// movement, not the one-hot-matmul FLOPs the MXU formulation uses.
    pub fn vq_decode_flops(&self, t: usize, groups: usize, _k: usize) -> f64 {
        let dg = (self.d_model / groups) as f64;
        groups as f64 * t as f64 * dg
    }

    /// Block weight parameters: attention (4 D^2) + MLP (2 D d_ff),
    /// biases/norms omitted (sub-percent).
    pub fn block_params(&self) -> f64 {
        let d = self.d_model as f64;
        4.0 * d * d + 2.0 * d * self.d_ff as f64
    }

    /// Bytes of the whole model's block weights at `elem_bytes` precision —
    /// the working set one decode step must stream (memory-bound floor).
    pub fn weight_bytes(&self) -> f64 {
        self.n_layers as f64 * self.block_params() * self.elem_bytes as f64
    }

    /// FLOPs of one single-token decode step over a KV cache of `ctx`
    /// positions: q/k/v are projected for the new token only (cache hit),
    /// attention reads `ctx + 1` positions, MLP runs on one token.
    pub fn decode_step_flops(&self, ctx: usize) -> f64 {
        let d = self.d_model as f64;
        let f = self.d_ff as f64;
        let kv = ctx as f64 + 1.0;
        let qkv = 3.0 * 2.0 * d * d;
        let attn = 2.0 * kv * d /* qK^T */ + 2.0 * kv * d /* PV */;
        let proj = 2.0 * d * d;
        let mlp = 2.0 * d * f * 2.0;
        self.n_layers as f64 * (qkv + attn + proj + mlp)
    }

    /// FLOPs of one block advancing a *prefill chunk* whose predecessors
    /// are already cached: q / output-projection / MLP for the device's
    /// `t_local` chunk rows, K/V projections for all `t_chunk` new rows
    /// (local full-precision plus dequantized remote — earlier rows' K/V
    /// live in the cache and are not re-projected, which is what separates
    /// a chunk from the from-scratch [`Self::block_flops`]), and attention
    /// of the local rows over `ctx` total positions.
    pub fn chunk_block_flops(&self, t_local: usize, t_chunk: usize, ctx: usize) -> f64 {
        let d = self.d_model as f64;
        let f = self.d_ff as f64;
        let tq = t_local as f64;
        let kv = ctx as f64;
        let q = 2.0 * tq * d * d;
        let kvproj = 2.0 * 2.0 * t_chunk as f64 * d * d;
        let attn = 2.0 * tq * kv * d /* QK^T */ + 2.0 * tq * kv * d /* PV */;
        let proj = 2.0 * tq * d * d;
        let mlp = 2.0 * tq * d * f * 2.0;
        q + kvproj + attn + proj + mlp
    }

    /// Bits of one full-precision token embedding (the paper's r*D).
    pub fn token_bits(&self) -> usize {
        self.d_model * self.elem_bytes * 8
    }

    /// Paper "Total Bits per Token" for full-precision baselines:
    /// r * D * L (one exchange per block).
    pub fn total_bits_per_token(&self) -> usize {
        self.token_bits() * self.n_layers
    }
}

/// ASTRA compression settings (paper: G groups, K codebook entries).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct VqSetting {
    pub groups: usize,
    pub codebook_size: usize,
}

impl VqSetting {
    pub fn new(groups: usize, codebook_size: usize) -> Self {
        VqSetting { groups, codebook_size }
    }

    /// Bits on the wire per transmitted token per block: G * ceil(log2 K).
    pub fn bits_per_token(&self) -> usize {
        self.groups * ceil_log2(self.codebook_size)
    }

    /// Paper "Total Bits per Token": per-block bits times layers.
    pub fn total_bits_per_token(&self, layers: usize) -> usize {
        self.bits_per_token() * layers
    }

    /// Paper "Compression Ratio" vs a full-precision token: rD / (G log2 K).
    pub fn compression_ratio(&self, shape: &TransformerShape) -> f64 {
        shape.token_bits() as f64 / self.bits_per_token() as f64
    }
}

pub fn ceil_log2(k: usize) -> usize {
    assert!(k >= 2);
    (usize::BITS - (k - 1).leading_zeros()) as usize
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_bits_per_token_table1() {
        // ViT-Base, K=1024: G=1 -> 10 bits/block, 120 total over 12 layers.
        let s = TransformerShape::vit_base(1024);
        let g1 = VqSetting::new(1, 1024);
        assert_eq!(g1.bits_per_token(), 10);
        assert_eq!(g1.total_bits_per_token(s.n_layers), 120);
        assert_eq!(VqSetting::new(16, 1024).total_bits_per_token(12), 1920);
        assert_eq!(VqSetting::new(32, 1024).total_bits_per_token(12), 3840);
        // original model: 294912 total bits/token
        assert_eq!(s.total_bits_per_token(), 294_912);
    }

    #[test]
    fn paper_compression_ratios() {
        let s = TransformerShape::vit_base(1024);
        assert!((VqSetting::new(1, 1024).compression_ratio(&s) - 2457.6).abs() < 0.1);
        assert!((VqSetting::new(16, 1024).compression_ratio(&s) - 153.6).abs() < 0.1);
        assert!((VqSetting::new(32, 1024).compression_ratio(&s) - 76.8).abs() < 0.1);
    }

    #[test]
    fn gpt2_medium_table3() {
        let s = TransformerShape::gpt2_medium(1024);
        assert_eq!(s.total_bits_per_token(), 786_432);
        assert_eq!(VqSetting::new(1, 1024).total_bits_per_token(24), 240);
        assert!((VqSetting::new(1, 1024).compression_ratio(&s) - 3276.8).abs() < 0.1);
        assert!((VqSetting::new(32, 1024).compression_ratio(&s) - 102.4).abs() < 0.1);
    }

    #[test]
    fn llama_table6() {
        let s = TransformerShape::llama3_8b(1024);
        // 8-bit: 8 * 4096 * 32 layers = 1,048,576 total bits/token
        assert_eq!(s.total_bits_per_token(), 1_048_576);
        assert_eq!(VqSetting::new(1, 1024).total_bits_per_token(32), 320);
        // paper reports 640 bits for G=1 on llama — it uses C=2 codebooks
        // (K and V separately); our accounting exposes that via 2 tokens'
        // worth of codes when quantizing K and V independently:
        assert_eq!(2 * VqSetting::new(1, 1024).total_bits_per_token(32), 640);
        assert!((VqSetting::new(1, 1024).compression_ratio(&s) - 3276.8).abs() < 0.1);
    }

    #[test]
    fn ceil_log2_values() {
        assert_eq!(ceil_log2(2), 1);
        assert_eq!(ceil_log2(256), 8);
        assert_eq!(ceil_log2(1000), 10);
        assert_eq!(ceil_log2(1024), 10);
        assert_eq!(ceil_log2(2048), 11);
    }

    #[test]
    fn flops_monotonic() {
        let s = TransformerShape::paper_encoder(1024);
        assert!(s.block_flops(256, 1024) < s.block_flops(1024, 1024));
        assert!(s.block_flops(1024, 256) < s.block_flops(1024, 1024));
        assert!(s.total_flops() > 0.0);
    }

    #[test]
    fn chunked_prefill_never_exceeds_from_scratch_flops() {
        // chunks tiling the prompt re-project K/V once per token (cached
        // thereafter) and attend triangularly, so their FLOP total stays at
        // or below the monolithic prefill that block_flops prices
        let s = TransformerShape::paper_encoder(1024);
        let n = 4;
        for chunk in [128usize, 256, 512, 1024] {
            let mut total = 0.0;
            let mut done = 0;
            while done < 1024 {
                let c = chunk.min(1024 - done);
                done += c;
                total += s.chunk_block_flops(c / n, c, done);
            }
            let whole = s.block_flops(1024 / n, 1024);
            assert!(total <= whole + 1.0, "chunk={chunk}: {total} vs {whole}");
            // and a single whole-prompt chunk is strictly cheaper than the
            // from-scratch pass only via attention context, not projections
            if chunk == 1024 {
                assert!(total > 0.9 * whole, "{total} vs {whole}");
            }
        }
        // chunk flops grow with the attention context the chunk pays
        assert!(s.chunk_block_flops(64, 256, 1024) > s.chunk_block_flops(64, 256, 256));
    }

    #[test]
    fn decode_step_is_tiny_vs_prefill() {
        let s = TransformerShape::paper_encoder(1024);
        // one cached decode step is orders of magnitude below a prefill
        assert!(s.decode_step_flops(1024) < s.total_flops() / 100.0);
        // and grows with context
        assert!(s.decode_step_flops(2048) > s.decode_step_flops(64));
        // ViT-Base block weights: 12 * (4*768^2 + 2*768*3072) * 4 bytes
        let want = 12.0 * (4.0 * 768.0 * 768.0 + 2.0 * 768.0 * 3072.0) * 4.0;
        assert!((s.weight_bytes() - want).abs() < 1.0);
    }
}
