//! Model shape math, FLOP/byte accounting, memory models, and the pure-rust
//! reference transformer.

pub mod memory;
pub mod native;
pub mod shape;

pub use memory::{
    codebook_bytes, kv_cache_bytes_astra, kv_cache_bytes_astra_live,
    kv_cache_bytes_astra_positional, kv_cache_bytes_full, kv_token_bytes_full,
};
pub use shape::TransformerShape;
